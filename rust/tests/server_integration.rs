//! Serving-layer integration: a quantized model behind the JSON-lines
//! protocol, exercised in memory (no sockets needed).

use kbitscale::data::corpus::{Corpus, CorpusConfig};
use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::runtime::Runtime;
use kbitscale::server::{serve_lines, Session};
use kbitscale::util::json::Json;

fn session<'a>(rt: &'a Runtime, manifest: &'a Manifest) -> Session<'a> {
    let tier = manifest.tier("t0").unwrap();
    // Init-only params are fine: the protocol is exercised, not accuracy.
    let params = init_params(tier, Family::get("gpt2like").unwrap());
    let corpus = Corpus::new(CorpusConfig {
        vocab: manifest.vocab,
        seq: manifest.seq,
        ..CorpusConfig::default()
    });
    Session::new(
        rt,
        manifest,
        tier,
        &params,
        QuantSpec::new(DataType::Fp, 4, Some(64)),
        corpus,
        "gpt2like_t0".into(),
    )
    .unwrap()
}

#[test]
fn protocol_roundtrip() {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))
        .expect("run `make artifacts` first");
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    // info
    let info = s.handle(&Json::parse(r#"{"op":"info"}"#).unwrap());
    assert_eq!(info.get("tier").unwrap().as_str().unwrap(), "t0");
    assert_eq!(info.get("quant").unwrap().as_str().unwrap(), "fp:4:b64");
    assert!((info.get("bits_per_param").unwrap().as_f64().unwrap() - 4.25).abs() < 1e-9);

    // score
    let score = s.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,200,3]}"#).unwrap());
    let ce = score.get("ce").unwrap().as_f64().unwrap();
    assert!(ce.is_finite() && ce > 0.0, "{score:?}");
    assert_eq!(score.get("tokens_scored").unwrap().as_f64().unwrap(), 5.0);

    // choose: identical choices tie -> still a valid index; distinct ones work
    let choose = s.handle(
        &Json::parse(r#"{"op":"choose","context":[1,5,9],"choices":[[7],[300,301]]}"#).unwrap(),
    );
    let best = choose.get("best").unwrap().as_usize().unwrap();
    assert!(best < 2);
    assert_eq!(choose.get("scores").unwrap().as_arr().unwrap().len(), 2);

    // errors are structured, not panics
    let err = s.handle(&Json::parse(r#"{"op":"nope"}"#).unwrap());
    assert!(err.get("error").unwrap().as_str().unwrap().contains("unknown op"));
    let err2 = s.handle(&Json::parse(r#"{"op":"score","tokens":[]}"#).unwrap());
    assert!(err2.opt("error").is_some());
}

#[test]
fn serve_lines_transport() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    let input = b"{\"op\":\"info\"}\nnot json\n{\"op\":\"score\",\"tokens\":[1,2,3]}\n";
    let mut out = Vec::new();
    let served = serve_lines(&mut s, &input[..], &mut out).unwrap();
    assert_eq!(served, 3);
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 3);
    assert!(Json::parse(lines[0]).unwrap().opt("model").is_some());
    assert!(Json::parse(lines[1]).unwrap().opt("error").is_some());
    assert!(Json::parse(lines[2]).unwrap().opt("ce").is_some());
}
