//! Serving-layer integration: quantized models behind the JSON-lines
//! protocol — the single-model [`Session`] API in memory (no sockets),
//! and the packed-model registry + concurrent batched TCP stack.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Barrier;
use std::time::Duration;

use kbitscale::data::corpus::{Corpus, CorpusConfig};
use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::runtime::Runtime;
use kbitscale::server::{
    serve_lines, serve_listener, Connection, ModelRegistry, ParamLoader, ServeOpts, Session,
};
use kbitscale::util::json::Json;

fn session<'a>(rt: &'a Runtime, manifest: &'a Manifest) -> Session<'a> {
    let tier = manifest.tier("t0").unwrap();
    // Init-only params are fine: the protocol is exercised, not accuracy.
    let params = init_params(tier, Family::get("gpt2like").unwrap());
    let corpus = Corpus::new(CorpusConfig {
        vocab: manifest.vocab,
        seq: manifest.seq,
        ..CorpusConfig::default()
    });
    Session::new(
        rt,
        manifest,
        tier,
        &params,
        QuantSpec::new(DataType::Fp, 4, Some(64)),
        corpus,
        "gpt2like_t0".into(),
    )
    .unwrap()
}

#[test]
fn protocol_roundtrip() {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))
        .expect("run `make artifacts` first");
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    // info
    let info = s.handle(&Json::parse(r#"{"op":"info"}"#).unwrap());
    assert_eq!(info.get("tier").unwrap().as_str().unwrap(), "t0");
    assert_eq!(info.get("quant").unwrap().as_str().unwrap(), "fp:4:b64");
    assert!((info.get("bits_per_param").unwrap().as_f64().unwrap() - 4.25).abs() < 1e-9);

    // score
    let score = s.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,200,3]}"#).unwrap());
    let ce = score.get("ce").unwrap().as_f64().unwrap();
    assert!(ce.is_finite() && ce > 0.0, "{score:?}");
    assert_eq!(score.get("tokens_scored").unwrap().as_f64().unwrap(), 5.0);

    // choose: identical choices tie -> still a valid index; distinct ones work
    let choose = s.handle(
        &Json::parse(r#"{"op":"choose","context":[1,5,9],"choices":[[7],[300,301]]}"#).unwrap(),
    );
    let best = choose.get("best").unwrap().as_usize().unwrap();
    assert!(best < 2);
    assert_eq!(choose.get("scores").unwrap().as_arr().unwrap().len(), 2);

    // errors are structured, not panics
    let err = s.handle(&Json::parse(r#"{"op":"nope"}"#).unwrap());
    assert!(err.get("error").unwrap().as_str().unwrap().contains("unknown op"));
    let err2 = s.handle(&Json::parse(r#"{"op":"score","tokens":[]}"#).unwrap());
    assert!(err2.opt("error").is_some());
}

#[test]
fn serve_lines_transport() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    let input = b"{\"op\":\"info\"}\nnot json\n{\"op\":\"score\",\"tokens\":[1,2,3]}\n";
    let mut out = Vec::new();
    let served = serve_lines(&mut s, &input[..], &mut out).unwrap();
    assert_eq!(served, 3);
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 3);
    assert!(Json::parse(lines[0]).unwrap().opt("model").is_some());
    assert!(Json::parse(lines[1]).unwrap().opt("error").is_some());
    assert!(Json::parse(lines[2]).unwrap().opt("ce").is_some());
}

// ---------------------------------------------------------------------------
// Registry / concurrency / residency
// ---------------------------------------------------------------------------

fn registry<'a>(rt: &'a Runtime, manifest: &'a Manifest) -> ModelRegistry<'a> {
    let mref = manifest.clone();
    let loader: ParamLoader<'static> = Box::new(move |family: &str, tier: &str| {
        Ok(init_params(mref.tier(tier)?, Family::get(family)?))
    });
    ModelRegistry::new(rt, manifest, loader)
}

#[test]
fn registry_serves_concurrent_clients_from_multiple_models() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let k1 = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap().key();
    let k2 = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Int, 3, Some(32))).unwrap().key();
    assert_eq!(reg.len(), 2);
    assert_ne!(k1, k2);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 2,
        flush: Duration::from_millis(3),
        batching: true,
        max_conns: Some(2),
    };
    let barrier_owned = Barrier::new(2);
    let barrier = &barrier_owned;
    std::thread::scope(|s| {
        let server = s.spawn(|| serve_listener(&reg, listener, &opts));
        let mut joins = Vec::new();
        for key in [k1.clone(), k2.clone()] {
            joins.push(s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                // Both clients hold open connections before either sends:
                // the old sequential accept loop would deadlock here.
                barrier.wait();
                for i in 0..5 {
                    writeln!(
                        writer,
                        "{{\"op\":\"score\",\"model\":\"{key}\",\"tokens\":[1,5,{},12,3]}}",
                        9 + i
                    )
                    .unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let resp = Json::parse(line.trim()).unwrap();
                    assert!(resp.opt("ce").is_some(), "client for {key}: {resp:?}");
                }
                writeln!(writer, "{{\"op\":\"info\",\"model\":\"{key}\"}}").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let info = Json::parse(line.trim()).unwrap();
                assert_eq!(info.get("models").unwrap().as_usize().unwrap(), 2);
                assert!(info.get("batched").unwrap().as_bool().unwrap());
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        server.join().unwrap().unwrap();
    });
}

#[test]
fn packed_residency_matches_bitcost_accounting() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let (bits, block) = (4usize, 64usize);
    let h = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, bits, Some(block))).unwrap();
    let tier = manifest.tier("t0").unwrap();

    // The handle keeps a packed entry for every quantized tensor and
    // nothing else (no f32 weight copies — enforced by construction).
    assert_eq!(h.packed.len(), tier.quantized_params.len());
    let nq: usize = tier
        .param_sizes()
        .iter()
        .filter(|(n, _)| tier.quantized_params.contains(n))
        .map(|(_, s)| *s)
        .sum();
    let resident_bits = (h.resident_bytes() * 8) as f64;
    // Lower bound: the k-bit payload itself. Upper bound: the paper's
    // analytic accounting (k + 16/block bits/param) plus the slack of
    // storing block constants as f32 instead of 16-bit, plus one u32 of
    // word padding per packed slice.
    let ideal = nq as f64 * (bits as f64 + 16.0 / block as f64);
    let slices: usize = h.packed.iter().map(|(_, p)| p.slices.len()).sum();
    let slack = nq as f64 * (16.0 / block as f64) + (slices * 32) as f64;
    assert!(resident_bits >= (nq * bits) as f64, "{resident_bits} < k-bit payload");
    assert!(
        resident_bits <= ideal + slack,
        "resident {resident_bits} bits exceeds ideal {ideal} + slack {slack}"
    );
    // Packed residency beats a dequantized f32 copy by ~32/(k+overhead).
    assert!(h.resident_bytes() * 6 < h.quantized_f32_bytes());

    // The info op reports the same numbers.
    let mut conn = Connection::new(&reg, None);
    let req = format!("{{\"op\":\"info\",\"model\":\"{}\"}}", h.key());
    let info = conn.handle(&Json::parse(&req).unwrap());
    assert_eq!(
        info.get("resident_bytes").unwrap().as_usize().unwrap(),
        h.resident_bytes()
    );
    assert!((info.get("total_bits").unwrap().as_f64().unwrap() - h.ideal_total_bits()).abs() < 1e-6);
}

#[test]
fn load_op_makes_variants_resident_and_routes() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);

    // Nothing resident yet: scoring errors, loading succeeds.
    let err = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,2,3]}"#).unwrap());
    assert!(err.opt("error").is_some());
    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","bits":3,"dtype":"int","block":32}"#)
            .unwrap(),
    );
    let key = loaded.get("model").unwrap().as_str().unwrap().to_string();
    assert!(key.ends_with("int:3:b32"), "{key}");
    assert_eq!(loaded.get("models").unwrap().as_usize().unwrap(), 1);

    // The connection now routes to the loaded variant implicitly.
    let score = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,2,3,4]}"#).unwrap());
    assert!(score.opt("ce").is_some(), "{score:?}");
    let models = conn.handle(&Json::parse(r#"{"op":"models"}"#).unwrap());
    assert_eq!(models.get("models").unwrap().as_arr().unwrap().len(), 1);

    // Loading the same variant again is idempotent.
    let again = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","bits":3,"dtype":"int","block":32}"#)
            .unwrap(),
    );
    assert_eq!(again.get("models").unwrap().as_usize().unwrap(), 1);
}
