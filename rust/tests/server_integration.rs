//! Serving-layer integration: quantized models behind the JSON-lines
//! protocol — the single-model [`Session`] API in memory (no sockets),
//! the packed-model registry + concurrent batched TCP stack, and the
//! governance layer: LRU/TTL eviction under a byte budget, `unload`,
//! single-flight loading, the score cache, the serving-path regression
//! fixes (vocab-bounded tokens, capped request lines), the fused native
//! scoring backend, and negotiated `bin1` binary-frame parity.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use kbitscale::data::corpus::{Corpus, CorpusConfig};
use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::runtime::Runtime;
use kbitscale::server::{
    frames, serve_lines, serve_listener, Connection, ModelRegistry, ParamLoader, ServeOpts,
    Session,
};
use kbitscale::util::json::Json;

fn session<'a>(rt: &'a Runtime, manifest: &'a Manifest) -> Session<'a> {
    let tier = manifest.tier("t0").unwrap();
    // Init-only params are fine: the protocol is exercised, not accuracy.
    let params = init_params(tier, Family::get("gpt2like").unwrap());
    let corpus = Corpus::new(CorpusConfig {
        vocab: manifest.vocab,
        seq: manifest.seq,
        ..CorpusConfig::default()
    });
    Session::new(
        rt,
        manifest,
        tier,
        &params,
        QuantSpec::new(DataType::Fp, 4, Some(64)),
        corpus,
        "gpt2like_t0".into(),
    )
    .unwrap()
}

#[test]
fn protocol_roundtrip() {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))
        .expect("run `make artifacts` first");
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    // info
    let info = s.handle(&Json::parse(r#"{"op":"info"}"#).unwrap());
    assert_eq!(info.get("tier").unwrap().as_str().unwrap(), "t0");
    assert_eq!(info.get("quant").unwrap().as_str().unwrap(), "fp:4:b64");
    assert!((info.get("bits_per_param").unwrap().as_f64().unwrap() - 4.25).abs() < 1e-9);

    // score
    let score = s.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,200,3]}"#).unwrap());
    let ce = score.get("ce").unwrap().as_f64().unwrap();
    assert!(ce.is_finite() && ce > 0.0, "{score:?}");
    assert_eq!(score.get("tokens_scored").unwrap().as_f64().unwrap(), 5.0);

    // choose: identical choices tie -> still a valid index; distinct ones work
    let choose = s.handle(
        &Json::parse(r#"{"op":"choose","context":[1,5,9],"choices":[[7],[300,301]]}"#).unwrap(),
    );
    let best = choose.get("best").unwrap().as_usize().unwrap();
    assert!(best < 2);
    assert_eq!(choose.get("scores").unwrap().as_arr().unwrap().len(), 2);

    // ping: the fleet router's health probe — cheap, structured, and ok.
    let pong = s.handle(&Json::parse(r#"{"op":"ping"}"#).unwrap());
    assert!(pong.get("ok").unwrap().as_bool().unwrap(), "{pong:?}");
    assert_eq!(pong.get("models").unwrap().as_usize().unwrap(), 1);
    assert!(pong.opt("resident_bytes_total").is_some());

    // errors are structured, not panics
    let err = s.handle(&Json::parse(r#"{"op":"nope"}"#).unwrap());
    assert!(err.get("error").unwrap().as_str().unwrap().contains("unknown op"));
    let err2 = s.handle(&Json::parse(r#"{"op":"score","tokens":[]}"#).unwrap());
    assert!(err2.opt("error").is_some());
}

#[test]
fn serve_lines_transport() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    let input = b"{\"op\":\"info\"}\nnot json\n{\"op\":\"score\",\"tokens\":[1,2,3]}\n";
    let mut out = Vec::new();
    let served = serve_lines(&mut s, &input[..], &mut out).unwrap();
    assert_eq!(served, 3);
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 3);
    assert!(Json::parse(lines[0]).unwrap().opt("model").is_some());
    assert!(Json::parse(lines[1]).unwrap().opt("error").is_some());
    assert!(Json::parse(lines[2]).unwrap().opt("ce").is_some());
}

// ---------------------------------------------------------------------------
// Streaming responses
// ---------------------------------------------------------------------------

#[test]
fn streamed_score_chunks_in_row_order() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    // 5 rows, chunk size 2 -> chunk lines 0/1/2 (2+2+1 rows) + done line.
    let input =
        b"{\"op\":\"score\",\"rows\":[[1,2,3],[4,5,6],[7,8],[9,10],[11]],\"stream\":true,\"chunk\":2}\n";
    let mut out = Vec::new();
    let served = serve_lines(&mut s, &input[..], &mut out).unwrap();
    assert_eq!(served, 1, "one streamed request, many lines");
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 4, "3 chunks + terminal summary: {lines:?}");
    for (i, l) in lines[..3].iter().enumerate() {
        let j = Json::parse(l).unwrap();
        assert_eq!(j.get("chunk").unwrap().as_usize().unwrap(), i, "chunk order");
        assert_eq!(j.get("first_row").unwrap().as_usize().unwrap(), i * 2, "row order");
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), if i < 2 { 2 } else { 1 });
        for r in rows {
            assert!(r.get("ce").unwrap().as_f64().unwrap() > 0.0, "{r:?}");
        }
    }
    let done = Json::parse(lines[3]).unwrap();
    assert!(done.get("done").unwrap().as_bool().unwrap());
    assert!(done.opt("error").is_none(), "{done:?}");
    assert_eq!(done.get("rows_scored").unwrap().as_usize().unwrap(), 5);
    assert_eq!(done.get("chunks").unwrap().as_usize().unwrap(), 3);
    assert!(done.get("ce").unwrap().as_f64().unwrap() > 0.0);

    // A streamed row scores exactly like the same row sent unstreamed.
    let single = s.handle(&Json::parse(r#"{"op":"score","tokens":[1,2,3]}"#).unwrap());
    let chunk0 = Json::parse(lines[0]).unwrap();
    let row0 = &chunk0.get("rows").unwrap().as_arr().unwrap()[0];
    assert_eq!(single.dump(), row0.dump(), "streamed row must equal unstreamed score");
}

#[test]
fn streamed_error_mid_stream_keeps_connection() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);
    let vocab = manifest.tier("t0").unwrap().vocab;

    // Third row is out of vocab: two chunks stream out, then the stream
    // terminates with an error line — and the connection keeps serving.
    let input = format!(
        "{{\"op\":\"score\",\"rows\":[[1,2],[3,4],[{vocab}]],\"stream\":true,\"chunk\":1}}\n\
         {{\"op\":\"info\"}}\n"
    );
    let mut out = Vec::new();
    let served = serve_lines(&mut s, input.as_bytes(), &mut out).unwrap();
    assert_eq!(served, 2);
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 4, "2 chunks + error line + info response: {lines:?}");
    assert_eq!(Json::parse(lines[0]).unwrap().get("chunk").unwrap().as_usize().unwrap(), 0);
    assert_eq!(Json::parse(lines[1]).unwrap().get("chunk").unwrap().as_usize().unwrap(), 1);
    let err = Json::parse(lines[2]).unwrap();
    assert!(err.get("done").unwrap().as_bool().unwrap(), "{err:?}");
    assert!(err.get("error").unwrap().as_str().unwrap().contains("out of range"));
    assert_eq!(err.get("chunks").unwrap().as_usize().unwrap(), 2);
    let info = Json::parse(lines[3]).unwrap();
    assert!(info.opt("model").is_some(), "connection must survive a mid-stream error");
}

#[test]
fn buffered_multi_row_score_responds_once() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);
    let resp =
        s.handle(&Json::parse(r#"{"op":"score","rows":[[1,2,3],[4,5,6]]}"#).unwrap());
    let rows = resp.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(resp.get("rows_scored").unwrap().as_usize().unwrap(), 2);
    assert!(resp.get("ce").unwrap().as_f64().unwrap() > 0.0);
    // Both row sources at once is ambiguous and rejected.
    let err = s.handle(
        &Json::parse(r#"{"op":"score","tokens":[1],"rows":[[2]]}"#).unwrap(),
    );
    assert!(err.opt("error").is_some());
    // Streaming without a line transport is an error, not a hang.
    let err = s.handle(
        &Json::parse(r#"{"op":"score","rows":[[1,2]],"stream":true}"#).unwrap(),
    );
    assert!(err.get("error").unwrap().as_str().unwrap().contains("transport"), "{err:?}");
}

// ---------------------------------------------------------------------------
// Registry / concurrency / residency
// ---------------------------------------------------------------------------

fn registry<'a>(rt: &'a Runtime, manifest: &'a Manifest) -> ModelRegistry<'a> {
    let mref = manifest.clone();
    let loader: ParamLoader<'static> = Box::new(move |family: &str, tier: &str| {
        Ok(init_params(mref.tier(tier)?, Family::get(family)?))
    });
    ModelRegistry::new(rt, manifest, loader)
}

#[test]
fn registry_serves_concurrent_clients_from_multiple_models() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let k1 = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap().key();
    let k2 = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Int, 3, Some(32))).unwrap().key();
    assert_eq!(reg.len(), 2);
    assert_ne!(k1, k2);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 2,
        flush: Duration::from_millis(3),
        batching: true,
        max_conns: Some(2),
        ..ServeOpts::default()
    };
    let barrier_owned = Barrier::new(2);
    let barrier = &barrier_owned;
    std::thread::scope(|s| {
        let server = s.spawn(|| serve_listener(&reg, listener, &opts));
        let mut joins = Vec::new();
        for key in [k1.clone(), k2.clone()] {
            joins.push(s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                // Both clients hold open connections before either sends:
                // the old sequential accept loop would deadlock here.
                barrier.wait();
                for i in 0..5 {
                    writeln!(
                        writer,
                        "{{\"op\":\"score\",\"model\":\"{key}\",\"tokens\":[1,5,{},12,3]}}",
                        9 + i
                    )
                    .unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let resp = Json::parse(line.trim()).unwrap();
                    assert!(resp.opt("ce").is_some(), "client for {key}: {resp:?}");
                }
                writeln!(writer, "{{\"op\":\"info\",\"model\":\"{key}\"}}").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let info = Json::parse(line.trim()).unwrap();
                assert_eq!(info.get("models").unwrap().as_usize().unwrap(), 2);
                assert!(info.get("batched").unwrap().as_bool().unwrap());
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        server.join().unwrap().unwrap();
    });
}

#[test]
fn packed_residency_matches_bitcost_accounting() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let (bits, block) = (4usize, 64usize);
    let h = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, bits, Some(block))).unwrap();
    let tier = manifest.tier("t0").unwrap();

    // The handle keeps a packed entry for every quantized tensor and
    // nothing else (no f32 weight copies — enforced by construction).
    assert_eq!(h.packed.len(), tier.quantized_params.len());
    let nq: usize = tier
        .param_sizes()
        .iter()
        .filter(|(n, _)| tier.quantized_params.contains(n))
        .map(|(_, s)| *s)
        .sum();
    let resident_bits = (h.resident_bytes() * 8) as f64;
    // Lower bound: the k-bit payload itself. Upper bound: the paper's
    // analytic accounting (k + 16/block bits/param) plus the slack of
    // storing block constants as f32 instead of 16-bit, plus one u32 of
    // word padding per packed slice.
    let ideal = nq as f64 * (bits as f64 + 16.0 / block as f64);
    let slices: usize = h.packed.iter().map(|(_, p)| p.slices.len()).sum();
    let slack = nq as f64 * (16.0 / block as f64) + (slices * 32) as f64;
    assert!(resident_bits >= (nq * bits) as f64, "{resident_bits} < k-bit payload");
    assert!(
        resident_bits <= ideal + slack,
        "resident {resident_bits} bits exceeds ideal {ideal} + slack {slack}"
    );
    // Packed residency beats a dequantized f32 copy by ~32/(k+overhead).
    assert!(h.resident_bytes() * 6 < h.quantized_f32_bytes());

    // The info op reports the same numbers.
    let mut conn = Connection::new(&reg, None);
    let req = format!("{{\"op\":\"info\",\"model\":\"{}\"}}", h.key());
    let info = conn.handle(&Json::parse(&req).unwrap());
    assert_eq!(
        info.get("resident_bytes").unwrap().as_usize().unwrap(),
        h.resident_bytes()
    );
    assert!((info.get("total_bits").unwrap().as_f64().unwrap() - h.ideal_total_bits()).abs() < 1e-6);
}

#[test]
fn load_op_makes_variants_resident_and_routes() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);

    // Nothing resident yet: scoring errors, loading succeeds.
    let err = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,2,3]}"#).unwrap());
    assert!(err.opt("error").is_some());
    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","bits":3,"dtype":"int","block":32}"#)
            .unwrap(),
    );
    let key = loaded.get("model").unwrap().as_str().unwrap().to_string();
    assert!(key.ends_with("int:3:b32"), "{key}");
    assert_eq!(loaded.get("models").unwrap().as_usize().unwrap(), 1);

    // The connection now routes to the loaded variant implicitly.
    let score = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,2,3,4]}"#).unwrap());
    assert!(score.opt("ce").is_some(), "{score:?}");
    let models = conn.handle(&Json::parse(r#"{"op":"models"}"#).unwrap());
    assert_eq!(models.get("models").unwrap().as_arr().unwrap().len(), 1);

    // Loading the same variant again is idempotent.
    let again = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","bits":3,"dtype":"int","block":32}"#)
            .unwrap(),
    );
    assert_eq!(again.get("models").unwrap().as_usize().unwrap(), 1);
}

// ---------------------------------------------------------------------------
// Memory governance: eviction, TTL, unload, single-flight
// ---------------------------------------------------------------------------

#[test]
fn eviction_under_budget_keeps_pinned_handles_alive() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    // A 1-byte budget: every insert is over budget, so each new variant
    // evicts all unprotected residents while itself staying (the
    // just-used variant is never evicted by its own enforcement pass).
    let reg = registry(&rt, &manifest).with_memory_budget(Some(1));
    let a = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();
    let a_key = a.key();
    assert_eq!(reg.len(), 1, "a single over-budget variant must still serve");
    let b = reg.load("gpt2like", "t0", QuantSpec::new(DataType::Int, 3, Some(32))).unwrap();
    assert_eq!(reg.len(), 1, "loading past the budget must evict the LRU variant");
    assert!(reg.evictions() >= 1);
    assert!(reg.get(Some(a_key.as_str())).is_err(), "evicted variant must not resolve");

    // The evicted variant is pinned by our Arc: in-flight scoring still
    // works until the last reference drops.
    let tier = manifest.tier("t0").unwrap();
    let (row, mask) = kbitscale::data::corpus::pad_score_row(&[1, 5, 9], tier.seq);
    let scored = a.score_rows(&[(row, mask)]).unwrap();
    assert!(scored[0].0.is_finite(), "pinned evicted handle must still score");

    // stats reports the survivor (pinned: we hold `b`) and the eviction.
    let mut conn = Connection::new(&reg, None);
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    let models = stats.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("key").unwrap().as_str().unwrap(), b.key());
    assert!(models[0].get("pinned").unwrap().as_bool().unwrap());
    assert!(stats.get("evictions").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(stats.get("budget_bytes").unwrap().as_usize().unwrap(), 1);

    // The default key was repaired onto a survivor: implicit routing works.
    let score = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,2,3]}"#).unwrap());
    assert!(score.opt("ce").is_some(), "{score:?}");
}

#[test]
fn ttl_evicts_idle_variants() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest).with_ttl(Some(Duration::from_millis(5)));
    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();
    assert_eq!(reg.len(), 1);
    std::thread::sleep(Duration::from_millis(30));
    // stats runs the TTL sweep (no background thread).
    assert!(reg.stats().is_empty(), "idle variant must be TTL-evicted");
    assert_eq!(reg.len(), 0);
    assert!(reg.evictions() >= 1);
}

#[test]
fn single_flight_load_builds_exactly_once() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let builds = Arc::new(AtomicUsize::new(0));
    let counter = builds.clone();
    let mref = manifest.clone();
    let loader: ParamLoader<'static> = Box::new(move |family: &str, tier: &str| {
        counter.fetch_add(1, Ordering::SeqCst);
        // Widen the race window: without single-flight every racer lands
        // in here and pays a full quantize+compile.
        std::thread::sleep(Duration::from_millis(30));
        Ok(init_params(mref.tier(tier)?, Family::get(family)?))
    });
    let reg = ModelRegistry::new(&rt, &manifest, loader);
    let handles: Vec<_> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..6)
            .map(|_| {
                s.spawn(|| {
                    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap()
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    assert_eq!(builds.load(Ordering::SeqCst), 1, "racing loads must build once");
    assert_eq!(reg.len(), 1);
    for h in &handles[1..] {
        assert!(Arc::ptr_eq(&handles[0], h), "all racers share the winner's handle");
    }
}

#[test]
fn unload_op_drops_variant() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);
    let loaded = conn
        .handle(&Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0"}"#).unwrap());
    let key = loaded.get("model").unwrap().as_str().unwrap().to_string();

    let err = conn.handle(&Json::parse(r#"{"op":"unload","model":"nope_t9"}"#).unwrap());
    assert!(err.get("error").unwrap().as_str().unwrap().contains("not resident"));

    let req = format!("{{\"op\":\"unload\",\"model\":\"{key}\"}}");
    let gone = conn.handle(&Json::parse(&req).unwrap());
    assert_eq!(gone.get("unloaded").unwrap().as_str().unwrap(), key);
    assert_eq!(gone.get("models").unwrap().as_usize().unwrap(), 0);
    assert_eq!(reg.len(), 0);

    // Nothing resident: scoring is a structured error again.
    let err = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,2]}"#).unwrap());
    assert!(err.opt("error").is_some());
}

// ---------------------------------------------------------------------------
// Score cache
// ---------------------------------------------------------------------------

#[test]
fn repeated_rows_hit_the_cache_with_identical_scores() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest).with_score_cache(256);
    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();
    let mut conn = Connection::new(&reg, None);

    let req = Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap();
    let first = conn.handle(&req);
    assert!(first.opt("ce").is_some(), "{first:?}");
    let info = conn.handle(&Json::parse(r#"{"op":"info"}"#).unwrap());
    assert!(info.get("cached").unwrap().as_bool().unwrap());
    assert_eq!(info.get("cache_hits").unwrap().as_usize().unwrap(), 0);
    assert!(info.get("cache_misses").unwrap().as_usize().unwrap() >= 1);
    assert!(info.get("cache_rows").unwrap().as_usize().unwrap() >= 1);

    // The repeat is a hit and returns byte-identical scores.
    let second = conn.handle(&req);
    assert_eq!(first.dump(), second.dump());
    let info = conn.handle(&Json::parse(r#"{"op":"info"}"#).unwrap());
    assert!(info.get("cache_hits").unwrap().as_usize().unwrap() >= 1);

    // A different row is a fresh miss, not a false hit.
    let other = conn.handle(&Json::parse(r#"{"op":"score","tokens":[2,6,10,13,4]}"#).unwrap());
    assert!(other.opt("ce").is_some());
    assert_ne!(first.dump(), other.dump());
}

#[test]
fn batched_serving_publishes_and_hits_the_cache() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest).with_score_cache(256);
    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 2,
        flush: Duration::from_millis(1),
        batching: true,
        max_conns: Some(1),
        ..ServeOpts::default()
    };
    std::thread::scope(|s| {
        let server = s.spawn(|| serve_listener(&reg, listener, &opts));
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let mut responses = Vec::new();
        for _ in 0..6 {
            writeln!(writer, "{{\"op\":\"score\",\"tokens\":[1,5,9,12,3]}}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            responses.push(line.trim().to_string());
        }
        for r in &responses[1..] {
            assert_eq!(&responses[0], r, "cached repeats must score identically");
        }
        writeln!(writer, "{{\"op\":\"info\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let info = Json::parse(line.trim()).unwrap();
        assert!(
            info.get("cache_hits").unwrap().as_usize().unwrap() >= 4,
            "batched path must serve repeats from the cache: {info:?}"
        );
        drop(writer);
        drop(reader);
        server.join().unwrap().unwrap();
    });
}

#[test]
fn tcp_streamed_request_returns_chunks_before_summary() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest).with_score_cache(256);
    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 2,
        flush: Duration::from_millis(1),
        batching: true,
        max_conns: Some(1),
        ..ServeOpts::default()
    };
    std::thread::scope(|s| {
        let server = s.spawn(|| serve_listener(&reg, listener, &opts));
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(
            writer,
            "{{\"op\":\"score\",\"rows\":[[1,2,3],[4,5],[6,7,8],[9]],\"stream\":true,\"chunk\":2}}"
        )
        .unwrap();
        // Partial chunks arrive as their own lines before the summary.
        let mut chunks = 0usize;
        let done = loop {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "server hung up mid-stream");
            let j = Json::parse(line.trim()).unwrap();
            if j.opt("done").is_some() {
                break j;
            }
            assert_eq!(j.get("chunk").unwrap().as_usize().unwrap(), chunks);
            chunks += 1;
        };
        assert_eq!(chunks, 2, "two partial chunks must precede the summary");
        assert!(done.opt("error").is_none(), "{done:?}");
        assert_eq!(done.get("rows_scored").unwrap().as_usize().unwrap(), 4);
        // Same connection serves ordinary requests afterwards.
        writeln!(writer, "{{\"op\":\"score\",\"tokens\":[1,2,3]}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(line.trim()).unwrap().opt("ce").is_some(), "{line}");
        drop(writer);
        drop(reader);
        server.join().unwrap().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Binary score frames (bin1)
// ---------------------------------------------------------------------------

#[test]
fn bin1_stream_decodes_to_exactly_the_json_stream() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 2,
        flush: Duration::from_millis(1),
        batching: true,
        max_conns: Some(2),
        ..ServeOpts::default()
    };
    let req = r#"{"op":"score","rows":[[1,2,3],[4,5],[6,7,8],[9]],"stream":true,"chunk":2}"#;
    std::thread::scope(|s| {
        let server = s.spawn(|| serve_listener(&reg, listener, &opts));

        // Reference connection: default JSON framing, no handshake.
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(writer, "{req}").unwrap();
        let mut json_stream: Vec<Json> = Vec::new();
        loop {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "server hung up mid-stream");
            let j = Json::parse(line.trim()).unwrap();
            let done = j.opt("done").is_some();
            json_stream.push(j);
            if done {
                break;
            }
        }
        drop(writer);
        drop(reader);

        // bin1 connection: after the hello handshake the same request's
        // chunks arrive as binary frames; the done-line stays JSON.
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(writer, "{{\"op\":\"hello\",\"frames\":\"bin1\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let hello = Json::parse(line.trim()).unwrap();
        assert_eq!(hello.get("frames").unwrap().as_str().unwrap(), "bin1", "{hello:?}");
        writeln!(writer, "{req}").unwrap();
        let mut bin_stream: Vec<Json> = Vec::new();
        let mut frames_seen = 0usize;
        let mut frame: Vec<u8> = Vec::new();
        loop {
            if reader.fill_buf().unwrap().first() == Some(&frames::MAGIC) {
                frames::read_frame(&mut reader, &mut frame).unwrap();
                bin_stream.push(frames::decode_chunk(&frame).unwrap());
                frames_seen += 1;
                continue;
            }
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "server hung up mid-stream");
            let j = Json::parse(line.trim()).unwrap();
            let done = j.opt("done").is_some();
            bin_stream.push(j);
            if done {
                break;
            }
        }
        assert_eq!(frames_seen, 2, "both chunks must arrive as binary frames");
        // Field-identical parity: every decoded frame dumps to the exact
        // text the JSON framing produced (shortest-round-trip f64s travel
        // losslessly in both formats).
        assert_eq!(json_stream.len(), bin_stream.len());
        for (a, b) in json_stream.iter().zip(&bin_stream) {
            assert_eq!(a.dump(), b.dump(), "bin1 stream must decode to the JSON stream");
        }
        drop(writer);
        drop(reader);
        server.join().unwrap().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Pipeline-sharded variants over the protocol
// ---------------------------------------------------------------------------

#[test]
fn pipeline_variant_loads_scores_and_accounts_per_stage() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    if manifest.tier("t0").unwrap().stages.is_empty() {
        eprintln!("skipping: artifacts predate pipeline stages (rerun make artifacts)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);

    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","pipeline":true}"#)
            .unwrap(),
    );
    let key = loaded.get("model").unwrap().as_str().unwrap().to_string();
    assert!(key.ends_with("#pipe"), "{key}");
    assert_eq!(loaded.get("stages").unwrap().as_usize().unwrap(), 2);

    // The sharded variant scores, close to the monolithic build.
    let piped = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap());
    let pipe_ce = piped.get("ce").unwrap().as_f64().unwrap();
    assert!(pipe_ce.is_finite() && pipe_ce > 0.0, "{piped:?}");
    let mono = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    assert_eq!(mono.get("models").unwrap().as_usize().unwrap(), 2, "plan shapes coexist");
    let mono_score =
        conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap());
    let mono_ce = mono_score.get("ce").unwrap().as_f64().unwrap();
    assert!(
        (pipe_ce - mono_ce).abs() / mono_ce.max(1e-9) < 1e-4,
        "sharded ce {pipe_ce} vs monolithic {mono_ce}"
    );

    // stats reports the per-stage residency breakdown, summing to the
    // variant total (same packed payload as the monolithic build).
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    let models = stats.get("models").unwrap().as_arr().unwrap();
    let pipe_stats = models
        .iter()
        .find(|m| m.get("key").unwrap().as_str().unwrap() == key)
        .expect("sharded variant in stats");
    let stages = pipe_stats.get("stages").unwrap().as_arr().unwrap();
    assert_eq!(stages.len(), 2);
    let stage_sum: usize = stages
        .iter()
        .map(|s| s.get("resident_bytes").unwrap().as_usize().unwrap())
        .sum();
    let total = pipe_stats.get("resident_bytes").unwrap().as_usize().unwrap();
    assert_eq!(stage_sum, total, "per-stage bytes must sum to the variant total");
    assert!(stages.iter().all(|s| {
        s.get("resident_bytes").unwrap().as_usize().unwrap() > 0
    }), "every stage owns packed weights: {stages:?}");

    // Mixed precision: stage 0 unquantized, stage 1 packed at 4 bits.
    let mixed = conn.handle(
        &Json::parse(
            r#"{"op":"load","family":"gpt2like","tier":"t0","pipeline":true,"stage_bits":[16,4]}"#,
        )
        .unwrap(),
    );
    let mixed_key = mixed.get("model").unwrap().as_str().unwrap().to_string();
    assert!(mixed_key.ends_with("#pipe[16,4]"), "{mixed_key}");
    let mixed_bytes = mixed.get("resident_bytes").unwrap().as_usize().unwrap();
    assert!(
        mixed_bytes > 0 && mixed_bytes < total,
        "a 16-bit stage packs nothing: {mixed_bytes} vs full {total}"
    );
    let scored = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9]}"#).unwrap());
    assert!(scored.opt("ce").is_some(), "{scored:?}");

    // Bad per-stage widths are an error response, not a worker panic.
    let err = conn.handle(
        &Json::parse(
            r#"{"op":"load","family":"gpt2like","tier":"t0","pipeline":true,"stage_bits":[4]}"#,
        )
        .unwrap(),
    );
    assert!(err.opt("error").is_some(), "{err:?}");

    // stage_bits without pipeline errors even though its key collides
    // with the already-resident monolithic variant — validation must not
    // depend on resident state.
    let err = conn.handle(
        &Json::parse(
            r#"{"op":"load","family":"gpt2like","tier":"t0","stage_bits":[16,4]}"#,
        )
        .unwrap(),
    );
    assert!(
        err.get("error").unwrap().as_str().unwrap().contains("pipeline"),
        "{err:?}"
    );
}

#[test]
fn fused_variant_loads_scores_and_stays_packed() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);

    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","fused":true}"#).unwrap(),
    );
    let key = loaded.get("model").unwrap().as_str().unwrap().to_string();
    assert!(key.ends_with("#fused"), "{key}");

    let fused = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap());
    let fused_ce = fused.get("ce").unwrap().as_f64().unwrap();
    assert!(fused_ce.is_finite() && fused_ce > 0.0, "{fused:?}");

    // The executable build of the same spec scores to a close ce — same
    // packed payload, but XLA's GEMM accumulates f32 in its own order,
    // so close-not-identical is the expected relationship here.
    let mono =
        conn.handle(&Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0"}"#).unwrap());
    assert_eq!(mono.get("models").unwrap().as_usize().unwrap(), 2, "backends coexist");
    let plain = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap());
    let plain_ce = plain.get("ce").unwrap().as_f64().unwrap();
    assert!(
        (fused_ce - plain_ce).abs() / plain_ce.max(1e-9) < 1e-3,
        "fused ce {fused_ce} vs executable ce {plain_ce}"
    );

    // The packed payload is Arc-shared with the executable build: the
    // fused variant reports the same resident footprint (no f32 copies).
    assert_eq!(
        loaded.get("resident_bytes").unwrap().as_usize().unwrap(),
        mono.get("resident_bytes").unwrap().as_usize().unwrap(),
        "fused residency must equal the packed payload"
    );

    // A simulate-only (16-bit baseline) spec has nothing to fuse.
    let err = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","fused":true,"bits":16}"#)
            .unwrap(),
    );
    assert!(err.opt("error").is_some(), "baseline spec must not fuse: {err:?}");
}

#[test]
fn entropy_variant_scores_identically_and_measures_below_the_floor() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);

    let coded = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","entropy":true}"#).unwrap(),
    );
    let key = coded.get("model").unwrap().as_str().unwrap().to_string();
    assert!(key.ends_with("@fp:4:b64#ec"), "{key}");
    let ec_bytes = coded.get("resident_bytes").unwrap().as_usize().unwrap();
    let ec = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap());
    let ec_ce = ec.get("ce").unwrap().as_f64().unwrap();
    assert!(ec_ce.is_finite() && ec_ce > 0.0, "{ec:?}");
    let info = conn.handle(&Json::parse(r#"{"op":"info"}"#).unwrap());
    assert!(info.get("entropy_coded").unwrap().as_bool().unwrap(), "{info:?}");
    let ec_total = info.get("measured_total_bits").unwrap().as_f64().unwrap();

    // The packed twin of the same spec: coding is lossless, so the coded
    // stream decodes to bit-identical f32 literals and the exact same ce
    // — while the measured footprint lands strictly below the packed one.
    let packed = conn
        .handle(&Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0"}"#).unwrap());
    assert_eq!(packed.get("models").unwrap().as_usize().unwrap(), 2, "twins coexist");
    let pk = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap());
    let pk_ce = pk.get("ce").unwrap().as_f64().unwrap();
    assert_eq!(ec_ce, pk_ce, "lossless coding must not move the metric");
    let pk_bytes = packed.get("resident_bytes").unwrap().as_usize().unwrap();
    assert!(ec_bytes < pk_bytes, "coded {ec_bytes} B vs packed {pk_bytes} B");
    let info = conn.handle(&Json::parse(r#"{"op":"info"}"#).unwrap());
    assert!(!info.get("entropy_coded").unwrap().as_bool().unwrap());
    let pk_total = info.get("measured_total_bits").unwrap().as_f64().unwrap();
    assert!(ec_total < pk_total, "coded {ec_total} vs packed {pk_total} bits");

    // stats: the coded variant reports its payload accounting — strictly
    // under the nominal n*k floor (< 4.0 bits per 4-bit index here), and
    // never under the Shannon bound a prefix code cannot beat.
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    let models = stats.get("models").unwrap().as_arr().unwrap();
    let find = |k: &str| {
        models
            .iter()
            .find(|m| m.get("key").unwrap().as_str().unwrap() == k)
            .unwrap_or_else(|| panic!("{k} missing from stats"))
    };
    let e = find(&key).get("entropy").unwrap();
    let coded_bits = e.get("coded_payload_bits").unwrap().as_f64().unwrap();
    let nominal = e.get("nominal_payload_bits").unwrap().as_f64().unwrap();
    let bound = e.get("entropy_bound_bits").unwrap().as_f64().unwrap();
    assert!(coded_bits < nominal, "coded {coded_bits} vs nominal {nominal} payload bits");
    assert!(coded_bits >= bound, "coded {coded_bits} beat the Shannon bound {bound}");
    // The packed twin carries no entropy accounting.
    assert_eq!(*find("gpt2like_t0@fp:4:b64").get("entropy").unwrap(), Json::Null);

    // A simulate-only (16-bit baseline) spec has no index stream to code.
    let err = conn.handle(
        &Json::parse(r#"{"op":"load","family":"gpt2like","tier":"t0","entropy":true,"bits":16}"#)
            .unwrap(),
    );
    assert!(err.opt("error").is_some(), "baseline spec must not code: {err:?}");
}

#[test]
fn stats_reports_policy_identity() {
    use kbitscale::tune::{PolicyEntry, TunedPolicy};
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);

    // No policy: stats reports null, so fleet aggregation can tell
    // "policy-less" apart from "policy unknown".
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    assert_eq!(stats.get("policy").unwrap(), &Json::Null, "{stats:?}");

    let policy = TunedPolicy {
        suite: "ppl".into(),
        tuned_on: vec!["gpt2like_t0".into()],
        entries: vec![PolicyEntry {
            bits: 4,
            dtype: DataType::Fp,
            block: Some(64),
            stage_bits: None,
            entropy: false,
            metric: 0.5,
            total_bits: 4.25e5,
            bits_per_param: 4.25,
        }],
        classes: Default::default(),
    };
    let fp = policy.fingerprint();
    reg.set_policy_sourced(Some(policy), Some("runs/policy.json".into()));
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    let p = stats.get("policy").unwrap();
    assert_eq!(p.get("entries").unwrap().as_usize().unwrap(), 1);
    assert_eq!(p.get("hash").unwrap().as_str().unwrap(), fp);
    assert_eq!(p.get("source").unwrap().as_str().unwrap(), "runs/policy.json");

    // A live install (no artifact behind it) clears the source but keeps
    // the content hash.
    let set = format!(
        r#"{{"op":"policy","set":{}}}"#,
        conn.handle(&Json::parse(r#"{"op":"policy"}"#).unwrap()).get("policy").unwrap().dump()
    );
    let resp = conn.handle(&Json::parse(&set).unwrap());
    assert!(resp.opt("error").is_none(), "{resp:?}");
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    let p = stats.get("policy").unwrap();
    assert_eq!(p.get("hash").unwrap().as_str().unwrap(), fp);
    assert_eq!(p.get("source").unwrap(), &Json::Null);
}

#[test]
fn io_timeout_drops_stalled_client_without_pinning_worker() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // One worker thread, one connection: without the io timeout a silent
    // client would pin the worker (and this test) forever.
    let opts = ServeOpts {
        workers: 1,
        flush: Duration::from_millis(1),
        batching: false,
        max_conns: Some(1),
        // Generous enough that a loaded CI runner still delivers the
        // live request within the window; the stall phase then costs
        // this long once.
        io_timeout: Some(Duration::from_secs(2)),
    };
    std::thread::scope(|s| {
        let server = s.spawn(|| serve_listener(&reg, listener, &opts));
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        // A request before the stall proves the connection was live.
        writeln!(writer, "{{\"op\":\"ping\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        // Now stall: send a partial line and go silent. The server's
        // read times out and drops the connection (read returns 0), so
        // serve_listener's one worker is released and the scope joins.
        write!(writer, "{{\"op\":\"inf").unwrap();
        writer.flush().unwrap();
        let mut rest = String::new();
        let n = reader.read_line(&mut rest).unwrap();
        assert_eq!(n, 0, "server must hang up on a stalled client, got {rest:?}");
        server.join().unwrap().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Serving-path regression fixes
// ---------------------------------------------------------------------------

#[test]
fn out_of_vocab_tokens_are_rejected() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64))).unwrap();
    let vocab = manifest.tier("t0").unwrap().vocab;
    let mut conn = Connection::new(&reg, None);

    // 3e9 would saturate an unchecked `f64 as i32` cast to i32::MAX.
    let err = conn.handle(&Json::parse(r#"{"op":"score","tokens":[3000000000]}"#).unwrap());
    assert!(
        err.get("error").unwrap().as_str().unwrap().contains("out of range"),
        "{err:?}"
    );
    // The first out-of-vocab value (== vocab) is rejected too.
    let req = format!("{{\"op\":\"score\",\"tokens\":[{vocab}]}}");
    let err = conn.handle(&Json::parse(&req).unwrap());
    assert!(err.get("error").unwrap().as_str().unwrap().contains("out of range"));
    // Fractional tokens stay rejected.
    let err = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1.5]}"#).unwrap());
    assert!(err.opt("error").is_some());
    // The last in-vocab token scores fine.
    let req = format!("{{\"op\":\"score\",\"tokens\":[{},1,2]}}", vocab - 1);
    let ok = conn.handle(&Json::parse(&req).unwrap());
    assert!(ok.opt("ce").is_some(), "{ok:?}");
    // choose validates context and choices the same way.
    let req = format!(
        "{{\"op\":\"choose\",\"context\":[1,2],\"choices\":[[3],[{vocab}]]}}"
    );
    let err = conn.handle(&Json::parse(&req).unwrap());
    assert!(err.get("error").unwrap().as_str().unwrap().contains("out of range"));
}

#[test]
fn oversized_request_line_gets_error_response_not_oom() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut s = session(&rt, &manifest);

    let mut input: Vec<u8> = Vec::new();
    input.extend_from_slice(b"{\"op\":\"info\"}\n");
    // One 2 MiB line: over the 1 MiB cap, must be rejected without
    // buffering and without poisoning the rest of the stream.
    input.extend_from_slice(&vec![b'x'; 2 << 20]);
    input.push(b'\n');
    input.extend_from_slice(b"{\"op\":\"score\",\"tokens\":[1,2,3]}\n");
    let mut out = Vec::new();
    let served = serve_lines(&mut s, &input[..], &mut out).unwrap();
    assert_eq!(served, 3);
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 3);
    assert!(Json::parse(lines[0]).unwrap().opt("model").is_some());
    let err = Json::parse(lines[1]).unwrap();
    assert!(err.get("error").unwrap().as_str().unwrap().contains("exceeds"), "{err:?}");
    assert!(Json::parse(lines[2]).unwrap().opt("ce").is_some());
}
