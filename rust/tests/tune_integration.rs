//! Autotuner integration: the tune → policy → serving loop end to end —
//! a search over the k-bit config space on real (init-only) models, the
//! Pareto consistency of the emitted policy, policy-driven
//! `{"op":"load","auto":true}` resolution under a byte budget, the
//! `tune`/`policy` protocol ops, and the protocol-boundary `stage_bits`
//! validation.

use kbitscale::data::corpus::Corpus;
use kbitscale::eval::{EvalConfig, EvalSuite};
use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::DataType;
use kbitscale::runtime::Runtime;
use kbitscale::server::{Connection, ModelRegistry, ParamLoader};
use kbitscale::tensor::Tensor;
use kbitscale::tune::{self, PolicyEntry, TuneConfig, TuneTarget, TunedPolicy};
use kbitscale::util::json::Json;

fn registry<'a>(rt: &'a Runtime, manifest: &'a Manifest) -> ModelRegistry<'a> {
    let mref = manifest.clone();
    let loader: ParamLoader<'static> = Box::new(move |family: &str, tier: &str| {
        Ok(init_params(mref.tier(tier)?, Family::get(family)?))
    });
    ModelRegistry::new(rt, manifest, loader)
}

fn corpus(manifest: &Manifest) -> Corpus {
    Corpus::for_geometry(manifest.vocab, manifest.seq)
}

/// A small ppl-only search config (calibration, not a full sweep).
fn quick_cfg() -> TuneConfig {
    TuneConfig {
        bits: vec![3, 4, 8],
        dtypes: vec![DataType::Fp],
        blocks: vec![Some(64)],
        stage_mixes: false,
        entropy: false,
        suite: EvalSuite::Ppl,
        eval: EvalConfig { ppl_sequences: 4, zs_examples: 4 },
        threads: 2,
    }
}

fn entry(
    bits: usize,
    stage_bits: Option<Vec<usize>>,
    metric: f64,
    bits_per_param: f64,
) -> PolicyEntry {
    PolicyEntry {
        bits,
        dtype: DataType::Fp,
        block: Some(64),
        stage_bits,
        entropy: false,
        metric,
        total_bits: bits_per_param * 1e5,
        bits_per_param,
    }
}

#[test]
fn search_emits_pareto_consistent_policy_on_the_zoo() {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))
        .expect("run `make artifacts` first");
    let rt = Runtime::cpu().unwrap();
    let corpus = corpus(&manifest);
    let loader = |f: &str, t: &str| -> anyhow::Result<Vec<(String, Tensor)>> {
        Ok(init_params(manifest.tier(t)?, Family::get(f)?))
    };
    let targets = vec![TuneTarget::new("gpt2like", "t0")];
    let report =
        tune::search(&rt, &manifest, &corpus, &loader, &targets, &quick_cfg(), None).unwrap();

    // Every candidate measured (baseline + fp3/fp4/fp8), none skipped.
    assert_eq!(report.points.len(), 4, "cells: {}", report.points.len());
    assert_eq!(report.skipped, 0);
    assert_eq!(report.curves.len(), 4, "one curve per candidate config");

    // The policy is the Pareto frontier: consistent by construction, and
    // no budget can ever select a dominated config.
    let policy = &report.policy;
    assert!(!policy.entries.is_empty());
    policy.validate().expect("search produced a dominated policy entry");
    let tier = manifest.tier("t0").unwrap();
    for probe in &policy.entries {
        let budget = probe.estimated_model_bytes(tier);
        let chosen = policy.pick(tier, Some(budget)).expect("entry must fit its own estimate");
        for e in &policy.entries {
            if e.estimated_model_bytes(tier) <= budget {
                assert!(
                    e.metric <= chosen.metric,
                    "budget {budget}: pick {} dominated by {}",
                    chosen.key(),
                    e.key()
                );
            }
        }
    }

    // Serialize -> load -> identical selection at several budgets (the
    // artifact a server restarts from must pick exactly the same).
    let path = std::env::temp_dir()
        .join(format!("kbt_tune_policy_{}.json", std::process::id()));
    policy.save(&path).unwrap();
    let reloaded = TunedPolicy::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(&reloaded, policy);
    let probes: Vec<Option<usize>> = std::iter::once(None)
        .chain(policy.entries.iter().flat_map(|e| {
            let b = e.estimated_model_bytes(tier);
            [Some(b), Some(b.saturating_sub(1))]
        }))
        .collect();
    for budget in probes {
        assert_eq!(
            policy.pick(tier, budget).map(PolicyEntry::key),
            reloaded.pick(tier, budget).map(PolicyEntry::key),
            "round-trip changed the pick at budget {budget:?}"
        );
    }
}

#[test]
fn entropy_search_puts_the_coded_twin_on_the_frontier_below_the_floor() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let corpus = corpus(&manifest);
    let loader = |f: &str, t: &str| -> anyhow::Result<Vec<(String, Tensor)>> {
        Ok(init_params(manifest.tier(t)?, Family::get(f)?))
    };
    let targets = vec![TuneTarget::new("gpt2like", "t0")];
    let mut cfg = quick_cfg();
    cfg.bits = vec![4];
    cfg.entropy = true;
    let report =
        tune::search(&rt, &manifest, &corpus, &loader, &targets, &cfg, None).unwrap();

    // baseline + fp4 + its coded twin, all measured.
    assert_eq!(report.points.len(), 3, "cells: {}", report.points.len());
    assert_eq!(report.skipped, 0);
    let point = |k: &str| {
        report
            .points
            .iter()
            .find(|p| p.candidate.key() == k)
            .unwrap_or_else(|| panic!("{k} not measured"))
    };
    let packed = point("fp:4:b64");
    let coded = point("fp:4:b64#ec");

    // Lossless coding: the exact metric of the packed twin, with the
    // *measured* total bits strictly below it — the coded 4-bit variant
    // lands under the fixed-k floor packing can never cross.
    assert_eq!(coded.metric, packed.metric, "entropy coding must be lossless");
    assert!(
        coded.total_bits < packed.total_bits,
        "coded {} vs packed {} measured bits",
        coded.total_bits,
        packed.total_bits
    );
    assert!(
        coded.bits_per_param < packed.bits_per_param,
        "coded {} vs packed {} bits/param",
        coded.bits_per_param,
        packed.bits_per_param
    );

    // Equal metric at strictly fewer bits dominates: the coded twin is
    // the frontier's 4-bit point, the packed spelling is not.
    let policy = &report.policy;
    policy.validate().expect("entropy search produced a dominated policy entry");
    let keys: Vec<String> = policy.entries.iter().map(PolicyEntry::key).collect();
    assert!(keys.iter().any(|k| k == "fp:4:b64#ec"), "frontier: {keys:?}");
    assert!(!keys.iter().any(|k| k == "fp:4:b64"), "dominated twin kept: {keys:?}");

    // The coded entry round-trips through the policy artifact and keeps
    // its deploy shape (`entropy` survives serialization).
    let json = policy.to_json();
    let reloaded = TunedPolicy::from_json(&json).unwrap();
    assert_eq!(&reloaded, policy);
    let ec = reloaded.entries.iter().find(|e| e.key() == "fp:4:b64#ec").unwrap();
    assert!(ec.entropy);
    assert!(ec.plan_request().entropy);
}

#[test]
fn auto_load_serves_the_policy_pick_for_the_budget() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let tier = manifest.tier("t0").unwrap();
    let policy = TunedPolicy {
        suite: "ppl".into(),
        tuned_on: vec!["gpt2like_t0".into()],
        entries: vec![
            entry(3, None, -2.0, 3.25),
            entry(4, None, -1.5, 4.25),
            entry(16, None, -1.2, 16.0),
        ],
        classes: Default::default(),
    };
    // Budget exactly the 4-bit entry's estimated footprint: the frontier
    // pick for this budget is 4-bit (16-bit does not fit, 3-bit is worse).
    let budget = policy.entries[1].estimated_model_bytes(tier);
    let reg = registry(&rt, &manifest)
        .with_memory_budget(Some(budget))
        .with_policy(Some(policy.clone()));
    let expected = policy.pick(tier, reg.headroom()).unwrap().key();
    assert_eq!(expected, "fp:4:b64");

    let mut conn = Connection::new(&reg, None);
    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    let key = loaded.get("model").unwrap().as_str().unwrap().to_string();
    assert!(key.ends_with(&format!("@{expected}")), "{loaded:?}");
    assert!(loaded.get("auto").unwrap().as_bool().unwrap());
    assert_eq!(*loaded.get("stage_bits").unwrap(), Json::Null);

    // The auto-loaded variant becomes the connection's current model.
    let score = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9]}"#).unwrap());
    assert!(score.opt("ce").is_some(), "{score:?}");

    // Repeated auto-loads are idempotent: the resident frontier pick
    // costs zero additional bytes, so the same variant resolves again
    // even though packed headroom shrank below every fresh estimate — a
    // fleet of auto-loading clients converges on one variant instead of
    // cascading down the frontier.
    let again = conn.handle(&Json::parse(r#"{"op":"load","auto":true}"#).unwrap());
    assert_eq!(
        again.get("model").unwrap().as_str().unwrap(),
        key,
        "second auto-load must resolve the resident pick: {again:?}"
    );
    assert_eq!(reg.len(), 1, "idempotent auto-load must not grow residency");

    // Unbounded registry: the best-metric frontier entry wins outright.
    let unbounded = registry(&rt, &manifest).with_policy(Some(policy.clone()));
    let mut conn = Connection::new(&unbounded, None);
    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    let key = loaded.get("model").unwrap().as_str().unwrap();
    assert!(key.ends_with("@fp:16:bnone"), "{loaded:?}");

    // auto alongside explicit config fields is rejected, and auto with
    // no policy active is a clear error, not a panic.
    let err = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0","bits":4}"#)
            .unwrap(),
    );
    assert!(err.get("error").unwrap().as_str().unwrap().contains("policy"), "{err:?}");
    let bare = registry(&rt, &manifest);
    let mut conn = Connection::new(&bare, None);
    let err = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    assert!(err.get("error").unwrap().as_str().unwrap().contains("no tuned policy"), "{err:?}");
}

#[test]
fn auto_load_picks_staged_entries_for_sharded_tiers() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let tier = manifest.tier("t0").unwrap();
    if tier.stages.is_empty() {
        eprintln!("skipping: artifacts predate pipeline stages (rerun make artifacts)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let n_stages = tier.stages.len();
    let mut stage_bits = vec![4usize; n_stages];
    stage_bits[0] = 16; // the flagship mix: 16-bit stage 0 over 4-bit rest
    let policy = TunedPolicy {
        suite: "ppl".into(),
        tuned_on: vec!["gpt2like_t0".into()],
        entries: vec![
            entry(4, None, -1.5, 4.25),
            entry(4, Some(stage_bits.clone()), -1.3, 9.0),
            entry(16, None, -1.2, 16.0),
        ],
        classes: Default::default(),
    };
    // Budget fits the staged mix but not the full 16-bit baseline: the
    // frontier pick is the per-stage width vector.
    let budget = policy.entries[1].estimated_model_bytes(tier);
    let reg = registry(&rt, &manifest)
        .with_memory_budget(Some(budget))
        .with_policy(Some(policy.clone()));
    let expected = policy.pick(tier, reg.headroom()).unwrap();
    assert_eq!(expected.stage_bits.as_ref(), Some(&stage_bits));

    let mut conn = Connection::new(&reg, None);
    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    let key = loaded.get("model").unwrap().as_str().unwrap();
    assert!(
        key.ends_with(&format!("@{}", expected.key())),
        "served {key}, policy picked {}",
        expected.key()
    );
    let served_bits = loaded.get("stage_bits").unwrap().usizes().unwrap();
    assert_eq!(served_bits, stage_bits, "served stage_bits must equal the frontier pick");
    assert_eq!(loaded.get("stages").unwrap().as_usize().unwrap(), n_stages);
    let score = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#).unwrap());
    assert!(score.opt("ce").is_some(), "{score:?}");
}

#[test]
fn tune_and_policy_ops_drive_the_loop_over_the_protocol() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let mut conn = Connection::new(&reg, None);

    // No policy yet.
    let none = conn.handle(&Json::parse(r#"{"op":"policy"}"#).unwrap());
    assert_eq!(*none.get("policy").unwrap(), Json::Null);

    // A live search against the registry's own loader, tiny calibration.
    let tuned = conn.handle(
        &Json::parse(
            r#"{"op":"tune","family":"gpt2like","tier":"t0","bits":[3,4],
                "stage_mixes":false,"ppl_sequences":2,"zs_examples":2,"threads":2}"#,
        )
        .unwrap(),
    );
    assert!(tuned.opt("error").is_none(), "{tuned:?}");
    assert_eq!(tuned.get("tuned").unwrap().as_usize().unwrap(), 3, "baseline + fp3 + fp4");
    assert!(tuned.get("installed").unwrap().as_bool().unwrap());
    let entries = tuned.get("policy").unwrap().get("entries").unwrap().as_arr().unwrap();
    assert!(!entries.is_empty());

    // The installed policy is inspectable and drives auto loads.
    let shown = conn.handle(&Json::parse(r#"{"op":"policy"}"#).unwrap());
    assert_eq!(shown.get("policy").unwrap().dump(), tuned.get("policy").unwrap().dump());
    // Nothing resident yet, so the first auto load names its model; the
    // later one leans on the connection's current model.
    let loaded = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    assert!(loaded.opt("error").is_none(), "{loaded:?}");
    assert!(loaded.get("model").unwrap().as_str().unwrap().starts_with("gpt2like_t0@"));

    // Swap in a hand-written policy, then clear it.
    let hand = TunedPolicy {
        suite: "ppl".into(),
        tuned_on: vec!["gpt2like_t0".into()],
        entries: vec![entry(3, None, -2.0, 3.25)],
        classes: Default::default(),
    };
    let req = Json::obj(vec![("op", Json::str("policy")), ("set", hand.to_json())]);
    let swapped = conn.handle(&req);
    let suite = swapped.get("policy").unwrap().get("suite").unwrap().as_str().unwrap();
    assert_eq!(suite, "ppl");
    let loaded = conn.handle(&Json::parse(r#"{"op":"load","auto":true}"#).unwrap());
    assert!(
        loaded.get("model").unwrap().as_str().unwrap().ends_with("@fp:3:b64"),
        "{loaded:?}"
    );
    // A dominated hand-written policy is rejected at the protocol edge.
    let bad = TunedPolicy {
        suite: "ppl".into(),
        tuned_on: vec![],
        entries: vec![entry(4, None, -1.0, 4.25), entry(8, None, -2.0, 8.25)],
        classes: Default::default(),
    };
    let req = Json::obj(vec![("op", Json::str("policy")), ("set", bad.to_json())]);
    let err = conn.handle(&req);
    assert!(
        err.get("error").unwrap().as_str().unwrap().contains("Pareto"),
        "{err:?}"
    );
    let cleared = conn.handle(&Json::parse(r#"{"op":"policy","clear":true}"#).unwrap());
    assert_eq!(*cleared.get("policy").unwrap(), Json::Null);
}

#[test]
fn stage_bits_count_mismatch_is_a_boundary_error() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let reg = registry(&rt, &manifest);
    let declared = manifest.tier("t0").unwrap().stages.len();
    let mut conn = Connection::new(&reg, None);
    // One width against a plan that declares a different stage count:
    // the error must name both numbers (protocol boundary validation),
    // not surface as a deep plan-layout failure — and it must fire even
    // on pre-stage artifacts (declared == 0).
    let err = conn.handle(
        &Json::parse(
            r#"{"op":"load","family":"gpt2like","tier":"t0","pipeline":true,"stage_bits":[4,4,4,4,4]}"#,
        )
        .unwrap(),
    );
    let msg = err.get("error").unwrap().as_str().unwrap();
    assert!(msg.contains("5 widths"), "{msg}");
    assert!(msg.contains(&format!("{declared} pipeline stage")), "{msg}");
    // Nothing was made resident by the failed load.
    assert_eq!(reg.len(), 0);
}
