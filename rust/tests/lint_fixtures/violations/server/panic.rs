//! Fixture: every panic-path pattern the lint must flag on a network path.

fn unwrap_on_option(v: Option<u32>) -> u32 {
    v.unwrap()
}

fn expect_on_result(r: Result<u32, String>) -> u32 {
    r.expect("fixture")
}

fn aborting_macro(x: u32) -> u32 {
    if x > 3 {
        panic!("fixture");
    }
    unreachable!()
}

fn unchecked_index(rows: &[u32], i: usize) -> u32 {
    rows[i]
}
