//! Fixture: malformed escape hatches — both are `lint-allow` findings,
//! and neither suppresses the underlying violation.

fn unknown_rule(v: Option<u32>) -> u32 {
    // lint: allow(made-up-rule) — this rule does not exist
    v.unwrap()
}

fn missing_reason(v: Option<u32>) -> u32 {
    // lint: allow(panic-path)
    v.unwrap()
}
