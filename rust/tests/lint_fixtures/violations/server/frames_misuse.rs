//! Fixture: bin1 wire constants duplicated outside server/frames.rs.

const HEADER_BYTES: usize = 6;

fn magic() -> u8 {
    0xB1
}

fn header_len() -> usize {
    HEADER_BYTES
}
