//! Fixture: protocol doc block out of sync with the dispatch table.
//!
//! Documented ops: `{"op":"ping"}`, `{"op":"hello"}`, and `{"op":"ghost"}`.

fn try_handle(op: &str) -> u32 {
    match op {
        "ping" => 1,
        "extra" => 2,
        _ => 0,
    }
}

fn pump(line: &str) -> bool {
    line.contains("hello")
}
