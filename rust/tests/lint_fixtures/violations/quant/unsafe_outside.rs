//! Fixture: `unsafe` outside the allowlisted kernel modules.

fn forbidden(p: *const u32) -> u32 {
    // SAFETY: a comment does not move a module onto the allowlist.
    unsafe { *p }
}
