//! Fixture: an undeclared lock-order edge and an unregistered mutex field.

use std::sync::Mutex;

struct Fixture {
    workers: Mutex<u32>,
    models: Mutex<u32>,
    mystery: Mutex<u32>,
}

impl Fixture {
    fn undeclared_edge(&self) -> u32 {
        let roster = self.workers.lock().unwrap();
        let registry = self.models.lock().unwrap();
        *roster + *registry
    }

    fn unregistered_field(&self) -> u32 {
        *self.mystery.lock().unwrap()
    }
}
