//! Fixture: `unsafe` in an allowlisted module but with no SAFETY comment.

fn no_safety_comment(p: *const u32) -> u32 {
    unsafe { *p }
}
