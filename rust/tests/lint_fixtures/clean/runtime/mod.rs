//! Fixture: disciplined `unsafe` in an allowlisted module.

fn documented(p: *const u32) -> u32 {
    // SAFETY: fixture — p is non-null and aligned by construction.
    unsafe { *p }
}
