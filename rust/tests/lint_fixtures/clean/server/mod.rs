//! Fixture: protocol doc block exactly matching the dispatch table.
//!
//! Documented ops: `{"op":"ping"}`, `{"op":"score"}`, `{"op":"hello"}`.

fn try_handle(op: &str) -> u32 {
    match op {
        "ping" => 1,
        "score" => 2,
        _ => 0,
    }
}

fn pump(line: &str) -> bool {
    line.contains("hello")
}
