//! Fixture: idiomatic panic-free server code the lint must pass —
//! error propagation, the poisoning exemption, a justified allow, and
//! test-module freedom.

use std::sync::Mutex;

fn propagates(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "empty".to_string())
}

fn checked_access(rows: &[u32], i: usize) -> Option<u32> {
    rows.get(i).copied()
}

fn poisoning_convention(models: &Mutex<u32>) -> u32 {
    *models.lock().unwrap()
}

fn justified(rows: &[u32]) -> u32 {
    // lint: allow(panic-path) — fixture invariant: rows is never empty here
    rows[0]
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
