//! Fixture: lock nesting along declared edges only.

use std::sync::Mutex;

struct Fixture {
    workers: Mutex<u32>,
    models: Mutex<u32>,
    default_key: Mutex<u32>,
}

impl Fixture {
    fn declared_nesting(&self) -> u32 {
        let models = self.models.lock().unwrap();
        let default = self.default_key.lock().unwrap();
        *models + *default
    }

    fn early_drop(&self) -> u32 {
        let roster = self.workers.lock().unwrap();
        let n = *roster;
        drop(roster);
        n + *self.models.lock().unwrap()
    }
}
