//! End-to-end integration: train → checkpoint → coordinator sweep →
//! results store → scaling analysis, all through the public API, in a
//! temp run directory (does not touch `runs/`).
//!
//! Needs `make artifacts`. Kept small (one tiny model, ~30s) so it runs
//! in the default `cargo test` gate.

use std::path::PathBuf;

use kbitscale::coordinator::{Cell, Coordinator, ResultsStore};
use kbitscale::data::corpus::{Corpus, CorpusConfig};
use kbitscale::eval::EvalSuite;
use kbitscale::models::checkpoint::CheckpointStore;
use kbitscale::models::families::Family;
use kbitscale::models::manifest::Manifest;
use kbitscale::models::ModelId;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::runtime::Runtime;
use kbitscale::train::{train_model, TrainConfig};

struct TempRun(PathBuf);
impl Drop for TempRun {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

#[test]
fn full_pipeline_on_t0() {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))
        .expect("run `make artifacts` first");
    let rt = Runtime::cpu().unwrap();
    let corpus = Corpus::new(CorpusConfig {
        vocab: manifest.vocab,
        seq: manifest.seq,
        ..CorpusConfig::default()
    });
    let dir = std::env::temp_dir().join(format!("kbt_e2e_{}", std::process::id()));
    let _guard = TempRun(dir.clone());
    let ckpts = CheckpointStore::new(dir.join("ckpt"));
    let results = ResultsStore::open(dir.join("results.jsonl")).unwrap();

    // 1. Train a tiny model briefly.
    let family = Family::get("gpt2like").unwrap();
    let tier = manifest.tier("t0").unwrap();
    let cfg = TrainConfig { steps: 120, log_every: 1000, ..TrainConfig::default() };
    let rep = train_model(&rt, &manifest, tier, family, &corpus, &cfg, &ckpts).unwrap();
    assert!(rep.final_loss < rep.losses[0], "training must reduce loss");
    assert!(ckpts.exists(&ModelId::new("gpt2like", "t0")));

    // 2. Sweep three precisions through the coordinator.
    let coord = Coordinator::new(&rt, &manifest, &corpus, &ckpts, &results);
    let cells = vec![
        Cell::new("gpt2like", "t0", QuantSpec::baseline16(), EvalSuite::PplZeroShot),
        Cell::new("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64)), EvalSuite::PplZeroShot),
        Cell::new("gpt2like", "t0", QuantSpec::new(DataType::Int, 3, None), EvalSuite::Ppl),
    ];
    let out = coord.run_grid(&cells).unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(results.len(), 3);

    // 3. Monotonicity + accounting invariants.
    let base = &out[0];
    let fp4 = &out[1];
    let int3 = &out[2];
    assert!(base.ce.is_finite() && base.ce > 0.0);
    // Quantization can only hurt (or match) CE, and more bits hurt less.
    assert!(fp4.ce >= base.ce - 0.05, "4-bit ce {} << baseline {}", fp4.ce, base.ce);
    assert!(int3.ce >= fp4.ce - 0.05, "3-bit tensor-wise should be worst");
    assert!(base.total_bits > fp4.total_bits);
    assert!((fp4.bits_per_param - 4.25).abs() < 1e-9);
    assert!(base.zs_mean.is_finite());
    assert!(int3.zs_mean.is_nan(), "ppl-only suite has no zero-shot");

    // 4. Cache hit: re-running the grid must be instant and identical.
    let t = std::time::Instant::now();
    let again = coord.run_grid(&cells).unwrap();
    assert!(t.elapsed().as_secs_f64() < 0.5, "cache miss on rerun");
    for (a, b) in out.iter().zip(&again) {
        assert_eq!(a.key, b.key);
        assert_eq!(a.ce, b.ce);
    }

    // 5. Store survives reopen (resume path).
    drop(results);
    let reopened = ResultsStore::open(dir.join("results.jsonl")).unwrap();
    assert_eq!(reopened.len(), 3);
}
