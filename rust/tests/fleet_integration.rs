//! Fleet-tier integration: in-process `serve_listener` workers on
//! ephemeral ports behind the [`kbitscale::fleet`] router — routed vs
//! direct score parity (bit-identical NLLs), mid-stream worker death and
//! retry-on-next-worker failover, policy-aware placement under per-worker
//! headroom, fleet-wide stats aggregation with policy-skew detection, and
//! negotiated `bin1` binary-frame pass-through parity.
//!
//! Worker processes are simulated by leaked registries served from
//! detached threads (they idle until the test binary exits), so workers
//! "serve forever" exactly like real `kbitscale serve --tcp` processes
//! while each test joins only what it owns.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use kbitscale::fleet::{serve_fleet, Fleet, FleetConn, FleetOpts, ManualClock, WorkerSpec};
use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::runtime::Runtime;
use kbitscale::server::{frames, serve_listener, Emit, ModelRegistry, ParamLoader, ServeOpts};
use kbitscale::tune::{PolicyEntry, TunedPolicy};
use kbitscale::util::json::Json;

/// A "worker process": leaked registry + runtime served from a detached
/// thread on an ephemeral port, alive until the test binary exits.
fn spawn_worker(
    budget: Option<usize>,
    policy: Option<TunedPolicy>,
    source: Option<&str>,
) -> (&'static ModelRegistry<'static>, String) {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))
        .expect("run `make artifacts` first");
    let rt: &'static Runtime = Box::leak(Box::new(Runtime::cpu().unwrap()));
    let mref = manifest.clone();
    let loader: ParamLoader<'static> = Box::new(move |family: &str, tier: &str| {
        // Init-only params: deterministic, so every worker holds
        // bit-identical weights — the parity tests depend on this.
        Ok(init_params(mref.tier(tier)?, Family::get(family)?))
    });
    let reg: &'static ModelRegistry<'static> = Box::leak(Box::new(
        ModelRegistry::new(rt, &manifest, loader)
            .with_memory_budget(budget)
            .with_policy_sourced(policy, source.map(String::from)),
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts: &'static ServeOpts = Box::leak(Box::new(ServeOpts {
        workers: 4,
        flush: Duration::from_millis(1),
        batching: true,
        max_conns: None,
        io_timeout: Some(Duration::from_secs(30)),
    }));
    std::thread::spawn(move || {
        let _ = serve_listener(reg, listener, opts);
    });
    (reg, addr)
}

fn fleet_for(addrs: &[&str], policy: Option<TunedPolicy>) -> Fleet {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let specs = addrs.iter().map(|a| WorkerSpec::parse(a).unwrap()).collect();
    Fleet::new(
        &manifest,
        specs,
        policy,
        FleetOpts {
            io_timeout: Some(Duration::from_secs(10)),
            probe_interval: Duration::from_millis(200),
            push_policy: false,
            ..FleetOpts::default()
        },
    )
}

/// One request/response against a line-protocol TCP endpoint.
fn roundtrip(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    req: &str,
) -> Json {
    writeln!(writer, "{req}").unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "endpoint hung up on {req:?}");
    Json::parse(line.trim()).unwrap()
}

fn connect(addr: &str) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    (BufReader::new(stream.try_clone().unwrap()), stream)
}

const ROWS: &str = "[[1,2,3],[4,5,6],[7,8],[9,10],[11]]";

#[test]
fn routed_scores_match_direct_worker_bit_for_bit() {
    let (reg_a, addr_a) = spawn_worker(None, None, None);
    let (reg_b, addr_b) = spawn_worker(None, None, None);
    let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
    let key = reg_a.load("gpt2like", "t0", spec.clone()).unwrap().key();
    reg_b.load("gpt2like", "t0", spec).unwrap();

    // Router over both workers, served on its own ephemeral port. The
    // test owns exactly the connections it opens, so max_conns joins the
    // router thread deterministically.
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let fleet = Fleet::new(
        &manifest,
        vec![WorkerSpec::parse(&addr_a).unwrap(), WorkerSpec::parse(&addr_b).unwrap()],
        None,
        FleetOpts {
            io_timeout: Some(Duration::from_secs(10)),
            probe_interval: Duration::from_secs(60),
            push_policy: false,
            max_conns: Some(1),
            ..FleetOpts::default()
        },
    );
    fleet.probe();
    assert_eq!(fleet.topology().up_ids().len(), 2, "both workers must probe up");
    assert!(
        fleet.topology().snapshot().iter().all(|w| w.resident.contains(&key)),
        "probes must discover residency"
    );

    let router_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let router_addr = router_listener.local_addr().unwrap().to_string();
    std::thread::scope(|s| {
        let router = s.spawn(|| serve_fleet(&fleet, router_listener));
        let (mut rr, mut rw) = connect(&router_addr);

        // The router answers its own ping with fleet health.
        let pong = roundtrip(&mut rr, &mut rw, r#"{"op":"ping"}"#);
        assert!(pong.get("ok").unwrap().as_bool().unwrap(), "{pong:?}");
        assert_eq!(pong.get("role").unwrap().as_str().unwrap(), "router");
        assert_eq!(pong.get("workers_up").unwrap().as_usize().unwrap(), 2);

        // Direct reference response from worker A.
        let (mut dr, mut dw) = connect(&addr_a);
        let direct = roundtrip(
            &mut dr,
            &mut dw,
            &format!(r#"{{"op":"score","model":"{key}","rows":{ROWS}}}"#),
        );
        assert!(direct.opt("error").is_none(), "{direct:?}");

        // Buffered multi-row through the router scatters across both
        // replicas and must reassemble to the identical response.
        let routed = roundtrip(
            &mut rr,
            &mut rw,
            &format!(r#"{{"op":"score","model":"{key}","rows":{ROWS}}}"#),
        );
        assert!(routed.opt("error").is_none(), "{routed:?}");
        assert_eq!(routed.get("rows_scored").unwrap().as_usize().unwrap(), 5);
        assert_eq!(
            routed.get("rows").unwrap().dump(),
            direct.get("rows").unwrap().dump(),
            "scattered rows must be bit-identical to the direct worker"
        );
        assert_eq!(
            routed.get("nll").unwrap().as_f64().unwrap(),
            direct.get("nll").unwrap().as_f64().unwrap(),
            "summed NLL must match bit-for-bit (same addition order)"
        );

        // Streamed multi-row: chunks renumbered into global row order
        // with one terminal summary; row payloads identical to direct.
        let stream_req =
            format!(r#"{{"op":"score","model":"{key}","rows":{ROWS},"stream":true,"chunk":1}}"#);
        writeln!(rw, "{stream_req}").unwrap();
        let mut streamed_rows: Vec<Json> = Vec::new();
        let mut chunk_no = 0usize;
        let done = loop {
            let mut line = String::new();
            assert!(rr.read_line(&mut line).unwrap() > 0, "router hung up mid-stream");
            let j = Json::parse(line.trim()).unwrap();
            if j.opt("done").is_some() {
                break j;
            }
            assert_eq!(j.get("chunk").unwrap().as_usize().unwrap(), chunk_no, "chunk order");
            assert_eq!(
                j.get("first_row").unwrap().as_usize().unwrap(),
                streamed_rows.len(),
                "row order across replica blocks"
            );
            streamed_rows.extend(j.get("rows").unwrap().as_arr().unwrap().iter().cloned());
            chunk_no += 1;
        };
        assert!(done.opt("error").is_none(), "{done:?}");
        assert_eq!(done.get("rows_scored").unwrap().as_usize().unwrap(), 5);
        assert_eq!(done.get("chunks").unwrap().as_usize().unwrap(), 5, "chunk:1 over 5 rows");
        assert_eq!(
            done.get("nll").unwrap().as_f64().unwrap(),
            direct.get("nll").unwrap().as_f64().unwrap()
        );
        let direct_rows = direct.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(Json::Arr(streamed_rows).dump(), Json::Arr(direct_rows.to_vec()).dump());

        // models aggregation names the owning worker per entry.
        let models = roundtrip(&mut rr, &mut rw, r#"{"op":"models"}"#);
        let entries = models.get("models").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2, "one resident variant per worker: {models:?}");
        assert!(entries.iter().all(|e| e.opt("worker").is_some()));

        drop(rw);
        drop(rr);
        router.join().unwrap().unwrap();
    });
}

#[test]
fn router_bin1_stream_decodes_to_the_json_stream() {
    let (reg_a, addr_a) = spawn_worker(None, None, None);
    let (reg_b, addr_b) = spawn_worker(None, None, None);
    let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
    let key = reg_a.load("gpt2like", "t0", spec.clone()).unwrap().key();
    reg_b.load("gpt2like", "t0", spec).unwrap();

    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let fleet = Fleet::new(
        &manifest,
        vec![WorkerSpec::parse(&addr_a).unwrap(), WorkerSpec::parse(&addr_b).unwrap()],
        None,
        FleetOpts {
            io_timeout: Some(Duration::from_secs(10)),
            probe_interval: Duration::from_secs(60),
            push_policy: false,
            max_conns: Some(2),
            ..FleetOpts::default()
        },
    );
    fleet.probe();
    assert_eq!(fleet.topology().up_ids().len(), 2, "both workers must probe up");

    let router_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let router_addr = router_listener.local_addr().unwrap().to_string();
    let req = format!(r#"{{"op":"score","model":"{key}","rows":{ROWS},"stream":true,"chunk":1}}"#);
    std::thread::scope(|s| {
        let router = s.spawn(|| serve_fleet(&fleet, router_listener));

        // Reference connection: default JSON framing through the router.
        let (mut jr, mut jw) = connect(&router_addr);
        writeln!(jw, "{req}").unwrap();
        let mut json_stream: Vec<Json> = Vec::new();
        loop {
            let mut line = String::new();
            assert!(jr.read_line(&mut line).unwrap() > 0, "router hung up mid-stream");
            let j = Json::parse(line.trim()).unwrap();
            let done = j.opt("done").is_some();
            json_stream.push(j);
            if done {
                break;
            }
        }
        drop(jw);
        drop(jr);

        // bin1 connection: scattered chunks arrive as binary frames the
        // router renumbered in place (no per-hop float re-serialization);
        // the terminal summary stays JSON.
        let (mut br, mut bw) = connect(&router_addr);
        let hello = roundtrip(&mut br, &mut bw, r#"{"op":"hello","frames":"bin1"}"#);
        assert_eq!(hello.get("frames").unwrap().as_str().unwrap(), "bin1", "{hello:?}");
        writeln!(bw, "{req}").unwrap();
        let mut bin_stream: Vec<Json> = Vec::new();
        let mut frames_seen = 0usize;
        let mut frame: Vec<u8> = Vec::new();
        loop {
            if br.fill_buf().unwrap().first() == Some(&frames::MAGIC) {
                frames::read_frame(&mut br, &mut frame).unwrap();
                bin_stream.push(frames::decode_chunk(&frame).unwrap());
                frames_seen += 1;
                continue;
            }
            let mut line = String::new();
            assert!(br.read_line(&mut line).unwrap() > 0, "router hung up mid-stream");
            let j = Json::parse(line.trim()).unwrap();
            let done = j.opt("done").is_some();
            bin_stream.push(j);
            if done {
                break;
            }
        }
        assert_eq!(frames_seen, 5, "every chunk must arrive as a binary frame");
        assert_eq!(json_stream.len(), bin_stream.len());
        for (a, b) in json_stream.iter().zip(&bin_stream) {
            assert_eq!(a.dump(), b.dump(), "bin1 router stream must decode to the JSON stream");
        }
        drop(bw);
        drop(br);
        router.join().unwrap().unwrap();
    });
}

/// A fake worker that answers one chunk line and then drops the
/// connection mid-stream (or drops buffered requests outright) —
/// deterministic "worker dies mid-request" behavior no real
/// `serve_listener` can produce on demand.
fn crashy_worker(listener: TcpListener) {
    for stream in listener.incoming() {
        let Ok(stream) = stream else { return };
        let Ok(clone) = stream.try_clone() else { continue };
        let mut reader = BufReader::new(clone);
        let mut writer = stream;
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            continue;
        }
        if line.contains("\"stream\":true") {
            let chunk = r#"{"chunk":0,"first_row":0,"rows":[{"ce":1.5,"greedy_hits":0,"nll":1.5,"ppl":4.4817,"tokens_scored":1}]}"#;
            let _ = writeln!(writer, "{chunk}");
            let _ = writer.flush();
        }
        // Connection dropped here: mid-stream for streamed requests,
        // before any response for buffered ones.
    }
}

#[test]
fn worker_death_mid_stream_fails_over_to_healthy_replica() {
    let (_reg_a, addr_a) = spawn_worker(None, None, None);
    let crashy = TcpListener::bind("127.0.0.1:0").unwrap();
    let crashy_addr = crashy.local_addr().unwrap().to_string();
    std::thread::spawn(move || crashy_worker(crashy));

    let fleet = fleet_for(&[&addr_a, &crashy_addr], None);
    let key = "gpt2like_t0@fp:4:b64";
    // Seed the roster by hand (no probe): the crashy worker is the only
    // replica, the healthy worker is up but holds nothing relevant.
    fleet.topology().note_loaded(0, "gpt2like_t0@int:3:b32");
    fleet.topology().note_loaded(1, key);

    let mut conn = FleetConn::new(&fleet);
    let req = Json::parse(&format!(
        r#"{{"op":"score","model":"{key}","rows":[[1,2],[3,4],[5,6]],"stream":true,"chunk":1}}"#
    ))
    .unwrap();
    let mut lines: Vec<Json> = Vec::new();
    let term = conn.handle_streaming(&req, &mut |e: Emit<'_>| {
        if let Emit::Line(j) = e {
            lines.push(j.clone());
        }
        Ok(())
    });
    // The crashy replica delivered one chunk then died: the stream must
    // terminate with an error line, the delivered chunk stands, and the
    // worker is marked down.
    assert!(term.get("done").unwrap().as_bool().unwrap(), "{term:?}");
    assert!(
        term.get("error").unwrap().as_str().unwrap().contains("mid-stream"),
        "{term:?}"
    );
    assert_eq!(lines.len(), 1, "the chunk emitted before the crash stands");
    assert_eq!(lines[0].get("chunk").unwrap().as_usize().unwrap(), 0);
    assert_eq!(fleet.topology().up_ids(), vec![0], "crashy worker must be marked down");

    // The *same connection* survives; the next request fails over: the
    // healthy worker does not hold the variant, so the router replays
    // the load derived from the registry key, then scores there.
    let resp = conn.handle(
        &Json::parse(&format!(
            r#"{{"op":"score","model":"{key}","rows":[[1,2],[3,4],[5,6]]}}"#
        ))
        .unwrap(),
    );
    assert!(resp.opt("error").is_none(), "failover must succeed: {resp:?}");
    assert_eq!(resp.get("rows_scored").unwrap().as_usize().unwrap(), 3);
    assert!(
        fleet.topology().snapshot()[0].resident.contains(key),
        "failover load must be recorded in the roster"
    );

    // Single-row traffic keeps flowing on the survivor too.
    let resp = conn.handle(
        &Json::parse(&format!(r#"{{"op":"score","model":"{key}","tokens":[1,5,9]}}"#)).unwrap(),
    );
    assert!(resp.opt("ce").is_some(), "{resp:?}");
}

fn test_policy(param_count: usize) -> TunedPolicy {
    let entry = |bits: usize, metric: f64, bpp: f64| PolicyEntry {
        bits,
        dtype: DataType::Fp,
        block: Some(64),
        stage_bits: None,
        entropy: false,
        metric,
        total_bits: bpp * param_count as f64,
        bits_per_param: bpp,
    };
    TunedPolicy {
        suite: "ppl".into(),
        tuned_on: vec!["gpt2like_t0".into()],
        entries: vec![entry(4, 0.55, 4.25), entry(16, 0.60, 16.0)],
        classes: Default::default(),
    }
}

#[test]
fn auto_load_placement_respects_per_worker_headroom() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let tier = manifest.tier("t0").unwrap();
    let bytes = |bpp: f64| (bpp * tier.param_count as f64 / 8.0).ceil() as usize;
    let policy = test_policy(tier.param_count);

    // Worker A's budget fits only the 4-bit entry; worker B fits the
    // full frontier.
    let (_, addr_a) = spawn_worker(Some(bytes(4.25) + 4096), Some(policy.clone()), None);
    let (_, addr_b) = spawn_worker(Some(bytes(16.0) + 4096), Some(policy.clone()), None);
    let fleet = fleet_for(&[&addr_a, &addr_b], Some(policy));
    fleet.probe();
    let snap = fleet.topology().snapshot();
    assert!(snap.iter().all(|w| w.up), "{snap:?}");
    assert_eq!(snap[0].budget_bytes, Some(bytes(4.25) + 4096), "probed budget wins");

    // The frontier-best 16-bit entry fits only worker B → placed there,
    // and B's own policy picks the 16-bit config.
    let mut conn = FleetConn::new(&fleet);
    let resp = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    assert!(resp.opt("error").is_none(), "{resp:?}");
    assert_eq!(resp.get("worker").unwrap().as_str().unwrap(), addr_b);
    assert!(
        resp.get("model").unwrap().as_str().unwrap().ends_with("fp:16:bnone"),
        "{resp:?}"
    );

    // With B gone, placement spills down the frontier to the 4-bit
    // entry worker A's headroom can hold.
    fleet.topology().mark_down(1, "killed for the test");
    let resp = conn.handle(
        &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#).unwrap(),
    );
    assert!(resp.opt("error").is_none(), "{resp:?}");
    assert_eq!(resp.get("worker").unwrap().as_str().unwrap(), addr_a);
    assert!(
        resp.get("model").unwrap().as_str().unwrap().ends_with("fp:4:b64"),
        "spill to the frontier entry that fits: {resp:?}"
    );
}

#[test]
fn fleet_stats_detects_and_heals_policy_skew() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let policy = test_policy(manifest.tier("t0").unwrap().param_count);
    // A runs the policy (from a named artifact), B runs none: skew.
    let (_, addr_a) = spawn_worker(None, Some(policy.clone()), Some("runs/policy.json"));
    let (_, addr_b) = spawn_worker(None, None, None);
    let fleet = fleet_for(&[&addr_a, &addr_b], None);
    fleet.probe();

    let mut conn = FleetConn::new(&fleet);
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    assert!(stats.get("policy_skew").unwrap().as_bool().unwrap(), "{stats:?}");
    assert_eq!(stats.get("workers_up").unwrap().as_usize().unwrap(), 2);
    assert_eq!(stats.get("workers").unwrap().as_arr().unwrap().len(), 2);
    let a_stats = stats.get("workers").unwrap().as_arr().unwrap()[0].get("stats").unwrap();
    assert_eq!(
        a_stats.get("policy").unwrap().get("source").unwrap().as_str().unwrap(),
        "runs/policy.json",
        "skew reports must name the artifact behind each worker's policy"
    );

    // Broadcasting a policy through the router heals the skew.
    let set = format!(r#"{{"op":"policy","set":{}}}"#, policy.to_json().dump());
    let resp = conn.handle(&Json::parse(&set).unwrap());
    assert!(resp.opt("error").is_none(), "{resp:?}");
    assert!(!resp.get("policy_skew").unwrap().as_bool().unwrap(), "{resp:?}");
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    assert!(!stats.get("policy_skew").unwrap().as_bool().unwrap(), "{stats:?}");
}

#[test]
fn governor_demotes_promotes_and_stays_bit_identical() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let policy = test_policy(manifest.tier("t0").unwrap().param_count);
    let (reg_a, addr_a) = spawn_worker(None, None, None);
    let (_reg_b, addr_b) = spawn_worker(None, None, None);
    // Only the frontier-best 16-bit variant is resident at start — the
    // governor's implicit initial target for the bare model key.
    let key16 =
        reg_a.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 16, None)).unwrap().key();

    // Manual clock: window eviction and cooldowns advance only when the
    // test says so, making every governor decision deterministic.
    let clock = Arc::new(ManualClock::new(0));
    let fleet = Fleet::new(
        &manifest,
        vec![WorkerSpec::parse(&addr_a).unwrap(), WorkerSpec::parse(&addr_b).unwrap()],
        Some(policy),
        FleetOpts {
            io_timeout: Some(Duration::from_secs(10)),
            probe_interval: Duration::from_secs(60),
            push_policy: false,
            govern: true,
            target_p99_ms: 100.0,
            cooldown_ms: 20_000,
            ..FleetOpts::default()
        },
    )
    .with_clock(clock.clone());
    fleet.probe();
    assert_eq!(fleet.topology().up_ids().len(), 2, "both workers must probe up");

    // Cold window: below min_samples, the governor must not move.
    assert!(fleet.govern_tick().is_empty(), "no samples -> no migrations");

    // t=0: sustained p99 pressure -> one demote down the frontier, with
    // the 4-bit target pre-warmed on a worker *before* traffic moves.
    for _ in 0..16 {
        fleet.telemetry().record_router(500.0);
    }
    let demote = fleet.govern_tick();
    assert_eq!(demote.len(), 1, "{demote:?}");
    assert_eq!(demote[0].action, "demote");
    assert_eq!(demote[0].from, key16);
    let key4 = demote[0].to.clone();
    assert!(key4.ends_with("fp:4:b64"), "{demote:?}");
    let holder = fleet
        .topology()
        .snapshot()
        .iter()
        .find(|w| w.resident.contains(&key4))
        .expect("demote target must be pre-warmed before cutover")
        .id;
    assert_eq!(holder, demote[0].worker, "roster must record the pre-warm");

    // Bare-keyed traffic now resolves to the demoted variant —
    // bit-identical to scoring the explicit key on the pre-warmed worker,
    // because the migration was an ordinary keyed load replay.
    let mut conn = FleetConn::new(&fleet);
    let bare = format!(r#"{{"op":"score","model":"gpt2like_t0","rows":{ROWS}}}"#);
    let routed = conn.handle(&Json::parse(&bare).unwrap());
    assert!(routed.opt("error").is_none(), "{routed:?}");
    let holder_addr = [&addr_a, &addr_b][holder];
    let (mut dr, mut dw) = connect(holder_addr);
    let direct4 =
        roundtrip(&mut dr, &mut dw, &format!(r#"{{"op":"score","model":"{key4}","rows":{ROWS}}}"#));
    assert!(direct4.opt("error").is_none(), "{direct4:?}");
    assert_eq!(
        routed.get("rows").unwrap().dump(),
        direct4.get("rows").unwrap().dump(),
        "a governed demote must not change a single scored bit"
    );
    assert_eq!(
        routed.get("nll").unwrap().as_f64().unwrap(),
        direct4.get("nll").unwrap().as_f64().unwrap()
    );

    // t=11s: the pressure samples have aged out of the 10s window and
    // the fleet measures fast again — but the cooldown still pins the
    // target. Recovery inside the cooldown must not bounce the model.
    clock.advance(11_000);
    for _ in 0..16 {
        fleet.telemetry().record_router(5.0);
    }
    assert!(fleet.govern_tick().is_empty(), "cooldown must block the promote");
    assert_eq!(fleet.governor().target_for("gpt2like_t0", None).as_deref(), Some(key4.as_str()));

    // t=20.5s: cooldown expired -> promote back up the frontier (the
    // 16-bit variant is still resident on A, so pre-warm is a no-op).
    clock.advance(9_500);
    for _ in 0..16 {
        fleet.telemetry().record_router(5.0);
    }
    let promote = fleet.govern_tick();
    assert_eq!(promote.len(), 1, "{promote:?}");
    assert_eq!(promote[0].action, "promote");
    assert_eq!(promote[0].to, key16);
    assert_eq!(fleet.governor().target_for("gpt2like_t0", None).as_deref(), Some(key16.as_str()));
    let routed = conn.handle(&Json::parse(&bare).unwrap());
    assert!(routed.opt("error").is_none(), "{routed:?}");
    let (mut dr, mut dw) = connect(&addr_a);
    let direct16 = roundtrip(
        &mut dr,
        &mut dw,
        &format!(r#"{{"op":"score","model":"{key16}","rows":{ROWS}}}"#),
    );
    assert_eq!(
        routed.get("rows").unwrap().dump(),
        direct16.get("rows").unwrap().dump(),
        "promoted routing must match the statically loaded 16-bit variant"
    );

    // {"op":"governor"} tells the whole story, and consecutive applied
    // migrations are separated by at least one cooldown: zero flapping.
    let status = conn.handle(&Json::parse(r#"{"op":"governor"}"#).unwrap());
    assert!(status.get("enabled").unwrap().as_bool().unwrap(), "{status:?}");
    let log = status.get("decisions").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(log.len(), 2, "{status:?}");
    let at: Vec<usize> =
        log.iter().map(|d| d.get("at_ms").unwrap().as_usize().unwrap()).collect();
    assert!(
        at.windows(2).all(|w| w[1] - w[0] >= 20_000),
        "two migrations inside one cooldown window: {at:?}"
    );
    let router_tel = status.get("telemetry").unwrap().get("router").unwrap().clone();
    assert!(router_tel.get("count").unwrap().as_usize().unwrap() >= 32, "{status:?}");

    // Live toggle through the op: disabled governors ignore pressure,
    // re-enabled ones resume governing.
    let off = conn.handle(&Json::parse(r#"{"op":"governor","disable":true}"#).unwrap());
    assert!(!off.get("enabled").unwrap().as_bool().unwrap(), "{off:?}");
    clock.advance(30_000);
    for _ in 0..16 {
        fleet.telemetry().record_router(500.0);
    }
    assert!(fleet.govern_tick().is_empty(), "disabled governor must not migrate");
    let on = conn.handle(&Json::parse(r#"{"op":"governor","enable":true}"#).unwrap());
    assert!(on.get("enabled").unwrap().as_bool().unwrap(), "{on:?}");
    assert_eq!(fleet.govern_tick().len(), 1, "re-enabled governor resumes governing");
}

#[test]
fn class_tagged_scores_resolve_the_class_frontier() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let mut policy = test_policy(manifest.tier("t0").unwrap().param_count);
    // Latency-sensitive "chat" traffic is pinned to the 4-bit entry.
    policy.classes.insert("chat".to_string(), vec![policy.entries[0].clone()]);

    let (reg_a, addr_a) = spawn_worker(None, None, None);
    let key16 =
        reg_a.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 16, None)).unwrap().key();
    let fleet = fleet_for(&[&addr_a], Some(policy));
    fleet.probe();
    let mut conn = FleetConn::new(&fleet);

    // Untagged and unknown-class bare scores fall through to the only
    // resident variant (worker-side resolution), unchanged.
    let (mut dr, mut dw) = connect(&addr_a);
    let direct16 = roundtrip(
        &mut dr,
        &mut dw,
        &format!(r#"{{"op":"score","model":"{key16}","rows":{ROWS}}}"#),
    );
    let bare = format!(r#"{{"op":"score","model":"gpt2like_t0","rows":{ROWS}}}"#);
    let untagged = conn.handle(&Json::parse(&bare).unwrap());
    assert!(untagged.opt("error").is_none(), "{untagged:?}");
    assert_eq!(untagged.get("rows").unwrap().dump(), direct16.get("rows").unwrap().dump());
    let unknown = conn.handle(
        &Json::parse(&format!(
            r#"{{"op":"score","model":"gpt2like_t0","class":"batch","rows":{ROWS}}}"#
        ))
        .unwrap(),
    );
    assert!(unknown.opt("error").is_none(), "{unknown:?}");
    assert_eq!(
        unknown.get("rows").unwrap().dump(),
        direct16.get("rows").unwrap().dump(),
        "a class without a frontier falls back to plain bare-key routing"
    );

    // A "chat"-tagged score resolves against the class frontier: the
    // router replays the 4-bit load (load-then-route) and the response
    // is bit-identical to scoring the explicit key directly.
    let tagged = conn.handle(
        &Json::parse(&format!(
            r#"{{"op":"score","model":"gpt2like_t0","class":"chat","rows":{ROWS}}}"#
        ))
        .unwrap(),
    );
    assert!(tagged.opt("error").is_none(), "{tagged:?}");
    let key4 = "gpt2like_t0@fp:4:b64";
    assert!(
        fleet.topology().snapshot()[0].resident.contains(key4),
        "class routing must load the class pick before scoring"
    );
    let direct4 =
        roundtrip(&mut dr, &mut dw, &format!(r#"{{"op":"score","model":"{key4}","rows":{ROWS}}}"#));
    assert_eq!(
        tagged.get("rows").unwrap().dump(),
        direct4.get("rows").unwrap().dump(),
        "class-frontier routing must be bit-identical to the explicit key"
    );

    // The fleet stats latency block reflects the routed scoring above,
    // and per-worker stats carry their own latency block.
    let stats = conn.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
    let router_lat = stats.get("latency").unwrap().get("router").unwrap();
    assert!(router_lat.get("count").unwrap().as_usize().unwrap() >= 3, "{stats:?}");
    let w0 = stats.get("workers").unwrap().as_arr().unwrap()[0].clone();
    assert!(
        w0.get("stats").unwrap().opt("latency").is_some(),
        "worker stats must carry a latency block: {stats:?}"
    );
}

#[test]
fn probe_policy_push_carries_class_frontiers() {
    let manifest = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let mut policy = test_policy(manifest.tier("t0").unwrap().param_count);
    policy.classes.insert("chat".to_string(), vec![policy.entries[0].clone()]);

    // The worker starts policy-less; the prober's skew-heal push must
    // deliver the classed policy, not a stripped global frontier.
    let (reg_b, addr_b) = spawn_worker(None, None, None);
    let fleet = Fleet::new(
        &manifest,
        vec![WorkerSpec::parse(&addr_b).unwrap()],
        Some(policy.clone()),
        FleetOpts {
            io_timeout: Some(Duration::from_secs(10)),
            probe_interval: Duration::from_secs(60),
            push_policy: true,
            ..FleetOpts::default()
        },
    );
    fleet.probe();
    let healed = reg_b.policy().expect("probe must push the policy to the bare worker");
    assert_eq!(
        healed.fingerprint(),
        policy.fingerprint(),
        "healed policy must round-trip class frontiers bit-for-bit"
    );
    assert_eq!(healed.classes.get("chat").map(Vec::len), Some(1));
    assert_eq!(healed.classes.get("chat").and_then(|v| v.first()).map(|e| e.bits), Some(4));
}
