//! Cross-language golden test: Rust codebooks must match the python
//! reference vectors dumped to `artifacts/codebooks.json` by `aot.py`.
//!
//! Int/fp/dynexp are deterministic constructions → bit-exact equality.
//! Quantile codebooks are estimated from RNG samples whose generators
//! differ across languages → distribution-level tolerance instead.

use kbitscale::quant::codebook::{Codebook, DataType};
use kbitscale::util::json::Json;

fn golden() -> Json {
    let text = std::fs::read_to_string("artifacts/codebooks.json")
        .expect("run `make artifacts` first");
    Json::parse(&text).unwrap()
}

#[test]
fn int_fp_dynexp_bit_exact() {
    let g = golden();
    for k in 3..=8usize {
        for (name, dtype, ebits) in [
            (format!("int_{k}"), DataType::Int, None),
            (format!("dynexp_{k}"), DataType::DynExp, None),
        ] {
            let want = g.get(&name).unwrap().f32s().unwrap();
            let got = Codebook::build(dtype, k, ebits).unwrap();
            assert_eq!(got.values(), &want[..], "{name}");
        }
        for e in 1..k - 1 {
            let name = format!("fp_{k}_e{e}");
            let want = g.get(&name).unwrap().f32s().unwrap();
            let got = Codebook::build(DataType::Fp, k, Some(e)).unwrap();
            assert_eq!(got.values().len(), want.len(), "{name} size");
            for (a, b) in got.values().iter().zip(&want) {
                assert!((a - b).abs() <= f32::EPSILON * 4.0, "{name}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn quantile_distribution_level_parity() {
    let g = golden();
    for k in 3..=8usize {
        let want = g.get(&format!("quantile_{k}")).unwrap().f32s().unwrap();
        let got = Codebook::build(DataType::Quantile, k, None).unwrap();
        assert_eq!(got.values().len(), want.len(), "k={k} size");
        // Same construction over equally-sized standard-normal samples:
        // entries agree to a few percent of the full range.
        for (i, (a, b)) in got.values().iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 0.06,
                "quantile_{k}[{i}]: rust {a} vs python {b}"
            );
        }
        // Both contain an exact zero and are normalized.
        assert!(got.values().contains(&0.0) && want.contains(&0.0));
    }
}
