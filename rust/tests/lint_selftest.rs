//! Lint self-test: every rule must flag its deliberately-violating
//! fixture and pass the clean fixtures — the lint is itself under test.
//!
//! Fixtures live in `tests/lint_fixtures/{violations,clean}/`, laid out
//! like the real source tree (`server/…`, `fleet/…`) because the rules
//! key on repo-relative paths. They are plain `.rs` files in a
//! subdirectory, so cargo never compiles them — only the lint reads them.

use std::path::Path;

use kbitscale::analysis::{lint_tree, rules, Finding};

fn fixture_root(which: &str) -> std::path::PathBuf {
    let root = Path::new("tests/lint_fixtures").join(which);
    assert!(root.is_dir(), "fixture tree missing: {} (run from rust/)", root.display());
    root
}

fn findings(which: &str) -> Vec<Finding> {
    lint_tree(&fixture_root(which)).expect("fixture tree lints").findings
}

#[track_caller]
fn assert_flags(fs: &[Finding], file: &str, rule: &str, msg_part: &str) {
    assert!(
        fs.iter().any(|f| f.file == file && f.rule == rule && f.msg.contains(msg_part)),
        "expected [{rule}] finding in {file} matching {msg_part:?}; got:\n{}",
        fs.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn every_violation_fixture_is_flagged() {
    let fs = findings("violations");

    // panic-path: all four banned patterns.
    assert_flags(&fs, "server/panic.rs", rules::RULE_PANIC, "`.unwrap()`");
    assert_flags(&fs, "server/panic.rs", rules::RULE_PANIC, "`.expect()`");
    assert_flags(&fs, "server/panic.rs", rules::RULE_PANIC, "`panic!`");
    assert_flags(&fs, "server/panic.rs", rules::RULE_PANIC, "`unreachable!`");
    assert_flags(&fs, "server/panic.rs", rules::RULE_PANIC, "unchecked slice/array index");

    // unsafe-discipline: both failure modes.
    assert_flags(&fs, "quant/unsafe_outside.rs", rules::RULE_UNSAFE, "outside the allowlisted");
    assert_flags(&fs, "runtime/mod.rs", rules::RULE_UNSAFE, "SAFETY");

    // lock-order: undeclared edge and unregistered field.
    assert_flags(&fs, "fleet/lockorder.rs", rules::RULE_LOCK, "fleet.roster -> registry.models");
    assert_flags(&fs, "fleet/lockorder.rs", rules::RULE_LOCK, "unregistered field `mystery`");

    // protocol-doc: doc/dispatch diff in both directions + bin1 sourcing.
    assert_flags(&fs, "server/mod.rs", rules::RULE_PROTOCOL, "`extra` dispatched but missing");
    assert_flags(&fs, "server/mod.rs", rules::RULE_PROTOCOL, "`ghost` documented but not dispatched");
    assert_flags(&fs, "server/frames_misuse.rs", rules::RULE_PROTOCOL, "magic literal");
    assert_flags(&fs, "server/frames_misuse.rs", rules::RULE_PROTOCOL, "layout constant redefined");

    // lint-allow: the escape hatch is itself linted, and a malformed
    // annotation never suppresses.
    assert_flags(&fs, "server/bad_allow.rs", rules::RULE_ALLOW, "unknown rule `made-up-rule`");
    assert_flags(&fs, "server/bad_allow.rs", rules::RULE_ALLOW, "carries no justification");
    assert_flags(&fs, "server/bad_allow.rs", rules::RULE_PANIC, "`.unwrap()`");
}

#[test]
fn every_violation_file_fails_on_its_own() {
    let root = fixture_root("violations");
    let report = lint_tree(&root).expect("tree lints");
    let mut flagged: Vec<&str> = report.findings.iter().map(|f| f.file.as_str()).collect();
    flagged.sort_unstable();
    flagged.dedup();
    assert_eq!(
        flagged,
        vec![
            "fleet/lockorder.rs",
            "quant/unsafe_outside.rs",
            "runtime/mod.rs",
            "server/bad_allow.rs",
            "server/frames_misuse.rs",
            "server/mod.rs",
            "server/panic.rs",
        ],
        "every violation fixture must produce at least one finding"
    );
}

#[test]
fn clean_fixtures_pass() {
    let report = lint_tree(&fixture_root("clean")).expect("clean tree lints");
    assert!(
        report.clean(),
        "clean fixtures flagged:\n{}",
        report.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
    assert_eq!(report.allows, 1, "the justified allow in handlers.rs is counted");
}

#[test]
fn cli_exit_status_matches_findings() {
    let lint = |path: &Path| {
        kbitscale::cli::main_with_args(vec![
            "lint".to_string(),
            "--path".to_string(),
            path.display().to_string(),
        ])
    };
    assert!(lint(&fixture_root("violations")).is_err(), "violations must exit nonzero");
    assert!(lint(&fixture_root("clean")).is_ok(), "clean tree must exit zero");
}

/// The real source tree lints clean — the exact invariant the blocking
/// CI step (`kbitscale lint`) enforces, pinned here too so a plain
/// `cargo test` catches a regression before CI does.
#[test]
fn real_tree_lints_clean() {
    let root = Path::new("src");
    assert!(root.join("lib.rs").exists(), "run from rust/ (cargo does)");
    let report = lint_tree(root).expect("source tree lints");
    assert!(
        report.clean(),
        "source tree has lint findings:\n{}",
        report.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}
