//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they are the cross-layer
//! correctness gate (L2 graphs behave as the Rust side assumes: argument
//! order, output arity, masking semantics, kernel numerics).

use std::path::Path;

use kbitscale::data::corpus::{Corpus, CorpusConfig};
use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::{Codebook, DataType};
use kbitscale::runtime::{lit_f32, lit_i32, lit_u8, to_vec_f32, Runtime};
use kbitscale::tensor::Tensor;
use kbitscale::util::rng::Rng;

fn setup() -> (Manifest, Runtime) {
    let manifest = Manifest::load(Path::new("artifacts"))
        .expect("artifacts missing — run `make artifacts` before `cargo test`");
    (manifest, Runtime::cpu().unwrap())
}

fn corpus(m: &Manifest) -> Corpus {
    Corpus::new(CorpusConfig { vocab: m.vocab, seq: m.seq, ..CorpusConfig::default() })
}

#[test]
fn fwd_graph_shapes_and_masking() {
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    let exe = rt.load(&m.hlo_path(&tier.fwd_hlo)).unwrap();
    let params = init_params(tier, Family::get("gpt2like").unwrap());

    let b = tier.batch_eval;
    let s = tier.seq;
    let c = corpus(&m);
    let tokens = c.train_batch(0, b);

    // Full mask vs half mask: NLL must shrink accordingly and stay finite.
    let mut full = vec![1.0f32; b * s];
    for r in 0..b {
        full[r * s] = 0.0; // BOS is never a target
    }
    let mut half = full.clone();
    for r in 0..b {
        for i in s / 2..s {
            half[r * s + i] = 0.0;
        }
    }
    let run = |mask: &[f32]| {
        let mut args: Vec<xla::Literal> = params.iter().map(|(_, t)| lit_f32(t).unwrap()).collect();
        args.push(lit_i32(&[b, s], &tokens).unwrap());
        args.push(lit_f32(&Tensor::new(vec![b, s], mask.to_vec())).unwrap());
        let out = rt.execute(&exe, &args).unwrap();
        assert_eq!(out.len(), 2);
        (to_vec_f32(&out[0]).unwrap(), to_vec_f32(&out[1]).unwrap())
    };
    let (nll_full, hits_full) = run(&full);
    let (nll_half, _) = run(&half);
    assert_eq!(nll_full.len(), b);
    for r in 0..b {
        assert!(nll_full[r].is_finite() && nll_full[r] > 0.0);
        assert!(nll_half[r] < nll_full[r], "masking must reduce NLL sum");
        assert!(hits_full[r] >= 0.0 && hits_full[r] <= (s - 1) as f32);
    }
    // Untrained model ≈ uniform: per-token NLL near ln(V).
    let per_tok = nll_full.iter().sum::<f32>() / (b * (s - 1)) as f32;
    let uniform = (m.vocab as f32).ln();
    assert!((per_tok - uniform).abs() < 1.0, "per-token NLL {per_tok} vs ln V {uniform}");
}

#[test]
fn train_graph_reduces_loss() {
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    let exe = rt.load(&m.hlo_path(&tier.train_hlo)).unwrap();
    let family = Family::get("gpt2like").unwrap();
    let mut params: Vec<Tensor> =
        init_params(tier, family).into_iter().map(|(_, t)| t).collect();
    let mut mstate: Vec<Tensor> =
        tier.params.iter().map(|p| Tensor::zeros(p.shape.clone())).collect();
    let mut vstate = mstate.clone();
    let c = corpus(&m);
    let n = tier.params.len();

    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..30 {
        let tokens = c.train_batch(step, tier.batch_train);
        let mut args: Vec<xla::Literal> = Vec::new();
        for t in params.iter().chain(&mstate).chain(&vstate) {
            args.push(lit_f32(t).unwrap());
        }
        args.push(lit_i32(&[tier.batch_train, tier.seq], &tokens).unwrap());
        args.push(xla::Literal::scalar(3e-3f32));
        args.push(xla::Literal::scalar((step + 1) as f32));
        let out = rt.execute(&exe, &args).unwrap();
        assert_eq!(out.len(), 3 * n + 1);
        for (i, p) in tier.params.iter().enumerate() {
            params[i] = Tensor::new(p.shape.clone(), to_vec_f32(&out[i]).unwrap());
            mstate[i] = Tensor::new(p.shape.clone(), to_vec_f32(&out[n + i]).unwrap());
            vstate[i] = Tensor::new(p.shape.clone(), to_vec_f32(&out[2 * n + i]).unwrap());
        }
        last = to_vec_f32(&out[3 * n]).unwrap()[0];
        assert!(last.is_finite());
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first - 0.05, "loss did not fall: {first} -> {last}");
}

#[test]
fn fused_dequant_kernel_matches_rust_reference() {
    let (m, rt) = setup();
    let km = &m.kernels;
    let (mm, k, n, qb) = (km.m, km.k, km.n, km.qblock);
    let mut rng = Rng::new(9);
    let mut x = vec![0.0f32; mm * k];
    let mut w = vec![0.0f32; k * n];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut w, 0.1);

    for dtype in [DataType::Int, DataType::Fp, DataType::Quantile, DataType::DynExp] {
        let cb = Codebook::build(dtype, 4, None).unwrap();
        let mut idx = vec![0u8; k * n];
        let mut amax = vec![0.0f32; (k / qb) * n];
        for c in 0..n {
            for b in 0..k / qb {
                let mut a = 0.0f32;
                for r in b * qb..(b + 1) * qb {
                    a = a.max(w[r * n + c].abs());
                }
                let a = if a == 0.0 { 1.0 } else { a };
                amax[b * n + c] = a;
                for r in b * qb..(b + 1) * qb {
                    idx[r * n + c] = cb.assign(w[r * n + c] / a);
                }
            }
        }
        let exe = rt.load(&m.hlo_path(&km.u8_hlo)).unwrap();
        let args = vec![
            lit_f32(&Tensor::new(vec![mm, k], x.clone())).unwrap(),
            lit_u8(&[k, n], &idx).unwrap(),
            lit_f32(&Tensor::new(vec![k / qb, n], amax.clone())).unwrap(),
            lit_f32(&Tensor::new(vec![km.codebook_pad], cb.padded_values(km.codebook_pad)))
                .unwrap(),
        ];
        let got = to_vec_f32(&rt.execute(&exe, &args).unwrap()[0]).unwrap();
        // Rust-side reference dequant + matmul (f64 accumulation).
        let mut max_err = 0.0f32;
        for i in 0..mm {
            for c in 0..n {
                let mut acc = 0.0f64;
                for r in 0..k {
                    let dq = cb.value(idx[r * n + c]) * amax[(r / qb) * n + c];
                    acc += x[i * k + r] as f64 * dq as f64;
                }
                max_err = max_err.max((got[i * n + c] - acc as f32).abs());
            }
        }
        assert!(max_err < 2e-2, "{dtype:?}: fused kernel err {max_err}");
    }
}

#[test]
fn acts_graph_returns_layer_inputs() {
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    let Some(acts_hlo) = tier.acts_hlo.as_ref() else {
        panic!("manifest missing acts graph; rerun make artifacts");
    };
    let exe = rt.load(&m.hlo_path(acts_hlo)).unwrap();
    let params = init_params(tier, Family::get("gpt2like").unwrap());
    let c = corpus(&m);
    let tokens = c.train_batch(1, tier.batch_eval);
    let mut args: Vec<xla::Literal> = params.iter().map(|(_, t)| lit_f32(t).unwrap()).collect();
    args.push(lit_i32(&[tier.batch_eval, tier.seq], &tokens).unwrap());
    let out = rt.execute(&exe, &args).unwrap();
    assert_eq!(out.len(), 4);
    let rows = tier.batch_eval * tier.seq;
    let want = [
        tier.n_layer * rows * tier.d_model, // qkv_in
        tier.n_layer * rows * tier.d_model, // wo_in
        tier.n_layer * rows * tier.d_model, // fc1_in
        tier.n_layer * rows * tier.d_ff,    // fc2_in
    ];
    for (i, leaf) in out.iter().enumerate() {
        let v = to_vec_f32(leaf).unwrap();
        assert_eq!(v.len(), want[i], "acts output {i}");
        assert!(v.iter().all(|x| x.is_finite()));
        // LayerNormed inputs have ~unit scale.
        if i == 0 {
            let rms = (v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
                / v.len() as f64)
                .sqrt();
            assert!(rms > 0.3 && rms < 3.0, "qkv_in rms {rms}");
        }
    }
}
