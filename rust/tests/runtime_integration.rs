//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they are the cross-layer
//! correctness gate (L2 graphs behave as the Rust side assumes: argument
//! order, output arity, masking semantics, kernel numerics).

use std::path::Path;
use std::sync::Arc;

use kbitscale::data::corpus::{Corpus, CorpusConfig};
use kbitscale::eval::Evaluator;
use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::{Codebook, DataType};
use kbitscale::runtime::{lit_f32, lit_i32, lit_u8, to_vec_f32, Runtime};
use kbitscale::tensor::Tensor;
use kbitscale::util::rng::Rng;

fn setup() -> (Manifest, Runtime) {
    let manifest = Manifest::load(Path::new("artifacts"))
        .expect("artifacts missing — run `make artifacts` before `cargo test`");
    (manifest, Runtime::cpu().unwrap())
}

fn corpus(m: &Manifest) -> Corpus {
    Corpus::new(CorpusConfig { vocab: m.vocab, seq: m.seq, ..CorpusConfig::default() })
}

#[test]
fn fwd_graph_shapes_and_masking() {
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    let exe = rt.load(&m.hlo_path(&tier.fwd_hlo)).unwrap();
    let params = init_params(tier, Family::get("gpt2like").unwrap());

    let b = tier.batch_eval;
    let s = tier.seq;
    let c = corpus(&m);
    let tokens = c.train_batch(0, b);

    // Full mask vs half mask: NLL must shrink accordingly and stay finite.
    let mut full = vec![1.0f32; b * s];
    for r in 0..b {
        full[r * s] = 0.0; // BOS is never a target
    }
    let mut half = full.clone();
    for r in 0..b {
        for i in s / 2..s {
            half[r * s + i] = 0.0;
        }
    }
    let run = |mask: &[f32]| {
        let mut args: Vec<xla::Literal> = params.iter().map(|(_, t)| lit_f32(t).unwrap()).collect();
        args.push(lit_i32(&[b, s], &tokens).unwrap());
        args.push(lit_f32(&Tensor::new(vec![b, s], mask.to_vec())).unwrap());
        let out = rt.execute(&exe, &args).unwrap();
        assert_eq!(out.len(), 2);
        (to_vec_f32(&out[0]).unwrap(), to_vec_f32(&out[1]).unwrap())
    };
    let (nll_full, hits_full) = run(&full);
    let (nll_half, _) = run(&half);
    assert_eq!(nll_full.len(), b);
    for r in 0..b {
        assert!(nll_full[r].is_finite() && nll_full[r] > 0.0);
        assert!(nll_half[r] < nll_full[r], "masking must reduce NLL sum");
        assert!(hits_full[r] >= 0.0 && hits_full[r] <= (s - 1) as f32);
    }
    // Untrained model ≈ uniform: per-token NLL near ln(V).
    let per_tok = nll_full.iter().sum::<f32>() / (b * (s - 1)) as f32;
    let uniform = (m.vocab as f32).ln();
    assert!((per_tok - uniform).abs() < 1.0, "per-token NLL {per_tok} vs ln V {uniform}");
}

#[test]
fn single_stage_plan_matches_direct_executable_path() {
    // The ExecutionPlan refactor's parity gate: scoring through the
    // degenerate single-stage plan must be **bit-identical** to the
    // pre-plan direct-executable path (same artifact, same literals, same
    // deterministic CPU execution) on a fixed seed tier.
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    let params = init_params(tier, Family::get("gpt2like").unwrap());
    let c = corpus(&m);
    let (b, s) = (tier.batch_eval, tier.seq);
    let seqs = c.eval_sequences(5);
    let rows: Vec<(Vec<i32>, Vec<f32>)> = seqs.iter().map(|sq| c.pad_to_seq(sq)).collect();

    // The pre-refactor path, inlined: one monolithic executable, one
    // hand-padded batch of (params..., tokens, mask).
    let exe = rt.load(&m.hlo_path(&tier.fwd_hlo)).unwrap();
    let mut tokens = vec![0i32; b * s];
    let mut mask = vec![0.0f32; b * s];
    for (r, (t, mk)) in rows.iter().enumerate() {
        tokens[r * s..(r + 1) * s].copy_from_slice(t);
        mask[r * s..(r + 1) * s].copy_from_slice(mk);
    }
    let mut args: Vec<xla::Literal> =
        params.iter().map(|(_, t)| lit_f32(t).unwrap()).collect();
    args.push(lit_i32(&[b, s], &tokens).unwrap());
    args.push(lit_f32(&Tensor::new(vec![b, s], mask)).unwrap());
    let out = rt.execute(&exe, &args).unwrap();
    let nll = to_vec_f32(&out[0]).unwrap();
    let hits = to_vec_f32(&out[1]).unwrap();

    // The plan path (what every caller uses now).
    let ev = Evaluator::new(&rt, &m, tier).unwrap();
    assert!(ev.plan().layout.is_monolithic());
    let plits = ev.param_literals(&params).unwrap();
    let scored = ev.score_padded_rows(&plits, &rows).unwrap();
    assert_eq!(scored.len(), rows.len());
    for (r, &(p_nll, p_hits)) in scored.iter().enumerate() {
        assert_eq!(p_nll, nll[r] as f64, "row {r}: plan NLL diverged from direct path");
        assert_eq!(p_hits, hits[r] as f64, "row {r}: plan hits diverged from direct path");
    }
}

#[test]
fn pipeline_plan_scores_match_monolithic() {
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    if tier.stages.is_empty() {
        eprintln!("skipping: artifacts predate pipeline stages (rerun make artifacts)");
        return;
    }
    let params = init_params(tier, Family::get("gpt2like").unwrap());
    let c = corpus(&m);
    let seqs = c.eval_sequences(4);
    let rows: Vec<(Vec<i32>, Vec<f32>)> = seqs.iter().map(|sq| c.pad_to_seq(sq)).collect();

    let mono = Evaluator::new(&rt, &m, tier).unwrap();
    let piped = Evaluator::with_plan(&rt, &m, tier, true).unwrap();
    assert_eq!(piped.plan().layout.n_stages(), 2);
    let mono_scores =
        mono.score_padded_rows(&mono.param_literals(&params).unwrap(), &rows).unwrap();
    let pipe_scores =
        piped.score_padded_rows(&piped.param_literals(&params).unwrap(), &rows).unwrap();
    for (r, (a, b)) in mono_scores.iter().zip(&pipe_scores).enumerate() {
        let rel = (a.0 - b.0).abs() / a.0.abs().max(1.0);
        assert!(rel < 1e-4, "row {r}: staged NLL {} vs monolithic {}", b.0, a.0);
        // Greedy argmax can only flip on a numeric near-tie; allow one.
        assert!((a.1 - b.1).abs() <= 1.0, "row {r}: hits {} vs {}", b.1, a.1);
    }
}

#[test]
fn runtime_load_is_single_flight_and_shared() {
    let (m, rt) = setup();
    let path = m.hlo_path(&m.tier("t0").unwrap().fwd_hlo);
    assert_eq!(rt.cached_executables(), 0);
    let handles: Vec<_> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..6).map(|_| s.spawn(|| rt.load(&path).unwrap())).collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    // All racers share the winner's executable: without single-flight,
    // concurrent cache misses each compile and return distinct Arcs.
    for h in &handles[1..] {
        assert!(Arc::ptr_eq(&handles[0], h), "racing loads must share one executable");
    }
    assert_eq!(rt.cached_executables(), 1);
}

#[test]
fn train_graph_reduces_loss() {
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    let exe = rt.load(&m.hlo_path(&tier.train_hlo)).unwrap();
    let family = Family::get("gpt2like").unwrap();
    let mut params: Vec<Tensor> =
        init_params(tier, family).into_iter().map(|(_, t)| t).collect();
    let mut mstate: Vec<Tensor> =
        tier.params.iter().map(|p| Tensor::zeros(p.shape.clone())).collect();
    let mut vstate = mstate.clone();
    let c = corpus(&m);
    let n = tier.params.len();

    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..30 {
        let tokens = c.train_batch(step, tier.batch_train);
        let mut args: Vec<xla::Literal> = Vec::new();
        for t in params.iter().chain(&mstate).chain(&vstate) {
            args.push(lit_f32(t).unwrap());
        }
        args.push(lit_i32(&[tier.batch_train, tier.seq], &tokens).unwrap());
        args.push(xla::Literal::scalar(3e-3f32));
        args.push(xla::Literal::scalar((step + 1) as f32));
        let out = rt.execute(&exe, &args).unwrap();
        assert_eq!(out.len(), 3 * n + 1);
        for (i, p) in tier.params.iter().enumerate() {
            params[i] = Tensor::new(p.shape.clone(), to_vec_f32(&out[i]).unwrap());
            mstate[i] = Tensor::new(p.shape.clone(), to_vec_f32(&out[n + i]).unwrap());
            vstate[i] = Tensor::new(p.shape.clone(), to_vec_f32(&out[2 * n + i]).unwrap());
        }
        last = to_vec_f32(&out[3 * n]).unwrap()[0];
        assert!(last.is_finite());
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first - 0.05, "loss did not fall: {first} -> {last}");
}

#[test]
fn fused_dequant_kernel_matches_rust_reference() {
    let (m, rt) = setup();
    let km = &m.kernels;
    let (mm, k, n, qb) = (km.m, km.k, km.n, km.qblock);
    let mut rng = Rng::new(9);
    let mut x = vec![0.0f32; mm * k];
    let mut w = vec![0.0f32; k * n];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut w, 0.1);

    for dtype in [DataType::Int, DataType::Fp, DataType::Quantile, DataType::DynExp] {
        let cb = Codebook::build(dtype, 4, None).unwrap();
        let mut idx = vec![0u8; k * n];
        let mut amax = vec![0.0f32; (k / qb) * n];
        for c in 0..n {
            for b in 0..k / qb {
                let mut a = 0.0f32;
                for r in b * qb..(b + 1) * qb {
                    a = a.max(w[r * n + c].abs());
                }
                let a = if a == 0.0 { 1.0 } else { a };
                amax[b * n + c] = a;
                for r in b * qb..(b + 1) * qb {
                    idx[r * n + c] = cb.assign(w[r * n + c] / a);
                }
            }
        }
        let exe = rt.load(&m.hlo_path(&km.u8_hlo)).unwrap();
        let args = vec![
            lit_f32(&Tensor::new(vec![mm, k], x.clone())).unwrap(),
            lit_u8(&[k, n], &idx).unwrap(),
            lit_f32(&Tensor::new(vec![k / qb, n], amax.clone())).unwrap(),
            lit_f32(&Tensor::new(vec![km.codebook_pad], cb.padded_values(km.codebook_pad)))
                .unwrap(),
        ];
        let got = to_vec_f32(&rt.execute(&exe, &args).unwrap()[0]).unwrap();
        // Rust-side reference dequant + matmul (f64 accumulation).
        let mut max_err = 0.0f32;
        for i in 0..mm {
            for c in 0..n {
                let mut acc = 0.0f64;
                for r in 0..k {
                    let dq = cb.value(idx[r * n + c]) * amax[(r / qb) * n + c];
                    acc += x[i * k + r] as f64 * dq as f64;
                }
                max_err = max_err.max((got[i * n + c] - acc as f32).abs());
            }
        }
        assert!(max_err < 2e-2, "{dtype:?}: fused kernel err {max_err}");
    }
}

#[test]
fn acts_graph_returns_layer_inputs() {
    let (m, rt) = setup();
    let tier = m.tier("t0").unwrap();
    let Some(acts_hlo) = tier.acts_hlo.as_ref() else {
        panic!("manifest missing acts graph; rerun make artifacts");
    };
    let exe = rt.load(&m.hlo_path(acts_hlo)).unwrap();
    let params = init_params(tier, Family::get("gpt2like").unwrap());
    let c = corpus(&m);
    let tokens = c.train_batch(1, tier.batch_eval);
    let mut args: Vec<xla::Literal> = params.iter().map(|(_, t)| lit_f32(t).unwrap()).collect();
    args.push(lit_i32(&[tier.batch_eval, tier.seq], &tokens).unwrap());
    let out = rt.execute(&exe, &args).unwrap();
    assert_eq!(out.len(), 4);
    let rows = tier.batch_eval * tier.seq;
    let want = [
        tier.n_layer * rows * tier.d_model, // qkv_in
        tier.n_layer * rows * tier.d_model, // wo_in
        tier.n_layer * rows * tier.d_model, // fc1_in
        tier.n_layer * rows * tier.d_ff,    // fc2_in
    ];
    for (i, leaf) in out.iter().enumerate() {
        let v = to_vec_f32(leaf).unwrap();
        assert_eq!(v.len(), want[i], "acts output {i}");
        assert!(v.iter().all(|x| x.is_finite()));
        // LayerNormed inputs have ~unit scale.
        if i == 0 {
            let rms = (v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
                / v.len() as f64)
                .sqrt();
            assert!(rms > 0.3 && rms < 3.0, "qkv_in rms {rms}");
        }
    }
}
