//! Deterministic fuzz harness for the serving surface's parsers: the
//! bin1 frame codec ([`kbitscale::server::frames`]), the line
//! protocol loop ([`kbitscale::server::pump`]), the artifact manifest
//! parser ([`Manifest::load`]), the packed k-bit bitstream decoders
//! ([`PackedTensor`] / [`kbitscale::quant::fused`]), and the
//! entropy-coded residency decoders ([`kbitscale::quant::entropy`]:
//! Huffman tables from untrusted length lists, hostile
//! [`EncodedTensor`] field combinations, corrupted coded streams).
//!
//! The invariant under test is uniform: **error, not panic**. Every
//! input — structured-random, bit-mutated, truncated, or hostile
//! hand-built — must come back as `Ok`/`Err`; a panic anywhere fails the
//! test. All randomness flows from [`Rng`] with fixed seeds (forked per
//! case), so a failure reproduces bit-for-bit from the case index and
//! the whole budget stays bounded (seconds, well inside the CI timeout).

use std::io::Cursor;
use std::path::PathBuf;

use kbitscale::models::manifest::Manifest;
use kbitscale::quant::entropy::{Coding, EncodedTensor, HuffTable, MAX_CODE_LEN, SEGMENT_LEN};
use kbitscale::quant::{fused, DataType, PackedTensor, QuantSpec};
use kbitscale::server::{frames, pump, Emit, EmitSink, MAX_REQUEST_LINE};
use kbitscale::util::json::Json;
use kbitscale::util::rng::Rng;

/// Master seed; every test forks its own stream from a distinct tag.
const SEED: u64 = 0x4b42_4954_5343_414c; // "KBITSCAL"

// ---------------------------------------------------------------------------
// Shared builders
// ---------------------------------------------------------------------------

/// A score-chunk line shaped like `score_chunk` emits (only the fields
/// the codec reads: derived `ce`/`ppl` are reconstructed on decode).
fn chunk_line(chunk: usize, first_row: usize, rows: &[(f64, f64, u32)]) -> Json {
    let rows_json = rows
        .iter()
        .map(|&(nll, hits, ntok)| {
            Json::obj(vec![
                ("nll", Json::num(nll)),
                ("greedy_hits", Json::num(hits)),
                ("tokens_scored", Json::num(ntok as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("chunk", Json::num(chunk as f64)),
        ("first_row", Json::num(first_row as f64)),
        ("rows", Json::Arr(rows_json)),
    ])
}

/// Encode a 3-row frame: 6 header + 12 prefix + 3 x 20 row bytes.
fn valid_frame() -> Vec<u8> {
    let line = chunk_line(7, 40, &[(1.25, 3.0, 16), (0.5, 8.0, 16), (2.0, 0.0, 9)]);
    let mut buf = Vec::new();
    frames::encode_chunk_into(&line, &mut buf).expect("valid line encodes");
    assert_eq!(buf.len(), frames::HEADER_BYTES + frames::PREFIX_BYTES + 3 * frames::ROW_BYTES);
    buf
}

/// Run every frame decoder over one buffer; all must return (not panic).
/// Returns true if any accepted the buffer.
fn poke_frame_decoders(buf: &[u8]) -> bool {
    let a = frames::decode_chunk(buf).is_ok();
    let b = frames::chunk_header(buf).is_ok();
    let c = frames::rows_nll_tok(buf).is_ok();
    let mut copy = buf.to_vec();
    let d = frames::patch_header(&mut copy, 1, 2).is_ok();
    let mut out = Vec::new();
    let e = frames::read_frame(&mut Cursor::new(buf), &mut out).is_ok();
    a || b || c || d || e
}

// ---------------------------------------------------------------------------
// bin1 frame codec
// ---------------------------------------------------------------------------

/// Satellite pin: a frame cut at EVERY byte boundary — including each
/// header field edge and each of the 20-byte row edges (with the f64/f64/
/// u32 field edges inside a row) — is an error from every decoder, and
/// the unmodified frame round-trips.
#[test]
fn frame_truncation_at_every_boundary() {
    let frame = valid_frame();

    // The named boundaries first (documentation of the wire layout):
    // magic | version | payload-len | chunk | first_row | nrows | rows…
    let h = frames::HEADER_BYTES;
    let p = frames::PREFIX_BYTES;
    let r = frames::ROW_BYTES;
    let mut pinned = vec![0, 1, 2, h, h + 4, h + 8, h + p];
    for row in 0..3 {
        let base = h + p + row * r;
        pinned.extend([base + 8, base + 16, base + r]);
    }
    pinned.pop(); // the last edge is the full frame, which must succeed
    for &cut in &pinned {
        assert!(cut < frame.len());
        assert!(
            !poke_frame_decoders(&frame[..cut]),
            "decoder accepted a frame truncated at pinned boundary {cut}"
        );
    }

    // Then exhaustively: every proper prefix fails, the full frame parses.
    for cut in 0..frame.len() {
        assert!(
            !poke_frame_decoders(&frame[..cut]),
            "decoder accepted a frame truncated at byte {cut}"
        );
    }
    let decoded = frames::decode_chunk(&frame).expect("full frame decodes");
    assert_eq!(decoded.get("chunk").unwrap().as_usize().unwrap(), 7);
    assert_eq!(decoded.get("first_row").unwrap().as_usize().unwrap(), 40);
    assert_eq!(decoded.get("rows").unwrap().as_arr().unwrap().len(), 3);
    let (nll, tok, nrows) = frames::rows_nll_tok(&frame).expect("full frame sums");
    assert_eq!((nll, tok, nrows), (3.75, 41.0, 3));
}

/// Satellite pin: `first_row`/`chunk` at the top of the u32 range
/// survive the renumbering path (the router's overflow guard is upstream
/// of the codec; the codec itself must be exact at the boundary).
#[test]
fn oversized_first_row_offsets_round_trip() {
    let line = chunk_line(u32::MAX as usize, u32::MAX as usize - 3, &[(0.25, 1.0, 4)]);
    let mut frame = Vec::new();
    frames::encode_chunk_into(&line, &mut frame).expect("u32::MAX fields encode");
    frames::patch_header(&mut frame, u32::MAX - 1, u32::MAX).expect("patch at u32 boundary");
    let (chunk, first_row, nrows) = frames::chunk_header(&frame).expect("header reads back");
    assert_eq!((chunk, first_row, nrows), (u32::MAX - 1, u32::MAX, 1));

    // One past the wire range is an encode-side error, not a wrap.
    let over = chunk_line(u32::MAX as usize + 1, 0, &[(0.25, 1.0, 4)]);
    assert!(frames::encode_chunk_into(&over, &mut frame).is_err());
    let over = chunk_line(0, u32::MAX as usize + 1, &[(0.25, 1.0, 4)]);
    assert!(frames::encode_chunk_into(&over, &mut frame).is_err());
}

#[test]
fn frame_bit_flips_never_panic() {
    let frame = valid_frame();
    let mut rng = Rng::new(SEED).fork(1);
    let mut accepted = 0usize;
    for case in 0..600 {
        let mut r = rng.fork(case);
        let mut buf = frame.clone();
        for _ in 0..1 + r.below(4) {
            let bit = r.below(buf.len() * 8);
            buf[bit / 8] ^= 1 << (bit % 8);
        }
        if poke_frame_decoders(&buf) {
            accepted += 1;
        }
    }
    // Flips confined to the float payload still parse; flips in the
    // header do not. Both outcomes must occur across the budget or the
    // mutator is not exercising the codec.
    assert!(accepted > 0, "no mutated frame parsed: mutator too destructive");
    assert!(accepted < 600, "every mutated frame parsed: mutator inert");
}

#[test]
fn random_buffers_never_panic() {
    let mut rng = Rng::new(SEED).fork(2);
    for case in 0..2000 {
        let mut r = rng.fork(case);
        let len = r.below(96);
        let mut buf: Vec<u8> = (0..len).map(|_| r.next_u64() as u8).collect();
        // Half the cases get a valid magic/version prologue so the fuzz
        // reaches the length-field and row-count checks behind it.
        if r.below(2) == 0 && buf.len() >= 2 {
            buf[0] = frames::MAGIC;
            buf[1] = frames::VERSION;
        }
        poke_frame_decoders(&buf);
    }
}

/// `read_frame` against a lying length field: the header promises more
/// payload than the stream carries, or more than [`frames::MAX_PAYLOAD`].
#[test]
fn read_frame_hostile_lengths() {
    // Payload length beyond the sanity cap is rejected before allocation.
    let mut head = vec![frames::MAGIC, frames::VERSION];
    head.extend_from_slice(&(frames::MAX_PAYLOAD as u32 + 1).to_le_bytes());
    let mut buf = Vec::new();
    assert!(frames::read_frame(&mut Cursor::new(&head), &mut buf).is_err());

    // In-range length, truncated stream: error from read_exact, no hang.
    let mut head = vec![frames::MAGIC, frames::VERSION];
    head.extend_from_slice(&1024u32.to_le_bytes());
    head.extend_from_slice(&[0u8; 64]);
    assert!(frames::read_frame(&mut Cursor::new(&head), &mut buf).is_err());

    // Payload shorter than the fixed prefix is rejected up front.
    let mut head = vec![frames::MAGIC, frames::VERSION];
    head.extend_from_slice(&((frames::PREFIX_BYTES - 1) as u32).to_le_bytes());
    head.extend_from_slice(&[0u8; 32]);
    assert!(frames::read_frame(&mut Cursor::new(&head), &mut buf).is_err());
}

// ---------------------------------------------------------------------------
// Line-protocol loop (server::pump)
// ---------------------------------------------------------------------------

/// Stub handler: answers `{"ok":true}`, and for `op=stream` first emits
/// one chunk line through the sink (exercising the negotiated frame
/// encoding on the write side).
fn stub_handle(req: &Json, sink: &mut EmitSink<'_>) -> Json {
    if req.opt("op").and_then(|v| v.as_str().ok()) == Some("stream") {
        let line = chunk_line(0, 0, &[(0.75, 2.0, 8)]);
        if let Err(e) = sink(Emit::Line(&line)) {
            return Json::obj(vec![("error", Json::str(format!("{e:#}")))]);
        }
    }
    Json::obj(vec![("ok", Json::Bool(true))])
}

/// Run `pump` over one input script; malformed lines must surface as
/// per-line error responses, never as an `Err` (reserved for transport
/// I/O) and never as a panic.
fn run_pump(input: Vec<u8>) -> (u64, Vec<u8>) {
    let mut out = Vec::new();
    let served = pump(stub_handle, Cursor::new(input), &mut out)
        .expect("pump survives hostile input (Err is for transport I/O only)");
    (served, out)
}

#[test]
fn pump_hostile_line_scripts() {
    // Hand-picked corners first: oversized line, invalid UTF-8, bare
    // frame bytes where a JSON line belongs, hello followed by garbage.
    let mut input = Vec::new();
    input.extend_from_slice(b"{\"op\":\"ping\"}\n");
    input.extend_from_slice(&vec![b'a'; MAX_REQUEST_LINE + 10]);
    input.push(b'\n');
    input.extend_from_slice(&[0xFF, 0xFE, 0xB1, 0x00, b'\n']);
    input.extend_from_slice(&valid_frame()); // frames are response-side only
    input.push(b'\n');
    input.extend_from_slice(b"{\"op\":\"hello\",\"frames\":\"bin1\"}\n");
    input.extend_from_slice(b"not json at all\n");
    input.extend_from_slice(b"{\"op\":\"stream\"}\n");
    let (served, out) = run_pump(input);
    assert!(served >= 6, "every non-empty line gets a response, got {served}");
    let text = String::from_utf8_lossy(&out);
    assert!(text.contains("exceeds"), "oversized line must be refused: {text}");
    assert!(text.contains("bad request"), "unparseable lines must error: {text}");
}

#[test]
fn pump_random_line_scripts_never_panic() {
    let mut rng = Rng::new(SEED).fork(3);
    for case in 0..250 {
        let mut r = rng.fork(case);
        let mut input = Vec::new();
        for _ in 0..1 + r.below(8) {
            match r.below(5) {
                // Random bytes (often invalid UTF-8 / unterminated JSON).
                0 => {
                    let len = r.below(64);
                    input.extend((0..len).map(|_| r.next_u64() as u8));
                }
                // A mutated valid request line.
                1 => {
                    let mut line = b"{\"op\":\"stream\",\"rows\":[[1,2],[3]]}".to_vec();
                    let bit = r.below(line.len() * 8);
                    line[bit / 8] ^= 1 << (bit % 8);
                    input.extend_from_slice(&line);
                }
                // Frame negotiation, valid and mutated.
                2 => input.extend_from_slice(b"{\"op\":\"hello\",\"frames\":\"bin1\"}"),
                3 => input.extend_from_slice(b"{\"op\":\"hello\",\"frames\":\"b1n1\"}"),
                // Deep-ish nesting and stray control bytes.
                _ => {
                    input.extend_from_slice(b"{\"a\":[[[[[\"x\"]]]]],\"b\":");
                    input.push(r.next_u64() as u8);
                    input.push(b'}');
                }
            }
            input.push(b'\n');
        }
        run_pump(input);
    }
}

/// With bin1 negotiated, the sink's chunk line crosses the wire as one
/// frame that decodes back to the exact line; without negotiation it
/// stays JSON. Pins the encode side of the codec inside the real loop.
#[test]
fn pump_bin1_roundtrip() {
    let (_, out) = run_pump(b"{\"op\":\"hello\",\"frames\":\"bin1\"}\n{\"op\":\"stream\"}\n".to_vec());
    let first_nl = out.iter().position(|&b| b == b'\n').expect("hello reply line");
    let rest = &out[first_nl + 1..];
    assert_eq!(rest.first(), Some(&frames::MAGIC), "chunk must be framed after bin1 hello");
    let mut frame = Vec::new();
    frames::read_frame(&mut Cursor::new(rest), &mut frame).expect("frame reads");
    let chunk = frames::decode_chunk(&frame).expect("frame decodes");
    let rows = chunk.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].get("nll").unwrap().as_f64().unwrap(), 0.75);

    let (_, out) = run_pump(b"{\"op\":\"stream\"}\n".to_vec());
    assert_eq!(out.first(), Some(&b'{'), "without hello the chunk stays JSON");
}

// ---------------------------------------------------------------------------
// Manifest parser
// ---------------------------------------------------------------------------

/// Scoped temp dir (same idiom as the manifest unit tests); removed on
/// drop so fuzz runs leave nothing behind.
struct TempDirGuard {
    path: PathBuf,
}

impl Drop for TempDirGuard {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.path).ok();
    }
}

fn temp_guard(tag: &str) -> TempDirGuard {
    let path = std::env::temp_dir().join(format!("kbt_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&path).expect("temp dir");
    TempDirGuard { path }
}

const MANIFEST_JSON: &str = r#"{
    "version": 1, "vocab": 256, "seq": 32,
    "param_names": ["embed"],
    "tiers": [{
        "name": "t0", "d_model": 16, "n_layer": 1, "n_head": 2,
        "d_ff": 64, "vocab": 256, "seq": 32,
        "batch_train": 4, "batch_eval": 8, "param_count": 4096,
        "params": [{"name": "embed", "shape": [256, 16]}],
        "quantized_params": [],
        "fwd_hlo": "fwd.hlo.txt", "train_hlo": "train.hlo.txt"
    }],
    "kernels": {
        "m": 8, "k": 64, "n": 64, "qblock": 32, "codebook_pad": 256,
        "u8_hlo": "a.hlo.txt", "packed4_hlo": "b.hlo.txt", "f32_hlo": "c.hlo.txt"
    }
}"#;

#[test]
fn manifest_mutations_never_panic() {
    let guard = temp_guard("fuzz_manifest");
    let path = guard.path.join("manifest.json");

    // The pristine document loads.
    std::fs::write(&path, MANIFEST_JSON).expect("write manifest");
    Manifest::load(&guard.path).expect("valid manifest loads");

    let base = MANIFEST_JSON.as_bytes();
    let mut rng = Rng::new(SEED).fork(4);
    let mut survived_ok = 0usize;
    for case in 0..250 {
        let mut r = rng.fork(case);
        let mut doc = base.to_vec();
        for _ in 0..1 + r.below(3) {
            if doc.is_empty() {
                break;
            }
            match r.below(4) {
                0 => doc.truncate(r.below(doc.len())),
                1 => {
                    let i = r.below(doc.len());
                    doc[i] = r.next_u64() as u8;
                }
                2 => {
                    let i = r.below(doc.len());
                    doc.remove(i);
                }
                _ => {
                    let i = r.below(doc.len() + 1);
                    doc.insert(i, r.next_u64() as u8);
                }
            }
        }
        std::fs::write(&path, &doc).expect("write mutated manifest");
        if Manifest::load(&guard.path).is_ok() {
            survived_ok += 1;
        }
    }
    // Mutations in whitespace or inside string values can legitimately
    // still parse; most must not.
    assert!(survived_ok < 250, "every mutation parsed: mutator inert");

    // Structurally valid JSON, semantically broken: typed errors, no panic.
    for hostile in [
        r#"{"vocab": 1, "seq": 1, "param_names": [], "tiers": [], "kernels": {}}"#,
        r#"{"vocab": "x", "seq": 1, "param_names": [], "tiers": 3, "kernels": {}}"#,
        r#"{}"#,
        r#"[]"#,
        r#"null"#,
    ] {
        std::fs::write(&path, hostile).expect("write hostile manifest");
        assert!(Manifest::load(&guard.path).is_err(), "hostile manifest accepted: {hostile}");
    }
}

// ---------------------------------------------------------------------------
// Packed k-bit bitstream decoders
// ---------------------------------------------------------------------------

/// A legitimate 4-bit blockwise tensor with a ragged tail block
/// (300 = 4 x 64 + 44) — the shape every decoder must already handle.
fn legit_tensor() -> PackedTensor {
    let mut rng = Rng::new(SEED).fork(5);
    let mut data = vec![0.0f32; 300];
    rng.fill_normal(&mut data, 1.0);
    let spec = QuantSpec::new(DataType::Int, 4, Some(64));
    PackedTensor::quantize(&data, &spec).expect("quantize")
}

/// Every decode entry point over one tensor; all must return, and all
/// must agree on accept/reject (the invariants are shared).
fn poke_tensor_decoders(p: &PackedTensor) -> bool {
    let validated = p.validate().is_ok();
    let mut out = vec![0.0f32; p.n.min(1 << 16)];
    if out.len() == p.n {
        assert_eq!(
            p.dequantize_into(&mut out).is_ok(),
            validated,
            "dequantize_into disagrees with validate()"
        );
    }
    let span = p.n.min(8);
    let mut head = vec![0.0f32; span];
    assert_eq!(
        fused::decode_range(p, 0, span, &mut head).is_ok(),
        validated,
        "decode_range disagrees with validate()"
    );
    validated
}

#[test]
fn packed_tensor_ragged_tail_decodes() {
    let p = legit_tensor();
    assert_eq!(p.n % p.block, 44, "fixture must have a ragged tail block");
    assert!(poke_tensor_decoders(&p));

    // The ragged tail itself, decoded in isolation, matches the full decode.
    let mut full = vec![0.0f32; p.n];
    p.dequantize_into(&mut full).expect("full decode");
    let mut tail = vec![0.0f32; 44];
    fused::decode_range(&p, 256, 300, &mut tail).expect("tail decode");
    assert_eq!(&full[256..300], &tail[..]);

    // Out-of-bounds and inverted ranges are errors.
    let mut buf = vec![0.0f32; 20];
    assert!(fused::decode_range(&p, 290, 310, &mut buf).is_err());
    let mut empty: Vec<f32> = Vec::new();
    assert!(fused::decode_range(&p, 10, 5, &mut empty).is_err());
}

#[test]
fn packed_tensor_hostile_fields_error_not_panic() {
    let base = legit_tensor();

    let hostile: Vec<(&str, PackedTensor)> = vec![
        ("block=0", PackedTensor { block: 0, ..base.clone() }),
        ("bits=0", PackedTensor { bits: 0, ..base.clone() }),
        ("bits=9", PackedTensor { bits: 9, ..base.clone() }),
        ("absmax truncated", {
            let mut p = base.clone();
            p.absmax.truncate(2);
            p
        }),
        ("absmax padded", {
            let mut p = base.clone();
            p.absmax.push(1.0);
            p
        }),
        ("means wrong length", PackedTensor { means: Some(vec![0.0; 2]), ..base.clone() }),
        ("packed stream truncated", {
            let mut p = base.clone();
            let keep = p.packed.len() / 2;
            p.packed.truncate(keep);
            p
        }),
        ("element count inflated past the stream", {
            let mut p = base.clone();
            p.n *= 8;
            p.absmax = vec![1.0; p.n.div_ceil(p.block)];
            p
        }),
        ("n*bits overflows usize", {
            let mut p = base.clone();
            p.n = usize::MAX;
            p.block = usize::MAX;
            p.absmax = vec![1.0];
            p.means = None;
            p
        }),
    ];
    for (what, p) in &hostile {
        assert!(!poke_tensor_decoders(p), "hostile tensor accepted: {what}");
        // The fused matmul path rejects them too (dims chosen so the
        // shape checks pass and only validate() can refuse).
        if p.n == 300 {
            let x = vec![1.0f32; 30];
            let mut out = vec![0.0f32; 10];
            let mut wrow = Vec::new();
            assert!(
                fused::fused_matmul(&x, p, &mut out, 1, 30, 10, &mut wrow).is_err(),
                "fused_matmul accepted hostile tensor: {what}"
            );
        }
    }
}

/// A corrupted bitstream can name a codebook index past the table (the
/// int codebook has 2^k - 1 entries, so index 2^k - 1 is unmapped):
/// decode must surface a typed error, never an out-of-bounds read.
#[test]
fn packed_tensor_corrupt_bitstream_is_an_error() {
    let mut p = legit_tensor();
    assert!(p.codebook.len() < 1 << p.bits, "int codebook leaves an unmapped index");
    for w in p.packed.iter_mut() {
        *w = u32::MAX; // every 4-bit field becomes index 15
    }
    p.validate().expect("field invariants still hold");
    let mut out = vec![0.0f32; p.n];
    let err = p.dequantize_into(&mut out).expect_err("unmapped index must error");
    assert!(format!("{err:#}").contains("codebook"), "unexpected error: {err:#}");
    let mut head = vec![0.0f32; 8];
    assert!(fused::decode_range(&p, 0, 8, &mut head).is_err());
}

#[test]
fn packed_tensor_random_field_fuzz() {
    let base = legit_tensor();
    let mut rng = Rng::new(SEED).fork(6);
    for case in 0..300 {
        let mut r = rng.fork(case);
        let mut p = base.clone();
        for _ in 0..1 + r.below(3) {
            match r.below(7) {
                0 => p.n = r.next_u64() as usize,
                1 => p.block = r.below(512),
                2 => p.bits = r.below(12),
                3 => p.absmax.truncate(r.below(p.absmax.len() + 1)),
                4 => p.means = Some(vec![0.5; r.below(8)]),
                5 => p.packed.truncate(r.below(p.packed.len() + 1)),
                _ => {
                    if !p.packed.is_empty() {
                        let i = r.below(p.packed.len());
                        p.packed[i] = r.next_u64() as u32;
                    }
                }
            }
        }
        // Either outcome is fine; panicking is not. Corrupted words with
        // otherwise-consistent fields may still hit an unmapped codebook
        // index, which dequantize reports as Err even when validate()
        // passes — so only the panic-freedom and the validate/decode
        // agreement on *structural* errors are asserted here.
        let structural_ok = p.validate().is_ok();
        let mut out = vec![0.0f32; p.n.min(1 << 16)];
        if out.len() == p.n {
            let decoded = p.dequantize_into(&mut out).is_ok();
            assert!(structural_ok || !decoded, "decode accepted a structurally invalid tensor");
        }
        let span = p.n.min(8);
        let mut head = vec![0.0f32; span];
        let ranged = fused::decode_range(&p, 0, span, &mut head).is_ok();
        assert!(structural_ok || !ranged, "decode_range accepted a structurally invalid tensor");
    }
}

// ---------------------------------------------------------------------------
// Entropy-coded bitstream decoders (quant::entropy)
// ---------------------------------------------------------------------------

/// A legitimate entropy-coded tensor spanning two segments (5000 = 4096 +
/// 904), from normal data so the Huffman coding path actually engages.
fn legit_encoded() -> (PackedTensor, EncodedTensor) {
    let mut rng = Rng::new(SEED).fork(7);
    let mut data = vec![0.0f32; 5000];
    rng.fill_normal(&mut data, 1.0);
    let spec = QuantSpec::new(DataType::Int, 4, Some(64));
    let p = PackedTensor::quantize(&data, &spec).expect("quantize");
    let e = EncodedTensor::encode(&p).expect("encode");
    (p, e)
}

/// Every decode entry point over one encoded tensor; all must return, and
/// decode must never accept what `validate()` rejects (bit-level stream
/// corruption with intact structure may still decode — to an error or to
/// wrong floats — but never to a panic).
fn poke_encoded(t: &EncodedTensor) -> bool {
    let structural_ok = t.validate().is_ok();
    let cap = t.n.min(1 << 16);
    let mut out = vec![0.0f32; cap];
    if cap == t.n {
        let decoded = t.dequantize_into(&mut out).is_ok();
        assert!(
            structural_ok || !decoded,
            "decode accepted a structurally invalid encoded tensor"
        );
    }
    let span = t.n.min(8);
    let mut head = vec![0.0f32; span];
    let ranged = t.decode_range(0, span, &mut head).is_ok();
    assert!(
        structural_ok || !ranged,
        "decode_range accepted a structurally invalid encoded tensor"
    );
    structural_ok
}

#[test]
fn encoded_tensor_round_trips_below_the_nominal_payload() {
    let (p, e) = legit_encoded();
    assert_eq!(e.segments.len(), 2, "fixture must span two segments");
    assert!(poke_encoded(&e));
    // Coding is lossless and never pays more than packed n*k.
    assert!(e.payload_bits() <= e.nominal_payload_bits());
    let mut packed = vec![0.0f32; p.n];
    p.dequantize_into(&mut packed).expect("packed decode");
    let mut coded = vec![0.0f32; e.n];
    e.dequantize_into(&mut coded).expect("coded decode");
    assert_eq!(packed, coded, "coded decode must be bit-identical to the packed twin");
}

#[test]
fn encoded_tensor_hostile_fields_error_not_panic() {
    let (_, base) = legit_encoded();

    let hostile: Vec<(&str, EncodedTensor)> = vec![
        ("element count inflated past the segments", {
            let mut t = base.clone();
            t.n *= 4;
            t
        }),
        ("bits=0", EncodedTensor { bits: 0, ..base.clone() }),
        ("bits=9", EncodedTensor { bits: 9, ..base.clone() }),
        ("block=0", EncodedTensor { block: 0, ..base.clone() }),
        ("absmax truncated", {
            let mut t = base.clone();
            t.absmax.truncate(2);
            t
        }),
        ("means wrong length", {
            let mut t = base.clone();
            t.means = Some(vec![0.0; 2]);
            t
        }),
        ("segment dropped", {
            let mut t = base.clone();
            t.segments.pop();
            t
        }),
        ("segment length lies", {
            let mut t = base.clone();
            t.segments[0].len += 1;
            t.segments[1].len -= 1;
            t
        }),
        ("segment offset past the stream", {
            let mut t = base.clone();
            t.segments[1].bit_off = t.stream_bits + 1;
            t
        }),
        ("segment references a missing table", {
            let mut t = base.clone();
            t.segments[0].coding = Coding::Table(99);
            t
        }),
        ("stream_bits exceeds the words held", {
            let mut t = base.clone();
            t.stream_bits = t.stream.len() as u64 * 32 + 1;
            t
        }),
        ("stream truncated under its stream_bits", {
            let mut t = base.clone();
            let keep = t.stream.len() / 2;
            t.stream.truncate(keep);
            t
        }),
    ];
    for (what, t) in &hostile {
        assert!(!poke_encoded(t), "hostile encoded tensor accepted: {what}");
    }
}

#[test]
fn encoded_tensor_random_field_fuzz() {
    let (_, base) = legit_encoded();
    let mut rng = Rng::new(SEED).fork(8);
    for case in 0..300 {
        let mut r = rng.fork(case);
        let mut t = base.clone();
        for _ in 0..1 + r.below(3) {
            match r.below(8) {
                0 => t.n = r.below(SEGMENT_LEN * 4),
                1 => t.bits = r.below(12),
                2 => t.block = r.below(512),
                3 => t.absmax.truncate(r.below(t.absmax.len() + 1)),
                4 => t.stream.truncate(r.below(t.stream.len() + 1)),
                5 => t.stream_bits = r.next_u64() % (base.stream.len() as u64 * 32 + 64),
                6 => {
                    let i = r.below(t.segments.len().max(1));
                    if let Some(s) = t.segments.get_mut(i) {
                        match r.below(3) {
                            0 => s.bit_off = r.next_u64() % (base.stream_bits + 64),
                            1 => s.len = r.below(2 * SEGMENT_LEN),
                            _ => s.coding = Coding::Table(r.below(4)),
                        }
                    }
                }
                _ => {
                    if !t.stream.is_empty() {
                        let bit = r.below(t.stream.len() * 32);
                        t.stream[bit / 32] ^= 1 << (bit % 32);
                    }
                }
            }
        }
        poke_encoded(&t);
    }
}

/// Huffman tables built from untrusted code-length lists: the serialized
/// form every encoded tensor carries. Malformed alphabets, over-long
/// codes, and Kraft-violating lists are typed errors, never panics.
#[test]
fn huffman_length_lists_hostile_inputs_error_not_panic() {
    // Legitimate tables round-trip through their serialized form.
    let (_, e) = legit_encoded();
    assert!(!e.tables.is_empty(), "normal data must engage the Huffman path");
    for table in &e.tables {
        let rebuilt = HuffTable::from_lengths(table.lengths()).expect("lengths round-trip");
        assert_eq!(rebuilt.lengths(), table.lengths());
    }

    assert!(HuffTable::from_lengths(&[]).is_err(), "empty alphabet");
    assert!(HuffTable::from_lengths(&[1]).is_err(), "one-symbol alphabet");
    assert!(HuffTable::from_lengths(&[1, 1, 1]).is_err(), "non-power-of-two alphabet");
    assert!(HuffTable::from_lengths(&[2; 512]).is_err(), "alphabet past 2^8");
    assert!(HuffTable::from_lengths(&[0; 16]).is_err(), "no coded symbols");
    assert!(
        HuffTable::from_lengths(&[MAX_CODE_LEN as u8 + 1, 1, 0, 0]).is_err(),
        "length past MAX_CODE_LEN"
    );
    assert!(HuffTable::from_lengths(&[1, 1, 1, 1]).is_err(), "Kraft over-subscription");

    // Random length lists: accepted or rejected, never a panic.
    let mut rng = Rng::new(SEED).fork(9);
    let mut accepted = 0usize;
    for case in 0..400 {
        let mut r = rng.fork(case);
        let n_sym = 1usize << (1 + r.below(4)); // 2, 4, 8, or 16 symbols
        let lengths: Vec<u8> =
            (0..n_sym).map(|_| r.below(MAX_CODE_LEN as usize + 3) as u8).collect();
        if HuffTable::from_lengths(&lengths).is_ok() {
            accepted += 1;
        }
    }
    assert!(accepted > 0, "no random length list parsed: generator too hostile");
    assert!(accepted < 400, "every random length list parsed: validation inert");
}
