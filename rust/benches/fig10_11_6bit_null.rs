//! E9 — Figures 10 & 11: the 6-bit null result — neither data type nor
//! block size moves bit-level scaling at 6-bit precision (Appendix C.3),
//! because 6–8 bits already model the weights with enough precision.
//!
//! Expected shape: curves for all data types / block sizes nearly
//! coincide (tight spread), unlike the 4-bit panels.

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::{dedupe, GridBuilder};
use kbitscale::report::figures::{build_curves, spec_block, spec_dtype, Metric};
use kbitscale::report::{ascii_chart, write_csv};

/// Max spread of per-curve interpolations at matched budgets.
fn spread_at_budgets(curves: &[kbitscale::scaling::Curve]) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..16 {
        let budget = 10f64.powf(5.5 + 0.1 * i as f64);
        let vals: Vec<f64> = curves.iter().filter_map(|c| c.interpolate(budget)).collect();
        if vals.len() >= 2 {
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            worst = worst.max(hi - lo);
        }
    }
    worst
}

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let family = "pythialike";
    let gb = GridBuilder::new(vec![family], default_tiers());

    for (fig, k) in [("10/11 (6-bit)", 6usize), ("3-style contrast (4-bit)", 4)] {
        let mut cells = gb.datatype_sweep(k);
        cells.extend(gb.blocksize_sweep(k, &[Some(64), Some(1024), None]));
        let results = env.run_grid_timed(&format!("fig{fig}"), &dedupe(cells))?;

        let dt = build_curves(&results, Metric::ZsMean, |r| {
            (spec_block(&r.spec_key) == Some(64)).then(|| spec_dtype(&r.spec_key).to_string())
        });
        println!(
            "{}",
            ascii_chart(&format!("Figure {fig}: data types at {k}-bit, {family}"),
                "total model bits", "mean zero-shot accuracy", &dt, 62, 11)
        );
        write_csv(&env.paths().figures.join(format!("fig10_dtypes_{k}bit.csv")), &dt)?;
        println!("  data-type spread at matched budgets: {:.4}", spread_at_budgets(&dt));

        let bs = build_curves(&results, Metric::ZsMean, |r| {
            (spec_dtype(&r.spec_key) == "fp").then(|| match spec_block(&r.spec_key) {
                Some(b) => format!("block {b}"),
                None => "tensor-wise".into(),
            })
        });
        write_csv(&env.paths().figures.join(format!("fig11_blocks_{k}bit.csv")), &bs)?;
        println!("  block-size spread at matched budgets: {:.4}\n", spread_at_budgets(&bs));
    }
    println!("paper shape: spreads at 6-bit are much tighter than at 4-bit");
    println!("(no scaling improvement is possible above ~6 bits, App. C.3).");
    Ok(())
}
