//! E11 — Figures 13–15: perplexity-based (CE-loss) scaling laws — the
//! appendix's preferred, lower-noise metric — for total bits, data types,
//! and block sizes. Also verifies the §4 claim that perplexity and
//! zero-shot rank methods consistently (E12's Pearson check comes from the
//! same store via `kbitscale analyze --pearson`).

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::GridBuilder;
use kbitscale::report::figures::{build_curves, spec_bits, spec_block, spec_dtype, Metric};
use kbitscale::report::{ascii_chart, write_csv};
use kbitscale::scaling::pearson;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let families = vec!["optlike", "pythialike", "gpt2like", "bloomlike"];
    let gb = GridBuilder::new(families.clone(), default_tiers());
    let results = env.run_grid_timed("fig13_15", &gb.perplexity_scaling())?;

    // Fig 13: CE vs total bits per precision (all families pooled).
    let bits = build_curves(&results, Metric::Ce, |r| {
        spec_bits(&r.spec_key).map(|b| format!("{b}-bit"))
    });
    println!(
        "{}",
        ascii_chart("Figure 13: CE-loss scaling by precision (all families)",
            "total model bits", "CE loss (lower better)", &bits, 66, 14)
    );
    write_csv(&env.paths().figures.join("fig13_ce_bits.csv"), &bits)?;

    // Fig 14: CE by data type at 4-bit.
    let dtypes = build_curves(&results, Metric::Ce, |r| {
        (spec_bits(&r.spec_key) == Some(4) && spec_block(&r.spec_key) == Some(64))
            .then(|| spec_dtype(&r.spec_key).to_string())
    });
    println!(
        "{}",
        ascii_chart("Figure 14: CE-loss by data type (4-bit, block 64)",
            "total model bits", "CE loss (lower better)", &dtypes, 66, 12)
    );
    write_csv(&env.paths().figures.join("fig14_ce_dtypes.csv"), &dtypes)?;

    // Fig 15: CE by block size at 4-bit fp.
    let blocks = build_curves(&results, Metric::Ce, |r| {
        (spec_bits(&r.spec_key) == Some(4) && spec_dtype(&r.spec_key) == "fp").then(|| {
            match spec_block(&r.spec_key) {
                Some(b) => format!("block {b:>4}"),
                None => "tensor-wise".into(),
            }
        })
    });
    println!(
        "{}",
        ascii_chart("Figure 15: CE-loss by block size (4-bit fp)",
            "total model bits", "CE loss (lower better)", &blocks, 66, 12)
    );
    write_csv(&env.paths().figures.join("fig15_ce_blocks.csv"), &blocks)?;

    // §4 cross-metric consistency on whatever zero-shot cells exist.
    let pairs: Vec<(f64, f64)> = env
        .results
        .all()
        .into_iter()
        .filter(|r| r.zs_mean.is_finite())
        .map(|r| (r.ce, r.zs_mean))
        .collect();
    if pairs.len() >= 8 {
        let xs: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pairs.iter().map(|p| p.1).collect();
        println!(
            "Pearson(CE, mean zero-shot) over {} cells: {:.3}  (paper: -0.94 vs ppl)",
            pairs.len(),
            pearson(&xs, &ys)
        );
    }
    Ok(())
}
