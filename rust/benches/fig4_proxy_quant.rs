//! E4 — Figure 4: outlier-dependent (proxy) quantization for the two
//! outlier families.
//!
//! Expected shape: proxy stabilizes 3-bit OPT-like/Pythia-like (left
//! panel) but 3-bit+proxy still scales worse than plain 4-bit; at 4-bit
//! proxy adds bits without benefit (right panel).

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::GridBuilder;
use kbitscale::report::figures::{build_curves, spec_bits, spec_has_proxy, Metric};
use kbitscale::report::{ascii_chart, write_csv, TextTable};

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let families = vec!["optlike", "pythialike"];
    let gb = GridBuilder::new(families.clone(), default_tiers());
    let results = env.run_grid_timed("fig4", &gb.proxy_sweep(0.02))?;

    for family in &families {
        let curves = build_curves(&results, Metric::ZsMean, |r| {
            if r.family != *family {
                return None;
            }
            let bits = spec_bits(&r.spec_key)?;
            let proxy = if spec_has_proxy(&r.spec_key) { "+proxy" } else { "" };
            Some(format!("{bits}-bit{proxy}"))
        });
        println!(
            "{}",
            ascii_chart(&format!("Figure 4: proxy quantization, {family}"),
                "total model bits", "mean zero-shot accuracy", &curves, 64, 13)
        );
        write_csv(&env.paths().figures.join(format!("fig4_proxy_{family}.csv")), &curves)?;
    }

    // Summary table over the largest tier.
    let tier = default_tiers().last().cloned().unwrap();
    let mut table = TextTable::new(&["family", "config", "zs_mean", "bits/param"]);
    for family in &families {
        for r in results.iter().filter(|r| r.family == *family && r.tier == tier) {
            table.row(vec![
                family.to_string(),
                r.spec_key.clone(),
                format!("{:.3}", r.zs_mean),
                format!("{:.2}", r.bits_per_param),
            ]);
        }
    }
    println!("{}", table.render());
    println!("paper shape: proxy rescues 3-bit stability; 4-bit still wins bit-for-bit.");
    Ok(())
}
