//! E7/E8 — Figures 8 & 9: 4-bit block-size and data-type ablations for
//! every family (the appendix generalization of Figure 3).
//!
//! Expected shape: small blocks and fp/quantile data types improve 4-bit
//! scaling for most families at most scales; improvements are larger for
//! the outlier families (emergent features, Appendix C.2).

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::{dedupe, GridBuilder};
use kbitscale::report::figures::{build_curves, spec_block, spec_dtype, Metric};
use kbitscale::report::{ascii_chart, write_csv};

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let families = vec!["optlike", "pythialike", "gpt2like", "bloomlike"];
    let gb = GridBuilder::new(families.clone(), default_tiers());
    let mut cells = gb.blocksize_sweep(4, &[Some(64), Some(256), Some(1024), None]);
    cells.extend(gb.datatype_sweep(4));
    let results = env.run_grid_timed("fig8_9", &dedupe(cells))?;

    for family in &families {
        let bs = build_curves(&results, Metric::ZsMean, |r| {
            (r.family == *family && spec_dtype(&r.spec_key) == "fp").then(|| {
                match spec_block(&r.spec_key) {
                    Some(b) => format!("block {b:>4}"),
                    None => "tensor-wise".into(),
                }
            })
        });
        println!(
            "{}",
            ascii_chart(&format!("Figure 8 panel: 4-bit block sizes, {family}"),
                "total model bits", "mean zero-shot accuracy", &bs, 62, 11)
        );
        write_csv(&env.paths().figures.join(format!("fig8_{family}.csv")), &bs)?;

        let dt = build_curves(&results, Metric::ZsMean, |r| {
            (r.family == *family && spec_block(&r.spec_key) == Some(64))
                .then(|| spec_dtype(&r.spec_key).to_string())
        });
        println!(
            "{}",
            ascii_chart(&format!("Figure 9 panel: 4-bit data types, {family}"),
                "total model bits", "mean zero-shot accuracy", &dt, 62, 11)
        );
        write_csv(&env.paths().figures.join(format!("fig9_{family}.csv")), &dt)?;
    }
    Ok(())
}
