//! E5 — Table 1 + Figure 5: one-shot GPTQ vs zero-shot quantization.
//!
//! Table 1 analog: perplexity of 2-bit GPTQ vs zero-shot 3-bit Float
//! across block sizes {1024, 256, 64}. Figure 5 analog: LAMBADA-like
//! zero-shot accuracy scaling for 3/4-bit GPTQ without blocking vs
//! zero-shot Float with block 64.
//!
//! Expected shape: GPTQ needs blocking to win at 2-bit but then beats
//! 3-bit Float; unblocked 3-bit GPTQ scales poorly; 4-bit GPTQ ≈ 4-bit
//! Float + blocking.

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::data::tasks::Task;
use kbitscale::eval::Evaluator;
use kbitscale::gptq::model::quantize_checkpoint_gptq;
use kbitscale::gptq::GptqConfig;
use kbitscale::models::ModelId;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::{quantize_checkpoint, QuantSpec};
use kbitscale::report::TextTable;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let family = "pythialike";
    let tiers = default_tiers();
    env.ensure_trained(&[family], &tiers)?;

    // ---- Table 1: ppl on the second-largest tier ----
    let tier_name = &tiers[tiers.len() - 2];
    let tier = env.ctx.manifest.tier(tier_name)?;
    let (params, _) = env.checkpoints.load(&ModelId::new(family, tier_name))?;
    let ev = Evaluator::new(&env.ctx.rt, &env.ctx.manifest, tier)?;
    let gcfg = GptqConfig::default();

    let ppl_of = |p: &[(String, kbitscale::tensor::Tensor)]| -> anyhow::Result<f64> {
        let plits = ev.param_literals(p)?;
        Ok(ev.perplexity(&plits, &env.ctx.corpus, 32)?.1)
    };

    let mut table = TextTable::new(&["Blocksize", "2-bit GPTQ", "3-bit Float"]);
    for block in [1024usize, 256, 64] {
        let gspec = QuantSpec::new(DataType::Int, 2, Some(block));
        let g = quantize_checkpoint_gptq(
            &env.ctx.rt, &env.ctx.manifest, tier, &params, &env.ctx.corpus, &gspec, &gcfg,
        )?;
        let zspec = QuantSpec::new(DataType::Fp, 3, Some(block));
        let z = quantize_checkpoint(&params, &tier.quantized_params, &zspec);
        table.row(vec![
            block.to_string(),
            format!("{:.2}", ppl_of(&g)?),
            format!("{:.2}", ppl_of(&z)?),
        ]);
    }
    println!("Table 1 analog ({family}/{tier_name} perplexity):");
    println!("{}", table.render());
    println!("paper shape: blocking closes/flips the 2-bit GPTQ vs 3-bit Float gap.\n");

    // ---- Figure 5: LAMBADA-like accuracy scaling ----
    let mut rows = TextTable::new(&[
        "tier", "gptq3 noblock", "fp3 b64", "gptq4 noblock", "fp4 b64", "fp16",
    ]);
    for tier_name in &tiers {
        let tier = env.ctx.manifest.tier(tier_name)?;
        let (params, _) = env.checkpoints.load(&ModelId::new(family, tier_name))?;
        let ev = Evaluator::new(&env.ctx.rt, &env.ctx.manifest, tier)?;
        let lambada = |p: &[(String, kbitscale::tensor::Tensor)]| -> anyhow::Result<f64> {
            let plits = ev.param_literals(p)?;
            ev.zero_shot(&plits, &env.ctx.corpus, Task::Lambada, 48)
        };

        let mut cells = vec![tier_name.clone()];
        for (one_shot, dtype, bits, block) in [
            (true, DataType::Int, 3usize, None),
            (false, DataType::Fp, 3, Some(64)),
            (true, DataType::Int, 4, None),
            (false, DataType::Fp, 4, Some(64)),
        ] {
            let spec = QuantSpec::new(dtype, bits, block);
            let q = if one_shot {
                quantize_checkpoint_gptq(
                    &env.ctx.rt, &env.ctx.manifest, tier, &params, &env.ctx.corpus, &spec, &gcfg,
                )?
            } else {
                quantize_checkpoint(&params, &tier.quantized_params, &spec)
            };
            cells.push(format!("{:.3}", lambada(&q)?));
        }
        cells.push(format!("{:.3}", lambada(&params)?));
        rows.row(cells);
    }
    println!("Figure 5 analog (LAMBADA-like accuracy across scales, {family}):");
    println!("{}", rows.render());
    println!("paper shape: unblocked 3-bit GPTQ lags fp3+b64; 4-bit GPTQ ≈ fp4+b64.");
    Ok(())
}
