//! E10 — Figure 12: float exponent-bit allocation across precisions
//! (Appendix C.4). For each k ∈ 3..8, sweep every valid ExMy split with
//! block-64 weights and report which exponent width wins.
//!
//! Expected shape: 2–3 exponent bits win ("exponent bits should make up
//! at least half the bits rounded up" heuristic; 2-bit exponents do well
//! across all precisions).

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::GridBuilder;
use kbitscale::report::figures::{build_curves, spec_bits, Metric};
use kbitscale::report::{write_csv, TextTable};

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let family = "gpt2like";
    let ks = [3usize, 4, 5, 6, 7, 8];
    let gb = GridBuilder::new(vec![family], default_tiers());
    let results = env.run_grid_timed("fig12", &gb.exponent_sweep(&ks))?;

    // Per (k, e): mean CE across tiers (lower is better).
    let mut table = TextTable::new(&["k", "e1", "e2", "e3", "e4", "e5", "e6", "best"]);
    for &k in &ks {
        let mut cells = vec![k.to_string()];
        let mut best = (String::from("-"), f64::INFINITY);
        for e in 1..=6usize {
            let scores: Vec<f64> = results
                .iter()
                .filter(|r| {
                    spec_bits(&r.spec_key) == Some(k) && r.spec_key.contains(&format!(":e{e}"))
                })
                .map(|r| r.ce)
                .collect();
            if scores.is_empty() {
                cells.push("-".into());
                continue;
            }
            let mean = scores.iter().sum::<f64>() / scores.len() as f64;
            cells.push(format!("{mean:.3}"));
            if mean < best.1 {
                best = (format!("e{e}"), mean);
            }
        }
        cells.push(best.0);
        table.row(cells);
    }
    println!("Figure 12 analog: mean CE loss by float exponent bits ({family}, block 64):");
    println!("{}", table.render());
    println!("paper shape: 2–3 exponent bits optimal at every precision.");

    let curves = build_curves(&results, Metric::Ce, |r| {
        let b = spec_bits(&r.spec_key)?;
        let e = r.spec_key.split(":e").nth(1)?.to_string();
        Some(format!("k{b}e{e}"))
    });
    write_csv(&env.paths().figures.join("fig12_exponent_bits.csv"), &curves)?;
    Ok(())
}
