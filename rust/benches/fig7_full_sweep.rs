//! E6 — Figure 7: full 3–16-bit scaling for all families (the appendix
//! superset of Figure 2, including the Pythia-5-bit ≈ 4-bit note and the
//! BLOOM ≈ BLOOMZ fine-tuning observation from Appendix C.1).

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::GridBuilder;
use kbitscale::report::figures::bit_curves;
use kbitscale::report::{ascii_chart, write_csv};
use kbitscale::scaling::win_counts;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let families = vec!["optlike", "pythialike", "gpt2like", "bloomlike", "bloomzlike"];
    let gb = GridBuilder::new(families.clone(), default_tiers());
    let results = env.run_grid_timed("fig7", &gb.bit_scaling(&[3, 4, 5, 6, 8, 16]))?;

    for family in &families {
        let curves = bit_curves(&results, Some(family));
        if curves.is_empty() {
            continue;
        }
        println!(
            "{}",
            ascii_chart(&format!("Figure 7 panel: {family} (3–16 bit)"),
                "total model bits", "mean zero-shot accuracy", &curves, 64, 13)
        );
        write_csv(&env.paths().figures.join(format!("fig7_{family}.csv")), &curves)?;
        println!("  wins: {:?}\n", win_counts(&curves, 30));
    }

    // Appendix C.1 check: BLOOMZ-like (fine-tuned) quantizes like its parent.
    let delta: Vec<(String, f64)> = results
        .iter()
        .filter(|r| r.family == "bloomlike")
        .filter_map(|b| {
            results
                .iter()
                .find(|z| {
                    z.family == "bloomzlike" && z.tier == b.tier && z.spec_key == b.spec_key
                })
                .map(|z| {
                    let d16 = |r: &kbitscale::coordinator::CellResult| r.zs_mean;
                    (format!("{}/{}", b.tier, b.spec_key), d16(z) - d16(b))
                })
        })
        .collect();
    if !delta.is_empty() {
        let mean_abs: f64 =
            delta.iter().map(|(_, d)| d.abs()).sum::<f64>() / delta.len() as f64;
        println!(
            "BLOOM-like vs BLOOMZ-like mean |zero-shot delta| across {} matched cells: {mean_abs:.3}",
            delta.len()
        );
        println!("paper (App. C.1): fine-tuning does not change quantization behaviour.");
    }
    Ok(())
}
