//! E2 — Figure 2 (and the Figure 7 full-precision variant via --full):
//! bit-level scaling for all four headline families.
//!
//! Expected shape: 4-bit optimal for every family; OPT-like and
//! Pythia-like (outlier families) unstable — near random — at 3-bit while
//! GPT-2-like and BLOOM-like stay stable; curves near-parallel otherwise.

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::GridBuilder;
use kbitscale::data::tasks::suite_random_baseline;
use kbitscale::report::figures::{bit_curves, spec_bits};
use kbitscale::report::{ascii_chart, write_csv};
use kbitscale::scaling::{slope_spread, win_counts};

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let env = BenchEnv::open()?;
    let families = vec!["optlike", "pythialike", "gpt2like", "bloomlike"];
    let ks: &[usize] = if full { &[3, 4, 5, 6, 8, 16] } else { &[3, 4, 8, 16] };
    let gb = GridBuilder::new(families.clone(), default_tiers());
    let results = env.run_grid_timed("fig2", &gb.bit_scaling(ks))?;

    let random = suite_random_baseline();
    for family in &families {
        let curves = bit_curves(&results, Some(family));
        println!(
            "{}",
            ascii_chart(
                &format!("Figure 2 panel: {family}"),
                "total model bits",
                "mean zero-shot accuracy",
                &curves,
                64,
                13
            )
        );
        write_csv(&env.paths().figures.join(format!("fig2_{family}.csv")), &curves)?;
        let wins = win_counts(&curves, 30);
        println!("  wins: {wins:?}");

        // 3-bit instability check for outlier families.
        let three_bit: Vec<f64> = results
            .iter()
            .filter(|r| r.family == *family && spec_bits(&r.spec_key) == Some(3))
            .map(|r| r.zs_mean)
            .collect();
        if !three_bit.is_empty() {
            let mean3 = three_bit.iter().sum::<f64>() / three_bit.len() as f64;
            println!(
                "  3-bit mean zero-shot: {mean3:.3} (random = {random:.3}) — {}\n",
                if mean3 < random + 0.05 { "UNSTABLE (paper: OPT/Pythia)" } else { "stable" }
            );
        }
    }
    let all_curves = bit_curves(&results, None);
    if let Some(spread) = slope_spread(&all_curves) {
        println!("cross-precision slope spread {spread:.3} (paper: curves near-parallel)");
    }
    Ok(())
}
