//! E1 — Figure 1: bit-level scaling laws for the OPT-like family.
//!
//! Regenerates the paper's headline plot: mean zero-shot accuracy vs total
//! model bits for k ∈ {3, 4, 8, 16} (the paper's 16→4 improvement and the
//! 3-bit reversal). Expected shape: curves shift left as k drops until
//! 4-bit; the 3-bit curve falls below 4-bit.

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::GridBuilder;
use kbitscale::report::figures::bit_curves;
use kbitscale::report::{ascii_chart, write_csv};
use kbitscale::scaling::win_counts;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let gb = GridBuilder::new(vec!["optlike"], default_tiers());
    let cells = gb.bit_scaling(&[3, 4, 8, 16]);
    let results = env.run_grid_timed("fig1", &cells)?;

    let curves = bit_curves(&results, Some("optlike"));
    println!(
        "{}",
        ascii_chart(
            "Figure 1: bit-level scaling, OPT-like (mean zero-shot vs total bits)",
            "total model bits",
            "mean zero-shot accuracy",
            &curves,
            68,
            16
        )
    );
    write_csv(&env.paths().figures.join("fig1_optlike_bit_scaling.csv"), &curves)?;
    let wins = win_counts(&curves, 40);
    println!("precision wins across 40 matched budgets: {wins:?}");
    println!("paper shape: 4-bit dominates; 3-bit reverses the trend.");
    Ok(())
}
