//! E3 — Figure 3: what improves 4-bit scaling for Pythia-like models —
//! data types (left panel) and block sizes (right panel).
//!
//! Expected shape: quantile/float dominate int/dynexp; block 64 beats
//! block 1024 by roughly the 4→5-bit improvement while costing only
//! +0.25 bits/param.

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::{dedupe, GridBuilder};
use kbitscale::report::figures::{build_curves, spec_block, spec_bits, spec_dtype, Metric};
use kbitscale::report::{ascii_chart, write_csv};

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let gb = GridBuilder::new(vec!["pythialike"], default_tiers());
    let mut cells = gb.datatype_sweep(4);
    cells.extend(gb.blocksize_sweep(4, &[Some(16), Some(64), Some(256), Some(1024), None]));
    let results = env.run_grid_timed("fig3", &dedupe(cells))?;

    let dt = build_curves(&results, Metric::ZsMean, |r| {
        (spec_bits(&r.spec_key) == Some(4) && spec_block(&r.spec_key) == Some(64))
            .then(|| format!("4-bit {}", spec_dtype(&r.spec_key)))
    });
    println!(
        "{}",
        ascii_chart("Figure 3 (left): 4-bit Pythia-like data types", "total model bits",
            "mean zero-shot accuracy", &dt, 64, 13)
    );
    write_csv(&env.paths().figures.join("fig3_datatypes.csv"), &dt)?;

    let bs = build_curves(&results, Metric::ZsMean, |r| {
        (spec_bits(&r.spec_key) == Some(4) && spec_dtype(&r.spec_key) == "fp").then(|| {
            match spec_block(&r.spec_key) {
                Some(b) => format!("block {b:>4}"),
                None => "tensor-wise".to_string(),
            }
        })
    });
    println!(
        "{}",
        ascii_chart("Figure 3 (right): 4-bit Pythia-like block sizes", "total model bits",
            "mean zero-shot accuracy", &bs, 64, 13)
    );
    write_csv(&env.paths().figures.join("fig3_blocksizes.csv"), &bs)?;

    // Quantitative check of the paper's claims on the largest tier.
    let last_tier = default_tiers().last().cloned().unwrap();
    let at = |f: &dyn Fn(&kbitscale::coordinator::CellResult) -> bool| {
        results
            .iter()
            .find(|r| r.tier == last_tier && f(r))
            .map(|r| r.zs_mean)
    };
    if let (Some(b64), Some(b1024)) = (
        at(&|r| spec_dtype(&r.spec_key) == "fp" && spec_block(&r.spec_key) == Some(64)),
        at(&|r| spec_dtype(&r.spec_key) == "fp" && spec_block(&r.spec_key) == Some(1024)),
    ) {
        println!("block 64 vs 1024 on {last_tier}: {b64:.3} vs {b1024:.3} (paper: small blocks win)");
    }
    Ok(())
}
