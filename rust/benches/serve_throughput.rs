//! Serving throughput: requests/sec and p50/p95 latency for 1, 4, and 16
//! concurrent TCP clients, with micro-batching on (threaded workers +
//! cross-client coalescing) vs off (single worker, direct execution — the
//! pre-registry sequential serving path), plus the packed-vs-f32 resident
//! weight footprint of every variant hosted by the registry.
//!
//! Four focused sections follow the throughput table:
//!
//! * **score cache** — repeat traffic (every client resends the same row)
//!   against a cache-enabled vs cache-disabled registry; cached rows skip
//!   the forward pass entirely, target ≥ 5× the uncached rate.
//! * **eviction churn** — a registry whose `--max-resident-bytes` budget
//!   holds ~one variant, loaded round-robin with three variants: every
//!   load past the budget evicts the LRU resident and pays a rebuild.
//! * **pipeline plans** — the same traffic against the monolithic vs the
//!   2-stage sharded build of one spec; the activation handoff should
//!   cost < 10% added p50 latency.
//! * **fused native backend** — the same traffic against the `#fused`
//!   build of one spec (packed weights walked in the matmul inner loop,
//!   no f32 expansion) vs the classic dequantize→executable resident.
//! * **fused kernel microbench** — decode-only scalar vs AVX2, tiled vs
//!   untiled fused matmul, and a 1/2/4-thread column-parallel sweep on a
//!   standalone fp4 b64 tensor, so kernel regressions show up even when
//!   protocol overhead hides them in the end-to-end rows.
//! * **entropy-coded residency** — the same-geometry fp4 tensor behind
//!   per-segment Huffman coding (`#ec`): full decode throughput vs the
//!   packed decoder, measured coded bits/index vs the nominal k, and the
//!   resident-byte saving the coded form buys.
//! * **streamed vs buffered** — one 48-row request with `stream:true` vs
//!   buffered; streaming should put the first partial scores on the wire
//!   well before the buffered response completes.
//! * **binary score frames** — the same 48-row streamed request over
//!   negotiated `bin1` frames vs JSON lines; reports the wire bytes each
//!   format spends on chunk payloads.
//! * **tuned policy vs fixed precision** — a quick autotuner search
//!   (ppl-only calibration) emits a Pareto policy; serving the policy's
//!   pick under a byte budget is compared head-to-head with fixed 4-bit
//!   and fixed 16-bit residents under the same budget.
//! * **fleet scaling** — the same 4-client traffic against a 1-worker vs
//!   a 3-worker fleet behind the `fleet::` router, under the **same
//!   total byte budget** (split per worker), so the horizontal-scaling
//!   win of the router tier is measured rather than asserted.
//! * **precision governor** — bare-keyed scoring through a governed
//!   fleet at the 16-bit steady state, then a synthetic p99 spike
//!   triggers one live demote (pre-warm included in the measured tick
//!   cost) and the same traffic is re-measured on the 4-bit target; an
//!   immediate re-tick inside the cooldown must apply zero migrations.
//!
//! Init-only parameters are used (throughput does not depend on training),
//! so this bench needs artifacts but no checkpoints.
//!
//! Pass `--json <path>` to also write the headline numbers as a JSON
//! snapshot (the `BENCH_serve_throughput.json` baseline checked into the
//! repo root is regenerated this way on real hardware).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::runtime::Runtime;
use kbitscale::server::{frames, serve_listener, ModelRegistry, ParamLoader, PlanRequest, ServeOpts};
use kbitscale::util::json::Json;

const REQS_PER_CLIENT: usize = 40;

fn make_loader(manifest: &Manifest) -> ParamLoader<'static> {
    let mref = manifest.clone();
    Box::new(move |family: &str, tier: &str| {
        Ok(init_params(mref.tier(tier)?, Family::get(family)?))
    })
}

fn main() -> anyhow::Result<()> {
    kbitscale::util::progress::init_logging();
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .map(|i| argv.get(i + 1).cloned().expect("--json needs a path argument"));
    // Headline numbers accumulate here; `--json` dumps them at the end.
    let mut snap: BTreeMap<String, Json> = BTreeMap::new();
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let rt = Runtime::cpu()?;
    // No score cache on the main registry: the throughput table measures
    // the forward-execution serving path, not cache lookups.
    let registry = ModelRegistry::new(&rt, &manifest, make_loader(&manifest));
    let h0 = registry.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64)))?;
    // A second resident (tier x spec) variant: multi-model hosting in one
    // process is part of what is being measured.
    let h1 = registry.load("gpt2like", "t0", QuantSpec::new(DataType::Int, 3, Some(32)))?;

    println!("resident variants ({} in registry):", registry.len());
    for h in [&h0, &h1] {
        println!(
            "  {:<28} packed {:>10} B   f32 {:>10} B   ({:.2}x smaller)",
            h.key(),
            h.resident_bytes(),
            h.quantized_f32_bytes(),
            h.quantized_f32_bytes() as f64 / h.resident_bytes().max(1) as f64
        );
    }

    println!();
    println!(
        "{:<8} {:>9} {:>10} {:>10} {:>10}",
        "clients", "batching", "req/s", "p50 ms", "p95 ms"
    );
    let mut seq_1 = 0.0f64;
    let mut batched_4 = 0.0f64;
    let mut table: Vec<Json> = Vec::new();
    for &clients in &[1usize, 4, 16] {
        for &batching in &[false, true] {
            let (rps, p50, p95) = run_trial(&registry, clients, batching, false, None)?;
            if clients == 1 && !batching {
                seq_1 = rps;
            }
            if clients == 4 && batching {
                batched_4 = rps;
            }
            println!(
                "{clients:<8} {:>9} {rps:>10.1} {p50:>10.2} {p95:>10.2}",
                if batching { "on" } else { "off" }
            );
            table.push(Json::obj(vec![
                ("clients", Json::Num(clients as f64)),
                ("batching", Json::Bool(batching)),
                ("req_per_s", Json::Num(rps)),
                ("p50_ms", Json::Num(p50)),
                ("p95_ms", Json::Num(p95)),
            ]));
        }
    }
    snap.insert("throughput".to_string(), Json::Arr(table));
    snap.insert("batched4_vs_seq1".to_string(), Json::Num(batched_4 / seq_1.max(1e-9)));
    println!();
    println!(
        "batched 4-client throughput vs sequential path: {:.2}x (target >= 2x)",
        batched_4 / seq_1.max(1e-9)
    );

    // --- score cache: repeat traffic, cache on vs off -------------------
    println!();
    let cached = ModelRegistry::new(&rt, &manifest, make_loader(&manifest)).with_score_cache(4096);
    cached.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64)))?;
    let (uncached_rps, _, _) = run_trial(&registry, 4, true, true, None)?;
    let (cached_rps, cp50, _) = run_trial(&cached, 4, true, true, None)?;
    println!(
        "repeat traffic, 4 clients: uncached {uncached_rps:.1} req/s | cached {cached_rps:.1} req/s \
         (p50 {cp50:.3} ms) | {:.1}x (target >= 5x)",
        cached_rps / uncached_rps.max(1e-9)
    );
    snap.insert("cache_speedup".to_string(), Json::Num(cached_rps / uncached_rps.max(1e-9)));

    // --- pipeline plans: monolithic vs 2-stage sharded ------------------
    println!();
    if manifest.tier("t0")?.stages.is_empty() {
        println!("pipeline plans: artifacts declare no stages; section skipped");
    } else {
        let piped = registry.load_plan(
            "gpt2like",
            "t0",
            QuantSpec::new(DataType::Fp, 4, Some(64)),
            &PlanRequest::staged(),
        )?;
        let (mono_key, pipe_key) = (h0.key(), piped.key());
        let (_, mono_p50, _) = run_trial(&registry, 4, true, false, Some(mono_key.as_str()))?;
        let (_, pipe_p50, _) = run_trial(&registry, 4, true, false, Some(pipe_key.as_str()))?;
        println!(
            "pipeline handoff: monolithic p50 {mono_p50:.2} ms | 2-stage p50 {pipe_p50:.2} ms \
             ({:+.1}% overhead, target < 10%)",
            (pipe_p50 / mono_p50.max(1e-9) - 1.0) * 100.0
        );
        for (name, bytes) in &piped.stage_bytes {
            println!("  stage {name}: {bytes} packed B resident");
        }
    }

    // --- fused native backend vs the unfused executable path ------------
    println!();
    {
        let fused = registry.load_plan(
            "gpt2like",
            "t0",
            QuantSpec::new(DataType::Fp, 4, Some(64)),
            &PlanRequest::fused(),
        )?;
        let (base_key, fused_key) = (h0.key(), fused.key());
        drop(fused);
        let (u_rps, u_p50, _) = run_trial(&registry, 4, true, false, Some(base_key.as_str()))?;
        let (f_rps, f_p50, _) = run_trial(&registry, 4, true, false, Some(fused_key.as_str()))?;
        let backend = format!("{:?}", kbitscale::quant::fused::active_backend());
        println!(
            "fused dequant-matmul ({backend}): unfused {u_rps:.1} req/s p50 {u_p50:.2} ms | \
             fused {f_rps:.1} req/s p50 {f_p50:.2} ms ({:+.1}% p50)",
            (f_p50 / u_p50.max(1e-9) - 1.0) * 100.0
        );
        snap.insert(
            "fused".to_string(),
            Json::obj(vec![
                ("backend", Json::Str(backend)),
                ("unfused_req_per_s", Json::Num(u_rps)),
                ("unfused_p50_ms", Json::Num(u_p50)),
                ("fused_req_per_s", Json::Num(f_rps)),
                ("fused_p50_ms", Json::Num(f_p50)),
            ]),
        );
    }

    // --- fused kernel microbench: decode + tiling + thread sweep --------
    // Kernel-level numbers behind the serving rows above, captured in the
    // snapshot so regressions in the decode or tiling layers show up even
    // when end-to-end throughput hides them behind protocol overhead.
    println!();
    {
        use kbitscale::quant::fused::{self, Backend, Tiling};
        use kbitscale::quant::packing::PackedTensor;
        use kbitscale::util::progress::bench_best;
        use kbitscale::util::rng::Rng;

        let (m, kd, nn) = (8usize, 768usize, 768usize);
        let mut rng = Rng::new(7);
        let mut x = vec![0.0f32; m * kd];
        let mut w = vec![0.0f32; kd * nn];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut w, 0.05);
        let p = PackedTensor::quantize(&w, &QuantSpec::new(DataType::Fp, 4, Some(64)))?;
        let mut decoded = vec![0.0f32; p.n];
        let dec_scalar = bench_best(1, 7, || {
            fused::decode_range_with(Backend::Scalar, &p, 0, p.n, &mut decoded).unwrap();
            std::hint::black_box(&decoded);
        });
        let dec_avx2 = if fused::avx2_available() {
            Some(bench_best(1, 7, || {
                fused::decode_range_with(Backend::Avx2, &p, 0, p.n, &mut decoded).unwrap();
                std::hint::black_box(&decoded);
            }))
        } else {
            None
        };
        let dec_best = dec_avx2.unwrap_or(dec_scalar);
        println!(
            "decode_range ({} elems): scalar {:.3} ms | avx2 {} | {:.2} GB/s f32 out",
            p.n,
            dec_scalar * 1e3,
            dec_avx2.map_or_else(|| "n/a".to_string(), |t| format!("{:.3} ms", t * 1e3)),
            (p.n * 4) as f64 / dec_best / 1e9
        );
        snap.insert(
            "decode".to_string(),
            Json::obj(vec![
                ("elements", Json::Num(p.n as f64)),
                ("scalar_ms", Json::Num(dec_scalar * 1e3)),
                ("avx2_ms", dec_avx2.map_or(Json::Null, |t| Json::Num(t * 1e3))),
                ("gbps_f32_out", Json::Num((p.n * 4) as f64 / dec_best / 1e9)),
            ]),
        );

        let backend = fused::active_backend();
        let tile = Tiling::for_geometry(m, kd, nn);
        let mut out = vec![0.0f32; m * nn];
        let mut panel: Vec<f32> = Vec::new();
        let t_untiled = bench_best(2, 9, || {
            out.fill(0.0);
            fused::fused_matmul_untiled(backend, &x, &p, &mut out, m, kd, nn, &mut panel).unwrap();
            std::hint::black_box(&out);
        });
        let t_tiled = bench_best(2, 9, || {
            out.fill(0.0);
            fused::fused_matmul_tiled(backend, tile, &x, &p, &mut out, m, kd, nn, &mut panel)
                .unwrap();
            std::hint::black_box(&out);
        });
        println!(
            "fused kernel {m}x{kd}x{nn} ({backend:?}): untiled {:.2} ms | tiled {:.2} ms \
             ({:.2}x, {tile:?})",
            t_untiled * 1e3,
            t_tiled * 1e3,
            t_untiled / t_tiled.max(1e-12)
        );
        let mut thread_rows: Vec<Json> = Vec::new();
        for threads in [1usize, 2, 4] {
            let t_par = bench_best(2, 9, || {
                out.fill(0.0);
                fused::fused_matmul_parallel(&x, &p, &mut out, m, kd, nn, threads, &mut panel)
                    .unwrap();
                std::hint::black_box(&out);
            });
            println!(
                "  {threads} thread(s): {:.2} ms ({:.2}x vs 1-thread tiled)",
                t_par * 1e3,
                t_tiled / t_par.max(1e-12)
            );
            thread_rows.push(Json::obj(vec![
                ("threads", Json::Num(threads as f64)),
                ("ms", Json::Num(t_par * 1e3)),
            ]));
        }
        snap.insert(
            "fused_kernel".to_string(),
            Json::obj(vec![
                ("backend", Json::Str(format!("{backend:?}"))),
                ("untiled_ms", Json::Num(t_untiled * 1e3)),
                ("tiled_ms", Json::Num(t_tiled * 1e3)),
                ("tile_rows", Json::Num(tile.rows as f64)),
                ("tile_cols", Json::Num(tile.cols as f64)),
                ("threads", Json::Arr(thread_rows)),
            ]),
        );
    }

    // --- entropy-coded residency: decode throughput + footprint ---------
    // The same-geometry fp4 b64 tensor re-encoded with per-segment
    // canonical Huffman coding (`#ec` residency): full-tensor decode
    // throughput vs the packed decoder, plus the measured coded footprint
    // — the below-the-floor bits/index the `#ec` Pareto points report.
    println!();
    {
        use kbitscale::quant::entropy::EncodedTensor;
        use kbitscale::quant::packing::PackedTensor;
        use kbitscale::util::progress::bench_best;
        use kbitscale::util::rng::Rng;

        let (kd, nn) = (768usize, 768usize);
        let mut rng = Rng::new(7);
        let mut w = vec![0.0f32; kd * nn];
        rng.fill_normal(&mut w, 0.05);
        let p = PackedTensor::quantize(&w, &QuantSpec::new(DataType::Fp, 4, Some(64)))?;
        let e = EncodedTensor::encode(&p)?;
        let mut decoded = vec![0.0f32; e.n];
        let t_packed = bench_best(1, 7, || {
            p.dequantize_into(&mut decoded).unwrap();
            std::hint::black_box(&decoded);
        });
        let t_coded = bench_best(1, 7, || {
            e.dequantize_into(&mut decoded).unwrap();
            std::hint::black_box(&decoded);
        });
        let coded_bpi = e.payload_bits() as f64 / e.n as f64;
        println!(
            "entropy decode ({} elems): packed {:.3} ms ({:.2} GB/s) | coded {:.3} ms \
             ({:.2} GB/s) | {coded_bpi:.3} coded bits/index vs {} nominal | \
             resident {} B vs {} B packed",
            e.n,
            t_packed * 1e3,
            (e.n * 4) as f64 / t_packed / 1e9,
            t_coded * 1e3,
            (e.n * 4) as f64 / t_coded / 1e9,
            e.bits,
            e.resident_bytes(),
            p.resident_bytes(),
        );
        snap.insert(
            "entropy".to_string(),
            Json::obj(vec![
                ("elements", Json::Num(e.n as f64)),
                ("packed_decode_ms", Json::Num(t_packed * 1e3)),
                ("coded_decode_ms", Json::Num(t_coded * 1e3)),
                ("coded_gbps_f32_out", Json::Num((e.n * 4) as f64 / t_coded / 1e9)),
                ("coded_bits_per_index", Json::Num(coded_bpi)),
                ("nominal_bits_per_index", Json::Num(e.bits as f64)),
                ("coded_resident_bytes", Json::Num(e.resident_bytes() as f64)),
                ("packed_resident_bytes", Json::Num(p.resident_bytes() as f64)),
            ]),
        );
    }

    // --- streamed vs buffered multi-row responses -----------------------
    println!();
    let (buf_first, buf_total, _) = stream_trial(&registry, 48, false, false)?;
    let (str_first, str_total, json_bytes) = stream_trial(&registry, 48, true, false)?;
    println!(
        "48-row request: buffered first/total {buf_first:.1}/{buf_total:.1} ms | \
         streamed first/total {str_first:.1}/{str_total:.1} ms \
         (first-scores {:.1}x sooner)",
        buf_first / str_first.max(1e-9)
    );

    // --- binary score frames (bin1) vs JSON chunk lines -----------------
    println!();
    let (bin_first, _, bin_bytes) = stream_trial(&registry, 48, true, true)?;
    println!(
        "48-row stream, chunk payload bytes on the wire: json {json_bytes} B | \
         bin1 {bin_bytes} B ({:.2}x smaller; first-chunk {str_first:.1} vs {bin_first:.1} ms)",
        json_bytes as f64 / bin_bytes.max(1) as f64
    );
    snap.insert(
        "frames".to_string(),
        Json::obj(vec![
            ("json_chunk_bytes", Json::Num(json_bytes as f64)),
            ("bin1_chunk_bytes", Json::Num(bin_bytes as f64)),
            ("json_first_chunk_ms", Json::Num(str_first)),
            ("bin1_first_chunk_ms", Json::Num(bin_first)),
        ]),
    );

    // --- eviction churn: budget holds ~one variant ----------------------
    println!();
    let budget = h0.resident_bytes() + h0.resident_bytes() / 4;
    let churn = ModelRegistry::new(&rt, &manifest, make_loader(&manifest))
        .with_memory_budget(Some(budget));
    let specs = [
        QuantSpec::new(DataType::Fp, 4, Some(64)),
        QuantSpec::new(DataType::Int, 3, Some(32)),
        QuantSpec::new(DataType::Int, 4, Some(64)),
    ];
    let t = Instant::now();
    let mut loads = 0usize;
    for _ in 0..2 {
        for spec in &specs {
            churn.load("gpt2like", "t0", spec.clone())?;
            loads += 1;
        }
    }
    println!(
        "eviction churn: budget {budget} B, {loads} loads -> {} evictions, {} resident \
         ({} B), {:.2}s total rebuild cost",
        churn.evictions(),
        churn.len(),
        churn.resident_bytes_total(),
        t.elapsed().as_secs_f64()
    );

    // --- tuned policy vs fixed precision under one byte budget ----------
    println!();
    {
        use kbitscale::data::corpus::Corpus;
        use kbitscale::eval::{EvalConfig, EvalSuite};
        use kbitscale::tune::{self, TuneConfig, TuneTarget};

        // A quick ppl-only calibration search on init params exercises
        // the autotuner end to end; its policy then drives serving
        // against fixed residents under the same byte budget.
        let corpus = Corpus::for_geometry(manifest.vocab, manifest.seq);
        let cfg = TuneConfig {
            bits: vec![3, 4, 8],
            dtypes: vec![DataType::Fp],
            blocks: vec![Some(64)],
            stage_mixes: false,
            entropy: false,
            suite: EvalSuite::Ppl,
            eval: EvalConfig { ppl_sequences: 4, zs_examples: 4 },
            threads: 2,
        };
        let t = Instant::now();
        let report = tune::search(
            &rt,
            &manifest,
            &corpus,
            &|f: &str, tr: &str| Ok(init_params(manifest.tier(tr)?, Family::get(f)?)),
            &[TuneTarget::new("gpt2like", "t0")],
            &cfg,
            None,
        )?;
        println!(
            "tune: {} cells in {:.1}s -> {} frontier entries",
            report.points.len(),
            t.elapsed().as_secs_f64(),
            report.policy.entries.len()
        );
        // Budget: the 4-bit frontier entry's own estimated footprint —
        // the regime the paper's headline says 4-bit should win. (Falls
        // back to the smallest entry if 4-bit got out-measured.)
        let tier = manifest.tier("t0")?;
        let sized = report
            .policy
            .entries
            .iter()
            .find(|e| e.bits == 4 && e.stage_bits.is_none())
            .or_else(|| report.policy.entries.first())
            .expect("non-empty frontier");
        let model_budget = sized.estimated_model_bytes(tier);
        let mut rows: Vec<(String, f64, f64, usize)> = Vec::new();
        let tuned_reg = ModelRegistry::new(&rt, &manifest, make_loader(&manifest))
            .with_memory_budget(Some(model_budget))
            .with_policy(Some(report.policy.clone()));
        let (h, entry) = tuned_reg.load_auto("gpt2like", "t0")?;
        let (picked, tuned_bytes) = (entry.key(), h.resident_bytes());
        drop(h);
        let (rps, p50, _) = run_trial(&tuned_reg, 4, true, false, None)?;
        rows.push((format!("tuned policy pick ({picked})"), rps, p50, tuned_bytes));
        for (label, spec) in [
            ("fixed 4-bit fp/b64", QuantSpec::new(DataType::Fp, 4, Some(64))),
            ("fixed 16-bit baseline", QuantSpec::baseline16()),
        ] {
            let reg = ModelRegistry::new(&rt, &manifest, make_loader(&manifest))
                .with_memory_budget(Some(model_budget));
            let h = reg.load("gpt2like", "t0", spec.clone())?;
            let bytes = h.resident_bytes();
            drop(h);
            let (rps, p50, _) = run_trial(&reg, 4, true, false, None)?;
            // The registry budget only meters packed bytes (a baseline
            // keeps none), so flag rows whose *model* footprint breaks
            // the budget — the honest apples-to-apples column.
            let model_bytes = kbitscale::quant::bitcost::total_model_bits(
                &tier.param_sizes(),
                &tier.quantized_params,
                &spec,
            ) / 8.0;
            let label = if model_bytes as usize > model_budget {
                format!("{label} (EXCEEDS budget)")
            } else {
                label.to_string()
            };
            rows.push((label, rps, p50, bytes));
        }
        println!("policy serving under a {model_budget} B model-byte budget, 4 clients:");
        for (label, rps, p50, bytes) in &rows {
            println!(
                "  {label:<36} {rps:>8.1} req/s   p50 {p50:>6.2} ms   packed {bytes:>9} B"
            );
        }
    }

    // --- fleet: 1 worker vs 3 workers, same total byte budget -----------
    println!();
    {
        use kbitscale::fleet::{serve_fleet, Fleet, FleetOpts, WorkerSpec};

        // Worker "processes" are leaked registries served from detached
        // threads (alive until the bench exits), so the router sees
        // workers that serve forever — like real `serve --tcp` backends.
        let rt_fleet: &'static Runtime = Box::leak(Box::new(Runtime::cpu()?));
        let manifest_fleet: &'static Manifest = Box::leak(Box::new(manifest.clone()));
        let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
        let per_variant = h0.resident_bytes() + h0.resident_bytes() / 4;
        let total_budget = per_variant * 3;
        const CLIENTS: usize = 4;
        println!(
            "fleet scaling: {CLIENTS} clients via the router, {total_budget} B total fleet budget"
        );
        let mut base_rps = 0.0f64;
        for &n_workers in &[1usize, 3] {
            let worker_budget = total_budget / n_workers;
            let mut specs = Vec::new();
            let mut key = String::new();
            for _ in 0..n_workers {
                let reg: &'static ModelRegistry<'static> = Box::leak(Box::new(
                    ModelRegistry::new(rt_fleet, manifest_fleet, make_loader(manifest_fleet))
                        .with_memory_budget(Some(worker_budget)),
                ));
                key = reg.load("gpt2like", "t0", spec.clone())?.key();
                let listener = TcpListener::bind("127.0.0.1:0")?;
                let addr = listener.local_addr()?.to_string();
                let wo: &'static ServeOpts = Box::leak(Box::new(ServeOpts {
                    workers: CLIENTS,
                    flush: Duration::from_millis(1),
                    batching: true,
                    max_conns: None,
                    io_timeout: Some(Duration::from_secs(30)),
                }));
                std::thread::spawn(move || {
                    let _ = serve_listener(reg, listener, wo);
                });
                specs.push(WorkerSpec { addr, budget: Some(worker_budget) });
            }
            let fleet: &'static Fleet = Box::leak(Box::new(Fleet::new(
                manifest_fleet,
                specs,
                None,
                FleetOpts {
                    probe_interval: Duration::from_secs(60),
                    max_conns: Some(CLIENTS as u64),
                    ..FleetOpts::default()
                },
            )));
            fleet.probe();
            let router_listener = TcpListener::bind("127.0.0.1:0")?;
            let router_addr = router_listener.local_addr()?;
            let mut lats: Vec<f64> = Vec::new();
            let t0w = Instant::now();
            std::thread::scope(|s| -> anyhow::Result<()> {
                let router = s.spawn(move || serve_fleet(fleet, router_listener));
                let mut joins = Vec::new();
                let keyref = key.as_str();
                for c in 0..CLIENTS {
                    joins.push(s.spawn(move || client_run(router_addr, c, false, Some(keyref))));
                }
                for j in joins {
                    lats.extend(j.join().expect("client thread panicked")?);
                }
                router.join().expect("router thread panicked")?;
                Ok(())
            })?;
            let wall = t0w.elapsed().as_secs_f64();
            lats.sort_by(|a, b| a.total_cmp(b));
            let p50 = lats[((lats.len() - 1) as f64 * 0.5).round() as usize] * 1e3;
            let rps = (CLIENTS * REQS_PER_CLIENT) as f64 / wall;
            println!(
                "  {n_workers} worker(s) @ {worker_budget:>9} B each: {rps:>8.1} req/s   p50 {p50:>6.2} ms"
            );
            if n_workers == 1 {
                base_rps = rps;
            } else {
                println!(
                    "  {n_workers}-worker fleet vs 1 worker: {:.2}x (same total budget)",
                    rps / base_rps.max(1e-9)
                );
                snap.insert("fleet_3v1_speedup".to_string(), Json::Num(rps / base_rps.max(1e-9)));
            }
        }
    }

    // --- precision governor: live demote under synthetic pressure -------
    println!();
    {
        use kbitscale::fleet::{Fleet, FleetConn, FleetOpts, ManualClock, WorkerSpec};
        use kbitscale::tune::{PolicyEntry, TunedPolicy};
        use std::sync::Arc;

        let rt_gov: &'static Runtime = Box::leak(Box::new(Runtime::cpu()?));
        let manifest_gov: &'static Manifest = Box::leak(Box::new(manifest.clone()));
        let tier = manifest_gov.tier("t0")?;
        let entry = |bits: usize, metric: f64, bpp: f64| PolicyEntry {
            bits,
            dtype: DataType::Fp,
            block: Some(64),
            stage_bits: None,
            entropy: false,
            metric,
            total_bits: bpp * tier.param_count as f64,
            bits_per_param: bpp,
        };
        let policy = TunedPolicy {
            suite: "ppl".into(),
            tuned_on: vec!["gpt2like_t0".into()],
            entries: vec![entry(4, 0.55, 4.25), entry(16, 0.60, 16.0)],
            classes: Default::default(),
        };
        let reg: &'static ModelRegistry<'static> = Box::leak(Box::new(ModelRegistry::new(
            rt_gov,
            manifest_gov,
            make_loader(manifest_gov),
        )));
        reg.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 16, None))?;
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let wo: &'static ServeOpts = Box::leak(Box::new(ServeOpts {
            workers: 2,
            flush: Duration::from_millis(1),
            batching: true,
            max_conns: None,
            io_timeout: Some(Duration::from_secs(30)),
        }));
        std::thread::spawn(move || {
            let _ = serve_listener(reg, listener, wo);
        });

        // Manual clock: the spike, the tick, and the cooldown re-tick are
        // deterministic rather than wall-time dependent.
        let clock = Arc::new(ManualClock::new(0));
        let fleet = Fleet::new(
            manifest_gov,
            vec![WorkerSpec { addr, budget: None }],
            Some(policy),
            FleetOpts {
                probe_interval: Duration::from_secs(60),
                push_policy: false,
                govern: true,
                target_p99_ms: 50.0,
                cooldown_ms: 1_000,
                ..FleetOpts::default()
            },
        )
        .with_clock(clock);
        fleet.probe();
        let mut conn = FleetConn::new(&fleet);
        let req =
            Json::parse(r#"{"op":"score","model":"gpt2like_t0","tokens":[1,5,9,2,7,4,8,3]}"#)?;
        let mut governed_p50 = |n: usize| -> anyhow::Result<f64> {
            let mut lats: Vec<f64> = Vec::with_capacity(n);
            for _ in 0..n {
                let t = Instant::now();
                let resp = conn.handle(&req);
                anyhow::ensure!(resp.opt("error").is_none(), "governed score failed: {resp:?}");
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            lats.sort_by(|a, b| a.total_cmp(b));
            Ok(lats[(lats.len() - 1) / 2])
        };
        let p50_steady = governed_p50(32)?;
        // Synthetic spike at twice the target p99: one governed demote.
        // The tick's wall time is the full cutover cost, 4-bit pre-warm
        // load included (traffic only moves after the load lands).
        for _ in 0..16 {
            fleet.telemetry().record_router(100.0);
        }
        let t = Instant::now();
        let decisions = fleet.govern_tick();
        let demote_tick_ms = t.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(decisions.len() == 1, "expected one demote, got {decisions:?}");
        let p50_demoted = governed_p50(32)?;
        let flaps = fleet.govern_tick().len();
        println!(
            "governor: steady 16-bit p50 {p50_steady:.2} ms | demote tick (incl. pre-warm) \
             {demote_tick_ms:.1} ms -> {} | demoted 4-bit p50 {p50_demoted:.2} ms | \
             migrations on immediate re-tick (cooldown): {flaps}",
            decisions[0].to
        );
        snap.insert(
            "governor".to_string(),
            Json::obj(vec![
                ("p50_steady_ms", Json::Num(p50_steady)),
                ("p50_demoted_ms", Json::Num(p50_demoted)),
                ("demote_tick_ms", Json::Num(demote_tick_ms)),
                ("migrations", Json::Num(decisions.len() as f64)),
                ("flaps_in_cooldown", Json::Num(flaps as f64)),
            ]),
        );
        // The fleet's own latency accounting for the governed traffic
        // (the same block `{"op":"stats"}` reports).
        snap.insert("latency".to_string(), fleet.telemetry().to_json());
    }

    if let Some(path) = json_path {
        let doc = Json::obj(vec![
            ("bench", Json::str("serve_throughput")),
            // Honest provenance: true only when this process produced the
            // numbers above (the checked-in baseline starts as false).
            ("measured", Json::Bool(true)),
            ("results", Json::Obj(snap)),
        ]);
        std::fs::write(&path, doc.dump() + "\n")?;
        println!("\nwrote {path}");
    }
    Ok(())
}

/// One trial: spin up the server for exactly `clients` connections, run
/// the clients concurrently, and collect per-request latencies. With
/// `repeat`, every client sends the same row every time (the cache's best
/// case); otherwise rows vary per client and request. `model` routes
/// every request to one resident variant (`None` = the registry default).
fn run_trial(
    registry: &ModelRegistry<'_>,
    clients: usize,
    batching: bool,
    repeat: bool,
    model: Option<&str>,
) -> anyhow::Result<(f64, f64, f64)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let opts = ServeOpts {
        // Batching off = the pre-registry sequential serving path: one
        // worker, each row its own forward execution.
        workers: if batching { clients } else { 1 },
        flush: Duration::from_millis(2),
        batching,
        max_conns: Some(clients as u64),
        ..ServeOpts::default()
    };
    let mut lats: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    std::thread::scope(|s| -> anyhow::Result<()> {
        let server = s.spawn(|| serve_listener(registry, listener, &opts));
        let mut joins = Vec::new();
        for c in 0..clients {
            joins.push(s.spawn(move || client_run(addr, c, repeat, model)));
        }
        for j in joins {
            lats.extend(j.join().expect("client thread panicked")?);
        }
        server.join().expect("server thread panicked")?;
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| lats[((lats.len() - 1) as f64 * q).round() as usize] * 1e3;
    Ok(((clients * REQS_PER_CLIENT) as f64 / wall, pct(0.50), pct(0.95)))
}

/// One multi-row request against a 1-client server: returns
/// `(ms to first scored line, ms total, chunk payload bytes)`. With
/// `stream`, the first line is the first chunk; buffered, the single
/// response is both. With `bin`, the connection negotiates `bin1` frames
/// first and chunk payloads arrive as binary frames; the byte count
/// covers chunk payloads only (requests, handshake, and the terminal
/// done-line are JSON in both modes).
fn stream_trial(
    registry: &ModelRegistry<'_>,
    rows: usize,
    stream: bool,
    bin: bool,
) -> anyhow::Result<(f64, f64, usize)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let opts = ServeOpts {
        workers: 1,
        flush: Duration::from_millis(1),
        batching: false,
        max_conns: Some(1),
        ..ServeOpts::default()
    };
    let mut first_ms = 0.0f64;
    let mut total_ms = 0.0f64;
    let mut chunk_bytes = 0usize;
    std::thread::scope(|s| -> anyhow::Result<()> {
        let server = s.spawn(|| serve_listener(registry, listener, &opts));
        let sock = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(sock.try_clone()?);
        let mut writer = sock;
        if bin {
            writeln!(writer, "{{\"op\":\"hello\",\"frames\":\"bin1\"}}")?;
            let mut reply = String::new();
            reader.read_line(&mut reply)?;
            anyhow::ensure!(reply.contains("\"bin1\""), "server refused bin1 frames: {reply}");
        }
        let row_json: Vec<String> = (0..rows)
            .map(|i| format!("[1,{},9,{},3]", 2 + i % 200, 5 + i % 100))
            .collect();
        let t0 = Instant::now();
        writeln!(
            writer,
            "{{\"op\":\"score\",\"rows\":[{}],\"stream\":{stream}}}",
            row_json.join(",")
        )?;
        let mut frame: Vec<u8> = Vec::new();
        loop {
            if reader.fill_buf()?.first() == Some(&frames::MAGIC) {
                frames::read_frame(&mut reader, &mut frame)?;
                chunk_bytes += frame.len();
                if first_ms == 0.0 {
                    first_ms = t0.elapsed().as_secs_f64() * 1e3;
                }
                continue;
            }
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                anyhow::bail!("server hung up mid-response");
            }
            if line.contains("\"error\"") {
                anyhow::bail!("server error: {line}");
            }
            if first_ms == 0.0 {
                first_ms = t0.elapsed().as_secs_f64() * 1e3;
            }
            if line.contains("\"chunk\"") {
                chunk_bytes += line.len();
            }
            // Buffered: the one response line. Streamed: stop on "done".
            if !stream || line.contains("\"done\":true") {
                break;
            }
        }
        total_ms = t0.elapsed().as_secs_f64() * 1e3;
        drop(writer);
        drop(reader);
        server.join().expect("server thread panicked")?;
        Ok(())
    })?;
    Ok((first_ms, total_ms, chunk_bytes))
}

fn client_run(
    addr: SocketAddr,
    c: usize,
    repeat: bool,
    model: Option<&str>,
) -> anyhow::Result<Vec<f64>> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let route = model.map(|m| format!(",\"model\":\"{m}\"")).unwrap_or_default();
    let mut lats = Vec::with_capacity(REQS_PER_CLIENT);
    for i in 0..REQS_PER_CLIENT {
        let t = Instant::now();
        if repeat {
            // Identical row across all clients and requests: after the
            // first forward, every request is a cache hit (when enabled).
            writeln!(writer, "{{\"op\":\"score\",\"tokens\":[1,2,9,5,3,7]{route}}}")?;
        } else {
            writeln!(
                writer,
                "{{\"op\":\"score\",\"tokens\":[1,{},9,{},3,7]{route}}}",
                2 + (c + i) % 200,
                5 + i % 100
            )?;
        }
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server hung up after {i} requests");
        }
        if line.contains("\"error\"") {
            anyhow::bail!("server error: {line}");
        }
        lats.push(t.elapsed().as_secs_f64());
    }
    Ok(lats)
}
