//! Serving throughput: requests/sec and p50/p95 latency for 1, 4, and 16
//! concurrent TCP clients, with micro-batching on (threaded workers +
//! cross-client coalescing) vs off (single worker, direct execution — the
//! pre-registry sequential serving path), plus the packed-vs-f32 resident
//! weight footprint of every variant hosted by the registry.
//!
//! Init-only parameters are used (throughput does not depend on training),
//! so this bench needs artifacts but no checkpoints.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use kbitscale::models::families::Family;
use kbitscale::models::init::init_params;
use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::runtime::Runtime;
use kbitscale::server::{serve_listener, ModelRegistry, ParamLoader, ServeOpts};

const REQS_PER_CLIENT: usize = 40;

fn main() -> anyhow::Result<()> {
    kbitscale::util::progress::init_logging();
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let rt = Runtime::cpu()?;
    let mref = manifest.clone();
    let loader: ParamLoader<'static> = Box::new(move |family: &str, tier: &str| {
        Ok(init_params(mref.tier(tier)?, Family::get(family)?))
    });
    let registry = ModelRegistry::new(&rt, &manifest, loader);
    let h0 = registry.load("gpt2like", "t0", QuantSpec::new(DataType::Fp, 4, Some(64)))?;
    // A second resident (tier x spec) variant: multi-model hosting in one
    // process is part of what is being measured.
    let h1 = registry.load("gpt2like", "t0", QuantSpec::new(DataType::Int, 3, Some(32)))?;

    println!("resident variants ({} in registry):", registry.len());
    for h in [&h0, &h1] {
        println!(
            "  {:<28} packed {:>10} B   f32 {:>10} B   ({:.2}x smaller)",
            h.key(),
            h.resident_bytes(),
            h.quantized_f32_bytes(),
            h.quantized_f32_bytes() as f64 / h.resident_bytes().max(1) as f64
        );
    }

    println!();
    println!(
        "{:<8} {:>9} {:>10} {:>10} {:>10}",
        "clients", "batching", "req/s", "p50 ms", "p95 ms"
    );
    let mut seq_1 = 0.0f64;
    let mut batched_4 = 0.0f64;
    for &clients in &[1usize, 4, 16] {
        for &batching in &[false, true] {
            let (rps, p50, p95) = run_trial(&registry, clients, batching)?;
            if clients == 1 && !batching {
                seq_1 = rps;
            }
            if clients == 4 && batching {
                batched_4 = rps;
            }
            println!(
                "{clients:<8} {:>9} {rps:>10.1} {p50:>10.2} {p95:>10.2}",
                if batching { "on" } else { "off" }
            );
        }
    }
    println!();
    println!(
        "batched 4-client throughput vs sequential path: {:.2}x (target >= 2x)",
        batched_4 / seq_1.max(1e-9)
    );
    Ok(())
}

/// One trial: spin up the server for exactly `clients` connections, run
/// the clients concurrently, and collect per-request latencies.
fn run_trial(
    registry: &ModelRegistry<'_>,
    clients: usize,
    batching: bool,
) -> anyhow::Result<(f64, f64, f64)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let opts = ServeOpts {
        // Batching off = the pre-registry sequential serving path: one
        // worker, each row its own forward execution.
        workers: if batching { clients } else { 1 },
        flush: Duration::from_millis(2),
        batching,
        max_conns: Some(clients as u64),
    };
    let mut lats: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    std::thread::scope(|s| -> anyhow::Result<()> {
        let server = s.spawn(|| serve_listener(registry, listener, &opts));
        let mut joins = Vec::new();
        for c in 0..clients {
            joins.push(s.spawn(move || client_run(addr, c)));
        }
        for j in joins {
            lats.extend(j.join().expect("client thread panicked")?);
        }
        server.join().expect("server thread panicked")?;
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| lats[((lats.len() - 1) as f64 * q).round() as usize] * 1e3;
    Ok(((clients * REQS_PER_CLIENT) as f64 / wall, pct(0.50), pct(0.95)))
}

fn client_run(addr: SocketAddr, c: usize) -> anyhow::Result<Vec<f64>> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut lats = Vec::with_capacity(REQS_PER_CLIENT);
    for i in 0..REQS_PER_CLIENT {
        let t = Instant::now();
        writeln!(
            writer,
            "{{\"op\":\"score\",\"tokens\":[1,{},9,{},3,7]}}",
            2 + (c + i) % 200,
            5 + i % 100
        )?;
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server hung up after {i} requests");
        }
        if line.contains("\"error\"") {
            anyhow::bail!("server error: {line}");
        }
        lats.push(t.elapsed().as_secs_f64());
    }
    Ok(lats)
}
