//! E13 — Appendix B: distribution centering is ineffective for weights.
//!
//! Sweeps centering on/off across data types at 4-bit on real checkpoints
//! (model-level CE) and on raw weight slices (RMS error), showing the
//! negative result: no consistent gain, at +16/block bits/param cost.

use kbitscale::bench_support::{default_tiers, BenchEnv};
use kbitscale::coordinator::GridBuilder;
use kbitscale::models::ModelId;
use kbitscale::quant::centering::report as centering_report;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::report::TextTable;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let family = "gpt2like";
    let gb = GridBuilder::new(vec![family], default_tiers());
    let results = env.run_grid_timed("appb", &gb.centering_sweep(4))?;

    let mut table = TextTable::new(&["tier", "dtype", "ce plain", "ce centered", "delta"]);
    for tier in default_tiers() {
        for dt in DataType::ALL {
            let find = |centered: bool| {
                results.iter().find(|r| {
                    r.tier == tier
                        && r.spec_key.starts_with(dt.name())
                        && r.spec_key.contains(":c") == centered
                })
            };
            if let (Some(p), Some(c)) = (find(false), find(true)) {
                table.row(vec![
                    tier.clone(),
                    dt.name().into(),
                    format!("{:.4}", p.ce),
                    format!("{:.4}", c.ce),
                    format!("{:+.4}", c.ce - p.ce),
                ]);
            }
        }
    }
    println!("Appendix B analog: centering on/off, model-level CE ({family}):");
    println!("{}", table.render());

    // Weight-level view on a real checkpoint tensor.
    let (params, _) = env.checkpoints.load(&ModelId::new(family, "t1"))?;
    let fc1 = &params.iter().find(|(n, _)| n == "fc1").unwrap().1;
    let spec = QuantSpec::new(DataType::Int, 4, Some(64));
    let r = centering_report(fc1.data(), &spec);
    println!(
        "weight-level (fc1): plain rms {:.6}, centered rms {:.6} ({:+.1}%), cost +{:.2} bits/param",
        r.plain_rms,
        r.centered_rms,
        r.rel_change * 100.0,
        r.extra_bits_per_param
    );
    println!("paper shape: deltas hover around zero — centering does not help weights.");
    Ok(())
}
