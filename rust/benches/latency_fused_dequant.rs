//! E14 — §2.1 latency claim: fused dequant-matmul bits-loaded ratio and
//! CPU wall-clock, plus the L3 quantization hot-path throughput numbers
//! recorded in EXPERIMENTS.md §Perf.
//!
//! The paper's 4.46x OPT-175B speedup is a memory-bandwidth effect; the
//! CPU interpret path validates numerics + storage layout, and the
//! bits-loaded column is the hardware-independent quantity the claim is
//! proportional to.
//!
//! A native section (no artifacts needed) times the `quant::fused`
//! dequantize-matmul kernel — decode-only scalar vs AVX2, tiled vs
//! untiled, 1/2/4 scoring threads, and the classic `dequantize_into` +
//! GEMM composition — and spot-checks that every path produces
//! bit-identical outputs.

use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::{Codebook, DataType};
use kbitscale::quant::packing::{pack4_rows, pack_bits, unpack_bits};
use kbitscale::quant::{blockwise, QuantSpec};
use kbitscale::runtime::{lit_f32, lit_u8, Runtime};
use kbitscale::tensor::Tensor;
use kbitscale::util::progress::bench_best;
use kbitscale::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // ---- L3 hot path: blockwise quantize/dequantize throughput ----
    let mut rng = Rng::new(1);
    let n = 4_000_000usize;
    let mut w = vec![0.0f32; n];
    rng.fill_normal(&mut w, 0.05);
    println!("L3 quantization hot path ({}M f32 values):", n / 1_000_000);
    println!("{:<26} {:>12} {:>14}", "config", "ms", "GB/s (f32 in)");
    for (label, spec) in [
        ("int4 block 64", QuantSpec::new(DataType::Int, 4, Some(64))),
        ("fp4 block 64", QuantSpec::new(DataType::Fp, 4, Some(64))),
        ("quantile4 block 64", QuantSpec::new(DataType::Quantile, 4, Some(64))),
        ("dynexp4 block 64", QuantSpec::new(DataType::DynExp, 4, Some(64))),
        ("fp8 block 64", QuantSpec::new(DataType::Fp, 8, Some(64))),
        ("fp4 tensor-wise", QuantSpec::new(DataType::Fp, 4, None)),
    ] {
        let dt = bench_best(1, 5, || {
            let q = blockwise::quantize(&w, &spec);
            std::hint::black_box(&q);
        });
        println!(
            "{label:<26} {:>12.1} {:>14.2}",
            dt * 1e3,
            (n * 4) as f64 / dt / 1e9
        );
    }
    let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
    let q = blockwise::quantize(&w, &spec);
    let mut out = vec![0.0f32; n];
    let dt = bench_best(1, 5, || blockwise::dequantize(&q, &mut out));
    println!("{:<26} {:>12.1} {:>14.2}", "dequantize fp4 b64", dt * 1e3, (n * 4) as f64 / dt / 1e9);
    let dtp = bench_best(1, 5, || {
        std::hint::black_box(pack_bits(&q.idx, 4).unwrap());
    });
    println!("{:<26} {:>12.1} {:>14.2}", "pack 4-bit stream", dtp * 1e3, (n * 4) as f64 / dtp / 1e9);
    let packed = pack_bits(&q.idx, 4)?;
    let dtu = bench_best(1, 5, || {
        std::hint::black_box(unpack_bits(&packed, 4, n).unwrap());
    });
    println!("{:<26} {:>12.1} {:>14.2}", "unpack 4-bit stream", dtu * 1e3, (n * 4) as f64 / dtu / 1e9);

    // ---- Decode-only: vectorized bitstream decode (scalar vs AVX2) ----
    {
        use kbitscale::quant::fused::{self, Backend};
        use kbitscale::quant::packing::PackedTensor;

        let p = PackedTensor::from_quantized(&q)?;
        println!("\ndecode_range ({}M fp4 b64 elements -> f32):", n / 1_000_000);
        println!("{:<26} {:>12} {:>14}", "backend", "ms", "GB/s (f32 out)");
        let t_sc = bench_best(1, 7, || {
            fused::decode_range_with(Backend::Scalar, &p, 0, p.n, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        println!("{:<26} {:>12.1} {:>14.2}", "scalar", t_sc * 1e3, (n * 4) as f64 / t_sc / 1e9);
        if fused::avx2_available() {
            let t_vx = bench_best(1, 7, || {
                fused::decode_range_with(Backend::Avx2, &p, 0, p.n, &mut out).unwrap();
                std::hint::black_box(&out);
            });
            println!(
                "{:<26} {:>12.1} {:>14.2}",
                "avx2 gather",
                t_vx * 1e3,
                (n * 4) as f64 / t_vx / 1e9
            );
            println!("avx2 decode speedup: {:.2}x over scalar", t_sc / t_vx);
        } else {
            println!("{:<26} {:>12}", "avx2 gather", "n/a (no AVX2)");
        }
    }

    // ---- Native fused dequant-matmul kernel (no artifacts needed) ----
    {
        use kbitscale::quant::fused::{self, Backend, Tiling};
        use kbitscale::quant::packing::PackedTensor;

        let (m, kd, nn) = (8usize, 1024usize, 1024usize);
        let mut x = vec![0.0f32; m * kd];
        let mut wn = vec![0.0f32; kd * nn];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut wn, 0.05);
        let p = PackedTensor::quantize(&wn, &QuantSpec::new(DataType::Fp, 4, Some(64)))?;
        let backend = fused::active_backend();
        let tile = Tiling::for_geometry(m, kd, nn);
        println!("\nnative fused kernel ({m}x{kd}x{nn}, fp4 b64, auto backend {backend:?}):");
        println!("{:<26} {:>12}", "path", "ms");
        let mut dense = vec![0.0f32; kd * nn];
        let mut out = vec![0.0f32; m * nn];
        let mut wrow: Vec<f32> = Vec::new();
        let t_unfused = bench_best(2, 9, || {
            p.dequantize_into(&mut dense).unwrap();
            out.fill(0.0);
            fused::matmul_f32_with(Backend::Scalar, &x, &dense, &mut out, m, kd, nn);
            std::hint::black_box(&out);
        });
        println!("{:<26} {:>12.2}", "dequantize_into + GEMM", t_unfused * 1e3);
        let t_scalar = bench_best(2, 9, || {
            out.fill(0.0);
            fused::fused_matmul_untiled(Backend::Scalar, &x, &p, &mut out, m, kd, nn, &mut wrow)
                .unwrap();
            std::hint::black_box(&out);
        });
        println!("{:<26} {:>12.2}", "fused scalar untiled", t_scalar * 1e3);
        if fused::avx2_available() {
            let t_avx = bench_best(2, 9, || {
                out.fill(0.0);
                fused::fused_matmul_untiled(Backend::Avx2, &x, &p, &mut out, m, kd, nn, &mut wrow)
                    .unwrap();
                std::hint::black_box(&out);
            });
            println!("{:<26} {:>12.2}", "fused avx2 untiled", t_avx * 1e3);
        } else {
            println!("{:<26} {:>12}", "fused avx2 untiled", "n/a (no AVX2)");
        }
        // Tiled (cache-blocked) vs the untiled row-streaming loop, on the
        // auto backend: the PR's headline kernel comparison.
        let t_untiled = bench_best(2, 9, || {
            out.fill(0.0);
            fused::fused_matmul_untiled(backend, &x, &p, &mut out, m, kd, nn, &mut wrow).unwrap();
            std::hint::black_box(&out);
        });
        let t_tiled = bench_best(2, 9, || {
            out.fill(0.0);
            fused::fused_matmul_tiled(backend, tile, &x, &p, &mut out, m, kd, nn, &mut wrow)
                .unwrap();
            std::hint::black_box(&out);
        });
        println!("{:<26} {:>12.2}", "fused untiled (auto)", t_untiled * 1e3);
        println!(
            "{:<26} {:>12.2}   ({:?}, {:.2}x vs untiled)",
            "fused tiled (auto)",
            t_tiled * 1e3,
            tile,
            t_untiled / t_tiled
        );
        // Thread scaling: deterministic column split, bit-identical by
        // construction, so this row is pure wall-clock.
        for threads in [1usize, 2, 4] {
            let t_par = bench_best(2, 9, || {
                out.fill(0.0);
                fused::fused_matmul_parallel(&x, &p, &mut out, m, kd, nn, threads, &mut wrow)
                    .unwrap();
                std::hint::black_box(&out);
            });
            println!("{:<26} {:>12.2}", format!("fused tiled {threads} thread(s)"), t_par * 1e3);
        }
        // Bit-identity spot check: the honest part of the speedup claim.
        let mut a = vec![0.0f32; m * nn];
        p.dequantize_into(&mut dense)?;
        fused::matmul_f32_with(Backend::Scalar, &x, &dense, &mut a, m, kd, nn);
        let mut b = vec![0.0f32; m * nn];
        fused::fused_matmul_with(Backend::Scalar, &x, &p, &mut b, m, kd, nn, &mut wrow)?;
        anyhow::ensure!(a == b, "scalar fused output diverged from dequantize_into + GEMM");
        if fused::avx2_available() {
            let mut c = vec![0.0f32; m * nn];
            fused::fused_matmul_with(Backend::Avx2, &x, &p, &mut c, m, kd, nn, &mut wrow)?;
            anyhow::ensure!(a == c, "avx2 fused output diverged from the scalar reference");
        }
        for threads in [2usize, 4] {
            let mut d = vec![0.0f32; m * nn];
            fused::fused_matmul_parallel(&x, &p, &mut d, m, kd, nn, threads, &mut wrow)?;
            anyhow::ensure!(a == d, "{threads}-thread fused output diverged from the reference");
        }
        println!("bit-identity: all fused paths agree on {} outputs", m * nn);
    }

    // ---- Fused kernel path (needs artifacts) ----
    let Ok(manifest) = Manifest::load(std::path::Path::new("artifacts")) else {
        println!("\n(artifacts missing — skipping fused-kernel section; run `make artifacts`)");
        return Ok(());
    };
    let km = &manifest.kernels;
    let (m, k, nn, qb) = (km.m, km.k, km.n, km.qblock);
    let rt = Runtime::cpu()?;

    let mut x = vec![0.0f32; m * k];
    let mut wk = vec![0.0f32; k * nn];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut wk, 0.05);
    let cb = Codebook::build(DataType::Fp, 4, None)?;
    let mut idx = vec![0u8; k * nn];
    let mut amax = vec![0.0f32; (k / qb) * nn];
    for c in 0..nn {
        for b in 0..k / qb {
            let mut a = 0.0f32;
            for r in b * qb..(b + 1) * qb {
                a = a.max(wk[r * nn + c].abs());
            }
            let a = if a == 0.0 { 1.0 } else { a };
            amax[b * nn + c] = a;
            for r in b * qb..(b + 1) * qb {
                idx[r * nn + c] = cb.assign(wk[r * nn + c] / a);
            }
        }
    }
    let packed4 = pack4_rows(&idx, k, nn)?;
    let x_t = Tensor::new(vec![m, k], x);
    let w_t = Tensor::new(vec![k, nn], wk);
    let amax_t = Tensor::new(vec![k / qb, nn], amax);
    let cb_t = Tensor::new(vec![km.codebook_pad], cb.padded_values(km.codebook_pad));

    let f32_exe = rt.load(&manifest.hlo_path(&km.f32_hlo))?;
    let u8_exe = rt.load(&manifest.hlo_path(&km.u8_hlo))?;
    let p4_exe = rt.load(&manifest.hlo_path(&km.packed4_hlo))?;
    let reps = 15;
    let t_f32 = bench_best(2, reps, || {
        rt.execute(&f32_exe, &[lit_f32(&x_t).unwrap(), lit_f32(&w_t).unwrap()]).unwrap();
    });
    let t_u8 = bench_best(2, reps, || {
        rt.execute(&u8_exe, &[
            lit_f32(&x_t).unwrap(),
            lit_u8(&[k, nn], &idx).unwrap(),
            lit_f32(&amax_t).unwrap(),
            lit_f32(&cb_t).unwrap(),
        ]).unwrap();
    });
    let t_p4 = bench_best(2, reps, || {
        rt.execute(&p4_exe, &[
            lit_f32(&x_t).unwrap(),
            lit_u8(&[k / 2, nn], &packed4).unwrap(),
            lit_f32(&amax_t).unwrap(),
            lit_f32(&cb_t).unwrap(),
        ]).unwrap();
    });

    let bits = |wb: f64| (k * nn) as f64 * wb + ((k / qb) * nn * 32) as f64;
    println!("\nfused kernel path ({m}x{k}x{nn}, qblock {qb}):");
    println!("{:<22} {:>10} {:>18}", "variant", "wall (ms)", "bits-loaded ratio");
    println!("{:<22} {:>10.2} {:>18.2}", "f32 matmul", t_f32 * 1e3, 1.0);
    println!("{:<22} {:>10.2} {:>18.2}", "u8-idx dequant", t_u8 * 1e3, (k * nn * 32) as f64 / bits(8.0));
    println!("{:<22} {:>10.2} {:>18.2}", "packed4 dequant", t_p4 * 1e3, (k * nn * 32) as f64 / bits(4.0));
    println!("\npaper: 3-bit CUDA kernels gave 4.46x vs 16-bit (5.33x bits ratio);");
    println!("here the 4-bit packed path moves 7.53x fewer weight bits.");
    Ok(())
}
