//! [`TunedPolicy`]: the serialized product of a tuning run — the Pareto
//! frontier of the measured config space, ready to drive serving.
//!
//! A policy is a list of [`PolicyEntry`]s sorted by bits-per-param, each
//! one frontier point of the accuracy-vs-size trade-off: *"below this
//! many model bytes, this is the best measured configuration"*. The
//! serving layer resolves `{"op":"load","auto":true}` by picking the
//! highest-metric entry whose estimated footprint fits the registry's
//! byte headroom — because only frontier points are stored, that pick can
//! never be a dominated configuration, for any budget.
//!
//! The artifact is plain JSON (`kbitscale tune --out runs/policy.json`,
//! `kbitscale serve --policy runs/policy.json`), so operators can
//! inspect, diff, and hand-edit it; [`TunedPolicy::from_json`] re-checks
//! Pareto consistency on every load so a hand-edited file cannot smuggle
//! a dominated entry back in.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::models::manifest::TierManifest;
use crate::quant::{DataType, QuantSpec};
use crate::server::registry::{spec_from_parts, PlanRequest};
use crate::util::json::Json;
use crate::util::order::nan_last_cmp;

/// One frontier point: a full serving configuration plus the measured
/// numbers that earned it its place.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyEntry {
    /// Quantization bit width (>= 16 = unquantized baseline). For staged
    /// entries this is the narrowest quantized stage width.
    pub bits: usize,
    pub dtype: DataType,
    /// Block size; `None` = tensor-wise.
    pub block: Option<usize>,
    /// Per-stage widths for pipeline-sharded serving; `None` = the
    /// monolithic plan.
    pub stage_bits: Option<Vec<usize>>,
    /// Deploy entropy-coded residency (`#ec`): lossless Huffman coding of
    /// the packed indices, so the metric matches the uncoded twin while
    /// the measured bits (and the footprint estimate) drop below `k`.
    pub entropy: bool,
    /// The calibration metric maximized by [`TunedPolicy::pick`] (mean
    /// zero-shot accuracy, or negative CE for ppl-only tuning). Policies
    /// distilled by `tune::frontier_policy` center each model's metrics
    /// on its own mean before aggregating across scales, so this is a
    /// *relative* score — only its ordering within one policy matters.
    pub metric: f64,
    /// Resident model bits measured at tune time (info; tier-specific).
    pub total_bits: f64,
    /// `total_bits / param_count` at tune time — the transferable size
    /// axis used to estimate this config's footprint on any tier.
    pub bits_per_param: f64,
}

impl PolicyEntry {
    /// The quantization spec this entry deploys (validated like the
    /// serving boundary's `spec_from_parts` — the one defaulting rule).
    pub fn spec(&self) -> Result<QuantSpec> {
        spec_from_parts(self.bits, self.dtype, self.block)
    }

    /// The plan shape this entry deploys (pipeline iff staged).
    pub fn plan_request(&self) -> PlanRequest {
        PlanRequest {
            pipeline: self.stage_bits.is_some(),
            stage_bits: self.stage_bits.clone(),
            fused: false,
            entropy: self.entropy,
        }
    }

    /// Human identity, matching the registry-key spelling:
    /// `fp:4:b64`, `fp:4:b64#pipe[16,4]`, `fp:4:b64#ec`.
    pub fn key(&self) -> String {
        let spec = self
            .spec()
            .map(|s| s.key())
            .unwrap_or_else(|_| format!("{}:{}", self.dtype.name(), self.bits));
        format!("{spec}{}", self.plan_request().suffix())
    }

    /// Estimated resident model bytes of this config on `tier`, from the
    /// measured bits-per-param. This is *model* bytes (quantized and
    /// pass-through tensors both counted), deliberately an over-estimate
    /// of the registry's packed-byte accounting, so budget-driven picks
    /// err conservative.
    pub fn estimated_model_bytes(&self, tier: &TierManifest) -> usize {
        (self.bits_per_param * tier.param_count as f64 / 8.0).ceil() as usize
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bits", Json::num(self.bits as f64)),
            ("dtype", Json::str(self.dtype.name())),
            (
                "block",
                match self.block {
                    Some(b) => Json::num(b as f64),
                    None => Json::Null,
                },
            ),
            (
                "stage_bits",
                match &self.stage_bits {
                    Some(v) => Json::Arr(v.iter().map(|&b| Json::num(b as f64)).collect()),
                    None => Json::Null,
                },
            ),
            ("entropy", Json::Bool(self.entropy)),
            ("metric", Json::num(self.metric)),
            ("total_bits", Json::num(self.total_bits)),
            ("bits_per_param", Json::num(self.bits_per_param)),
        ])
    }

    fn from_json(j: &Json) -> Result<PolicyEntry> {
        let block = match j.get("block")? {
            Json::Null => None,
            v => match v.as_usize()? {
                0 => None,
                b => Some(b),
            },
        };
        let stage_bits = match j.get("stage_bits")? {
            Json::Null => None,
            v => Some(v.usizes()?),
        };
        // Absent in policies written before entropy coding existed.
        let entropy = match j.opt("entropy") {
            Some(v) => v.as_bool()?,
            None => false,
        };
        let e = PolicyEntry {
            bits: j.get("bits")?.as_usize()?,
            dtype: DataType::parse(j.get("dtype")?.as_str()?)?,
            block,
            stage_bits,
            entropy,
            metric: j.get("metric")?.as_f64()?,
            total_bits: j.get("total_bits")?.as_f64()?,
            bits_per_param: j.get("bits_per_param")?.as_f64()?,
        };
        // A policy entry must be deployable: the spec it names has to
        // build a codebook now, not when a load request arrives.
        e.spec().with_context(|| format!("policy entry {} names an unbuildable spec", e.key()))?;
        Ok(e)
    }
}

/// The tuned serving policy: the measured Pareto frontier, serialized.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedPolicy {
    /// Eval suite the metric came from (`ppl` or `ppl_zs`).
    pub suite: String,
    /// Model keys (`family_tier`) the search measured.
    pub tuned_on: Vec<String>,
    /// Frontier entries, sorted by `bits_per_param` ascending with
    /// strictly increasing metric (the Pareto invariant).
    pub entries: Vec<PolicyEntry>,
    /// Optional per-workload-class frontiers (capability loss under
    /// quantization is task-dependent): `{"op":"score"}` requests
    /// tagged `"class":"name"` resolve against `classes["name"]` when
    /// present, falling back to the global `entries` otherwise. Each
    /// class frontier obeys the same Pareto invariant. Empty for
    /// global-only policies — and omitted from the serialization, so
    /// pre-class artifacts keep their fingerprint.
    pub classes: BTreeMap<String, Vec<PolicyEntry>>,
}

/// The frontier-optimal entry of `entries` for `tier` under a byte
/// budget — the shared selection core of [`TunedPolicy::pick`] and
/// [`TunedPolicy::pick_for_class`].
fn pick_from<'a>(
    entries: &'a [PolicyEntry],
    tier: &TierManifest,
    budget_bytes: Option<usize>,
) -> Option<&'a PolicyEntry> {
    let n_stages = tier.stages.len();
    entries
        .iter()
        .filter(|e| match &e.stage_bits {
            None => true,
            Some(v) => v.len() == n_stages,
        })
        .filter(|e| match budget_bytes {
            None => true,
            Some(b) => e.estimated_model_bytes(tier) <= b,
        })
        .max_by(|a, b| nan_last_cmp(a.metric, b.metric))
}

/// The Pareto-invariant check for one frontier (`label` names it in
/// the error: the global frontier or a workload class).
fn validate_entries(label: &str, entries: &[PolicyEntry]) -> Result<()> {
    for w in entries.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        if !(a.bits_per_param < b.bits_per_param) || !(a.metric < b.metric) {
            bail!(
                "{label} is not Pareto-consistent: {} ({:.3} bits/param, metric {:.4}) \
                 vs {} ({:.3} bits/param, metric {:.4})",
                a.key(),
                a.bits_per_param,
                a.metric,
                b.key(),
                b.bits_per_param,
                b.metric
            );
        }
    }
    if entries.iter().any(|e| e.metric.is_nan() || !e.bits_per_param.is_finite()) {
        bail!("{label} contains non-finite entries");
    }
    Ok(())
}

impl TunedPolicy {
    /// Pick the frontier-optimal entry for `tier` under a byte budget
    /// (`None` = unbounded): the highest-metric entry whose estimated
    /// footprint fits, skipping staged entries whose width vector does
    /// not match the tier's declared stage count. Returns `None` when
    /// nothing fits.
    pub fn pick(&self, tier: &TierManifest, budget_bytes: Option<usize>) -> Option<&PolicyEntry> {
        pick_from(&self.entries, tier, budget_bytes)
    }

    /// [`TunedPolicy::pick`] against a workload class's own frontier.
    /// A class with no frontier of its own (or no class tag at all)
    /// resolves against the global entries — tagging a request can
    /// specialize the pick, never brick it.
    pub fn pick_for_class(
        &self,
        class: Option<&str>,
        tier: &TierManifest,
        budget_bytes: Option<usize>,
    ) -> Option<&PolicyEntry> {
        let entries = class
            .and_then(|c| self.classes.get(c))
            .map(Vec::as_slice)
            .unwrap_or(&self.entries);
        pick_from(entries, tier, budget_bytes)
    }

    /// Check the Pareto invariant: entries sorted by `bits_per_param`
    /// ascending must have strictly increasing metric — otherwise some
    /// entry is dominated (same-or-more bits, same-or-less metric) and a
    /// budget exists at which `pick` could do strictly better smaller.
    /// Every per-class frontier is held to the same invariant.
    pub fn validate(&self) -> Result<()> {
        validate_entries("policy", &self.entries)?;
        for (class, entries) in &self.classes {
            validate_entries(&format!("policy class {class:?}"), entries)?;
        }
        Ok(())
    }

    /// Stable identity of this policy's *content* (entry set, metrics,
    /// suite): the FNV-1a hash of the canonical JSON serialization
    /// (`Json` objects serialize key-sorted, so the hash is
    /// representation-independent). Fleet-wide stats aggregation compares
    /// fingerprints across workers to detect policy skew — two workers
    /// serving different frontiers would make `auto` placement
    /// inconsistent.
    pub fn fingerprint(&self) -> String {
        format!("{:016x}", crate::util::fnv1a(self.to_json().dump().as_bytes()))
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("version", Json::num(1.0)),
            ("suite", Json::str(&self.suite)),
            (
                "tuned_on",
                Json::Arr(self.tuned_on.iter().map(Json::str).collect()),
            ),
            (
                "entries",
                Json::Arr(self.entries.iter().map(PolicyEntry::to_json).collect()),
            ),
        ];
        // Emitted only when present: a global-only policy serializes
        // exactly as it did before classes existed, keeping old
        // artifacts' fingerprints (and fleet skew checks) stable.
        if !self.classes.is_empty() {
            let classes: BTreeMap<String, Json> = self
                .classes
                .iter()
                .map(|(c, es)| {
                    (c.clone(), Json::Arr(es.iter().map(PolicyEntry::to_json).collect()))
                })
                .collect();
            pairs.push(("classes", Json::Obj(classes)));
        }
        Json::obj(pairs)
    }

    /// Parse a policy, re-checking the Pareto invariant — a hand-edited
    /// artifact (or a bad `{"op":"policy","set":...}`) must fail loudly,
    /// not serve dominated configs.
    pub fn from_json(j: &Json) -> Result<TunedPolicy> {
        // Absent in policies written before per-class frontiers.
        let classes = match j.opt("classes") {
            None => BTreeMap::new(),
            Some(v) => v
                .as_obj()?
                .iter()
                .map(|(c, es)| {
                    let entries = es
                        .as_arr()
                        .with_context(|| format!("class {c:?} frontier"))?
                        .iter()
                        .map(PolicyEntry::from_json)
                        .collect::<Result<Vec<_>>>()?;
                    Ok((c.clone(), entries))
                })
                .collect::<Result<BTreeMap<_, _>>>()?,
        };
        let p = TunedPolicy {
            suite: j.get("suite")?.as_str()?.to_string(),
            tuned_on: j
                .get("tuned_on")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            entries: j
                .get("entries")?
                .as_arr()?
                .iter()
                .map(PolicyEntry::from_json)
                .collect::<Result<Vec<_>>>()?,
            classes,
        };
        p.validate()?;
        Ok(p)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().dump() + "\n")
            .with_context(|| format!("writing policy {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<TunedPolicy> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading policy {}", path.display()))?;
        Self::from_json(&Json::parse(&text).context("parsing policy JSON")?)
            .with_context(|| format!("loading policy {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::{ParamInfo, StageManifest, StageParamRef};

    fn entry(
        bits: usize,
        stage_bits: Option<Vec<usize>>,
        metric: f64,
        bpp: f64,
    ) -> PolicyEntry {
        PolicyEntry {
            bits,
            dtype: DataType::Fp,
            block: Some(64),
            stage_bits,
            entropy: false,
            metric,
            total_bits: bpp * 1e5,
            bits_per_param: bpp,
        }
    }

    fn tier(n_stages: usize) -> TierManifest {
        let stages = (0..n_stages)
            .map(|i| StageManifest {
                name: format!("s{i}"),
                hlo: format!("fwd_{i}.hlo.txt"),
                outputs: if i + 1 == n_stages { 2 } else { 1 },
                params: vec![StageParamRef { source: "embed".into(), layers: None }],
            })
            .collect();
        TierManifest {
            name: "t0".into(),
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 128,
            vocab: 512,
            seq: 64,
            batch_train: 8,
            batch_eval: 16,
            param_count: 100_000,
            params: vec![ParamInfo { name: "embed".into(), shape: vec![512, 32] }],
            quantized_params: vec![],
            fwd_hlo: "fwd.hlo.txt".into(),
            train_hlo: "train.hlo.txt".into(),
            acts_hlo: None,
            stages,
        }
    }

    fn policy() -> TunedPolicy {
        TunedPolicy {
            suite: "ppl".into(),
            tuned_on: vec!["gpt2like_t0".into()],
            entries: vec![
                entry(3, None, 0.40, 3.25),
                entry(4, None, 0.55, 4.25),
                entry(4, Some(vec![16, 4]), 0.58, 9.0),
                entry(16, None, 0.60, 16.0),
            ],
            classes: BTreeMap::new(),
        }
    }

    #[test]
    fn pick_is_frontier_optimal_per_budget() {
        let p = policy();
        let t = tier(2);
        // Unbounded: the best metric wins.
        assert_eq!(p.pick(&t, None).unwrap().bits, 16);
        // Budgets between entry footprints select the best fitting entry.
        let bytes = |bpp: f64| (bpp * t.param_count as f64 / 8.0).ceil() as usize;
        assert_eq!(p.pick(&t, Some(bytes(16.0))).unwrap().bits_per_param, 16.0);
        assert_eq!(p.pick(&t, Some(bytes(16.0) - 1)).unwrap().bits_per_param, 9.0);
        assert_eq!(p.pick(&t, Some(bytes(4.25))).unwrap().bits, 4);
        assert_eq!(p.pick(&t, Some(bytes(3.25))).unwrap().bits, 3);
        // Nothing fits: no pick, not a panic.
        assert!(p.pick(&t, Some(10)).is_none());
        // A pick is never dominated by another affordable entry.
        for budget in [bytes(3.25), bytes(4.25), bytes(9.0), bytes(16.0)] {
            let chosen = p.pick(&t, Some(budget)).unwrap();
            for e in &p.entries {
                if e.estimated_model_bytes(&t) <= budget {
                    assert!(
                        e.metric <= chosen.metric,
                        "budget {budget}: {} dominates chosen {}",
                        e.key(),
                        chosen.key()
                    );
                }
            }
        }
    }

    #[test]
    fn pick_skips_stage_entries_on_mismatched_plans() {
        let p = policy();
        // A monolithic-only tier (no declared stages) must never be
        // handed a 2-stage width vector.
        let t = tier(0);
        let best = p.pick(&t, None).unwrap();
        assert!(best.stage_bits.is_none());
        let mid = p.pick(&t, Some((9.5 * t.param_count as f64 / 8.0) as usize)).unwrap();
        assert!(mid.stage_bits.is_none(), "staged entry leaked onto a monolithic tier");
        assert_eq!(mid.bits, 4);
    }

    #[test]
    fn round_trip_preserves_selection_at_every_budget() {
        let p = policy();
        let parsed = TunedPolicy::from_json(&Json::parse(&p.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed, p);
        let t = tier(2);
        for budget in [None, Some(40_000), Some(55_000), Some(120_000), Some(250_000)] {
            assert_eq!(
                p.pick(&t, budget).map(PolicyEntry::key),
                parsed.pick(&t, budget).map(PolicyEntry::key),
                "selection diverged after round-trip at budget {budget:?}"
            );
        }
    }

    #[test]
    fn validate_rejects_dominated_entries() {
        let mut p = policy();
        assert!(p.validate().is_ok());
        // More bits, less metric: dominated.
        p.entries.push(entry(8, None, 0.1, 20.0));
        assert!(p.validate().is_err());
        // And from_json re-checks, so a hand-edited artifact fails loudly.
        assert!(TunedPolicy::from_json(&Json::parse(&p.to_json().dump()).unwrap()).is_err());
    }

    #[test]
    fn fingerprint_tracks_content_not_representation() {
        let p = policy();
        let parsed =
            TunedPolicy::from_json(&Json::parse(&p.to_json().dump()).unwrap()).unwrap();
        assert_eq!(p.fingerprint(), parsed.fingerprint(), "round-trip must not change identity");
        let mut other = policy();
        other.entries.pop();
        assert_ne!(p.fingerprint(), other.fingerprint(), "different frontiers must hash apart");
    }

    #[test]
    fn entry_keys_match_registry_spelling() {
        assert_eq!(entry(4, None, 0.5, 4.25).key(), "fp:4:b64");
        assert_eq!(entry(4, Some(vec![16, 4]), 0.5, 9.0).key(), "fp:4:b64#pipe[16,4]");
        let base = entry(16, None, 0.6, 16.0);
        assert_eq!(base.key(), "fp:16:bnone");
        let mut coded = entry(4, None, 0.5, 3.1);
        coded.entropy = true;
        assert_eq!(coded.key(), "fp:4:b64#ec");
    }

    #[test]
    fn entropy_entries_round_trip_and_old_policies_default_uncoded() {
        let mut p = policy();
        // A coded twin sits left of its uncoded sibling on the frontier
        // (fewer measured bits, same metric would be dominated — give it
        // a frontier-consistent slot below the fp3 point).
        let mut coded = entry(4, None, 0.30, 2.9);
        coded.entropy = true;
        p.entries.insert(0, coded);
        assert!(p.validate().is_ok(), "{:?}", p.entries);
        let parsed = TunedPolicy::from_json(&Json::parse(&p.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed, p);
        assert!(parsed.entries.first().map(|e| e.entropy).unwrap_or(false));
        assert_eq!(parsed.entries.first().map(PolicyEntry::key), Some("fp:4:b64#ec".into()));
        // A pre-entropy artifact (no "entropy" field at all) parses as
        // uncoded rather than failing.
        let legacy = policy().to_json().dump().replace("\"entropy\":false,", "");
        assert!(!legacy.contains("entropy"), "field not stripped: {legacy}");
        let parsed = TunedPolicy::from_json(&Json::parse(&legacy).unwrap()).unwrap();
        assert_eq!(parsed, policy());
    }

    /// The class-carrying fixture: `chat` has its own lower-bit-leaning
    /// frontier, every other class falls back to the global entries.
    fn classed_policy() -> TunedPolicy {
        let mut p = policy();
        p.classes.insert(
            "chat".into(),
            vec![entry(3, None, 0.45, 3.25), entry(4, None, 0.52, 4.25)],
        );
        p
    }

    #[test]
    fn class_pick_uses_the_class_frontier_and_falls_back() {
        let p = classed_policy();
        let t = tier(0);
        // Tagged with a known class: the class frontier's best pick.
        assert_eq!(p.pick_for_class(Some("chat"), &t, None).unwrap().bits, 4);
        assert_eq!(
            p.pick_for_class(Some("chat"), &t, None).unwrap().metric,
            0.52,
            "class entry, not the global 4-bit entry"
        );
        // Unknown class / no class: the global frontier.
        assert_eq!(p.pick_for_class(Some("batch"), &t, None).unwrap().bits, 16);
        assert_eq!(p.pick_for_class(None, &t, None).unwrap().bits, 16);
        // Budget pressure spills down the class frontier like the
        // global one.
        let bytes = |bpp: f64| (bpp * t.param_count as f64 / 8.0).ceil() as usize;
        assert_eq!(p.pick_for_class(Some("chat"), &t, Some(bytes(3.25))).unwrap().bits, 3);
        assert!(p.pick_for_class(Some("chat"), &t, Some(10)).is_none());
    }

    #[test]
    fn classes_round_trip_and_are_validated() {
        let p = classed_policy();
        let parsed = TunedPolicy::from_json(&Json::parse(&p.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed, p);
        // A dominated entry inside a class frontier fails validation
        // just like one in the global frontier.
        let mut bad = classed_policy();
        if let Some(es) = bad.classes.get_mut("chat") {
            es.push(entry(8, None, 0.1, 20.0));
        }
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("chat"), "error should name the class: {err}");
        assert!(TunedPolicy::from_json(&Json::parse(&bad.to_json().dump()).unwrap()).is_err());
    }

    #[test]
    fn empty_classes_keep_legacy_serialization_and_fingerprint() {
        let p = policy();
        assert!(
            !p.to_json().dump().contains("classes"),
            "a global-only policy must serialize exactly as before classes existed"
        );
        // A classed policy changes the fingerprint (it *is* different
        // content), and skew detection keys off exactly that.
        assert_ne!(p.fingerprint(), classed_policy().fingerprint());
        // Legacy artifact without the field parses to empty classes.
        let parsed = TunedPolicy::from_json(&Json::parse(&p.to_json().dump()).unwrap()).unwrap();
        assert!(parsed.classes.is_empty());
    }
}
