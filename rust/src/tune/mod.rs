//! Pareto-guided precision autotuner: search the k-bit config space and
//! distill the measurements into a serving policy.
//!
//! The paper's result is that the accuracy/size trade-off is governed by
//! precision, block size, and data type, with 4-bit almost universally
//! optimal; mixed-precision work pushes further by assigning widths
//! per layer/stage. This module closes the loop between the repo's two
//! halves — `scaling::` can *measure* the frontier and `server::` can
//! *serve* any per-stage width vector — by connecting measurement to
//! deployment:
//!
//! 1. [`candidates`] enumerates configurations over the paper's axes
//!    (bit width × block size × data type) plus per-stage width vectors
//!    for tiers that declare pipeline stages,
//! 2. [`search`] evaluates each candidate's calibration metric through
//!    the existing [`Evaluator`]/plan path (built as a real
//!    [`ModelHandle`], so packed residency is *measured*, not modeled),
//!    fanned out on the coordinator's worker pool and deduped into a
//!    [`store::TuneStore`],
//! 3. the measured points are fitted into [`scaling::Curve`]s and the
//!    Pareto frontier over resident model bits is extracted,
//! 4. the frontier is serialized as a [`policy::TunedPolicy`] mapping a
//!    byte budget to the frontier-optimal config — the artifact
//!    `kbitscale serve --policy` and `{"op":"load","auto":true}` run on.
//!
//! A failed evaluation cell is logged and **skipped**, never fatal: one
//! unbuildable config or NaN metric must not kill a long tuning run (the
//! NaN-tolerant [`scaling::Curve`]/frontier path drops such points).
//!
//! [`Evaluator`]: crate::eval::Evaluator
//! [`ModelHandle`]: crate::server::registry::ModelHandle

pub mod policy;
pub mod store;

pub use policy::{PolicyEntry, TunedPolicy};
pub use store::{point_key, TunePoint, TuneStore};

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::DATA_VERSION;
use crate::data::corpus::Corpus;
use crate::eval::{EvalConfig, EvalSuite};
use crate::models::manifest::{Manifest, TierManifest};
use crate::quant::{self, DataType, QuantSpec};
use crate::runtime::{ExecutionPlan, PlanLayout, Runtime};
use crate::scaling::{self, Curve, Point};
use crate::server::registry::{ModelHandle, PlanRequest};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::order::nan_last_cmp;
use crate::util::pool;

/// One point of the search space: a quantization spec, optionally with a
/// per-stage width vector (pipeline-sharded mixed precision). Candidates
/// vary the paper's main axes only — exponent bits, centering, and proxy
/// quantization are out of scope for the tuner (and for the store's
/// serialization).
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub spec: QuantSpec,
    /// Per-stage widths (`16` = unquantized stage); requires the tier to
    /// declare pipeline stages. `None` = the monolithic plan.
    pub stage_bits: Option<Vec<usize>>,
    /// Hold the built variant entropy-coded ([`quant::entropy`]): the
    /// coding is lossless, so the metric equals the uncoded twin's — only
    /// the *measured* total bits move, which is exactly what puts coded
    /// variants on (or off) the frontier. Requires a packable spec.
    pub entropy: bool,
}

impl Candidate {
    /// A uniform-precision candidate on the monolithic plan.
    pub fn uniform(spec: QuantSpec) -> Candidate {
        Candidate { spec, stage_bits: None, entropy: false }
    }

    /// A pipeline-sharded candidate with per-stage widths over the base
    /// spec's dtype/block.
    pub fn staged(spec: QuantSpec, stage_bits: Vec<usize>) -> Candidate {
        Candidate { spec, stage_bits: Some(stage_bits), entropy: false }
    }

    /// The entropy-coded twin of this candidate.
    pub fn with_entropy(mut self) -> Candidate {
        self.entropy = true;
        self
    }

    /// The plan shape this candidate executes with.
    pub fn plan_request(&self) -> PlanRequest {
        PlanRequest {
            pipeline: self.stage_bits.is_some(),
            stage_bits: self.stage_bits.clone(),
            fused: false,
            entropy: self.entropy,
        }
    }

    /// Stable identity matching the registry-key spelling:
    /// `fp:4:b64`, `fp:4:b64#pipe[16,4]`, `fp:4:b64#ec`.
    pub fn key(&self) -> String {
        format!("{}{}", self.spec.key(), self.plan_request().suffix())
    }

    /// Analytic resident model bits of this candidate on `tier` — the
    /// pre-build *estimate* of the Pareto x-axis (the search records the
    /// built handle's [`measured_total_bits`] for the actual frontier,
    /// which is the only honest figure for entropy-coded candidates).
    /// Charges what a packed variant actually stores
    /// ([`quant::bitcost::stored_bits_per_param`]: f32 block constants,
    /// not the paper's 16-bit figure), so estimated points carry the same
    /// side-channel costs the measured ones do. Staged candidates account
    /// each plan parameter under its stage's spec, so a replicated
    /// parameter (the tied LM head) counts once per owning stage, exactly
    /// as it is resident in a sharded deployment.
    ///
    /// [`measured_total_bits`]: crate::server::registry::ModelHandle::measured_total_bits
    pub fn total_bits(&self, tier: &TierManifest) -> Result<f64> {
        match &self.stage_bits {
            None => {
                let bpp = quant::bitcost::stored_bits_per_param(&self.spec);
                Ok(tier
                    .param_sizes()
                    .iter()
                    .map(|(name, n)| {
                        if tier.quantized_params.iter().any(|q| q == name) {
                            bpp * *n as f64
                        } else {
                            16.0 * *n as f64
                        }
                    })
                    .sum())
            }
            Some(bits) => {
                let layout = PlanLayout::staged(tier)?;
                let specs = quant::stage_specs(&self.spec, layout.n_stages(), Some(bits))?;
                Ok(layout
                    .params
                    .iter()
                    .map(|pp| {
                        let quantized =
                            tier.quantized_params.iter().any(|q| q == &pp.source);
                        let bpp = if quantized {
                            quant::bitcost::stored_bits_per_param(&specs[pp.stage])
                        } else {
                            16.0
                        };
                        bpp * pp.numel() as f64
                    })
                    .sum())
            }
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bits", Json::num(self.spec.bits as f64)),
            ("dtype", Json::str(self.spec.dtype.name())),
            (
                "block",
                match self.spec.block {
                    Some(b) => Json::num(b as f64),
                    None => Json::Null,
                },
            ),
            (
                "stage_bits",
                match &self.stage_bits {
                    Some(v) => Json::Arr(v.iter().map(|&b| Json::num(b as f64)).collect()),
                    None => Json::Null,
                },
            ),
            ("entropy", Json::Bool(self.entropy)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Candidate> {
        let block = match j.get("block")? {
            Json::Null => None,
            v => Some(v.as_usize()?),
        };
        let spec = QuantSpec::new(
            DataType::parse(j.get("dtype")?.as_str()?)?,
            j.get("bits")?.as_usize()?,
            block,
        );
        let stage_bits = match j.get("stage_bits")? {
            Json::Null => None,
            v => Some(v.usizes()?),
        };
        // Absent in stores written before entropy coding existed.
        let entropy = match j.opt("entropy") {
            Some(v) => v.as_bool()?,
            None => false,
        };
        Ok(Candidate { spec, stage_bits, entropy })
    }
}

/// What the search sweeps and how hard it evaluates each cell.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// Candidate bit widths (values >= 16 fold into the always-included
    /// baseline reference point).
    pub bits: Vec<usize>,
    pub dtypes: Vec<DataType>,
    /// Candidate block sizes; `None` = tensor-wise.
    pub blocks: Vec<Option<usize>>,
    /// Also generate per-stage width vectors for tiers with pipeline
    /// stages (hi-precision prefix / lo-precision suffix splits over the
    /// first dtype × block).
    pub stage_mixes: bool,
    /// Also generate the entropy-coded twin of every packable candidate
    /// (`#ec` keys): the metric is identical by construction (lossless
    /// coding), but the *measured* total bits land below the fixed-k
    /// floor, so coded twins compete on the frontier as distinct points.
    pub entropy: bool,
    /// Calibration suite; `Ppl` maximizes `-ce`, `PplZeroShot` maximizes
    /// mean zero-shot accuracy.
    pub suite: EvalSuite,
    /// Calibration slice sizes (deliberately smaller than a full sweep
    /// cell: tuning trades eval precision for search breadth).
    pub eval: EvalConfig,
    pub threads: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            bits: vec![3, 4, 8],
            dtypes: vec![DataType::Fp],
            blocks: vec![Some(64)],
            stage_mixes: true,
            entropy: false,
            suite: EvalSuite::Ppl,
            eval: EvalConfig { ppl_sequences: 16, zs_examples: 16 },
            threads: 2,
        }
    }
}

/// Enumerate the candidate set for a plan with `n_stages` stages: the
/// 16-bit baseline, every buildable uniform (dtype × bits × block)
/// config, and — when `stage_mixes` is on and the plan is sharded —
/// two-width prefix/suffix stage vectors (e.g. `[16,4]`: a 16-bit
/// embedding-heavy stage 0 over a 4-bit stage 1). With `cfg.entropy`,
/// every packable candidate additionally gets its entropy-coded twin
/// (`#ec`). Unbuildable combos (e.g. dynexp below 3 bits) are silently
/// dropped, not errors.
pub fn candidates(cfg: &TuneConfig, n_stages: usize) -> Vec<Candidate> {
    let mut out = vec![Candidate::uniform(QuantSpec::baseline16())];
    for &k in &cfg.bits {
        if k >= 16 {
            continue; // the baseline is already in
        }
        for &dt in &cfg.dtypes {
            for &block in &cfg.blocks {
                let spec = QuantSpec::new(dt, k, block);
                if spec.codebook().is_ok() {
                    out.push(Candidate::uniform(spec));
                }
            }
        }
    }
    if cfg.stage_mixes && n_stages >= 2 {
        let dt = cfg.dtypes.first().copied().unwrap_or(DataType::Fp);
        let block = cfg.blocks.first().copied().unwrap_or(Some(64));
        let mut widths: Vec<usize> = cfg
            .bits
            .iter()
            .copied()
            .filter(|&k| k < 16 && QuantSpec::new(dt, k, block).codebook().is_ok())
            .collect();
        widths.push(16);
        widths.sort_unstable();
        widths.dedup();
        for &hi in &widths {
            for &lo in &widths {
                if hi == lo {
                    continue;
                }
                for split in 1..n_stages {
                    let v: Vec<usize> =
                        (0..n_stages).map(|s| if s < split { hi } else { lo }).collect();
                    // The base spec's bits field is the narrowest
                    // quantized width (every stage overrides it anyway;
                    // this keeps the registry key readable).
                    let base = v.iter().copied().filter(|&k| k < 16).min().unwrap_or(4);
                    out.push(Candidate::staged(QuantSpec::new(dt, base, block), v));
                }
            }
        }
    }
    if cfg.entropy {
        // Coded twins of every packable candidate (the baseline has no
        // index stream to code). Staged mixes qualify too: their 16-bit
        // stages simply stay uncoded inside the variant.
        let coded: Vec<Candidate> = out
            .iter()
            .filter(|c| !c.spec.is_baseline())
            .cloned()
            .map(Candidate::with_entropy)
            .collect();
        out.extend(coded);
    }
    let mut seen = HashSet::new();
    out.retain(|c| seen.insert(c.key()));
    out
}

/// One model the search measures.
#[derive(Debug, Clone)]
pub struct TuneTarget {
    pub family: String,
    pub tier: String,
}

impl TuneTarget {
    pub fn new(family: impl Into<String>, tier: impl Into<String>) -> TuneTarget {
        TuneTarget { family: family.into(), tier: tier.into() }
    }

    pub fn key(&self) -> String {
        format!("{}_{}", self.family, self.tier)
    }
}

/// Everything a search run produced.
pub struct TuneReport {
    /// All measured points (cached + freshly evaluated), target order.
    pub points: Vec<TunePoint>,
    /// Cells evaluated this run (the rest were store hits).
    pub fresh: usize,
    pub cached: usize,
    /// Cells that failed and were skipped (logged, never fatal).
    pub skipped: usize,
    /// Per-candidate scaling curves over (total bits, metric) — one point
    /// per measured target, the paper's Figure-1 geometry.
    pub curves: Vec<Curve>,
    /// The distilled serving policy (the measured Pareto frontier).
    pub policy: TunedPolicy,
}

fn suite_name(suite: EvalSuite) -> &'static str {
    match suite {
        EvalSuite::Ppl => "ppl",
        EvalSuite::PplZeroShot => "ppl_zs",
    }
}

/// Run the search: evaluate every (target × candidate) cell not already
/// in `store`, fit the points into scaling curves, and distill the
/// Pareto-frontier policy. `loader` produces checkpoint parameters per
/// (family, tier) — the CLI wires the on-disk store, the serve op wires
/// the registry's loader, tests/benches inject init-only params.
pub fn search(
    rt: &Runtime,
    manifest: &Manifest,
    corpus: &Corpus,
    loader: &(dyn Fn(&str, &str) -> Result<Vec<(String, Tensor)>> + Sync),
    targets: &[TuneTarget],
    cfg: &TuneConfig,
    store: Option<&TuneStore>,
) -> Result<TuneReport> {
    struct Cell<'m> {
        target: TuneTarget,
        tier: &'m TierManifest,
        cand: Candidate,
        key: String,
    }
    let mut cells: Vec<Cell<'_>> = Vec::new();
    for t in targets {
        let tier = manifest.tier(&t.tier)?;
        for cand in candidates(cfg, tier.stages.len()) {
            let key = point_key(
                &t.family,
                &t.tier,
                &cand.key(),
                suite_name(cfg.suite),
                cfg.eval.ppl_sequences,
                cfg.eval.zs_examples,
                corpus.cfg.seed,
                DATA_VERSION,
            );
            cells.push(Cell { target: t.clone(), tier, cand, key });
        }
    }
    if cells.is_empty() {
        bail!("tune: no candidates to evaluate (empty targets or config)");
    }

    // Partition into cached / to-run (the store's dedupe economics).
    let mut points: Vec<Option<TunePoint>> = Vec::with_capacity(cells.len());
    let mut todo: Vec<usize> = Vec::new();
    for (i, c) in cells.iter().enumerate() {
        match store.and_then(|s| s.get(&c.key)) {
            Some(hit) => points.push(Some(hit)),
            None => {
                points.push(None);
                todo.push(i);
            }
        }
    }
    let cached = cells.len() - todo.len();
    let mut skipped = 0usize;

    if !todo.is_empty() {
        log::info!(
            "tune: {} cells ({cached} cached, {} to run) on {} workers",
            cells.len(),
            todo.len(),
            cfg.threads.max(1)
        );
        // Pre-compile each involved plan serially: PJRT compilation is
        // not profitably concurrent (the coordinator does the same). A
        // staged-plan compile failure only dooms the staged cells, which
        // fail-and-skip individually below.
        let mut seen_plans: HashSet<(String, bool)> = HashSet::new();
        for &i in &todo {
            let c = &cells[i];
            let pipeline = c.cand.stage_bits.is_some();
            if seen_plans.insert((c.tier.name.clone(), pipeline)) {
                if let Err(e) = ExecutionPlan::compile(rt, manifest, c.tier, pipeline) {
                    log::warn!(
                        "tune: pre-compile of {} (pipeline={pipeline}) failed: {e:#}",
                        c.tier.name
                    );
                }
            }
        }
        // In-memory checkpoint cache shared by the workers.
        let params_cache: Mutex<HashMap<String, Arc<Vec<(String, Tensor)>>>> =
            Mutex::new(HashMap::new());
        let load_params = |family: &str, tier: &str| -> Result<Arc<Vec<(String, Tensor)>>> {
            let ck = format!("{family}_{tier}");
            if let Some(hit) = params_cache.lock().unwrap().get(&ck) {
                return Ok(hit.clone());
            }
            let params = loader(family, tier)
                .with_context(|| format!("loading checkpoint {ck} for tuning"))?;
            let arc = Arc::new(params);
            params_cache.lock().unwrap().insert(ck, arc.clone());
            Ok(arc)
        };
        // Warm the cache serially: the check-then-insert above is not
        // single-flight, so the first wave of workers would otherwise
        // all re-read the same checkpoint at once. Errors are left for
        // the cells to rediscover and fail-skip individually.
        let mut seen_targets: HashSet<String> = HashSet::new();
        for &i in &todo {
            let t = &cells[i].target;
            if seen_targets.insert(t.key()) {
                if let Err(e) = load_params(&t.family, &t.tier) {
                    log::warn!("tune: pre-loading {} failed: {e:#}", t.key());
                }
            }
        }
        let fresh = pool::parallel_map(todo.len(), cfg.threads.max(1), |j| {
            let c = &cells[todo[j]];
            run_cell(rt, manifest, corpus, cfg, c.tier, &c.target, &c.cand, &c.key, &load_params)
                .with_context(|| format!("tune cell {} {}", c.target.key(), c.cand.key()))
        });
        for (j, res) in fresh.into_iter().enumerate() {
            match res {
                Ok(p) => {
                    if let Some(s) = store {
                        s.put(p.clone())?;
                    }
                    points[todo[j]] = Some(p);
                }
                // One failed cell (unbuildable config, missing stage
                // artifacts, a NaN blow-up) must not kill the run.
                Err(e) => {
                    log::warn!("tune: skipping cell: {e:#}");
                    skipped += 1;
                }
            }
        }
    }

    let points: Vec<TunePoint> = points.into_iter().flatten().collect();
    if points.is_empty() {
        bail!("tune: every cell failed — nothing to fit a policy from");
    }
    let curves = fit_curves(&points);
    let policy = frontier_policy(&points, suite_name(cfg.suite));
    debug_assert!(policy.validate().is_ok(), "frontier extraction produced a dominated entry");
    Ok(TuneReport { fresh: points.len() - cached, cached, skipped, curves, policy, points })
}

/// Evaluate one (target × candidate) cell: build the candidate as a real
/// resident [`ModelHandle`] (so packed bytes are measured) and score the
/// calibration slice through its execution plan.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    rt: &Runtime,
    manifest: &Manifest,
    corpus: &Corpus,
    cfg: &TuneConfig,
    tier: &TierManifest,
    target: &TuneTarget,
    cand: &Candidate,
    key: &str,
    load_params: &dyn Fn(&str, &str) -> Result<Arc<Vec<(String, Tensor)>>>,
) -> Result<TunePoint> {
    let t0 = std::time::Instant::now();
    let params = load_params(&target.family, &target.tier)?;
    let handle = ModelHandle::with_plan(
        rt,
        manifest,
        tier,
        &params,
        cand.spec.clone(),
        &cand.plan_request(),
        target.key(),
    )?;
    let r = handle.evaluate(corpus, cfg.suite, &cfg.eval)?;
    let metric = if r.zs_mean.is_finite() { r.zs_mean } else { -r.ce };
    // The frontier x-axis is *measured* on the built handle (coded
    // payload + tables + f32 constants for entropy variants, exact n·k +
    // constants for packed; analytic fallback for simulate-only specs) —
    // `Candidate::total_bits` remains the pre-build estimate only.
    let total_bits = handle.measured_total_bits();
    Ok(TunePoint {
        key: key.to_string(),
        family: target.family.clone(),
        tier: target.tier.clone(),
        candidate: cand.clone(),
        suite: suite_name(cfg.suite).to_string(),
        ce: r.ce,
        ppl: r.ppl,
        zs_mean: r.zs_mean,
        metric,
        total_bits,
        bits_per_param: total_bits / tier.param_count.max(1) as f64,
        resident_bytes: handle.resident_bytes(),
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Fit measured points into per-candidate scaling curves over
/// (total model bits, metric) — one point per measured target, the
/// paper's per-configuration curve family.
pub fn fit_curves(points: &[TunePoint]) -> Vec<Curve> {
    let mut by_label: BTreeMap<String, Vec<Point>> = BTreeMap::new();
    for p in points {
        by_label
            .entry(p.candidate.key())
            .or_default()
            .push(Point { bits: p.total_bits, metric: p.metric });
    }
    by_label.into_iter().map(|(label, pts)| Curve::new(label, pts)).collect()
}

/// Distill measured points into the serving policy: extract each model's
/// Pareto frontier over (bits-per-param, metric), merge the surviving
/// configs across models, then re-extract the frontier so the final
/// entry set is itself Pareto-consistent — no dominated config can ever
/// be picked, for any budget.
///
/// Raw metrics are **not comparable across model scales**, so merging
/// centers each model's metrics on its own mean first: with the paper's
/// near-parallel curves, `metric(config, model) ≈ f(model) + g(config)`,
/// and the centered score estimates `g`. This keeps a config measured on
/// only a subset of models (a skipped cell) from being unfairly ranked
/// against configs that carry a larger model's better absolute numbers.
/// A config's footprint keeps its **largest** measured bits-per-param,
/// so budget estimates stay conservative.
pub fn frontier_policy(points: &[TunePoint], suite: &str) -> TunedPolicy {
    let all: Vec<&TunePoint> = points.iter().collect();
    let entries = distill_frontier(&all);
    let mut tuned_on: Vec<String> =
        points.iter().map(|p| format!("{}_{}", p.family, p.tier)).collect();
    tuned_on.sort();
    tuned_on.dedup();
    // Per-workload-class frontiers: each model *family* is a workload
    // class (families differ in data mix and architecture, the axes
    // capability loss is sensitive to), so its points distill into a
    // class-specific frontier. With a single family the class frontier
    // would equal the global one, so it is omitted and the artifact
    // stays byte-identical to a pre-class policy.
    let mut by_family: BTreeMap<String, Vec<&TunePoint>> = BTreeMap::new();
    for p in points {
        by_family.entry(p.family.clone()).or_default().push(p);
    }
    let classes: BTreeMap<String, Vec<PolicyEntry>> = if by_family.len() >= 2 {
        by_family
            .into_iter()
            .filter_map(|(family, pts)| {
                let es = distill_frontier(&pts);
                (!es.is_empty()).then_some((family, es))
            })
            .collect()
    } else {
        BTreeMap::new()
    };
    TunedPolicy { suite: suite.to_string(), tuned_on, entries, classes }
}

/// The frontier-distillation core shared by the global policy and each
/// per-family class: per-model frontier extraction with mean-centered
/// metrics, cross-model merge, and a final re-frontier pass.
fn distill_frontier(points: &[&TunePoint]) -> Vec<PolicyEntry> {
    let entry_of = |p: &TunePoint| PolicyEntry {
        bits: p.candidate.spec.bits,
        dtype: p.candidate.spec.dtype,
        block: p.candidate.spec.block,
        stage_bits: p.candidate.stage_bits.clone(),
        entropy: p.candidate.entropy,
        metric: p.metric,
        total_bits: p.total_bits,
        bits_per_param: p.bits_per_param,
    };
    let mut by_model: BTreeMap<String, Vec<&TunePoint>> = BTreeMap::new();
    for p in points {
        by_model.entry(format!("{}_{}", p.family, p.tier)).or_default().push(*p);
    }
    struct Agg {
        centered_sum: f64,
        n: usize,
        entry: PolicyEntry,
    }
    let mut agg: BTreeMap<String, Agg> = BTreeMap::new();
    for pts in by_model.values() {
        let finite: Vec<f64> = pts.iter().map(|p| p.metric).filter(|m| m.is_finite()).collect();
        if finite.is_empty() {
            continue;
        }
        let mean = finite.iter().sum::<f64>() / finite.len() as f64;
        let mut triples: Vec<(f64, f64, &TunePoint)> =
            pts.iter().map(|p| (p.bits_per_param, p.metric, *p)).collect();
        // Sort ties metric-descending so the frontier keeps the best of
        // equal-size configs (pareto_frontier's re-sort is stable).
        triples.sort_by(|a, b| nan_last_cmp(a.0, b.0).then(nan_last_cmp(b.1, a.1)));
        // Only per-model frontier survivors qualify: a config dominated
        // at its own scale never enters the merged set.
        for (_, _, p) in scaling::pareto_frontier(&triples) {
            let a = agg.entry(p.candidate.key()).or_insert_with(|| Agg {
                centered_sum: 0.0,
                n: 0,
                entry: entry_of(p),
            });
            a.centered_sum += p.metric - mean;
            a.n += 1;
            if p.bits_per_param > a.entry.bits_per_param {
                a.entry.bits_per_param = p.bits_per_param;
                a.entry.total_bits = p.total_bits;
            }
        }
    }
    let mut merged: Vec<(f64, f64, PolicyEntry)> = agg
        .into_values()
        .map(|a| {
            let mut e = a.entry;
            e.metric = a.centered_sum / a.n.max(1) as f64;
            (e.bits_per_param, e.metric, e)
        })
        .collect();
    merged.sort_by(|a, b| nan_last_cmp(a.0, b.0).then(nan_last_cmp(b.1, a.1)));
    scaling::pareto_frontier(&merged).into_iter().map(|(_, _, e)| e).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TuneConfig {
        TuneConfig {
            bits: vec![3, 4, 8],
            dtypes: vec![DataType::Fp, DataType::Int],
            blocks: vec![Some(64)],
            ..TuneConfig::default()
        }
    }

    #[test]
    fn candidates_cover_axes_and_dedupe() {
        let c = candidates(&cfg(), 1);
        // Baseline + 3 bits x 2 dtypes, no stage mixes on a 1-stage plan.
        assert_eq!(c.len(), 1 + 3 * 2);
        assert!(c.iter().any(|x| x.spec.is_baseline()));
        assert!(c.iter().all(|x| x.stage_bits.is_none()));
        let mut keys: Vec<String> = c.iter().map(Candidate::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), c.len(), "candidate keys must be unique");
    }

    #[test]
    fn staged_candidates_appear_for_sharded_plans() {
        let c = candidates(&cfg(), 2);
        let staged: Vec<&Candidate> = c.iter().filter(|x| x.stage_bits.is_some()).collect();
        // Widths {3,4,8,16}: 4*3 ordered pairs, one split point.
        assert_eq!(staged.len(), 12);
        assert!(staged
            .iter()
            .any(|x| x.stage_bits.as_deref() == Some(&[16, 4][..])), "the flagship [16,4] mix");
        // Every staged vector matches the stage count and mixes widths.
        for s in &staged {
            let v = s.stage_bits.as_ref().unwrap();
            assert_eq!(v.len(), 2);
            assert_ne!(v[0], v[1]);
        }
        // Unbuildable widths are dropped, not errors: dynexp needs k >= 3.
        let dyncfg = TuneConfig {
            bits: vec![2, 4],
            dtypes: vec![DataType::DynExp],
            ..TuneConfig::default()
        };
        let c = candidates(&dyncfg, 2);
        assert!(c.iter().all(|x| x.spec.is_baseline() || x.spec.bits != 2));
    }

    #[test]
    fn entropy_twins_double_the_packable_candidates() {
        let mut c = cfg();
        c.entropy = true;
        let cands = candidates(&c, 1);
        // The baseline has nothing to code; every packable candidate
        // gains exactly one #ec twin.
        assert_eq!(cands.len(), 1 + 2 * (3 * 2));
        let coded: Vec<&Candidate> = cands.iter().filter(|x| x.entropy).collect();
        assert_eq!(coded.len(), 3 * 2);
        assert!(coded.iter().all(|x| x.key().ends_with("#ec")), "keys must carry #ec");
        assert!(coded.iter().all(|x| !x.spec.is_baseline()));
        // A twin differs from its uncoded sibling only in residency —
        // same spec, same plan shape, distinct key.
        for t in &coded {
            assert!(cands
                .iter()
                .any(|u| !u.entropy && u.spec == t.spec && u.stage_bits == t.stage_bits));
        }
    }

    #[test]
    fn candidate_json_round_trips() {
        for c in [
            Candidate::uniform(QuantSpec::baseline16()),
            Candidate::uniform(QuantSpec::new(DataType::Int, 3, None)),
            Candidate::staged(QuantSpec::new(DataType::Fp, 4, Some(64)), vec![16, 4]),
            Candidate::uniform(QuantSpec::new(DataType::Fp, 4, Some(64))).with_entropy(),
        ] {
            let back = Candidate::from_json(&Json::parse(&c.to_json().dump()).unwrap()).unwrap();
            assert_eq!(back, c);
            assert_eq!(back.key(), c.key());
        }
    }

    fn point(tier: &str, cand: Candidate, bpp: f64, metric: f64) -> TunePoint {
        TunePoint {
            key: format!("{tier}|{}", cand.key()),
            family: "gpt2like".into(),
            tier: tier.into(),
            candidate: cand,
            suite: "ppl".into(),
            ce: -metric,
            ppl: (-metric).exp(),
            zs_mean: f64::NAN,
            metric,
            total_bits: bpp * 1e5,
            bits_per_param: bpp,
            resident_bytes: (bpp * 1e5 / 8.0) as usize,
            wall_s: 0.1,
        }
    }

    #[test]
    fn frontier_policy_drops_dominated_configs() {
        let fp4 = Candidate::uniform(QuantSpec::new(DataType::Fp, 4, Some(64)));
        let int4 = Candidate::uniform(QuantSpec::new(DataType::Int, 4, Some(64)));
        let fp3 = Candidate::uniform(QuantSpec::new(DataType::Fp, 3, Some(64)));
        let base = Candidate::uniform(QuantSpec::baseline16());
        let points = vec![
            point("t0", fp3, 3.25, -2.0),
            point("t0", fp4.clone(), 4.25, -1.5),
            // Same size as fp4, worse metric: dominated, must not appear.
            point("t0", int4.clone(), 4.25, -1.8),
            point("t0", base, 16.0, -1.4),
        ];
        let p = frontier_policy(&points, "ppl");
        assert!(p.validate().is_ok());
        let keys: Vec<String> = p.entries.iter().map(PolicyEntry::key).collect();
        assert!(keys.contains(&"fp:4:b64".to_string()), "{keys:?}");
        assert!(!keys.contains(&"int:4:b64".to_string()), "dominated config on frontier: {keys:?}");
        assert_eq!(p.tuned_on, vec!["gpt2like_t0".to_string()]);
        // NaN metrics are skipped, not propagated into the policy.
        let mut with_nan = points.clone();
        with_nan.push(point("t0", fp4, 4.5, f64::NAN));
        let p2 = frontier_policy(&with_nan, "ppl");
        assert!(p2.validate().is_ok());
        assert!(p2.entries.iter().all(|e| e.metric.is_finite()));
    }

    #[test]
    fn frontier_policy_merges_targets_pareto_consistently() {
        let fp4 = Candidate::uniform(QuantSpec::new(DataType::Fp, 4, Some(64)));
        let fp3 = Candidate::uniform(QuantSpec::new(DataType::Fp, 3, Some(64)));
        let base = Candidate::uniform(QuantSpec::baseline16());
        // Two tiers, near-parallel curves (the paper's geometry): larger
        // tier has better absolute metrics for the same configs.
        let points = vec![
            point("t0", fp3.clone(), 3.25, -2.2),
            point("t0", fp4.clone(), 4.25, -1.9),
            point("t0", base.clone(), 16.0, -1.8),
            point("t1", fp3, 3.25, -1.6),
            point("t1", fp4, 4.25, -1.2),
            point("t1", base, 16.0, -1.1),
        ];
        let p = frontier_policy(&points, "ppl");
        assert!(p.validate().is_ok(), "{:?}", p.entries);
        assert_eq!(p.tuned_on, vec!["gpt2like_t0".to_string(), "gpt2like_t1".to_string()]);
        // The merged frontier keeps the config ordering: 3 < 4 < 16 bits.
        let bits: Vec<usize> = p.entries.iter().map(|e| e.bits).collect();
        assert_eq!(bits, vec![3, 4, 16]);
    }

    #[test]
    fn fit_curves_groups_by_candidate_across_targets() {
        let fp4 = Candidate::uniform(QuantSpec::new(DataType::Fp, 4, Some(64)));
        let points = vec![
            point("t0", fp4.clone(), 4.25, -2.0),
            point("t1", fp4, 4.25, -1.5),
        ];
        let curves = fit_curves(&points);
        assert_eq!(curves.len(), 1);
        assert_eq!(curves[0].points().len(), 2);
        assert_eq!(curves[0].label, "fp:4:b64");
    }
}
