//! Tuning store: an append-only JSONL database of evaluated candidates,
//! mirroring `coordinator::store::ResultsStore`'s economics — a candidate
//! is measured **once** per (model, config, calibration workload) across
//! every tuning run that shares the store, so re-tuning after adding one
//! bit width only pays for the new cells.

use std::collections::HashMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::fnv1a;
use crate::util::json::Json;

use super::Candidate;

/// Everything stored for one measured candidate on one model.
#[derive(Debug, Clone, PartialEq)]
pub struct TunePoint {
    /// Stable dedupe key (see [`point_key`]).
    pub key: String,
    pub family: String,
    pub tier: String,
    pub candidate: Candidate,
    /// Calibration suite (`ppl` or `ppl_zs`).
    pub suite: String,
    pub ce: f64,
    pub ppl: f64,
    /// NaN for ppl-only calibration.
    pub zs_mean: f64,
    /// The maximized tuning metric: `zs_mean` when measured, else `-ce`.
    pub metric: f64,
    /// Resident model bits of this candidate on this tier (the Pareto
    /// x-axis; per-stage accounting for staged candidates).
    pub total_bits: f64,
    /// `total_bits / param_count` — the tier-transferable size axis.
    pub bits_per_param: f64,
    /// Measured packed host bytes of the built variant (0 for baseline).
    pub resident_bytes: usize,
    pub wall_s: f64,
}

impl TunePoint {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::str(&self.key)),
            ("family", Json::str(&self.family)),
            ("tier", Json::str(&self.tier)),
            ("candidate", self.candidate.to_json()),
            ("suite", Json::str(&self.suite)),
            ("ce", Json::num(self.ce)),
            ("ppl", Json::num(self.ppl)),
            ("zs_mean", Json::num(self.zs_mean)),
            ("metric", Json::num(self.metric)),
            ("total_bits", Json::num(self.total_bits)),
            ("bits_per_param", Json::num(self.bits_per_param)),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("wall_s", Json::num(self.wall_s)),
        ])
    }

    fn from_json(j: &Json) -> Result<TunePoint> {
        Ok(TunePoint {
            key: j.get("key")?.as_str()?.to_string(),
            family: j.get("family")?.as_str()?.to_string(),
            tier: j.get("tier")?.as_str()?.to_string(),
            candidate: Candidate::from_json(j.get("candidate")?)?,
            suite: j.get("suite")?.as_str()?.to_string(),
            ce: j.get("ce")?.as_f64()?,
            ppl: j.get("ppl")?.as_f64()?,
            zs_mean: match j.get("zs_mean")? {
                Json::Null => f64::NAN,
                v => v.as_f64()?,
            },
            metric: j.get("metric")?.as_f64()?,
            total_bits: j.get("total_bits")?.as_f64()?,
            bits_per_param: j.get("bits_per_param")?.as_f64()?,
            resident_bytes: j.get("resident_bytes")?.as_usize()?,
            wall_s: j.get("wall_s")?.as_f64()?,
        })
    }
}

/// Build the stable tuning-cell key. Includes the calibration workload
/// and corpus seed, so changing the slice re-measures instead of serving
/// stale numbers; `data_version` is `coordinator::DATA_VERSION`.
#[allow(clippy::too_many_arguments)]
pub fn point_key(
    family: &str,
    tier: &str,
    candidate_key: &str,
    suite: &str,
    ppl_sequences: usize,
    zs_examples: usize,
    corpus_seed: u64,
    data_version: u32,
) -> String {
    let raw = format!(
        "tune|{family}|{tier}|{candidate_key}|{suite}|p{ppl_sequences}|z{zs_examples}|s{corpus_seed}|v{data_version}"
    );
    format!("{:016x}", fnv1a(raw.as_bytes()))
}

/// JSONL-backed tuning store with an in-memory index; thread safe.
pub struct TuneStore {
    path: PathBuf,
    inner: Mutex<HashMap<String, TunePoint>>,
}

impl TuneStore {
    /// Open (or create) a store, loading all prior tuning points.
    pub fn open(path: impl Into<PathBuf>) -> Result<TuneStore> {
        let path = path.into();
        let mut map = HashMap::new();
        if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let j = Json::parse(line)
                    .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
                let p = TunePoint::from_json(&j)?;
                map.insert(p.key.clone(), p);
            }
        }
        Ok(TuneStore { path, inner: Mutex::new(map) })
    }

    pub fn get(&self, key: &str) -> Option<TunePoint> {
        self.inner.lock().unwrap().get(key).cloned()
    }

    pub fn put(&self, p: TunePoint) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.insert(p.key.clone(), p.clone());
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", p.to_json().dump())?;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{DataType, QuantSpec};

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kbt_tune_{tag}_{}.jsonl", std::process::id()))
    }

    fn sample(key: &str, staged: bool) -> TunePoint {
        TunePoint {
            key: key.to_string(),
            family: "gpt2like".into(),
            tier: "t0".into(),
            candidate: if staged {
                Candidate::staged(QuantSpec::new(DataType::Fp, 4, Some(64)), vec![16, 4])
            } else {
                Candidate::uniform(QuantSpec::new(DataType::Fp, 4, Some(64)))
            },
            suite: "ppl".into(),
            ce: 1.5,
            ppl: 4.48,
            // Staged sample: NaN zs_mean (ppl-only tuning); uniform
            // sample keeps it finite so equality comparisons work.
            zs_mean: if staged { f64::NAN } else { 0.55 },
            metric: -1.5,
            total_bits: 5.0e5,
            bits_per_param: 5.0,
            resident_bytes: 12_000,
            wall_s: 0.4,
        }
    }

    #[test]
    fn roundtrip_and_reload_including_staged_candidates() {
        let path = tmp("rt");
        std::fs::remove_file(&path).ok();
        {
            let s = TuneStore::open(&path).unwrap();
            s.put(sample("aaa", false)).unwrap();
            s.put(sample("bbb", true)).unwrap();
            assert_eq!(s.len(), 2);
        }
        let s2 = TuneStore::open(&path).unwrap();
        assert_eq!(s2.len(), 2);
        assert_eq!(s2.get("aaa").unwrap(), sample("aaa", false));
        let staged = s2.get("bbb").unwrap();
        assert_eq!(staged.candidate.stage_bits, Some(vec![16, 4]));
        assert!(staged.zs_mean.is_nan(), "NaN zs_mean must survive the round-trip");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn keys_are_stable_and_workload_sensitive() {
        let a = point_key("gpt2like", "t0", "fp:4:b64", "ppl", 16, 16, 7, 1);
        let b = point_key("gpt2like", "t0", "fp:4:b64", "ppl", 16, 16, 7, 1);
        let c = point_key("gpt2like", "t0", "fp:4:b64", "ppl", 32, 16, 7, 1);
        let d = point_key("gpt2like", "t0", "fp:4:b64#pipe[16,4]", "ppl", 16, 16, 7, 1);
        assert_eq!(a, b);
        assert_ne!(a, c, "calibration size must re-key");
        assert_ne!(a, d, "plan shape must re-key");
    }
}
