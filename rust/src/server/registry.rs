//! The packed-model registry: shared, immutable model residency.
//!
//! A [`ModelHandle`] is everything one (model × quantization spec) variant
//! needs to serve: the compiled forward evaluator, the resident PJRT
//! parameter literals, and the **packed k-bit weights** that are the
//! model's storage-format residency (`quant::packing`). Handles are
//! immutable after construction and shared via `Arc`, so any number of
//! connections and the batch dispatcher can score against the same model
//! concurrently with no per-request copying.
//!
//! A [`ModelRegistry`] hosts many variants in one process, keyed
//! `"{family}_{tier}@{spec}"` plus a plan suffix (`#pipe`, `#pipe[16,4]`)
//! for pipeline-sharded and mixed-precision builds, so every plan shape
//! of one spec is its own governed resident — with per-stage packed-byte
//! accounting. Checkpoints come through a caller-supplied
//! [`ParamLoader`], so the CLI wires the on-disk [`CheckpointStore`] while
//! tests and benches inject init-only parameters.
//!
//! # Memory governance
//!
//! Residency is budgeted, not unbounded. The registry can be configured
//! with a packed-byte budget ([`ModelRegistry::with_memory_budget`], the
//! CLI's `--max-resident-bytes`) and an idle TTL
//! ([`ModelRegistry::with_ttl`]): past the budget, least-recently-used
//! variants are **evicted** — dropped from the registry map. Handles are
//! `Arc`-shared, so eviction never invalidates in-flight work: a
//! connection or the batch dispatcher holding a handle pins the variant's
//! memory until its last reference drops, at which point the packed
//! weights and PJRT literals are freed. The variant being inserted or
//! resolved is always protected from its own eviction pass, so a single
//! variant larger than the budget still serves.
//!
//! Loading is **single-flight**: concurrent `load`s of the same variant
//! build (quantize + compile) it exactly once; the losers of the race
//! block until the winner's handle is resident and share it.
//!
//! [`CheckpointStore`]: crate::models::checkpoint::CheckpointStore

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::cache::ScoreCache;
use crate::data::corpus::Corpus;
use crate::eval::{EvalConfig, EvalResult, EvalSuite, Evaluator};
use crate::fleet::telemetry::{Clock, LatencySnapshot, LatencyWindow, WallClock};
use crate::models::manifest::{Manifest, TierManifest};
use crate::quant::{self, EncodedParam, PackedParam, QuantSpec};
use crate::runtime::native::{NativeModel, NativeParam};
use crate::runtime::{lit_f32_slice, ParamLiterals, Runtime};
use crate::tensor::Tensor;
use crate::tune::policy::{PolicyEntry, TunedPolicy};
use crate::util::pool;

/// Produces the checkpoint parameters for `(family, tier)` on demand.
pub type ParamLoader<'a> =
    Box<dyn Fn(&str, &str) -> Result<Vec<(String, Tensor)>> + Send + Sync + 'a>;

/// How a variant should execute: the monolithic single-stage plan
/// (default) or the tier's declared pipeline stages, optionally with
/// per-stage bit widths (mixed precision — e.g. `[16, 4]` keeps stage 0
/// unquantized while stage 1 packs to 4-bit).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanRequest {
    pub pipeline: bool,
    /// Per-stage bit-width overrides (requires `pipeline`); `None` =
    /// the variant's base spec everywhere.
    pub stage_bits: Option<Vec<usize>>,
    /// Execute through the native fused dequant×matmul backend
    /// (`runtime::native`): packed weights never expand to f32 literals;
    /// scoring walks the k-bit bitstream inside the matmul inner loop.
    pub fused: bool,
    /// Keep quantized indices **entropy-coded** in residency
    /// (`quant::entropy`): per-segment canonical Huffman over the k-bit
    /// index stream, decoded losslessly. Residency and `total_bits` become
    /// *measured* coded bytes/bits instead of the nominal `n * k`.
    pub entropy: bool,
}

impl PlanRequest {
    /// The pipeline plan with the base spec in every stage.
    pub fn staged() -> Self {
        PlanRequest { pipeline: true, ..Self::default() }
    }

    /// The monolithic plan on the native fused backend.
    pub fn fused() -> Self {
        PlanRequest { fused: true, ..Self::default() }
    }

    /// The monolithic plan with entropy-coded residency.
    pub fn entropy_coded() -> Self {
        PlanRequest { entropy: true, ..Self::default() }
    }

    /// Registry-key suffix distinguishing plan shapes of one spec, so
    /// monolithic, sharded, fused, and entropy-coded variants coexist as
    /// separate residents: `""`, `#pipe`, `#pipe[8,4]`, `#ec`, `#fused`,
    /// `#pipe#ec#fused`, … (canonical order: `#pipe…` then `#ec` then
    /// `#fused` — fleet key replay depends on it).
    pub fn suffix(&self) -> String {
        let mut s = if !self.pipeline {
            String::new()
        } else {
            match &self.stage_bits {
                None => "#pipe".into(),
                Some(b) => {
                    let bits: Vec<String> = b.iter().map(|k| k.to_string()).collect();
                    format!("#pipe[{}]", bits.join(","))
                }
            }
        };
        if self.entropy {
            s.push_str("#ec");
        }
        if self.fused {
            s.push_str("#fused");
        }
        s
    }
}

/// One resident model variant: immutable, `Arc`-shared across connections.
pub struct ModelHandle<'rt> {
    /// Human identity, e.g. `gpt2like_t0`.
    pub model_key: String,
    pub tier: TierManifest,
    pub spec: QuantSpec,
    /// The plan shape this variant executes with (part of its identity).
    pub plan_req: PlanRequest,
    ev: Evaluator<'rt>,
    plits: ParamLiterals,
    /// Packed k-bit residency of every quantized tensor, in plan-param
    /// order (`qkv` for the monolithic plan, `s1/qkv[1..2]`-style labels
    /// for pipeline slices). Empty for baseline and proxy specs (the
    /// former has nothing to pack; the latter is mixed-precision and
    /// stays simulated). `Arc`-shared so the fused native backend scores
    /// the same allocations — fused variants add zero packed bytes.
    /// Empty for entropy-coded variants, whose only residency is
    /// [`Self::encoded`].
    pub packed: Vec<(String, Arc<PackedParam>)>,
    /// Entropy-coded residency (`plan_req.entropy`): the same plan params
    /// as [`Self::packed`] would hold, Huffman-coded (`quant::entropy`).
    /// The packed form is dropped after encoding, so an entropy variant's
    /// resident bytes are the *measured* coded bytes. Empty otherwise.
    pub encoded: Vec<(String, Arc<EncodedParam>)>,
    /// Packed resident bytes per plan stage (stage name, bytes) — the
    /// governance layer's per-stage view of a sharded variant.
    pub stage_bytes: Vec<(String, usize)>,
}

impl<'rt> ModelHandle<'rt> {
    /// Quantize `params` under `spec` for the monolithic plan and build
    /// the resident state (see [`ModelHandle::with_plan`]).
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tier: &TierManifest,
        params: &[(String, Tensor)],
        spec: QuantSpec,
        model_key: String,
    ) -> Result<Self> {
        Self::with_plan(rt, manifest, tier, params, spec, &PlanRequest::default(), model_key)
    }

    /// Quantize `params` and build the resident state for one plan shape.
    ///
    /// Quantize+pack — the expensive step — fans out across pool workers,
    /// one task per plan parameter (a tier tensor, or a pipeline stage's
    /// layer slice of one); every task owns its output and no buffer is
    /// shared, so concurrent loads of different variants (and the
    /// column-parallel fused scoring pool) never contend on a load-time
    /// allocation. The dequantize→literal walk stays serial on **per-load
    /// scratch**: one buffer owned by this call, pre-sized to the largest
    /// quantized plan param, so only a single dequantized copy exists at a
    /// time. Neither the unpacked index vector nor a dequantized f32
    /// `Tensor` survives construction — the packed form is the only
    /// host-side weight residency. Per-layer slice quantization makes a
    /// sharded variant's dequantized weights bit-identical to the
    /// monolithic build under the same spec.
    ///
    /// Fused variants (`plan_req.fused`) skip the dequantize step
    /// entirely: quantized params go straight into the native fused
    /// backend as packed residency (`Arc`-shared with [`Self::packed`], so
    /// resident bytes are unchanged), and no XLA parameter literals are
    /// built.
    pub fn with_plan(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tier: &TierManifest,
        params: &[(String, Tensor)],
        spec: QuantSpec,
        plan_req: &PlanRequest,
        model_key: String,
    ) -> Result<Self> {
        if params.len() != tier.params.len() {
            bail!("expected {} parameter tensors, got {}", tier.params.len(), params.len());
        }
        if plan_req.stage_bits.is_some() && !plan_req.pipeline {
            bail!("stage_bits requires the pipeline plan");
        }
        if spec.proxy_outlier_pct.is_some() && plan_req.pipeline {
            bail!("proxy quantization has no pipeline form (stays simulated)");
        }
        let simulate_only = spec.is_baseline() || spec.proxy_outlier_pct.is_some();
        if plan_req.fused && simulate_only {
            bail!(
                "fused execution requires a packable quantized spec \
                 (baseline/proxy variants have no packed residency)"
            );
        }
        if plan_req.entropy && simulate_only {
            bail!(
                "entropy-coded residency requires a packable quantized spec \
                 (baseline/proxy variants have no index stream to code)"
            );
        }
        let mut ev = Evaluator::with_plan(rt, manifest, tier, plan_req.pipeline)?;
        let layout = ev.plan().layout.clone();
        let stage_specs =
            quant::stage_specs(&spec, layout.n_stages(), plan_req.stage_bits.as_deref())?;
        if simulate_only && plan_req.stage_bits.is_none() {
            // Proxy quantization is mixed-precision (16-bit outlier columns
            // inside k-bit tensors) and has no pure packed form; baseline
            // has nothing to pack. Both fall back to the simulated path
            // (the plan's literal mapping handles stage slicing).
            let q = quant::quantize_checkpoint_cow(params, &tier.quantized_params, &spec);
            let stage_bytes =
                layout.stages.iter().map(|s| (s.name.clone(), 0usize)).collect();
            let plits = ParamLiterals(ev.param_literals(&q)?);
            return Ok(ModelHandle {
                model_key,
                tier: tier.clone(),
                spec,
                plan_req: plan_req.clone(),
                ev,
                plits,
                packed: Vec::new(),
                encoded: Vec::new(),
                stage_bytes,
            });
        }
        let mut plits = Vec::with_capacity(layout.params.len());
        let mut packed = Vec::new();
        let mut encoded = Vec::new();
        let mut native_params: Vec<NativeParam> = Vec::new();
        let mut bytes_per_stage = vec![0usize; layout.n_stages()];
        // Resolve every plan param up front (cheap and serial): source
        // slice, stage spec, and whether it quantizes under that spec —
        // so the fan-out below borrows plain `Send` slices.
        let mut resolved: Vec<(&crate::runtime::plan::PlanParam, &[f32], &QuantSpec, bool)> =
            Vec::with_capacity(layout.params.len());
        for pp in &layout.params {
            let (_, t) = params
                .iter()
                .find(|(n, _)| n == &pp.source)
                .with_context(|| format!("checkpoint missing param {:?}", pp.source))?;
            let data = pp.slice_of(t)?;
            let sspec = stage_specs
                .get(pp.stage)
                .with_context(|| format!("param {:?} names stage {} of {}", pp.source, pp.stage, stage_specs.len()))?;
            let quantizes =
                tier.quantized_params.iter().any(|q| q == &pp.source) && !sspec.is_baseline();
            resolved.push((pp, data, sspec, quantizes));
        }
        // Quantize + pack — the expensive step — in parallel across pool
        // workers, one task per quantized param. Each task owns its
        // output; nothing is shared across tasks or across loads.
        // Entropy-coded variants Huffman-encode in the same worker task
        // and drop the packed intermediate before returning, so the coded
        // form is the only residency that ever leaves the fan-out.
        enum Residency {
            Packed(Arc<PackedParam>),
            Encoded(Arc<EncodedParam>),
        }
        let entropy = plan_req.entropy;
        let packed_parts = pool::parallel_map(
            resolved.len(),
            pool::default_threads(),
            |i| -> Result<Option<Residency>> {
                let Some(&(pp, data, sspec, quantizes)) = resolved.get(i) else {
                    return Ok(None);
                };
                if !quantizes {
                    return Ok(None);
                }
                let pk = PackedParam::quantize_slice(&pp.shape, data, sspec)?;
                if entropy {
                    return Ok(Some(Residency::Encoded(Arc::new(EncodedParam::encode(&pk)?))));
                }
                Ok(Some(Residency::Packed(Arc::new(pk))))
            },
        );
        // Dequant scratch is per load (owned by this call, never shared
        // across loads or threads), pre-sized to the largest quantized
        // plan param so the serial literal walk below never reallocates.
        let max_quant_numel = resolved
            .iter()
            .filter(|(_, _, _, q)| *q)
            .map(|(pp, ..)| pp.numel())
            .max()
            .unwrap_or(0);
        let mut scratch = vec![0.0f32; if plan_req.fused { 0 } else { max_quant_numel }];
        for (&(pp, data, _, _), part) in resolved.iter().zip(packed_parts) {
            if let Some(res) = part? {
                let label = if layout.is_monolithic() {
                    pp.source.clone()
                } else {
                    let stage = layout
                        .stages
                        .get(pp.stage)
                        .with_context(|| format!("stage {} out of range", pp.stage))?;
                    pp.label(&stage.name)
                };
                let bytes = bytes_per_stage
                    .get_mut(pp.stage)
                    .with_context(|| format!("stage {} out of range", pp.stage))?;
                match res {
                    Residency::Packed(pk) => {
                        if plan_req.fused {
                            // Fused variants keep only the packed form: the
                            // native backend decodes it inside the matmul
                            // inner loop.
                            native_params.push(NativeParam::Packed(pk.clone()));
                        } else {
                            let buf = scratch
                                .get_mut(..data.len())
                                .context("dequant scratch smaller than param")?;
                            pk.dequantize_into(buf)?;
                            plits.push(lit_f32_slice(&pp.shape, buf)?);
                        }
                        *bytes += pk.resident_bytes();
                        packed.push((label, pk));
                    }
                    Residency::Encoded(ep) => {
                        if plan_req.fused {
                            // Fused + entropy: the native backend
                            // stream-decodes the Huffman bitstream inside
                            // the matmul (single-threaded per matmul —
                            // variable-length decode is sequential).
                            native_params.push(NativeParam::Encoded(ep.clone()));
                        } else {
                            // Lossless: the coded stream decodes to floats
                            // bit-identical to the packed twin, so the XLA
                            // literals match an uncoded build exactly.
                            let buf = scratch
                                .get_mut(..data.len())
                                .context("dequant scratch smaller than param")?;
                            ep.dequantize_into(buf)?;
                            plits.push(lit_f32_slice(&pp.shape, buf)?);
                        }
                        *bytes += ep.resident_bytes();
                        encoded.push((label, ep));
                    }
                }
            } else if plan_req.fused {
                native_params.push(NativeParam::Dense(data.to_vec()));
            } else {
                plits.push(lit_f32_slice(&pp.shape, data)?);
            }
        }
        if plan_req.fused {
            ev.set_native(Arc::new(NativeModel::build(tier, &layout, native_params)?));
        }
        let stage_bytes = layout
            .stages
            .iter()
            .zip(bytes_per_stage)
            .map(|(s, b)| (s.name.clone(), b))
            .collect();
        Ok(ModelHandle {
            model_key,
            tier: tier.clone(),
            spec,
            plan_req: plan_req.clone(),
            ev,
            plits: ParamLiterals(plits),
            packed,
            encoded,
            stage_bytes,
        })
    }

    /// Registry key of this variant (plan shape included, so monolithic
    /// and sharded builds of one spec are distinct residents).
    pub fn key(&self) -> String {
        format!("{}@{}{}", self.model_key, self.spec.key(), self.plan_req.suffix())
    }

    /// Stages of this variant's execution plan (1 = monolithic).
    pub fn n_stages(&self) -> usize {
        self.stage_bytes.len()
    }

    /// Score padded `(tokens, mask)` rows through the resident literals.
    pub fn score_rows(&self, rows: &[(Vec<i32>, Vec<f32>)]) -> Result<Vec<(f64, f64)>> {
        self.ev.score_padded_rows(&self.plits.0, rows)
    }

    /// Run a calibration evaluation suite against the resident literals —
    /// the autotuner's measurement primitive: perplexity (and optionally
    /// the four zero-shot tasks) on a held-out corpus slice, through
    /// whatever plan shape this variant executes with. Delegates to the
    /// sweep's own suite assembly ([`Evaluator::run_literals`]), so the
    /// tuner's metric and the sweep's metric can never diverge.
    pub fn evaluate(
        &self,
        corpus: &Corpus,
        suite: EvalSuite,
        cfg: &EvalConfig,
    ) -> Result<EvalResult> {
        self.ev.run_literals(&self.plits.0, corpus, suite, cfg)
    }

    /// Host-resident weight bytes: packed form (indices + per-block
    /// constants) or, for entropy variants, *measured coded* bytes
    /// (Huffman streams + tables + constants). Zero for baseline/proxy
    /// specs, which keep no packed store.
    pub fn resident_bytes(&self) -> usize {
        self.packed.iter().map(|(_, p)| p.resident_bytes()).sum::<usize>()
            + self.encoded.iter().map(|(_, e)| e.resident_bytes()).sum::<usize>()
    }

    /// What a dequantized f32 copy of the quantized tensors would cost —
    /// the residency saving the paper's x-axis is about.
    pub fn quantized_f32_bytes(&self) -> usize {
        self.packed.iter().map(|(_, p)| p.len() * 4).sum::<usize>()
            + self.encoded.iter().map(|(_, e)| e.len() * 4).sum::<usize>()
    }

    /// Whether this variant keeps its indices entropy-coded in residency.
    pub fn entropy_coded(&self) -> bool {
        self.plan_req.entropy
    }

    /// The paper's analytic bit accounting for this model under this spec
    /// (`bitcost::total_model_bits`). `resident_bytes * 8` matches the
    /// quantized share of this within the absmax-overhead term (we store
    /// block constants as f32 where the paper accounts 16-bit) plus u32
    /// word-padding.
    pub fn ideal_total_bits(&self) -> f64 {
        quant::bitcost::total_model_bits(
            &self.tier.param_sizes(),
            &self.tier.quantized_params,
            &self.spec,
        )
    }

    /// **Measured** total model bits: quantized tensors at what they
    /// actually store (coded payload + tables + f32 block constants for
    /// entropy variants; exact `n*k` + f32 constants for packed), plus the
    /// `total_model_bits` convention of 16 bits per unquantized parameter.
    /// Falls back to the analytic figure for simulate-only variants
    /// (baseline/proxy), which store nothing to measure.
    pub fn measured_total_bits(&self) -> f64 {
        if self.packed.is_empty() && self.encoded.is_empty() {
            return self.ideal_total_bits();
        }
        let quant_bits: u64 = self.packed.iter().map(|(_, p)| p.measured_bits()).sum::<u64>()
            + self.encoded.iter().map(|(_, e)| e.measured_bits()).sum::<u64>();
        let quant_elems: usize = self.packed.iter().map(|(_, p)| p.len()).sum::<usize>()
            + self.encoded.iter().map(|(_, e)| e.len()).sum::<usize>();
        let total_elems: usize = self.tier.param_sizes().iter().map(|(_, n)| n).sum();
        let plain_elems = total_elems.saturating_sub(quant_elems);
        quant_bits as f64 + 16.0 * plain_elems as f64
    }

    /// Coded payload bits actually spent on entropy-coded index streams
    /// (zero for uncoded variants).
    pub fn coded_payload_bits(&self) -> u64 {
        self.encoded.iter().map(|(_, e)| e.payload_bits()).sum()
    }

    /// The nominal `n * k` payload those same streams would spend packed.
    pub fn coded_nominal_bits(&self) -> u64 {
        self.encoded.iter().map(|(_, e)| e.nominal_payload_bits()).sum()
    }

    /// Shannon lower bound (bits) of the entropy-coded index streams —
    /// the floor the coder is measured against in `{"op":"stats"}`.
    pub fn index_entropy_bits(&self) -> f64 {
        self.encoded.iter().map(|(_, e)| e.entropy_bits()).sum()
    }
}

/// Registry-internal residency record: the shared handle plus the
/// governance metadata (`{"op":"stats"}` reports exactly these fields).
struct Resident<'rt> {
    handle: Arc<ModelHandle<'rt>>,
    /// Cached `handle.resident_bytes()` (immutable after construction).
    bytes: usize,
    /// Times this variant was resolved (`load` fast path, `get`).
    hits: u64,
    last_use: Instant,
}

/// One variant's governance snapshot, as reported by `{"op":"stats"}`.
#[derive(Debug, Clone)]
pub struct VariantStats {
    pub key: String,
    pub resident_bytes: usize,
    /// Per-stage packed-byte breakdown of `resident_bytes` — one entry
    /// for the monolithic plan, one per pipeline stage for sharded
    /// variants, so governance reporting sees where a variant's memory
    /// lives.
    pub stage_bytes: Vec<(String, usize)>,
    pub hits: u64,
    /// Time since the variant was last resolved.
    pub idle: Duration,
    /// Whether `Arc` references beyond the registry's own exist —
    /// in-flight scoring pins an evicted variant until these drop.
    pub pinned: bool,
    /// Entropy-coding accounting, `None` for uncoded variants:
    /// `(coded payload bits, nominal n·k bits, Shannon bound bits,
    /// measured total model bits)`.
    pub entropy: Option<(u64, u64, f64, f64)>,
}

/// A process-wide collection of resident model variants with LRU/TTL
/// memory governance and an optional shared score cache.
pub struct ModelRegistry<'rt> {
    rt: &'rt Runtime,
    pub manifest: Manifest,
    loader: ParamLoader<'rt>,
    models: Mutex<HashMap<String, Resident<'rt>>>,
    default_key: Mutex<Option<String>>,
    /// Packed-byte residency budget; `None` = unbounded.
    max_resident_bytes: Option<usize>,
    /// Idle eviction deadline; `None` = no TTL.
    ttl: Option<Duration>,
    evictions: AtomicU64,
    /// Keys some thread is currently building (single-flight loading).
    loading: Mutex<HashSet<String>>,
    loaded_cv: Condvar,
    /// Shared score cache; `None` = caching disabled.
    cache: Option<Arc<ScoreCache>>,
    /// Active tuned policy driving `{"op":"load","auto":true}` picks;
    /// `Arc`-shared so in-flight picks survive a concurrent swap.
    policy: Mutex<Option<Arc<TunedPolicy>>>,
    /// Where the active policy came from (the `--policy` file path);
    /// `None` for live installs (`{"op":"tune"}`/`{"op":"policy"}`).
    /// Reported by `{"op":"stats"}` so fleet-wide aggregation can name
    /// the artifact behind a policy-skew finding.
    policy_source: Mutex<Option<String>>,
    /// Sliding-window scoring-request latency, reported in the
    /// `{"op":"stats"}` `latency` block (inspectable with or without a
    /// fleet governor in front of this worker).
    latency: LatencyWindow,
    latency_clock: WallClock,
}

impl<'rt> ModelRegistry<'rt> {
    /// An ungoverned registry: no byte budget, no TTL, no score cache.
    /// Chain the `with_*` builders to opt in (the CLI always does).
    pub fn new(rt: &'rt Runtime, manifest: &Manifest, loader: ParamLoader<'rt>) -> Self {
        ModelRegistry {
            rt,
            manifest: manifest.clone(),
            loader,
            models: Mutex::new(HashMap::new()),
            default_key: Mutex::new(None),
            max_resident_bytes: None,
            ttl: None,
            evictions: AtomicU64::new(0),
            loading: Mutex::new(HashSet::new()),
            loaded_cv: Condvar::new(),
            cache: None,
            policy: Mutex::new(None),
            policy_source: Mutex::new(None),
            latency: LatencyWindow::new(
                crate::fleet::telemetry::DEFAULT_WINDOW_MS,
                crate::fleet::telemetry::DEFAULT_WINDOW_CAP,
            ),
            latency_clock: WallClock::new(),
        }
    }

    /// Record one scoring-request latency sample (the protocol layer
    /// times `score`/`choose` handling).
    pub fn record_latency(&self, latency_ms: f32) {
        self.latency.record(self.latency_clock.now_ms(), latency_ms);
    }

    /// Percentile summary of recent scoring-request latency.
    pub fn latency_snapshot(&self) -> LatencySnapshot {
        self.latency.snapshot(self.latency_clock.now_ms())
    }

    /// Evict least-recently-used variants once total packed bytes exceed
    /// `max_bytes` (`None` = unbounded). The variant being inserted or
    /// resolved is never evicted by its own enforcement pass.
    pub fn with_memory_budget(mut self, max_bytes: Option<usize>) -> Self {
        self.max_resident_bytes = max_bytes;
        self
    }

    /// Evict variants idle (not resolved) for longer than `ttl`.
    pub fn with_ttl(mut self, ttl: Option<Duration>) -> Self {
        self.ttl = ttl;
        self
    }

    /// Attach a score cache holding up to `rows` scored rows (`0`
    /// disables caching).
    pub fn with_score_cache(mut self, rows: usize) -> Self {
        self.cache = (rows > 0).then(|| Arc::new(ScoreCache::new(rows)));
        self
    }

    /// The shared score cache, if enabled (the batch dispatcher holds a
    /// second reference).
    pub fn score_cache(&self) -> Option<Arc<ScoreCache>> {
        self.cache.clone()
    }

    /// Attach a tuned policy at construction (the CLI's `--policy`).
    pub fn with_policy(self, policy: Option<TunedPolicy>) -> Self {
        self.set_policy(policy);
        self
    }

    /// Attach a tuned policy together with its provenance (the artifact
    /// path the CLI loaded it from) — `{"op":"stats"}` reports both.
    pub fn with_policy_sourced(self, policy: Option<TunedPolicy>, source: Option<String>) -> Self {
        self.set_policy_sourced(policy, source);
        self
    }

    /// Install (or clear) the active tuned policy — the `{"op":"policy",
    /// "set":...}` / `{"op":"tune"}` swap path. In-flight auto-loads keep
    /// the policy they already resolved. Live installs have no artifact
    /// source; the source is cleared with the swap.
    pub fn set_policy(&self, policy: Option<TunedPolicy>) {
        self.set_policy_sourced(policy, None);
    }

    /// [`ModelRegistry::set_policy`] with provenance.
    pub fn set_policy_sourced(&self, policy: Option<TunedPolicy>, source: Option<String>) {
        *self.policy.lock().unwrap() = policy.map(Arc::new);
        *self.policy_source.lock().unwrap() = source;
    }

    /// The active tuned policy, if any.
    pub fn policy(&self) -> Option<Arc<TunedPolicy>> {
        self.policy.lock().unwrap().clone()
    }

    /// Provenance of the active policy (artifact path), if it was loaded
    /// from a file rather than installed live.
    pub fn policy_source(&self) -> Option<String> {
        self.policy_source.lock().unwrap().clone()
    }

    /// Packed-byte headroom left under the configured budget (`None` =
    /// unbounded): what an `auto` load may still spend.
    pub fn headroom(&self) -> Option<usize> {
        self.max_resident_bytes.map(|b| b.saturating_sub(self.resident_bytes_total()))
    }

    /// The shared PJRT runtime (the tune op runs its search on it).
    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    /// Pull checkpoint parameters through the registry's loader — the
    /// tune op's parameter source, so a search measures exactly the
    /// weights this registry would serve.
    pub fn checkpoint(&self, family: &str, tier: &str) -> Result<Vec<(String, Tensor)>> {
        (self.loader)(family, tier)
    }

    /// Policy-driven load: pick the frontier-optimal config for
    /// `(family, tier)` under the current byte headroom and make that
    /// variant resident. Returns the handle together with the policy
    /// entry that chose it, so the protocol layer can report the pick.
    ///
    /// Idempotent under repeated calls: a frontier entry that is
    /// **already resident** costs zero additional bytes, so it is
    /// preferred over any fresh load the shrunken headroom would allow —
    /// a fleet of clients all sending `{"op":"load","auto":true}` on
    /// connect converge on one variant instead of cascading down the
    /// frontier as each load eats the budget. A strictly better entry
    /// that fits the remaining headroom fresh still wins (upgrades
    /// happen when an operator raises the budget).
    pub fn load_auto(
        &self,
        family: &str,
        tier_name: &str,
    ) -> Result<(Arc<ModelHandle<'rt>>, PolicyEntry)> {
        self.load_auto_class(family, tier_name, None)
    }

    /// [`ModelRegistry::load_auto`] resolved against a per-workload-class
    /// frontier: when the active policy carries entries for `class`, the
    /// resident probe and the fresh pick both use that class's frontier;
    /// an unknown (or absent) class uses the global entries.
    pub fn load_auto_class(
        &self,
        family: &str,
        tier_name: &str,
        class: Option<&str>,
    ) -> Result<(Arc<ModelHandle<'rt>>, PolicyEntry)> {
        let policy = self.policy().ok_or_else(|| {
            anyhow!(
                "no tuned policy active (start with --policy <file>, or install one \
                 via {{\"op\":\"tune\"}} / {{\"op\":\"policy\",\"set\":...}})"
            )
        })?;
        let tier = self.manifest.tier(tier_name)?;
        let n_stages = tier.stages.len();
        let applicable = |e: &PolicyEntry| match &e.stage_bits {
            None => true,
            Some(v) => v.len() == n_stages,
        };
        let entries: &[PolicyEntry] = class
            .and_then(|c| policy.classes.get(c))
            .map(Vec::as_slice)
            .unwrap_or(&policy.entries);
        // Best already-resident frontier entry (entries sort by metric
        // ascending, so scan in reverse). The probe must not touch
        // LRU/hit state — it may lose to a better fresh pick, and a
        // non-serving resolution counting as a use would shield an idle
        // variant from eviction (the same reason `peek` exists).
        let model_key = format!("{family}_{tier_name}");
        let resident = {
            let map = self.models.lock().unwrap();
            entries.iter().rev().filter(|e| applicable(e)).find_map(|e| {
                let spec = e.spec().ok()?;
                let key = format!("{model_key}@{}{}", spec.key(), e.plan_request().suffix());
                map.get(&key).map(|r| (key, r.handle.clone(), e.clone()))
            })
        };
        let headroom = self.headroom();
        let fresh = policy.pick_for_class(class, tier, headroom).cloned();
        let entry = match (resident, fresh) {
            (Some((_, _, r)), Some(f))
                if crate::util::order::nan_last_cmp(f.metric, r.metric).is_gt() =>
            {
                f
            }
            (Some((key, h, r)), _) => {
                // Serving the resident pick *is* a use: record it now
                // (fall back to the probed handle if it was evicted in
                // the gap — our Arc pins it).
                let h = self.touch(&key).unwrap_or(h);
                return Ok((h, r));
            }
            (None, Some(f)) => f,
            (None, None) => {
                // The hint must only cite entries pick() could ever
                // choose for this tier (stage-count applicable), or an
                // operator chases a byte figure that can never fit.
                let smallest = entries
                    .iter()
                    .filter(|e| applicable(e))
                    .map(|e| e.estimated_model_bytes(tier))
                    .min();
                return Err(match (headroom, smallest) {
                    (Some(b), Some(n)) => anyhow!(
                        "no policy entry fits {b} bytes of headroom for tier {tier_name} \
                         (smallest applicable entry wants ~{n} bytes)"
                    ),
                    _ => anyhow!("policy has no entry applicable to tier {tier_name}"),
                });
            }
        };
        let handle = self.load_plan(family, tier_name, entry.spec()?, &entry.plan_request())?;
        Ok((handle, entry))
    }

    /// Insert an already-built handle; the first insert becomes the
    /// default model for connections that don't route explicitly. If the
    /// variant is already resident (two clients racing the same `load`),
    /// the existing handle wins and the new one is dropped, so shared
    /// `Arc`s never dangle off a silently replaced entry.
    pub fn insert(&self, handle: ModelHandle<'rt>) -> Arc<ModelHandle<'rt>> {
        let key = handle.key();
        let mut map = self.models.lock().unwrap();
        let arc = match map.entry(key.clone()) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let r = e.get_mut();
                r.hits += 1;
                r.last_use = Instant::now();
                r.handle.clone()
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                let bytes = handle.resident_bytes();
                let arc = Arc::new(handle);
                e.insert(Resident {
                    handle: arc.clone(),
                    bytes,
                    hits: 0,
                    last_use: Instant::now(),
                });
                arc
            }
        };
        {
            let mut def = self.default_key.lock().unwrap();
            if def.is_none() {
                *def = Some(key.clone());
            }
        }
        self.enforce_policy(&mut map, Some(&key));
        arc
    }

    /// Load (or return the already-resident) `(family, tier, spec)`
    /// variant on the monolithic plan (see [`ModelRegistry::load_plan`]).
    pub fn load(
        &self,
        family: &str,
        tier_name: &str,
        spec: QuantSpec,
    ) -> Result<Arc<ModelHandle<'rt>>> {
        self.load_plan(family, tier_name, spec, &PlanRequest::default())
    }

    /// Load (or return the already-resident) `(family, tier, spec, plan)`
    /// variant via the attached checkpoint loader. Racing `load`s of the
    /// same key build it once: one caller quantizes + compiles, the rest
    /// wait and share the winner's handle.
    pub fn load_plan(
        &self,
        family: &str,
        tier_name: &str,
        spec: QuantSpec,
        plan: &PlanRequest,
    ) -> Result<Arc<ModelHandle<'rt>>> {
        // Validate the plan shape before the residency lookup: a
        // malformed request (stage_bits without pipeline) must error even
        // when its key collides with an already-resident variant —
        // otherwise validation would depend on resident state.
        if plan.stage_bits.is_some() && !plan.pipeline {
            bail!("stage_bits requires the pipeline plan");
        }
        // Validate the width count against the tier's declared stage
        // count here at the protocol boundary: a mismatch used to
        // surface as a deep plan-layout error after the stage graphs had
        // already compiled; it must be one clear error line instead.
        if let Some(bits) = &plan.stage_bits {
            let declared = self.manifest.tier(tier_name)?.stages.len();
            if bits.len() != declared {
                bail!(
                    "stage_bits has {} widths but tier {tier_name} declares {declared} \
                     pipeline stage(s)",
                    bits.len()
                );
            }
        }
        let model_key = format!("{family}_{tier_name}");
        let key = format!("{}@{}{}", model_key, spec.key(), plan.suffix());
        loop {
            if let Some(hit) = self.touch(&key) {
                return Ok(hit);
            }
            // Claim the build, or wait for the thread that holds it.
            {
                let mut loading = self.loading.lock().unwrap();
                if !loading.contains(&key) {
                    loading.insert(key.clone());
                    break;
                }
                while loading.contains(&key) {
                    loading = self.loaded_cv.wait(loading).unwrap();
                }
            }
            // The builder finished (or failed): re-check residency; on
            // failure this thread claims the build and retries it.
        }
        // Release the claim on every exit path, including build errors,
        // so waiters never block on a dead flight.
        struct FlightGuard<'g, 'rt> {
            reg: &'g ModelRegistry<'rt>,
            key: &'g str,
        }
        impl Drop for FlightGuard<'_, '_> {
            fn drop(&mut self) {
                self.reg.loading.lock().unwrap().remove(self.key);
                self.reg.loaded_cv.notify_all();
            }
        }
        let _flight = FlightGuard { reg: self, key: &key };
        // A winner may have inserted between our residency check and the
        // claim; one more look avoids a redundant build.
        if let Some(hit) = self.touch(&key) {
            return Ok(hit);
        }
        let tier = self.manifest.tier(tier_name)?;
        let params = (self.loader)(family, tier_name)
            .with_context(|| format!("loading checkpoint {model_key}"))?;
        let handle = ModelHandle::with_plan(
            self.rt,
            &self.manifest,
            tier,
            &params,
            spec,
            plan,
            model_key,
        )?;
        Ok(self.insert(handle))
    }

    /// Fast-path residency check that also records the use (LRU + hit
    /// count).
    fn touch(&self, key: &str) -> Option<Arc<ModelHandle<'rt>>> {
        let mut map = self.models.lock().unwrap();
        let r = map.get_mut(key)?;
        r.hits += 1;
        r.last_use = Instant::now();
        Some(r.handle.clone())
    }

    /// Resolve a request's model reference: `None` → the default model; a
    /// full registry key, or a bare model key when exactly one variant of
    /// it is resident. Resolution counts as a use (LRU touch + hit).
    pub fn get(&self, key: Option<&str>) -> Result<Arc<ModelHandle<'rt>>> {
        let mut map = self.models.lock().unwrap();
        let key = match key {
            Some(k) => k.to_string(),
            None => self
                .default_key
                .lock()
                .unwrap()
                .clone()
                .ok_or_else(|| anyhow!("registry has no models loaded"))?,
        };
        let full = Self::resolve_full_key(&map, &key)?;
        let r = map
            .get_mut(&full)
            .ok_or_else(|| anyhow!("model {full:?} vanished during resolution"))?;
        r.hits += 1;
        r.last_use = Instant::now();
        let handle = r.handle.clone();
        // Opportunistic TTL sweep — no background thread needed; the
        // just-resolved variant is protected. The byte budget is enforced
        // at insert time only (resolution never grows residency).
        if self.ttl.is_some() {
            self.sweep_ttl(&mut map, Some(&full));
            self.repair_default(&map);
        }
        Ok(handle)
    }

    /// Resolve like [`ModelRegistry::get`] but **without** the LRU touch
    /// or hit count: metadata reads (the `info` op) must not keep an
    /// otherwise-idle variant warm against TTL eviction or inflate its
    /// hit counter.
    pub fn peek(&self, key: Option<&str>) -> Result<Arc<ModelHandle<'rt>>> {
        let map = self.models.lock().unwrap();
        let key = match key {
            Some(k) => k.to_string(),
            None => self
                .default_key
                .lock()
                .unwrap()
                .clone()
                .ok_or_else(|| anyhow!("registry has no models loaded"))?,
        };
        let full = Self::resolve_full_key(&map, &key)?;
        map.get(&full)
            .map(|r| r.handle.clone())
            .ok_or_else(|| anyhow!("model {full:?} vanished during resolution"))
    }

    /// Drop a resident variant (resolved like [`ModelRegistry::get`]:
    /// full key or unambiguous bare model key). In-flight `Arc`s keep the
    /// memory alive until they drop; the registry forgets the variant
    /// immediately. Returns the full key that was unloaded.
    pub fn unload(&self, key: &str) -> Result<String> {
        let mut map = self.models.lock().unwrap();
        let full = Self::resolve_full_key(&map, key)?;
        map.remove(&full);
        self.repair_default(&map);
        Ok(full)
    }

    /// Resolve a full registry key from a full key or an unambiguous bare
    /// model key — the one resolution rule shared by `get` and `unload`.
    fn resolve_full_key(map: &HashMap<String, Resident<'rt>>, key: &str) -> Result<String> {
        if map.contains_key(key) {
            return Ok(key.to_string());
        }
        let matching: Vec<String> = map
            .iter()
            .filter(|(_, r)| r.handle.model_key == key)
            .map(|(k, _)| k.clone())
            .collect();
        match matching.as_slice() {
            [one] => Ok(one.clone()),
            [] => bail!("model {key:?} not resident (have: {:?})", {
                let mut ks: Vec<&String> = map.keys().collect();
                ks.sort();
                ks
            }),
            many => bail!(
                "model {key:?} is ambiguous ({} quantization variants resident); \
                 use the full key",
                many.len()
            ),
        }
    }

    /// Governance snapshot for `{"op":"stats"}`: runs a TTL sweep, then
    /// reports every resident variant (key-sorted) without touching LRU
    /// state.
    pub fn stats(&self) -> Vec<VariantStats> {
        let mut map = self.models.lock().unwrap();
        // TTL only: the byte budget is enforced at insert time, and a
        // read-only stats call must never evict an over-budget variant
        // that insert deliberately protected (it may be the only one).
        self.sweep_ttl(&mut map, None);
        self.repair_default(&map);
        let now = Instant::now();
        let mut v: Vec<VariantStats> = map
            .iter()
            .map(|(k, r)| VariantStats {
                key: k.clone(),
                resident_bytes: r.bytes,
                stage_bytes: r.handle.stage_bytes.clone(),
                hits: r.hits,
                idle: now.duration_since(r.last_use),
                pinned: Arc::strong_count(&r.handle) > 1,
                entropy: r.handle.entropy_coded().then(|| {
                    (
                        r.handle.coded_payload_bits(),
                        r.handle.coded_nominal_bits(),
                        r.handle.index_entropy_bits(),
                        r.handle.measured_total_bits(),
                    )
                }),
            })
            .collect();
        v.sort_by(|a, b| a.key.cmp(&b.key));
        v
    }

    /// Variants evicted so far (budget + TTL; explicit `unload`s do not
    /// count).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn memory_budget(&self) -> Option<usize> {
        self.max_resident_bytes
    }

    pub fn ttl(&self) -> Option<Duration> {
        self.ttl
    }

    /// Snapshot of resident variants (key-sorted) **without** an LRU
    /// touch — listing models must not make everything recently-used.
    pub fn list(&self) -> Vec<(String, Arc<ModelHandle<'rt>>)> {
        let map = self.models.lock().unwrap();
        let mut v: Vec<(String, Arc<ModelHandle<'rt>>)> =
            map.iter().map(|(k, r)| (k.clone(), r.handle.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn len(&self) -> usize {
        self.models.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total packed weight bytes resident across all variants.
    pub fn resident_bytes_total(&self) -> usize {
        self.models.lock().unwrap().values().map(|r| r.bytes).sum()
    }

    /// TTL sweep + LRU budget enforcement + default-key repair (the full
    /// pass run on insert). `protect` (the variant just inserted or
    /// resolved) is never evicted.
    fn enforce_policy(&self, map: &mut HashMap<String, Resident<'rt>>, protect: Option<&str>) {
        self.sweep_ttl(map, protect);
        if let Some(budget) = self.max_resident_bytes {
            while map.values().map(|r| r.bytes).sum::<usize>() > budget {
                let victim = map
                    .iter()
                    .filter(|(k, _)| protect != Some(k.as_str()))
                    .min_by_key(|(_, r)| r.last_use)
                    .map(|(k, _)| k.clone());
                match victim {
                    Some(k) => {
                        map.remove(&k);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                        log::info!("registry: evicted {k} (over byte budget)");
                    }
                    // Only the protected variant remains: it may exceed
                    // the budget on its own and must keep serving.
                    None => break,
                }
            }
        }
        self.repair_default(map);
    }

    /// Evict variants idle past the TTL (if one is configured).
    fn sweep_ttl(&self, map: &mut HashMap<String, Resident<'rt>>, protect: Option<&str>) {
        if let Some(ttl) = self.ttl {
            let now = Instant::now();
            let expired: Vec<String> = map
                .iter()
                .filter(|(k, r)| {
                    protect != Some(k.as_str()) && now.duration_since(r.last_use) > ttl
                })
                .map(|(k, _)| k.clone())
                .collect();
            for k in expired {
                map.remove(&k);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                log::info!("registry: evicted {k} (idle past TTL)");
            }
        }
    }

    /// Keep the default key pointing at a resident variant: if the
    /// default was evicted/unloaded, fall forward to the most recently
    /// used survivor (or none).
    fn repair_default(&self, map: &HashMap<String, Resident<'rt>>) {
        let mut def = self.default_key.lock().unwrap();
        let ok = def.as_ref().is_some_and(|k| map.contains_key(k));
        if !ok {
            *def = map.iter().max_by_key(|(_, r)| r.last_use).map(|(k, _)| k.clone());
        }
    }
}

/// The serving layer's one spec-defaulting rule: 4-bit fp/b64 (the
/// paper's recommendation) unless overridden; block `0` means
/// tensor-wise; bits ≥ 16 is the unquantized baseline. Shared by the
/// `{"op":"load"}` handler, the CLI flags, and [`ModelSpecReq::parse`] so
/// the three request formats can never diverge.
///
/// Validates the configuration here — network input must come back as an
/// error response, not hit the quantizer's `expect` from a worker thread.
/// Bits are capped at 8 (codebook indices are `u8`; packing is 1..=8)
/// and the dtype/bit/exponent combination must build a codebook.
pub fn spec_from_parts(
    bits: usize,
    dtype: crate::quant::DataType,
    block: Option<usize>,
) -> Result<QuantSpec> {
    if bits >= 16 {
        return Ok(QuantSpec::baseline16());
    }
    if !(1..=8).contains(&bits) {
        bail!("unsupported bit width {bits} (1..=8, or >=16 for the baseline)");
    }
    let spec = QuantSpec::new(dtype, bits, block);
    spec.codebook()
        .with_context(|| format!("unsupported quantization config {}", spec.key()))?;
    Ok(spec)
}

/// A `family:tier[:bits[:dtype[:block]]]` model request, e.g.
/// `gpt2like:t0:4:fp:64` (the CLI `--preload` format). Block `0` or
/// `none` means tensor-wise; bits ≥ 16 is the baseline.
#[derive(Debug, Clone)]
pub struct ModelSpecReq {
    pub family: String,
    pub tier: String,
    pub spec: QuantSpec,
}

impl ModelSpecReq {
    pub fn parse(s: &str) -> Result<ModelSpecReq> {
        let parts: Vec<&str> = s.split(':').collect();
        let (family, tier) = match parts.as_slice() {
            [f, t, ..] if !f.is_empty() && !t.is_empty() && parts.len() <= 5 => (*f, *t),
            _ => bail!("bad model spec {s:?} (want family:tier[:bits[:dtype[:block]]])"),
        };
        let bits: usize = match parts.get(2) {
            Some(b) => b.parse().map_err(|_| anyhow!("bad bits in {s:?}"))?,
            None => 4,
        };
        let dtype = match parts.get(3) {
            Some(d) => crate::quant::DataType::parse(d)?,
            None => crate::quant::DataType::Fp,
        };
        let block = match parts.get(4) {
            Some(&"none") | Some(&"0") => None,
            Some(b) => Some(b.parse().map_err(|_| anyhow!("bad block in {s:?}"))?),
            None => Some(64),
        };
        Ok(ModelSpecReq {
            family: family.to_string(),
            tier: tier.to_string(),
            spec: spec_from_parts(bits, dtype, block)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::DataType;

    #[test]
    fn model_spec_req_parses_all_arities() {
        let m = ModelSpecReq::parse("gpt2like:t0").unwrap();
        assert_eq!((m.family.as_str(), m.tier.as_str()), ("gpt2like", "t0"));
        assert_eq!(m.spec.key(), "fp:4:b64");
        let m = ModelSpecReq::parse("optlike:t2:3:int:32").unwrap();
        assert_eq!(m.spec, QuantSpec::new(DataType::Int, 3, Some(32)));
        let m = ModelSpecReq::parse("optlike:t2:4:quantile:none").unwrap();
        assert_eq!(m.spec.block, None);
        let m = ModelSpecReq::parse("optlike:t2:16").unwrap();
        assert!(m.spec.is_baseline());
        assert!(ModelSpecReq::parse("justfamily").is_err());
        assert!(ModelSpecReq::parse("f:t:x").is_err());
        assert!(ModelSpecReq::parse("f:t:4:fp:64:extra").is_err());
    }

    #[test]
    fn plan_request_suffixes_distinguish_shapes() {
        // The suffix is part of the registry key: monolithic, sharded,
        // and mixed-precision builds of one spec must never collide.
        assert_eq!(PlanRequest::default().suffix(), "");
        assert_eq!(PlanRequest::staged().suffix(), "#pipe");
        let mixed = PlanRequest {
            pipeline: true,
            stage_bits: Some(vec![16, 4]),
            ..PlanRequest::default()
        };
        assert_eq!(mixed.suffix(), "#pipe[16,4]");
        assert_eq!(PlanRequest::fused().suffix(), "#fused");
        let staged_fused = PlanRequest { pipeline: true, fused: true, ..PlanRequest::default() };
        assert_eq!(staged_fused.suffix(), "#pipe#fused");
        let mixed_fused = PlanRequest { fused: true, ..mixed.clone() };
        assert_eq!(mixed_fused.suffix(), "#pipe[16,4]#fused");
        // Entropy-coded shapes: `#ec` sits between the pipe part and
        // `#fused` (the canonical order fleet key replay re-parses).
        assert_eq!(PlanRequest::entropy_coded().suffix(), "#ec");
        let ec_fused = PlanRequest { entropy: true, fused: true, ..PlanRequest::default() };
        assert_eq!(ec_fused.suffix(), "#ec#fused");
        let staged_ec = PlanRequest { pipeline: true, entropy: true, ..PlanRequest::default() };
        assert_eq!(staged_ec.suffix(), "#pipe#ec");
        let mixed_ec_fused = PlanRequest { entropy: true, fused: true, ..mixed.clone() };
        assert_eq!(mixed_ec_fused.suffix(), "#pipe[16,4]#ec#fused");
        let suffixes = [
            PlanRequest::default().suffix(),
            PlanRequest::staged().suffix(),
            mixed.suffix(),
            PlanRequest::fused().suffix(),
            staged_fused.suffix(),
            mixed_fused.suffix(),
            PlanRequest::entropy_coded().suffix(),
            ec_fused.suffix(),
            staged_ec.suffix(),
            mixed_ec_fused.suffix(),
        ];
        let mut dedup = suffixes.to_vec();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), suffixes.len());
    }

    #[test]
    fn spec_from_parts_rejects_unbuildable_configs() {
        // Out-of-range bits must be an error at the serving boundary, not
        // a panic inside the quantizer (codebook indices are u8).
        assert!(spec_from_parts(9, DataType::Int, Some(64)).is_err());
        assert!(spec_from_parts(0, DataType::Fp, Some(64)).is_err());
        assert!(spec_from_parts(2, DataType::DynExp, Some(64)).is_err(), "dynexp needs k >= 3");
        assert!(spec_from_parts(4, DataType::Fp, Some(64)).is_ok());
        assert!(spec_from_parts(16, DataType::Int, None).unwrap().is_baseline());
    }
}
