//! The packed-model registry: shared, immutable model residency.
//!
//! A [`ModelHandle`] is everything one (model × quantization spec) variant
//! needs to serve: the compiled forward evaluator, the resident PJRT
//! parameter literals, and the **packed k-bit weights** that are the
//! model's storage-format residency (`quant::packing`). Handles are
//! immutable after construction and shared via `Arc`, so any number of
//! connections and the batch dispatcher can score against the same model
//! concurrently with no per-request copying.
//!
//! A [`ModelRegistry`] hosts many variants in one process, keyed
//! `"{family}_{tier}@{spec}"`. Checkpoints come through a caller-supplied
//! [`ParamLoader`], so the CLI wires the on-disk [`CheckpointStore`] while
//! tests and benches inject init-only parameters.
//!
//! [`CheckpointStore`]: crate::models::checkpoint::CheckpointStore

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::eval::Evaluator;
use crate::models::manifest::{Manifest, TierManifest};
use crate::quant::{self, PackedParam, QuantSpec};
use crate::runtime::{lit_f32, lit_f32_slice, ParamLiterals, Runtime};
use crate::tensor::Tensor;

/// Produces the checkpoint parameters for `(family, tier)` on demand.
pub type ParamLoader<'a> =
    Box<dyn Fn(&str, &str) -> Result<Vec<(String, Tensor)>> + Send + Sync + 'a>;

/// One resident model variant: immutable, `Arc`-shared across connections.
pub struct ModelHandle<'rt> {
    /// Human identity, e.g. `gpt2like_t0`.
    pub model_key: String,
    pub tier: TierManifest,
    pub spec: QuantSpec,
    ev: Evaluator<'rt>,
    plits: ParamLiterals,
    /// Packed k-bit residency of every quantized tensor, in manifest
    /// order. Empty for baseline and proxy specs (the former has nothing
    /// to pack; the latter is mixed-precision and stays simulated).
    pub packed: Vec<(String, PackedParam)>,
}

impl<'rt> ModelHandle<'rt> {
    /// Quantize `params` under `spec` and build the resident state.
    ///
    /// Quantized tensors stream through **one reusable scratch buffer**:
    /// quantize → pack → `dequantize_into(scratch)` → parameter literal.
    /// Neither the unpacked index vector nor a dequantized f32 `Tensor`
    /// survives construction — the packed form is the only host-side
    /// weight residency.
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tier: &TierManifest,
        params: &[(String, Tensor)],
        spec: QuantSpec,
        model_key: String,
    ) -> Result<Self> {
        let ev = Evaluator::new(rt, manifest, tier)?;
        if params.len() != tier.params.len() {
            bail!("expected {} parameter tensors, got {}", tier.params.len(), params.len());
        }
        let simulate_only = spec.is_baseline() || spec.proxy_outlier_pct.is_some();
        if simulate_only {
            // Proxy quantization is mixed-precision (16-bit outlier columns
            // inside k-bit tensors) and has no pure packed form; baseline
            // has nothing to pack. Both fall back to the simulated path.
            let q = quant::quantize_checkpoint_cow(params, &tier.quantized_params, &spec);
            let plits = ParamLiterals(ev.param_literals(&q)?);
            return Ok(ModelHandle {
                model_key,
                tier: tier.clone(),
                spec,
                ev,
                plits,
                packed: Vec::new(),
            });
        }
        let mut plits = Vec::with_capacity(params.len());
        let mut packed = Vec::new();
        let mut scratch: Vec<f32> = Vec::new();
        for (name, t) in params {
            if tier.quantized_params.iter().any(|q| q == name) {
                let pp = PackedParam::quantize(t, &spec)?;
                scratch.clear();
                scratch.resize(t.len(), 0.0);
                pp.dequantize_into(&mut scratch)?;
                plits.push(lit_f32_slice(t.shape(), &scratch)?);
                packed.push((name.clone(), pp));
            } else {
                plits.push(lit_f32(t)?);
            }
        }
        Ok(ModelHandle {
            model_key,
            tier: tier.clone(),
            spec,
            ev,
            plits: ParamLiterals(plits),
            packed,
        })
    }

    /// Registry key of this variant.
    pub fn key(&self) -> String {
        format!("{}@{}", self.model_key, self.spec.key())
    }

    /// Score padded `(tokens, mask)` rows through the resident literals.
    pub fn score_rows(&self, rows: &[(Vec<i32>, Vec<f32>)]) -> Result<Vec<(f64, f64)>> {
        self.ev.score_padded_rows(&self.plits.0, rows)
    }

    /// Host-resident weight bytes in packed form (indices + per-block
    /// constants). Zero for baseline/proxy specs, which keep no packed
    /// store.
    pub fn resident_bytes(&self) -> usize {
        self.packed.iter().map(|(_, p)| p.resident_bytes()).sum()
    }

    /// What a dequantized f32 copy of the quantized tensors would cost —
    /// the residency saving the paper's x-axis is about.
    pub fn quantized_f32_bytes(&self) -> usize {
        self.packed.iter().map(|(_, p)| p.len() * 4).sum()
    }

    /// The paper's analytic bit accounting for this model under this spec
    /// (`bitcost::total_model_bits`). `resident_bytes * 8` matches the
    /// quantized share of this within the absmax-overhead term (we store
    /// block constants as f32 where the paper accounts 16-bit) plus u32
    /// word-padding.
    pub fn ideal_total_bits(&self) -> f64 {
        quant::bitcost::total_model_bits(
            &self.tier.param_sizes(),
            &self.tier.quantized_params,
            &self.spec,
        )
    }
}

/// A process-wide collection of resident model variants.
pub struct ModelRegistry<'rt> {
    rt: &'rt Runtime,
    pub manifest: Manifest,
    loader: ParamLoader<'rt>,
    models: Mutex<HashMap<String, Arc<ModelHandle<'rt>>>>,
    default_key: Mutex<Option<String>>,
}

impl<'rt> ModelRegistry<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: &Manifest, loader: ParamLoader<'rt>) -> Self {
        ModelRegistry {
            rt,
            manifest: manifest.clone(),
            loader,
            models: Mutex::new(HashMap::new()),
            default_key: Mutex::new(None),
        }
    }

    /// Insert an already-built handle; the first insert becomes the
    /// default model for connections that don't route explicitly. If the
    /// variant is already resident (two clients racing the same `load`),
    /// the existing handle wins and the new one is dropped, so shared
    /// `Arc`s never dangle off a silently replaced entry.
    pub fn insert(&self, handle: ModelHandle<'rt>) -> Arc<ModelHandle<'rt>> {
        let key = handle.key();
        let arc = self
            .models
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_insert_with(|| Arc::new(handle))
            .clone();
        let mut def = self.default_key.lock().unwrap();
        if def.is_none() {
            *def = Some(key);
        }
        arc
    }

    /// Load (or return the already-resident) `(family, tier, spec)`
    /// variant via the attached checkpoint loader.
    pub fn load(
        &self,
        family: &str,
        tier_name: &str,
        spec: QuantSpec,
    ) -> Result<Arc<ModelHandle<'rt>>> {
        let model_key = format!("{family}_{tier_name}");
        let key = format!("{}@{}", model_key, spec.key());
        if let Some(hit) = self.models.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let tier = self.manifest.tier(tier_name)?;
        let params = (self.loader)(family, tier_name)
            .with_context(|| format!("loading checkpoint {model_key}"))?;
        let handle =
            ModelHandle::new(self.rt, &self.manifest, tier, &params, spec, model_key)?;
        Ok(self.insert(handle))
    }

    /// Resolve a request's model reference: `None` → the default model; a
    /// full registry key, or a bare model key when exactly one variant of
    /// it is resident.
    pub fn get(&self, key: Option<&str>) -> Result<Arc<ModelHandle<'rt>>> {
        let models = self.models.lock().unwrap();
        let key = match key {
            Some(k) => k.to_string(),
            None => self
                .default_key
                .lock()
                .unwrap()
                .clone()
                .ok_or_else(|| anyhow!("registry has no models loaded"))?,
        };
        if let Some(hit) = models.get(&key) {
            return Ok(hit.clone());
        }
        let matching: Vec<&Arc<ModelHandle<'rt>>> =
            models.values().filter(|h| h.model_key == key).collect();
        match matching.len() {
            1 => Ok(matching[0].clone()),
            0 => bail!("model {key:?} not resident (have: {:?})", {
                let mut ks: Vec<&String> = models.keys().collect();
                ks.sort();
                ks
            }),
            n => bail!(
                "model {key:?} is ambiguous ({n} quantization variants resident); \
                 use the full key"
            ),
        }
    }

    pub fn keys(&self) -> Vec<String> {
        let mut ks: Vec<String> = self.models.lock().unwrap().keys().cloned().collect();
        ks.sort();
        ks
    }

    pub fn len(&self) -> usize {
        self.models.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total packed weight bytes resident across all variants.
    pub fn resident_bytes_total(&self) -> usize {
        self.models.lock().unwrap().values().map(|h| h.resident_bytes()).sum()
    }
}

/// The serving layer's one spec-defaulting rule: 4-bit fp/b64 (the
/// paper's recommendation) unless overridden; block `0` means
/// tensor-wise; bits ≥ 16 is the unquantized baseline. Shared by the
/// `{"op":"load"}` handler, the CLI flags, and [`ModelSpecReq::parse`] so
/// the three request formats can never diverge.
///
/// Validates the configuration here — network input must come back as an
/// error response, not hit the quantizer's `expect` from a worker thread.
/// Bits are capped at 8 (codebook indices are `u8`; packing is 1..=8)
/// and the dtype/bit/exponent combination must build a codebook.
pub fn spec_from_parts(
    bits: usize,
    dtype: crate::quant::DataType,
    block: Option<usize>,
) -> Result<QuantSpec> {
    if bits >= 16 {
        return Ok(QuantSpec::baseline16());
    }
    if !(1..=8).contains(&bits) {
        bail!("unsupported bit width {bits} (1..=8, or >=16 for the baseline)");
    }
    let spec = QuantSpec::new(dtype, bits, block);
    spec.codebook()
        .with_context(|| format!("unsupported quantization config {}", spec.key()))?;
    Ok(spec)
}

/// A `family:tier[:bits[:dtype[:block]]]` model request, e.g.
/// `gpt2like:t0:4:fp:64` (the CLI `--preload` format). Block `0` or
/// `none` means tensor-wise; bits ≥ 16 is the baseline.
#[derive(Debug, Clone)]
pub struct ModelSpecReq {
    pub family: String,
    pub tier: String,
    pub spec: QuantSpec,
}

impl ModelSpecReq {
    pub fn parse(s: &str) -> Result<ModelSpecReq> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() < 2 || parts.len() > 5 || parts[0].is_empty() || parts[1].is_empty() {
            bail!("bad model spec {s:?} (want family:tier[:bits[:dtype[:block]]])");
        }
        let bits: usize = match parts.get(2) {
            Some(b) => b.parse().map_err(|_| anyhow!("bad bits in {s:?}"))?,
            None => 4,
        };
        let dtype = match parts.get(3) {
            Some(d) => crate::quant::DataType::parse(d)?,
            None => crate::quant::DataType::Fp,
        };
        let block = match parts.get(4) {
            Some(&"none") | Some(&"0") => None,
            Some(b) => Some(b.parse().map_err(|_| anyhow!("bad block in {s:?}"))?),
            None => Some(64),
        };
        Ok(ModelSpecReq {
            family: parts[0].to_string(),
            tier: parts[1].to_string(),
            spec: spec_from_parts(bits, dtype, block)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::DataType;

    #[test]
    fn model_spec_req_parses_all_arities() {
        let m = ModelSpecReq::parse("gpt2like:t0").unwrap();
        assert_eq!((m.family.as_str(), m.tier.as_str()), ("gpt2like", "t0"));
        assert_eq!(m.spec.key(), "fp:4:b64");
        let m = ModelSpecReq::parse("optlike:t2:3:int:32").unwrap();
        assert_eq!(m.spec, QuantSpec::new(DataType::Int, 3, Some(32)));
        let m = ModelSpecReq::parse("optlike:t2:4:quantile:none").unwrap();
        assert_eq!(m.spec.block, None);
        let m = ModelSpecReq::parse("optlike:t2:16").unwrap();
        assert!(m.spec.is_baseline());
        assert!(ModelSpecReq::parse("justfamily").is_err());
        assert!(ModelSpecReq::parse("f:t:x").is_err());
        assert!(ModelSpecReq::parse("f:t:4:fp:64:extra").is_err());
    }

    #[test]
    fn spec_from_parts_rejects_unbuildable_configs() {
        // Out-of-range bits must be an error at the serving boundary, not
        // a panic inside the quantizer (codebook indices are u8).
        assert!(spec_from_parts(9, DataType::Int, Some(64)).is_err());
        assert!(spec_from_parts(0, DataType::Fp, Some(64)).is_err());
        assert!(spec_from_parts(2, DataType::DynExp, Some(64)).is_err(), "dynexp needs k >= 3");
        assert!(spec_from_parts(4, DataType::Fp, Some(64)).is_ok());
        assert!(spec_from_parts(16, DataType::Int, None).unwrap().is_baseline());
    }
}
