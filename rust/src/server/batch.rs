//! Cross-client micro-batching.
//!
//! The AOT forward graph always executes a full `batch_eval × seq` batch;
//! a single-row request wastes `(B-1)/B` of every forward pass. The
//! [`Batcher`] closes that gap: connection threads submit scoring rows
//! into a shared [`BoundedQueue`] and block on a response channel; one
//! dispatcher thread drains the queue, coalescing rows **across clients**
//! within a latency-bound flush window, then runs a single forward
//! execution per (model, batch) group and fans the per-row results back
//! out.
//!
//! Requests for different resident models can land in the same drain; the
//! dispatcher groups by registry key and executes the groups back to
//! back, so a multi-model registry never mixes rows across executables.
//! Batch caps are **per model**: rows destined for one model never count
//! against (or prematurely close) another model's `batch_eval` cap. A job
//! that would overflow its model's group is carried into the next round
//! and flushed with **zero additional wait** — it already waited a full
//! flush window, so it coalesces only with whatever is queued at that
//! moment.
//!
//! When the registry has a score cache, the dispatcher re-probes it at
//! execution time (rows whose identical twin completed while this row was
//! queued become hits) and inserts every freshly scored row, so repeated
//! rows skip the forward on both the direct and the batched path.
//!
//! [`BoundedQueue`]: crate::util::pool::BoundedQueue

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::cache::ScoreCache;
use super::registry::ModelHandle;
use crate::util::pool::BoundedQueue;

/// One client's scoring work: rows to score against a resident model,
/// plus the channel its connection thread is blocked on.
struct ScoreJob<'rt> {
    handle: Arc<ModelHandle<'rt>>,
    rows: Vec<(Vec<i32>, Vec<f32>)>,
    tx: mpsc::Sender<Result<Vec<(f64, f64)>>>,
}

/// The micro-batching queue + dispatcher state.
pub struct Batcher<'rt> {
    queue: BoundedQueue<ScoreJob<'rt>>,
    /// How long the dispatcher waits for co-batchable rows once it holds
    /// work. Zero disables coalescing beyond what is already queued.
    pub flush: Duration,
    /// Shared score cache (the registry's), probed at execution time.
    cache: Option<Arc<ScoreCache>>,
}

impl<'rt> Batcher<'rt> {
    pub fn new(flush: Duration) -> Self {
        // Queue capacity bounds how far clients can run ahead of the
        // dispatcher; past it, submitters block (backpressure).
        Batcher { queue: BoundedQueue::new(256), flush, cache: None }
    }

    /// Attach the registry's score cache so scored rows are published and
    /// queued duplicates short-circuit.
    pub fn with_cache(mut self, cache: Option<Arc<ScoreCache>>) -> Self {
        self.cache = cache;
        self
    }

    /// Submit rows and block until the dispatcher returns their scores.
    /// Called from connection worker threads.
    pub fn submit(
        &self,
        handle: Arc<ModelHandle<'rt>>,
        rows: Vec<(Vec<i32>, Vec<f32>)>,
    ) -> Result<Vec<(f64, f64)>> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(ScoreJob { handle, rows, tx }) {
            anyhow::bail!("server is shutting down");
        }
        rx.recv().context("batch dispatcher exited")?
    }

    /// Dispatcher loop: runs until [`Batcher::shutdown`] closes the queue
    /// and the backlog drains. Intended for one dedicated thread.
    pub fn run(&self) {
        // If the dispatcher dies (a panic unwinding out of this loop),
        // submitters must not block forever on their response channels:
        // close the queue against new work and drop the queued jobs so
        // their senders disconnect and every pending `submit` errors.
        struct PanicGuard<'g, 'rt>(&'g Batcher<'rt>);
        impl Drop for PanicGuard<'_, '_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.0.queue.close();
                    while self.0.queue.pop_timeout(Duration::ZERO).is_some() {}
                }
            }
        }
        let _guard = PanicGuard(self);

        // A job popped past its model's cap is carried into the next
        // round instead of forcing an extra mostly-padding forward.
        let mut carry: Option<ScoreJob<'rt>> = None;
        loop {
            let carried = carry.is_some();
            let Some(first) = carry.take().or_else(|| self.queue.pop()) else {
                break;
            };
            // A carried job already waited one full flush window: flush
            // it with whatever is queued *right now* (zero extra wait);
            // fresh work gets the usual coalescing window.
            let deadline = if carried {
                Instant::now()
            } else {
                Instant::now() + self.flush
            };
            let lead = first.handle.clone();
            let lead_cap = lead.tier.batch_eval.max(1);
            let mut batch = vec![first];
            while rows_for(&batch, &lead) < lead_cap {
                let wait = deadline.saturating_duration_since(Instant::now());
                // With `wait` elapsed this still drains already-queued
                // jobs (pop_timeout delivers queued items before its
                // deadline check) and stops once the queue is empty.
                let Some(job) = self.queue.pop_timeout(wait) else {
                    break;
                };
                let cap = job.handle.tier.batch_eval.max(1);
                let have = rows_for(&batch, &job.handle);
                // Per-model cap: only this job's own model group can
                // reject it. A job bigger than its cap on its own is
                // still accepted (score_rows chunks internally).
                if have > 0 && have + job.rows.len() > cap {
                    carry = Some(job);
                    break;
                }
                batch.push(job);
            }
            // Group by resident model (arrival order preserved) and run
            // one forward execution per group. Same variant == same Arc
            // from the registry, so pointer identity is the group key.
            loop {
                let Some(first) = batch.first() else { break };
                let g = first.handle.clone();
                let (group, rest): (Vec<ScoreJob>, Vec<ScoreJob>) =
                    batch.into_iter().partition(|j| Arc::ptr_eq(&j.handle, &g));
                batch = rest;
                execute_group(group, self.cache.as_deref());
            }
        }
    }

    /// Close the queue: pending jobs still drain, new submissions fail.
    pub fn shutdown(&self) {
        self.queue.close();
    }
}

/// Rows already batched for `handle`'s model (Arc pointer identity).
fn rows_for<'rt>(batch: &[ScoreJob<'rt>], handle: &Arc<ModelHandle<'rt>>) -> usize {
    batch
        .iter()
        .filter(|j| Arc::ptr_eq(&j.handle, handle))
        .map(|j| j.rows.len())
        .sum()
}

/// Run one coalesced forward for jobs that share a model and fan results
/// back to each submitter. Cached rows are served without touching the
/// executable; freshly scored rows are published to the cache. Channel
/// sends ignore disconnects (a client may have hung up mid-flight; that
/// is its problem, not the dispatcher's).
fn execute_group(mut jobs: Vec<ScoreJob<'_>>, cache: Option<&ScoreCache>) {
    let handle = match jobs.first() {
        Some(j) => j.handle.clone(),
        None => return,
    };
    let key = handle.key();
    // Move the rows out of the jobs (remembering each job's share) rather
    // than cloning seq-length token/mask vectors on the hot path.
    let lens: Vec<usize> = jobs.iter().map(|j| j.rows.len()).collect();
    let rows: Vec<(Vec<i32>, Vec<f32>)> =
        jobs.iter_mut().flat_map(|j| j.rows.drain(..)).collect();
    // Silent re-probe (shared seam: `cache::RowLookup`): rows whose twin
    // completed while queued become hits without touching the counters
    // the request handler already maintained.
    let mut lk = super::cache::RowLookup::probe(cache, &key, rows, false);
    if !lk.is_complete() {
        match handle.score_rows(&lk.miss_rows) {
            Ok(scored) => {
                if let Some(c) = cache {
                    lk.publish(c, &key, &scored);
                }
                lk.fill(scored);
            }
            Err(e) => {
                // Fail only the jobs that needed the forward; a job whose
                // rows were all cache hits already has its scores in the
                // lookup and must not inherit a stranger's fault.
                let msg = format!("batched execution failed: {e:#}");
                let mut off = 0;
                for (job, n) in jobs.into_iter().zip(lens) {
                    let span = lk.vals.get(off..off + n).unwrap_or(&[]);
                    if span.len() == n && span.iter().all(|v| v.is_some()) {
                        let out: Vec<(f64, f64)> = span.iter().copied().flatten().collect();
                        let _ = job.tx.send(Ok(out));
                    } else {
                        let _ = job.tx.send(Err(anyhow!("{msg}")));
                    }
                    off += n;
                }
                return;
            }
        }
    }
    let scores = lk.into_scores();
    let mut off = 0;
    for (job, n) in jobs.into_iter().zip(lens) {
        match scores.get(off..off + n) {
            Some(span) => {
                let _ = job.tx.send(Ok(span.to_vec()));
            }
            None => {
                let _ = job.tx.send(Err(anyhow!("scorer returned fewer rows than submitted")));
            }
        }
        off += n;
    }
}
