//! Cross-client micro-batching.
//!
//! The AOT forward graph always executes a full `batch_eval × seq` batch;
//! a single-row request wastes `(B-1)/B` of every forward pass. The
//! [`Batcher`] closes that gap: connection threads submit scoring rows
//! into a shared [`BoundedQueue`] and block on a response channel; one
//! dispatcher thread drains the queue, coalescing rows **across clients**
//! up to the model's batch size within a latency-bound flush window, then
//! runs a single forward execution per (model, batch) group and fans the
//! per-row results back out.
//!
//! Requests for different resident models can land in the same drain; the
//! dispatcher groups by registry key and executes the groups back to
//! back, so a multi-model registry never mixes rows across executables.
//!
//! [`BoundedQueue`]: crate::util::pool::BoundedQueue

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::registry::ModelHandle;
use crate::util::pool::BoundedQueue;

/// One client's scoring work: rows to score against a resident model,
/// plus the channel its connection thread is blocked on.
struct ScoreJob<'rt> {
    handle: Arc<ModelHandle<'rt>>,
    rows: Vec<(Vec<i32>, Vec<f32>)>,
    tx: mpsc::Sender<Result<Vec<(f64, f64)>>>,
}

/// The micro-batching queue + dispatcher state.
pub struct Batcher<'rt> {
    queue: BoundedQueue<ScoreJob<'rt>>,
    /// How long the dispatcher waits for co-batchable rows once it holds
    /// work. Zero disables coalescing beyond what is already queued.
    pub flush: Duration,
}

impl<'rt> Batcher<'rt> {
    pub fn new(flush: Duration) -> Self {
        // Queue capacity bounds how far clients can run ahead of the
        // dispatcher; past it, submitters block (backpressure).
        Batcher { queue: BoundedQueue::new(256), flush }
    }

    /// Submit rows and block until the dispatcher returns their scores.
    /// Called from connection worker threads.
    pub fn submit(
        &self,
        handle: Arc<ModelHandle<'rt>>,
        rows: Vec<(Vec<i32>, Vec<f32>)>,
    ) -> Result<Vec<(f64, f64)>> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(ScoreJob { handle, rows, tx }) {
            anyhow::bail!("server is shutting down");
        }
        rx.recv().context("batch dispatcher exited")?
    }

    /// Dispatcher loop: runs until [`Batcher::shutdown`] closes the queue
    /// and the backlog drains. Intended for one dedicated thread.
    pub fn run(&self) {
        // If the dispatcher dies (a panic unwinding out of this loop),
        // submitters must not block forever on their response channels:
        // close the queue against new work and drop the queued jobs so
        // their senders disconnect and every pending `submit` errors.
        struct PanicGuard<'g, 'rt>(&'g Batcher<'rt>);
        impl Drop for PanicGuard<'_, '_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.0.queue.close();
                    while self.0.queue.pop_timeout(Duration::ZERO).is_some() {}
                }
            }
        }
        let _guard = PanicGuard(self);

        // A job popped past the batch cap is carried into the next round
        // instead of forcing an extra mostly-padding forward execution.
        let mut carry: Option<ScoreJob<'rt>> = None;
        loop {
            let Some(first) = carry.take().or_else(|| self.queue.pop()) else {
                break;
            };
            // Greedily coalesce more jobs up to the first model's batch
            // size, waiting at most `flush` past the first arrival.
            let cap = first.handle.tier.batch_eval.max(1);
            let deadline = Instant::now() + self.flush;
            let mut nrows = first.rows.len();
            let mut batch = vec![first];
            while nrows < cap {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.queue.pop_timeout(deadline - now) {
                    Some(job) => {
                        if nrows + job.rows.len() > cap {
                            carry = Some(job);
                            break;
                        }
                        nrows += job.rows.len();
                        batch.push(job);
                    }
                    None => break,
                }
            }
            // Group by resident model (arrival order preserved) and run
            // one forward execution per group. Same variant == same Arc
            // from the registry, so pointer identity is the group key.
            while !batch.is_empty() {
                let lead = batch[0].handle.clone();
                let (group, rest): (Vec<ScoreJob>, Vec<ScoreJob>) = batch
                    .into_iter()
                    .partition(|j| Arc::ptr_eq(&j.handle, &lead));
                batch = rest;
                execute_group(group);
            }
        }
    }

    /// Close the queue: pending jobs still drain, new submissions fail.
    pub fn shutdown(&self) {
        self.queue.close();
    }
}

/// Run one coalesced forward for jobs that share a model and fan results
/// back to each submitter. Channel sends ignore disconnects (a client may
/// have hung up mid-flight; that is its problem, not the dispatcher's).
fn execute_group(mut jobs: Vec<ScoreJob<'_>>) {
    let handle = jobs[0].handle.clone();
    // Move the rows out of the jobs (remembering each job's share) rather
    // than cloning seq-length token/mask vectors on the hot path.
    let lens: Vec<usize> = jobs.iter().map(|j| j.rows.len()).collect();
    let rows: Vec<(Vec<i32>, Vec<f32>)> =
        jobs.iter_mut().flat_map(|j| j.rows.drain(..)).collect();
    match handle.score_rows(&rows) {
        Ok(scored) => {
            let mut off = 0;
            for (job, n) in jobs.into_iter().zip(lens) {
                let _ = job.tx.send(Ok(scored[off..off + n].to_vec()));
                off += n;
            }
        }
        Err(e) => {
            let msg = format!("batched execution failed: {e:#}");
            for job in jobs {
                let _ = job.tx.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
