//! `bin1` binary score frames: a length-prefixed wire encoding for
//! streamed score-chunk lines.
//!
//! The JSON line protocol re-serializes every float on every hop: worker
//! → router → client each print and re-parse `nll`/`ce`/`ppl` per row.
//! A connection that negotiates frames (`{"op":"hello","frames":"bin1"}`,
//! see [`super`'s protocol docs](super)) instead receives each streamed
//! `{"chunk":..,"first_row":..,"rows":[..]}` line as one binary frame;
//! requests and the terminal `{"done":true,...}` summary stay JSON, and
//! JSON remains the default and the only format a worker must accept.
//!
//! # Frame layout (all integers little-endian)
//!
//! ```text
//! offset 0  u8   magic 0xB1
//! offset 1  u8   version (1)
//! offset 2  u32  payload length (bytes after the 6-byte header)
//! offset 6  u32  chunk index
//! offset 10 u32  first_row
//! offset 14 u32  row count
//! offset 18      rows: per row  f64 nll | f64 greedy_hits | u32 tokens_scored
//! ```
//!
//! Only the three independent per-row quantities travel on the wire;
//! `ce`/`ppl` are derived at decode through the *same* `row_response`
//! shaping as the JSON path, so a decoded frame is field-for-field
//! identical to the line it replaced (f64 text round-trips exactly under
//! the JSON writer's shortest-representation formatting). The fleet
//! router forwards worker frames verbatim — [`patch_header`] renumbers
//! `chunk`/`first_row` in place without touching the float payload, and
//! [`rows_nll_tok`] reads the totals it needs for the terminal summary
//! straight out of the frame.

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// First byte of every frame; distinguishes a frame from a JSON line
/// (which always starts with `{`) when peeking a stream.
pub const MAGIC: u8 = 0xB1;
/// Wire-format version; bumped on any layout change.
pub const VERSION: u8 = 1;
/// Bytes before the payload: magic, version, payload length.
pub const HEADER_BYTES: usize = 6;
/// Fixed payload prefix: chunk, first_row, row count.
pub const PREFIX_BYTES: usize = 12;
/// Bytes per row: nll f64, greedy_hits f64, tokens_scored u32.
pub const ROW_BYTES: usize = 20;
/// Sanity cap on one frame's payload; a row cap derives from the request
/// line cap, so anything near this is a corrupt or hostile length field.
pub const MAX_PAYLOAD: usize = 1 << 24;

/// Is this streamed line a score-chunk line (the only shape frames
/// encode)? Terminal `done` lines and error lines stay JSON.
pub fn is_chunk_line(j: &Json) -> bool {
    j.opt("chunk").is_some() && j.opt("rows").is_some()
}

/// Encode one `{"chunk":..,"first_row":..,"rows":[..]}` line into `out`
/// (cleared first). Rows carry only `nll`/`greedy_hits`/`tokens_scored`;
/// the derived fields are reconstructed by [`decode_chunk`].
pub fn encode_chunk_into(line: &Json, out: &mut Vec<u8>) -> Result<()> {
    let chunk = line.get("chunk")?.as_usize()?;
    let first_row = line.get("first_row")?.as_usize()?;
    let rows = line.get("rows")?.as_arr()?;
    ensure!(chunk <= u32::MAX as usize, "chunk index {chunk} exceeds frame range");
    ensure!(first_row <= u32::MAX as usize, "first_row {first_row} exceeds frame range");
    let payload = PREFIX_BYTES + ROW_BYTES * rows.len();
    ensure!(payload <= MAX_PAYLOAD, "{} rows exceed one frame", rows.len());
    out.clear();
    out.reserve(HEADER_BYTES + payload);
    out.push(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(payload as u32).to_le_bytes());
    out.extend_from_slice(&(chunk as u32).to_le_bytes());
    out.extend_from_slice(&(first_row as u32).to_le_bytes());
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for r in rows {
        let nll = r.get("nll")?.as_f64()?;
        let hits = r.get("greedy_hits")?.as_f64()?;
        let ntok = r.get("tokens_scored")?.as_f64()?;
        ensure!(
            ntok.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&ntok),
            "tokens_scored {ntok} is not a u32 count"
        );
        out.extend_from_slice(&nll.to_le_bytes());
        out.extend_from_slice(&hits.to_le_bytes());
        out.extend_from_slice(&(ntok as u32).to_le_bytes());
    }
    Ok(())
}

/// Checked field reads: every decode goes through these so a truncated
/// buffer or lying length field surfaces as a protocol error (the
/// connection answers with an error line and survives), never as a slice
/// panic inside a connection handler. See the `panic-path` lint rule.
fn bytes_at<const N: usize>(buf: &[u8], off: usize) -> Result<[u8; N]> {
    let b = buf.get(off..off + N).with_context(|| {
        format!("frame truncated: need {N} bytes at offset {off}, have {}", buf.len())
    })?;
    Ok(b.try_into()?)
}

fn byte_at(buf: &[u8], off: usize) -> Result<u8> {
    buf.get(off).copied().with_context(|| format!("frame truncated at byte {off}"))
}

fn u32_at(buf: &[u8], off: usize) -> Result<u32> {
    Ok(u32::from_le_bytes(bytes_at(buf, off)?))
}

fn f64_at(buf: &[u8], off: usize) -> Result<f64> {
    Ok(f64::from_le_bytes(bytes_at(buf, off)?))
}

fn write_u32(buf: &mut [u8], off: usize, v: u32) -> Result<()> {
    buf.get_mut(off..off + 4)
        .with_context(|| format!("frame truncated: cannot write u32 at offset {off}"))?
        .copy_from_slice(&v.to_le_bytes());
    Ok(())
}

/// Validate a complete frame and return `(chunk, first_row, nrows)`.
fn header(buf: &[u8]) -> Result<(u32, u32, usize)> {
    ensure!(buf.len() >= HEADER_BYTES + PREFIX_BYTES, "frame too short ({} bytes)", buf.len());
    let magic = byte_at(buf, 0)?;
    ensure!(magic == MAGIC, "bad frame magic {magic:#04x}");
    let version = byte_at(buf, 1)?;
    ensure!(version == VERSION, "unsupported frame version {version}");
    let payload = u32_at(buf, 2)? as usize;
    ensure!(
        buf.len() == HEADER_BYTES + payload,
        "frame length mismatch: header says {payload} payload bytes, have {}",
        buf.len() - HEADER_BYTES
    );
    let chunk = u32_at(buf, 6)?;
    let first_row = u32_at(buf, 10)?;
    let nrows = u32_at(buf, 14)? as usize;
    ensure!(
        payload == PREFIX_BYTES + ROW_BYTES * nrows,
        "frame row count {nrows} disagrees with payload length {payload}"
    );
    Ok((chunk, first_row, nrows))
}

/// Validate a complete frame and expose its header fields
/// `(chunk, first_row, nrows)` — what a forwarding hop needs before
/// renumbering with [`patch_header`].
pub fn chunk_header(buf: &[u8]) -> Result<(u32, u32, usize)> {
    header(buf)
}

/// Decode one frame back into the exact chunk line it encodes. Derived
/// fields (`ce`, `ppl`) are rebuilt through the same shaping as the JSON
/// path, so both formats deliver identical objects.
pub fn decode_chunk(buf: &[u8]) -> Result<Json> {
    let (chunk, first_row, nrows) = header(buf)?;
    let mut rows = Vec::with_capacity(nrows);
    let mut off = HEADER_BYTES + PREFIX_BYTES;
    for _ in 0..nrows {
        let nll = f64_at(buf, off)?;
        let hits = f64_at(buf, off + 8)?;
        let ntok = u32_at(buf, off + 16)?;
        rows.push(super::row_response(nll, hits, ntok as f64));
        off += ROW_BYTES;
    }
    Ok(Json::obj(vec![
        ("chunk", Json::num(chunk as f64)),
        ("first_row", Json::num(first_row as f64)),
        ("rows", Json::Arr(rows)),
    ]))
}

/// Renumber a forwarded frame's `chunk`/`first_row` in place — the fleet
/// router's per-hop rewrite, done without touching the float payload.
pub fn patch_header(buf: &mut [u8], chunk: u32, first_row: u32) -> Result<()> {
    header(buf)?;
    write_u32(buf, 6, chunk)?;
    write_u32(buf, 10, first_row)?;
    Ok(())
}

/// Sum a frame's `(nll, tokens_scored)` and return its row count — the
/// accumulation the router needs for the terminal summary line.
pub fn rows_nll_tok(buf: &[u8]) -> Result<(f64, f64, usize)> {
    let (_, _, nrows) = header(buf)?;
    let mut nll = 0.0f64;
    let mut tok = 0.0f64;
    let mut off = HEADER_BYTES + PREFIX_BYTES;
    for _ in 0..nrows {
        nll += f64_at(buf, off)?;
        tok += u32_at(buf, off + 16)? as f64;
        off += ROW_BYTES;
    }
    Ok((nll, tok, nrows))
}

/// Read one complete frame (header + payload) from `r` into `buf`. The
/// caller has already peeked that the next byte is [`MAGIC`] (a JSON
/// line starts with `{`, so one byte disambiguates).
pub fn read_frame<R: std::io::Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<()> {
    let mut head = [0u8; HEADER_BYTES];
    r.read_exact(&mut head).context("reading frame header")?;
    let magic = byte_at(&head, 0)?;
    ensure!(magic == MAGIC, "bad frame magic {magic:#04x}");
    let version = byte_at(&head, 1)?;
    ensure!(version == VERSION, "unsupported frame version {version}");
    let payload = u32_at(&head, 2)? as usize;
    ensure!(
        (PREFIX_BYTES..=MAX_PAYLOAD).contains(&payload),
        "frame payload length {payload} out of range"
    );
    buf.clear();
    buf.extend_from_slice(&head);
    buf.resize(HEADER_BYTES + payload, 0);
    let body = buf.get_mut(HEADER_BYTES..).context("frame buffer shorter than header")?;
    r.read_exact(body).context("reading frame payload")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A chunk line exactly as `score_chunk` shapes it.
    fn chunk_line(chunk: usize, first_row: usize, rows: &[(f64, f64, f64)]) -> Json {
        let rows_json = rows
            .iter()
            .map(|&(nll, hits, ntok)| crate::server::row_response(nll, hits, ntok))
            .collect();
        Json::obj(vec![
            ("chunk", Json::num(chunk as f64)),
            ("first_row", Json::num(first_row as f64)),
            ("rows", Json::Arr(rows_json)),
        ])
    }

    #[test]
    fn round_trip_is_field_identical() {
        let line = chunk_line(
            3,
            48,
            &[(12.75, 4.0, 16.0), (0.0, 0.0, 0.0), (1.0e-3, 1.0, 63.0)],
        );
        let mut buf = Vec::new();
        encode_chunk_into(&line, &mut buf).unwrap();
        assert_eq!(buf.len(), HEADER_BYTES + PREFIX_BYTES + 3 * ROW_BYTES);
        let back = decode_chunk(&buf).unwrap();
        assert_eq!(back, line);
        // And the JSON text forms agree too (what a client would see).
        assert_eq!(back.dump(), line.dump());
    }

    #[test]
    fn round_trip_preserves_f64_bits() {
        // An NLL with no short decimal form survives encode/decode
        // bit-exactly — the point of a binary wire format.
        let nll = 123.456_789_012_345_67_f64;
        let line = chunk_line(0, 0, &[(nll, 7.0, 32.0)]);
        let mut buf = Vec::new();
        encode_chunk_into(&line, &mut buf).unwrap();
        let back = decode_chunk(&buf).unwrap();
        let got = back.get("rows").unwrap().as_arr().unwrap()[0]
            .get("nll")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(got.to_bits(), nll.to_bits());
    }

    #[test]
    fn patch_header_renumbers_without_touching_rows() {
        let line = chunk_line(0, 0, &[(2.5, 1.0, 8.0), (3.5, 0.0, 8.0)]);
        let mut buf = Vec::new();
        encode_chunk_into(&line, &mut buf).unwrap();
        patch_header(&mut buf, 9, 144).unwrap();
        let back = decode_chunk(&buf).unwrap();
        assert_eq!(back.get("chunk").unwrap().as_usize().unwrap(), 9);
        assert_eq!(back.get("first_row").unwrap().as_usize().unwrap(), 144);
        assert_eq!(back.get("rows").unwrap(), line.get("rows").unwrap());
    }

    #[test]
    fn rows_nll_tok_sums_the_payload() {
        let line = chunk_line(1, 16, &[(2.0, 1.0, 8.0), (3.0, 2.0, 12.0)]);
        let mut buf = Vec::new();
        encode_chunk_into(&line, &mut buf).unwrap();
        let (nll, tok, nrows) = rows_nll_tok(&buf).unwrap();
        assert_eq!(nll, 5.0);
        assert_eq!(tok, 20.0);
        assert_eq!(nrows, 2);
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        let line = chunk_line(0, 0, &[(1.0, 1.0, 4.0)]);
        let mut buf = Vec::new();
        encode_chunk_into(&line, &mut buf).unwrap();
        // Bad magic.
        let mut bad = buf.clone();
        bad[0] = b'{';
        assert!(decode_chunk(&bad).is_err());
        // Bad version.
        let mut bad = buf.clone();
        bad[1] = 2;
        assert!(decode_chunk(&bad).is_err());
        // Truncated payload.
        assert!(decode_chunk(&buf[..buf.len() - 1]).is_err());
        // Length field disagrees with the row count.
        let mut bad = buf.clone();
        bad[14..18].copy_from_slice(&9u32.to_le_bytes());
        assert!(decode_chunk(&bad).is_err());
        // Non-chunk lines refuse to encode.
        let done = Json::obj(vec![("done", Json::Bool(true))]);
        assert!(!is_chunk_line(&done));
        assert!(encode_chunk_into(&done, &mut buf).is_err());
    }

    #[test]
    fn read_frame_consumes_exactly_one_frame() {
        let a = chunk_line(0, 0, &[(1.0, 0.0, 4.0)]);
        let b = chunk_line(1, 4, &[(2.0, 1.0, 4.0)]);
        let mut wire = Vec::new();
        let mut one = Vec::new();
        encode_chunk_into(&a, &mut one).unwrap();
        wire.extend_from_slice(&one);
        encode_chunk_into(&b, &mut one).unwrap();
        wire.extend_from_slice(&one);
        wire.extend_from_slice(b"{\"done\":true}\n");
        let mut r = std::io::Cursor::new(wire);
        let mut buf = Vec::new();
        read_frame(&mut r, &mut buf).unwrap();
        assert_eq!(decode_chunk(&buf).unwrap(), a);
        read_frame(&mut r, &mut buf).unwrap();
        assert_eq!(decode_chunk(&buf).unwrap(), b);
        // The JSON tail is untouched.
        let rest = &r.get_ref()[r.position() as usize..];
        assert_eq!(rest, b"{\"done\":true}\n");
    }
}
