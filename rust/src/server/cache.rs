//! Sharded score cache: `(registry key, token row) → (nll, hits)`.
//!
//! Scoring is deterministic — a resident variant is immutable and the
//! forward executable is a pure function of `(params, tokens, mask)` — so
//! a repeated `score`/`choose` row can skip the forward pass entirely.
//! The cache is consulted twice on the serving path:
//!
//! 1. in the request handler (`server::score_via`), where hits bypass
//!    both the batcher and the executable and the hit/miss counters are
//!    maintained, and
//! 2. in the batch dispatcher ([`super::batch`]), a silent last-moment
//!    [`ScoreCache::probe`] that catches rows whose identical twin
//!    completed between submit and flush (two clients sending the same
//!    row concurrently land in the same drain).
//!
//! Shards are mutex-striped by row hash so concurrent connection workers
//! do not serialize on one lock. Entries verify the full
//! `(model, tokens, mask)` key on lookup — the 64-bit FNV row hash only
//! picks the slot, it is never trusted for equality — so a hash collision
//! degrades to a miss/overwrite, never a wrong score. Per-shard capacity
//! is enforced FIFO; non-finite scores are not cached so a transient
//! numeric fault can be retried.
//!
//! Entries are keyed by the registry key, so evicting and re-loading a
//! variant revalidates against the same entries (same spec → same packed
//! weights → same scores); no invalidation hook is needed.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default `--cache-rows` capacity (total rows across shards).
pub const DEFAULT_CACHE_ROWS: usize = 4096;

const SHARDS: usize = 16;

/// One cached row: the full key for collision verification plus the
/// `(nll_sum, greedy_hits)` pair `score_rows` produced for it.
struct Entry {
    model: String,
    tokens: Vec<i32>,
    mask_bits: Vec<u32>,
    val: (f64, f64),
}

impl Entry {
    fn matches(&self, model: &str, row: &(Vec<i32>, Vec<f32>)) -> bool {
        self.model == model
            && self.tokens == row.0
            && self.mask_bits.len() == row.1.len()
            && self.mask_bits.iter().zip(&row.1).all(|(b, m)| *b == m.to_bits())
    }
}

struct Shard {
    map: HashMap<u64, Entry>,
    /// Insertion order for FIFO eviction once the shard is full.
    order: VecDeque<u64>,
}

/// A fixed-capacity, mutex-striped map from scoring rows to their scores.
pub struct ScoreCache {
    shards: Vec<Mutex<Shard>>,
    cap_per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScoreCache {
    /// `capacity` is the total row budget, split evenly across shards.
    pub fn new(capacity: usize) -> ScoreCache {
        let cap_per_shard = capacity.max(1).div_ceil(SHARDS);
        ScoreCache {
            shards: (0..SHARDS)
                .map(|_| {
                    Mutex::new(Shard { map: HashMap::new(), order: VecDeque::new() })
                })
                .collect(),
            cap_per_shard: cap_per_shard.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The shard owning hash `h`. The modulo keeps the index in range for
    /// any hash; `new` always builds at least one shard.
    fn shard_for(&self, h: u64) -> &Mutex<Shard> {
        // lint: allow(panic-path) — index is taken modulo the (non-empty) shard vector length
        &self.shards[(h as usize) % self.shards.len()]
    }

    /// Counted lookup: the request-level view. Bumps the hit or miss
    /// counter surfaced by `{"op":"info"}`/`{"op":"stats"}`.
    pub fn get(&self, model: &str, row: &(Vec<i32>, Vec<f32>)) -> Option<(f64, f64)> {
        match self.probe(model, row) {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Silent lookup (no counter update) — the batch dispatcher's
    /// last-moment re-check, which would otherwise double-count rows the
    /// request handler already counted as misses.
    pub fn probe(&self, model: &str, row: &(Vec<i32>, Vec<f32>)) -> Option<(f64, f64)> {
        let h = row_hash(model, row);
        let shard = self.shard_for(h).lock().unwrap();
        match shard.map.get(&h) {
            Some(e) if e.matches(model, row) => Some(e.val),
            _ => None,
        }
    }

    /// Insert a scored row. Non-finite scores are dropped (never cached)
    /// so a transient numeric fault does not become permanent.
    pub fn put(&self, model: &str, row: &(Vec<i32>, Vec<f32>), val: (f64, f64)) {
        if !val.0.is_finite() || !val.1.is_finite() {
            return;
        }
        let h = row_hash(model, row);
        let mut shard = self.shard_for(h).lock().unwrap();
        if !shard.map.contains_key(&h) {
            while shard.map.len() >= self.cap_per_shard {
                match shard.order.pop_front() {
                    Some(old) => {
                        shard.map.remove(&old);
                    }
                    None => break,
                }
            }
            shard.order.push_back(h);
        }
        let entry = Entry {
            model: model.to_string(),
            tokens: row.0.clone(),
            mask_bits: row.1.iter().map(|m| m.to_bits()).collect(),
            val,
        };
        shard.map.insert(h, entry);
    }

    /// `(hits, misses)` as counted by [`ScoreCache::get`].
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Rows currently cached across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|shard| shard.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The cache-probe half of scoring a row batch — the one row-assembly
/// seam shared by the request handler's `score_via` (direct and streamed
/// chunks) and the batch dispatcher, so cache semantics can never diverge
/// between the two paths.
///
/// `probe` splits rows into hits (`vals[i] = Some`) and misses
/// (`miss_rows`, with their original positions in `miss_idx`); after the
/// caller scores the misses, [`RowLookup::fill`] merges the fresh scores
/// back in and [`RowLookup::into_scores`] yields the complete per-row
/// vector in request order. Only **complete** rows ever enter the cache
/// ([`RowLookup::publish`]): streamed chunks publish per finished chunk,
/// partial stage activations never.
pub struct RowLookup {
    /// Per-row scores; `Some` for cache hits, filled for misses by `fill`.
    pub vals: Vec<Option<(f64, f64)>>,
    /// Original positions of the rows in `miss_rows`.
    pub miss_idx: Vec<usize>,
    /// The rows that need a forward pass, in `miss_idx` order.
    pub miss_rows: Vec<(Vec<i32>, Vec<f32>)>,
}

impl RowLookup {
    /// Probe `cache` for every row. `counted` selects the request-level
    /// counted lookup ([`ScoreCache::get`]) vs the dispatcher's silent
    /// re-check ([`ScoreCache::probe`]). With no cache, every row is a
    /// miss.
    pub fn probe(
        cache: Option<&ScoreCache>,
        key: &str,
        rows: Vec<(Vec<i32>, Vec<f32>)>,
        counted: bool,
    ) -> RowLookup {
        let vals: Vec<Option<(f64, f64)>> = rows
            .iter()
            .map(|r| {
                cache.and_then(|c| if counted { c.get(key, r) } else { c.probe(key, r) })
            })
            .collect();
        let mut rows = rows;
        let mut miss_idx = Vec::new();
        let mut miss_rows = Vec::new();
        for (i, (v, row)) in vals.iter().zip(rows.iter_mut()).enumerate() {
            if v.is_none() {
                miss_idx.push(i);
                miss_rows.push(std::mem::take(row));
            }
        }
        RowLookup { vals, miss_idx, miss_rows }
    }

    /// Every row was a cache hit — nothing to score.
    pub fn is_complete(&self) -> bool {
        self.miss_idx.is_empty()
    }

    /// Publish freshly scored miss rows to the cache (call before
    /// [`RowLookup::fill`], which does not retain the rows).
    pub fn publish(&self, cache: &ScoreCache, key: &str, scored: &[(f64, f64)]) {
        for (row, val) in self.miss_rows.iter().zip(scored) {
            cache.put(key, row, *val);
        }
    }

    /// Merge the miss scores (in `miss_rows` order) back into `vals`.
    pub fn fill(&mut self, scored: Vec<(f64, f64)>) {
        assert_eq!(scored.len(), self.miss_idx.len(), "scorer returned wrong row count");
        for (&i, val) in self.miss_idx.iter().zip(scored) {
            if let Some(slot) = self.vals.get_mut(i) {
                *slot = Some(val);
            }
        }
    }

    /// The complete per-row score vector, in original request order.
    /// Panics if misses were never filled — a caller bug, not a runtime
    /// state.
    pub fn into_scores(self) -> Vec<(f64, f64)> {
        self.vals
            .into_iter()
            // lint: allow(panic-path) — local invariant: fill() ran first; an unfilled slot is a caller bug, not wire data
            .map(|v| v.expect("every row cached or scored"))
            .collect()
    }
}

/// Streaming FNV-1a ([`crate::util::fnv1a_fold`]) over the full row key:
/// model key, token count, tokens, mask bits. Stable across platforms.
fn row_hash(model: &str, row: &(Vec<i32>, Vec<f32>)) -> u64 {
    use crate::util::{fnv1a_fold, FNV1A_OFFSET};
    let mut h = fnv1a_fold(FNV1A_OFFSET, model.as_bytes());
    h = fnv1a_fold(h, &(row.0.len() as u64).to_le_bytes());
    for &t in &row.0 {
        h = fnv1a_fold(h, &t.to_le_bytes());
    }
    for &m in &row.1 {
        h = fnv1a_fold(h, &m.to_bits().to_le_bytes());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(toks: &[i32]) -> (Vec<i32>, Vec<f32>) {
        (toks.to_vec(), toks.iter().map(|_| 1.0).collect())
    }

    #[test]
    fn roundtrip_and_counters() {
        let c = ScoreCache::new(64);
        let r = row(&[1, 2, 3]);
        assert_eq!(c.get("m@fp:4:b64", &r), None);
        c.put("m@fp:4:b64", &r, (2.5, 1.0));
        assert_eq!(c.get("m@fp:4:b64", &r), Some((2.5, 1.0)));
        // Same row under a different registry key is a distinct entry.
        assert_eq!(c.get("m@int:3:b32", &r), None);
        assert_eq!(c.counters(), (1, 2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn probe_is_silent() {
        let c = ScoreCache::new(64);
        let r = row(&[4, 5]);
        assert_eq!(c.probe("m", &r), None);
        c.put("m", &r, (1.0, 0.0));
        assert_eq!(c.probe("m", &r), Some((1.0, 0.0)));
        assert_eq!(c.counters(), (0, 0));
    }

    #[test]
    fn mask_is_part_of_the_key() {
        let c = ScoreCache::new(64);
        let a = (vec![1, 2, 3], vec![1.0, 1.0, 1.0]);
        let b = (vec![1, 2, 3], vec![0.0, 1.0, 1.0]);
        c.put("m", &a, (9.0, 2.0));
        assert_eq!(c.get("m", &b), None, "different mask must not hit");
        assert_eq!(c.get("m", &a), Some((9.0, 2.0)));
    }

    #[test]
    fn capacity_is_bounded() {
        let c = ScoreCache::new(32);
        for i in 0..1000 {
            c.put("m", &row(&[i, i + 1]), (i as f64, 0.0));
        }
        assert!(c.len() <= 2 * 32, "len {} exceeds capacity slack", c.len());
        assert!(!c.is_empty());
    }

    #[test]
    fn non_finite_scores_are_not_cached() {
        let c = ScoreCache::new(16);
        let r = row(&[7]);
        c.put("m", &r, (f64::NAN, 0.0));
        c.put("m", &r, (f64::INFINITY, 0.0));
        assert_eq!(c.get("m", &r), None);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn row_lookup_splits_hits_and_misses() {
        let c = ScoreCache::new(64);
        let (a, b, d) = (row(&[1]), row(&[2]), row(&[3]));
        c.put("m", &b, (2.0, 0.0));
        let mut lk =
            RowLookup::probe(Some(&c), "m", vec![a.clone(), b.clone(), d.clone()], true);
        assert!(!lk.is_complete());
        assert_eq!(lk.miss_idx, vec![0, 2]);
        assert_eq!(lk.miss_rows, vec![a, d]);
        assert_eq!(lk.vals[1], Some((2.0, 0.0)));
        lk.publish(&c, "m", &[(1.0, 0.0), (3.0, 0.0)]);
        lk.fill(vec![(1.0, 0.0), (3.0, 0.0)]);
        assert_eq!(lk.into_scores(), vec![(1.0, 0.0), (2.0, 0.0), (3.0, 0.0)]);
        // Published misses hit next time (counted: 1 hit above + 2 now).
        let lk2 = RowLookup::probe(Some(&c), "m", vec![row(&[1]), row(&[3])], true);
        assert!(lk2.is_complete());
        assert_eq!(lk2.into_scores(), vec![(1.0, 0.0), (3.0, 0.0)]);
    }

    #[test]
    fn row_lookup_without_cache_misses_everything() {
        let rows = vec![row(&[1]), row(&[2])];
        let lk = RowLookup::probe(None, "m", rows.clone(), true);
        assert_eq!(lk.miss_rows, rows);
        assert_eq!(lk.vals, vec![None, None]);
    }

    #[test]
    fn overwrite_keeps_len_stable() {
        let c = ScoreCache::new(16);
        let r = row(&[1]);
        c.put("m", &r, (1.0, 0.0));
        c.put("m", &r, (1.0, 0.0));
        assert_eq!(c.len(), 1);
    }
}
