//! Inference scoring server: quantized models behind a line-oriented
//! JSON-over-TCP protocol.
//!
//! The paper's motivation is cheap small-batch *inference*; this module
//! is the deployment face of that claim: load a checkpoint, quantize it
//! once under a [`QuantSpec`] (4-bit fp/b64 by default, the paper's
//! recommendation), keep the parameter literals resident, and serve
//! scoring requests through the AOT forward executable — Python-free,
//! one process, warm PJRT state.
//!
//! Protocol (one JSON object per line, response per line):
//!
//! ```text
//! → {"op":"score", "tokens":[1,5,9,...]}               sequence NLL + ppl
//! → {"op":"choose", "context":[...], "choices":[[..],[..]]}
//!                                       length-normalized best choice
//! → {"op":"info"}                       model + quantization metadata
//! ```
//!
//! A [`Session`] owns the request loop and is transport-agnostic (tested
//! in-memory; `serve_tcp` binds it to a listener; the CLI's `serve`
//! subcommand wires stdin/stdout for shell use).

use std::io::{BufRead, Write};

use anyhow::{bail, Context, Result};

use crate::data::corpus::Corpus;
use crate::eval::Evaluator;
use crate::models::manifest::{Manifest, TierManifest};
use crate::quant::{bits_per_param, quantize_checkpoint, QuantSpec};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// A ready-to-serve quantized model session.
pub struct Session<'rt> {
    ev: Evaluator<'rt>,
    plits: Vec<xla::Literal>,
    corpus: Corpus,
    tier: TierManifest,
    spec: QuantSpec,
    model_key: String,
    requests: u64,
}

impl<'rt> Session<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tier: &TierManifest,
        params: &[(String, Tensor)],
        spec: QuantSpec,
        corpus: Corpus,
        model_key: String,
    ) -> Result<Self> {
        let q = quantize_checkpoint(params, &tier.quantized_params, &spec);
        let ev = Evaluator::new(rt, manifest, tier)?;
        let plits = ev.param_literals(&q)?;
        Ok(Session { ev, plits, corpus, tier: tier.clone(), spec, model_key, requests: 0 })
    }

    /// Handle one request object; returns the response object.
    pub fn handle(&mut self, req: &Json) -> Json {
        self.requests += 1;
        match self.try_handle(req) {
            Ok(resp) => resp,
            Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
        }
    }

    fn try_handle(&mut self, req: &Json) -> Result<Json> {
        match req.get("op")?.as_str()? {
            "info" => Ok(Json::obj(vec![
                ("model", Json::str(&self.model_key)),
                ("tier", Json::str(&self.tier.name)),
                ("params", Json::num(self.tier.param_count as f64)),
                ("quant", Json::str(self.spec.key())),
                ("bits_per_param", Json::num(bits_per_param(&self.spec))),
                ("requests", Json::num(self.requests as f64)),
            ])),
            "score" => {
                let tokens = tokens_of(req.get("tokens")?)?;
                if tokens.is_empty() {
                    bail!("empty token list");
                }
                let (row, mask) = self.corpus.pad_to_seq(&tokens);
                let scored = self.score_rows(&[(row, mask.clone())])?;
                let (nll, hits) = scored[0];
                let ntok = mask.iter().sum::<f32>() as f64;
                Ok(Json::obj(vec![
                    ("nll", Json::num(nll)),
                    ("tokens_scored", Json::num(ntok)),
                    ("ce", Json::num(nll / ntok.max(1.0))),
                    ("ppl", Json::num((nll / ntok.max(1.0)).exp().min(1e6))),
                    ("greedy_hits", Json::num(hits)),
                ]))
            }
            "choose" => {
                let context = tokens_of(req.get("context")?)?;
                let choices: Vec<Vec<i32>> = req
                    .get("choices")?
                    .as_arr()?
                    .iter()
                    .map(tokens_of)
                    .collect::<Result<_>>()?;
                if choices.is_empty() {
                    bail!("no choices given");
                }
                let ex = crate::data::tasks::Example { context, choices, answer: 0 };
                let rows_raw = crate::data::tasks::scoring_rows(&ex);
                let seq = self.tier.seq;
                let mut rows = Vec::new();
                let mut lens = Vec::new();
                for (toks, mask, clen) in rows_raw {
                    let (t, m) = fit_row(&toks, &mask, seq);
                    rows.push((t, m));
                    lens.push(clen.max(1));
                }
                let scored = self.score_rows(&rows)?;
                let norm: Vec<f64> = scored
                    .iter()
                    .zip(&lens)
                    .map(|((nll, _), &l)| -nll / l as f64)
                    .collect();
                let best = norm
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                Ok(Json::obj(vec![
                    ("best", Json::num(best as f64)),
                    ("scores", Json::arr_f64(&norm)),
                ]))
            }
            op => bail!("unknown op {op:?} (info|score|choose)"),
        }
    }

    fn score_rows(&self, rows: &[(Vec<i32>, Vec<f32>)]) -> Result<Vec<(f64, f64)>> {
        self.ev.score_padded_rows(&self.plits, rows)
    }
}

fn tokens_of(v: &Json) -> Result<Vec<i32>> {
    v.as_arr()?
        .iter()
        .map(|x| {
            let n = x.as_f64()?;
            if n < 0.0 || n.fract() != 0.0 {
                bail!("token {n} is not a non-negative integer");
            }
            Ok(n as i32)
        })
        .collect()
}

fn fit_row(toks: &[i32], mask: &[f32], seq: usize) -> (Vec<i32>, Vec<f32>) {
    if toks.len() > seq {
        let cut = toks.len() - seq;
        (toks[cut..].to_vec(), mask[cut..].to_vec())
    } else {
        let mut t = toks.to_vec();
        let mut m = mask.to_vec();
        t.resize(seq, crate::data::PAD);
        m.resize(seq, 0.0);
        (t, m)
    }
}

/// Drive a session over any line-based transport until EOF.
pub fn serve_lines<R: BufRead, W: Write>(
    session: &mut Session<'_>,
    reader: R,
    mut writer: W,
) -> Result<u64> {
    let mut served = 0;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Ok(req) => session.handle(&req),
            Err(e) => Json::obj(vec![("error", Json::str(format!("bad request: {e:#}")))]),
        };
        writeln!(writer, "{}", resp.dump())?;
        writer.flush()?;
        served += 1;
    }
    Ok(served)
}

/// Bind a TCP listener and serve clients sequentially (the PJRT executable
/// is shared; batching across clients is future work noted in DESIGN.md).
pub fn serve_tcp(session: &mut Session<'_>, addr: &str) -> Result<()> {
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding {addr}"))?;
    log::info!("serving on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
        let reader = std::io::BufReader::new(stream.try_clone()?);
        let n = serve_lines(session, reader, stream)?;
        log::info!("client {peer}: {n} requests");
    }
    Ok(())
}
