//! Inference scoring server: packed quantized models behind a
//! line-oriented JSON-over-TCP protocol.
//!
//! The paper's motivation is cheap small-batch *inference*; this module is
//! the deployment face of that claim: quantize checkpoints once under a
//! [`QuantSpec`] (4-bit fp/b64 by default, the paper's recommendation),
//! keep them resident in **packed k-bit form**, and serve scoring
//! requests from many concurrent clients through the tier's AOT
//! execution plan — monolithic or pipeline-sharded across per-stage
//! executables (`runtime::plan`), optionally with per-stage bit widths —
//! Python-free, one process, warm PJRT state.
//!
//! # Serving architecture
//!
//! Four layers, smallest state on top:
//!
//! * [`registry::ModelRegistry`] — the shared residency layer. Hosts any
//!   number of (family × tier × spec) variants in one process; each
//!   [`registry::ModelHandle`] is immutable and `Arc`-shared, holding the
//!   compiled evaluator, the resident PJRT parameter literals, and the
//!   packed k-bit weights (`quant::packing::PackedTensor`) that are the
//!   only host-side weight copy — no unpacked index vectors, no duplicate
//!   f32 tensors. Residency is governed: an optional packed-byte budget
//!   evicts least-recently-used variants (in-flight `Arc`s pin them until
//!   the last reference drops), an optional TTL evicts idle ones, and
//!   concurrent `load`s of one variant build it exactly once
//!   (single-flight).
//! * [`cache::ScoreCache`] — a sharded `(registry key, token row) →
//!   score` cache. Scoring is deterministic, so repeated `score`/`choose`
//!   rows skip the forward pass entirely; it is consulted both here in
//!   the request handler and again inside the batch dispatcher.
//! * [`batch::Batcher`] — cross-client micro-batching. Connection threads
//!   submit scoring rows into a bounded queue; one dispatcher coalesces
//!   rows from concurrent clients up to each model's `batch_eval` (caps
//!   are per model) within a latency-bound flush window and runs a single
//!   forward per group; overflow jobs carry over and flush with zero
//!   extra wait.
//! * [`Connection`] — thin per-client state: a current-model key and a
//!   request counter. [`serve_listener`] runs a fixed worker pool
//!   (`util::pool::BoundedQueue` of accepted sockets), so one slow or
//!   broken client never blocks the accept loop, and per-connection I/O
//!   errors are logged without tearing the server down. Request lines are
//!   capped at [`MAX_REQUEST_LINE`] bytes — an over-long line gets an
//!   error response and is discarded without buffering, so a client
//!   streaming one giant line cannot OOM a worker.
//!
//! # Protocol (one JSON object per line, response per line)
//!
//! ```text
//! → {"op":"score", "tokens":[1,5,9,...]}               sequence NLL + ppl
//! → {"op":"score", "rows":[[..],[..],...]}             many rows, one response
//! → {"op":"score", "rows":[...], "stream":true, "chunk":16}
//!                                       chunked streaming: one line per
//!                                       scored chunk, then a terminal
//!                                       {"done":true,...} summary line
//! → {"op":"score", ..., "class":"chat"} workload-class tag: auto-resolved
//!                                       models pick from the policy's
//!                                       per-class frontier entries when
//!                                       present (unknown classes fall
//!                                       back to the global frontier);
//!                                       explicit "model" keys are never
//!                                       rewritten
//! → {"op":"choose", "context":[...], "choices":[[..],[..]]}
//!                                       length-normalized best choice
//! → {"op":"ping"}                       liveness probe: {"ok":true} plus
//!                                       resident counts; never touches
//!                                       LRU/TTL state (fleet routers
//!                                       poll this for worker health)
//! → {"op":"info"}                       model + residency + cache counters
//! → {"op":"models"}                     all resident variants
//! → {"op":"load", "family":"gpt2like", "tier":"t1", "bits":4,
//!    "dtype":"fp", "block":64}          make a variant resident
//! → {"op":"load", ..., "pipeline":true, "stage_bits":[16,4]}
//!                                       pipeline-sharded variant (per-stage
//!                                       executables; optional per-stage
//!                                       bit widths = mixed precision)
//! → {"op":"load", ..., "fused":true}    native fused-kernel variant: score
//!                                       through quant::fused's dequant×
//!                                       matmul (packed weights never
//!                                       expand to full f32 tensors)
//! → {"op":"load", ..., "entropy":true}  entropy-coded residency: packed
//!                                       k-bit indices re-coded per block
//!                                       with canonical Huffman tables
//!                                       (quant::entropy) — lossless, so
//!                                       scores are bit-identical to the
//!                                       uncoded variant while resident
//!                                       bytes drop below the fixed-k
//!                                       floor; composes with "fused"
//!                                       (stream-decoded matmuls) and
//!                                       "pipeline"; key suffix "#ec"
//! → {"op":"hello", "frames":"bin1"}     negotiate binary score frames for
//!                                       this connection; replies
//!                                       {"ok":true,"frames":"bin1"}. Any
//!                                       other (or absent) format downgrades
//!                                       to {"frames":"json"}, the default
//! → {"op":"unload", "model":"gpt2like_t1@fp:4:b64"}
//!                                       drop a variant (in-flight work
//!                                       pins it until finished)
//! → {"op":"stats"}                      governance: per-variant resident
//!                                       bytes (per plan stage) / hits /
//!                                       idle / pinned, budget, evictions,
//!                                       cache counters, and a "latency"
//!                                       block (sliding-window p50/p99 +
//!                                       request counts for scoring ops);
//!                                       entropy-coded variants also
//!                                       report coded vs nominal payload
//!                                       bits and the Shannon bound of
//!                                       their index streams
//! → {"op":"governor"}                   precision-governor status: on a
//!                                       worker, {"governor":false} plus
//!                                       its latency window; on a fleet
//!                                       router, targets + recent
//!                                       promote/demote decisions +
//!                                       per-worker telemetry, with
//!                                       "enable"/"disable",
//!                                       "target_p99_ms", "cooldown_ms"
//!                                       config fields accepted
//! → {"op":"load", "auto":true}          policy-driven load: the active
//!                                       tuned policy picks spec/stage_bits
//!                                       under the byte-budget headroom
//! → {"op":"tune", "family":"gpt2like", "tier":"t0", "bits":[3,4,8]}
//!                                       search the k-bit config space on
//!                                       a calibration slice and install
//!                                       the resulting Pareto policy
//! → {"op":"policy"}                     inspect the active tuned policy;
//!                                       "set": {...} swaps it in,
//!                                       "clear": true removes it
//! ```
//!
//! The same line protocol is the **inter-node wire format** of the fleet
//! tier ([`crate::fleet`]): a `kbitscale fleet` router speaks it
//! downstream to N `serve_tcp` workers and upstream to clients, so a
//! worker cannot tell a router from a direct client. Router-aggregated
//! ops (`info`/`stats`/`models` fan out to every worker; `score` rows
//! scatter across replicas) keep the exact response shapes documented
//! here, plus fleet-only fields (`"worker"`, `"workers"`,
//! `"policy_skew"`). `{"op":"stats"}` reports the active policy identity
//! (`entries`/`hash`/`source`) so fleet aggregation can detect policy
//! skew between workers.
//!
//! # Tuned-policy serving
//!
//! A [`crate::tune::TunedPolicy`] (from `kbitscale tune`, the CLI's
//! `--policy`, or a live `{"op":"tune"}` search) holds the measured
//! Pareto frontier of the quantization config space. With a policy
//! active, `{"op":"load","auto":true}` resolves the frontier-optimal
//! configuration that fits the registry's remaining `--max-resident-bytes`
//! headroom — precision, data type, block size, and (for tiers with
//! declared pipeline stages) the per-stage width vector — so operators
//! state a byte budget instead of hand-picking `stage_bits`. Note that a
//! live `{"op":"tune"}` search builds its candidates *outside* the
//! packed-byte governance (transient, dropped per cell); on a budgeted
//! server the builds therefore default to serial (`"threads"` overrides).
//!
//! # Scoring parallelism (fused variants)
//!
//! `"fused":true` variants run their projection matmuls column-parallel
//! across a scoped worker pool: output columns split into one contiguous
//! span per worker, every column is written by exactly one thread, and
//! the per-element accumulation order is unchanged — so scores are
//! **bit-identical at every thread count**, and one `{"op":"score"}`
//! against a large fused variant saturates the box. The worker count is
//! latched once per process from the `KBITSCALE_THREADS` environment
//! variable (`>= 1`; unset or invalid falls back to one worker per
//! available core, capped at 16), alongside the existing
//! `KBITSCALE_FORCE_SCALAR` SIMD escape hatch — set either before the
//! first fused load. CI runs the full test suite with SIMD force-disabled
//! at both 1 and 4 scoring threads.
//!
//! # Streaming
//!
//! A `"stream":true` score request answers with **multiple lines**: one
//! `{"chunk":k,"first_row":i,"rows":[...]}` line per scored row group
//! (chunk size defaults to the tier's `batch_eval`; `"chunk"` overrides),
//! terminated by a `{"done":true,...}` summary. Chunks are emitted in row
//! order as their forward batches complete, so a slow multi-row request
//! delivers partial scores long before the last batch runs. A mid-stream
//! fault (bad row, model error) terminates the stream with a
//! `{"done":true,"error":...}` line — already-emitted chunks stand, and
//! the connection keeps serving. Only complete rows enter the score
//! cache; partial stage activations never do.
//!
//! # Binary score frames (`bin1`)
//!
//! Frame negotiation is a **transport** concern, handled entirely inside
//! [`pump`]: a client that sends `{"op":"hello","frames":"bin1"}` before
//! other traffic flips its connection into frame mode, after which each
//! streamed chunk line arrives as one length-prefixed binary frame
//! ([`frames`]) instead of JSON text — requests, buffered responses, and
//! the terminal `{"done":true,...}` line stay JSON, and the handler stack
//! never sees the hello. JSON remains the default and the only format a
//! worker must accept; an unknown `"frames"` value downgrades to
//! `{"frames":"json"}`. The fleet router negotiates `bin1` downstream and
//! forwards worker frames verbatim (header renumbered in place, float
//! payload untouched), so scattered score rows cross `worker → router →
//! client` without one per-hop float re-serialization.
//!
//! `score`/`choose`/`info` accept an optional `"model"` field (a registry
//! key from `models`/`load`) to route per request; otherwise the
//! connection's current model (set by `load`) or the registry default is
//! used. Token values are validated against the addressed tier's vocab;
//! out-of-range tokens are an error response, never a silently saturated
//! cast. Cache semantics: hits return the exact scores the forward would
//! produce (entries are verified against the full row, and variants are
//! immutable); `info`'s `cache_hits`/`cache_misses` count request-level
//! lookups.
//!
//! # Static analysis & invariants
//!
//! This module (with [`crate::fleet`]) is the lint pass's network
//! surface ([`crate::analysis`], `kbitscale lint`, blocking in CI):
//!
//! * **No panic paths.** Handler and router code must not `.unwrap()`,
//!   `.expect()`, call aborting macros, or index slices unchecked —
//!   malformed wire input (truncated bin1 frames, bad chunk renumbering,
//!   hostile JSON) comes back as an error line and the connection
//!   survives. `.lock().unwrap()` is exempt by convention: a poisoned
//!   mutex means another thread already panicked, and propagating beats
//!   serving torn state. Deliberate exceptions carry
//!   `// lint: allow(panic-path) — <reason>` with a mandatory
//!   justification.
//! * **Protocol doc = dispatch table.** The op list in this doc block is
//!   diffed against the string arms of `try_handle` (plus `hello` in
//!   [`pump`]) in both directions, so the block above cannot rot.
//! * **bin1 single-sourcing.** The frame magic and layout constants live
//!   only in [`frames`]; a stray `0xB1` or a redefined
//!   `HEADER_BYTES`/`PREFIX_BYTES`/`ROW_BYTES` elsewhere is a finding.
//! * **Lock order.** Declared in [`crate::analysis::rules::DECLARED_ORDER`]:
//!   `registry.models` → `registry.default`; `registry.models` →
//!   `cache.shard` → `registry.flight`; `registry.models` →
//!   `runtime.cache` → `runtime.flight`; `fleet.roster` → `fleet.conn`.
//!   Acquiring against these edges (or locking a mutex field with no
//!   registered class) fails the lint.
//!
//! [`Session`] wraps a single-model registry behind the original
//! in-memory API (tested without sockets; the CLI's `serve` subcommand
//! still wires stdin/stdout through it for shell use).

pub mod batch;
pub mod cache;
pub mod frames;
pub mod registry;

pub use batch::Batcher;
pub use cache::{RowLookup, ScoreCache, DEFAULT_CACHE_ROWS};
pub use registry::{
    ModelHandle, ModelRegistry, ModelSpecReq, ParamLoader, PlanRequest, VariantStats,
};

use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::corpus::Corpus;
use crate::eval::EvalSuite;
use crate::models::manifest::{Manifest, TierManifest};
use crate::quant::{bits_per_param, DataType, QuantSpec};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::tune::{self, TunedPolicy};
use crate::util::json::Json;
use crate::util::pool;

/// One streamed partial-response unit.
///
/// The streaming sink carries either a JSON line (the server's own chunk
/// output — [`pump`] re-encodes it as a binary frame when the connection
/// negotiated `bin1`) or an already-encoded frame forwarded verbatim (the
/// fleet router's pass-through — [`pump`] decodes it back to JSON lines
/// for JSON-mode clients). Terminal lines never travel here; a handler's
/// return value is always a JSON object.
pub enum Emit<'a> {
    /// A JSON object to deliver as one streamed line.
    Line(&'a Json),
    /// A complete pre-encoded [`frames`] frame to forward.
    Raw(&'a [u8]),
}

/// The streaming-sink callback type: one call per streamed unit.
pub type EmitSink<'s> = dyn FnMut(Emit<'_>) -> Result<()> + 's;

/// Per-connection mutable state — everything that is *not* shared.
#[derive(Default)]
struct ConnCore {
    /// Registry key selected by this connection's last `load` (requests
    /// may still route per-request via `"model"`).
    current: Option<String>,
    requests: u64,
}

/// A live client connection bound to a shared registry, optionally
/// scoring through the micro-batcher.
pub struct Connection<'a, 'rt> {
    registry: &'a ModelRegistry<'rt>,
    batcher: Option<&'a Batcher<'rt>>,
    core: ConnCore,
}

impl<'a, 'rt> Connection<'a, 'rt> {
    pub fn new(registry: &'a ModelRegistry<'rt>, batcher: Option<&'a Batcher<'rt>>) -> Self {
        Connection { registry, batcher, core: ConnCore::default() }
    }

    /// Handle one request object; returns the response object. Streamed
    /// (`"stream":true`) requests error here — they need a line
    /// transport; use [`Connection::handle_streaming`].
    pub fn handle(&mut self, req: &Json) -> Json {
        handle_request(self.registry, self.batcher, &mut self.core, req, None)
    }

    /// Handle one request with streaming support: partial-response units
    /// (JSON lines or forwarded binary frames) go through `sink`; the
    /// terminal line is the return value.
    pub fn handle_streaming(&mut self, req: &Json, sink: &mut EmitSink<'_>) -> Json {
        handle_request(self.registry, self.batcher, &mut self.core, req, Some(sink))
    }
}

/// A ready-to-serve single-model session — the original serving API,
/// now a thin wrapper over a one-entry [`ModelRegistry`].
pub struct Session<'rt> {
    registry: ModelRegistry<'rt>,
    core: ConnCore,
}

impl<'rt> Session<'rt> {
    /// `_corpus` is kept for call-site compatibility; scoring rows are
    /// padded tier-aware by the request handler, so the session itself
    /// no longer consults the corpus.
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tier: &TierManifest,
        params: &[(String, Tensor)],
        spec: QuantSpec,
        _corpus: Corpus,
        model_key: String,
    ) -> Result<Self> {
        let registry = ModelRegistry::new(
            rt,
            manifest,
            Box::new(|family: &str, tier: &str| {
                bail!("session has no checkpoint loader (cannot load {family}:{tier})")
            }),
        )
        .with_score_cache(cache::DEFAULT_CACHE_ROWS);
        let handle = ModelHandle::new(rt, manifest, tier, params, spec, model_key)?;
        registry.insert(handle);
        Ok(Session { registry, core: ConnCore::default() })
    }

    /// Handle one request object; returns the response object (streamed
    /// requests need [`Session::handle_streaming`]).
    pub fn handle(&mut self, req: &Json) -> Json {
        handle_request(&self.registry, None, &mut self.core, req, None)
    }

    /// Handle one request with streaming support (see
    /// [`Connection::handle_streaming`]).
    pub fn handle_streaming(&mut self, req: &Json, sink: &mut EmitSink<'_>) -> Json {
        handle_request(&self.registry, None, &mut self.core, req, Some(sink))
    }

    /// The underlying registry (e.g. to preload more variants).
    pub fn registry(&self) -> &ModelRegistry<'rt> {
        &self.registry
    }
}

// ---------------------------------------------------------------------------
// Request handling
// ---------------------------------------------------------------------------

fn handle_request<'rt>(
    registry: &ModelRegistry<'rt>,
    batcher: Option<&Batcher<'rt>>,
    core: &mut ConnCore,
    req: &Json,
    sink: Option<&mut EmitSink<'_>>,
) -> Json {
    core.requests += 1;
    // Scoring ops feed the stats/governor latency window; metadata ops
    // (ping, stats itself) stay out so probes don't dilute the signal.
    let timed = matches!(
        req.opt("op").and_then(|v| v.as_str().ok()),
        Some("score") | Some("choose")
    );
    let started = timed.then(std::time::Instant::now);
    let resp = match try_handle(registry, batcher, core, req, sink) {
        Ok(resp) => resp,
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    };
    if let Some(t0) = started {
        registry.record_latency((t0.elapsed().as_secs_f64() * 1e3) as f32);
    }
    resp
}

/// Resolve the model a request addresses: explicit `"model"` field, then
/// the connection's current model, then the registry default. `touch`
/// marks the resolution as a use (LRU + hit count) — scoring ops touch,
/// metadata reads (`info`) peek, so polling cannot defeat TTL eviction.
fn resolve<'rt>(
    registry: &ModelRegistry<'rt>,
    core: &ConnCore,
    req: &Json,
    touch: bool,
) -> Result<Arc<ModelHandle<'rt>>> {
    let explicit = match req.opt("model") {
        Some(v) => Some(v.as_str()?),
        None => None,
    };
    let key = explicit.or(core.current.as_deref());
    if touch {
        registry.get(key)
    } else {
        registry.peek(key)
    }
}

/// Resolve the `(family, tier)` a tune/auto-load request addresses:
/// explicit `"family"`/`"tier"` fields, else the identity of the
/// connection's current (or registry default) model — so `{"op":"tune"}`
/// with no arguments searches against whatever is being served.
fn model_identity(
    registry: &ModelRegistry<'_>,
    core: &ConnCore,
    req: &Json,
) -> Result<(String, String)> {
    match (req.opt("family"), req.opt("tier")) {
        (Some(f), Some(t)) => Ok((f.as_str()?.to_string(), t.as_str()?.to_string())),
        (None, None) => {
            let h = resolve(registry, core, req, false)?;
            // The handle carries the authoritative tier; strip it off the
            // `{family}_{tier}` key rather than string-splitting, so a
            // tier name containing '_' can never mis-parse the family.
            let tier = h.tier.name.clone();
            let family = h
                .model_key
                .strip_suffix(&format!("_{tier}"))
                .ok_or_else(|| anyhow!("cannot derive family/tier from {:?}", h.model_key))?;
            Ok((family.to_string(), tier))
        }
        _ => bail!(r#"give both "family" and "tier", or neither"#),
    }
}

/// `(enabled, hits, misses, rows)` — the score-cache counter fields the
/// `info` and `stats` ops both report.
fn cache_counters(registry: &ModelRegistry<'_>) -> (bool, u64, u64, usize) {
    match registry.score_cache() {
        Some(c) => {
            let (hits, misses) = c.counters();
            (true, hits, misses, c.len())
        }
        None => (false, 0, 0, 0),
    }
}

/// Score rows through the cache → batcher → execution-plan stack: cached
/// rows skip the forward entirely; only misses are submitted (batched
/// path publishes results to the cache inside the dispatcher, the direct
/// path publishes here). The cache split/merge lives in
/// [`cache::RowLookup`], the one row-assembly seam shared with the batch
/// dispatcher — streamed responses call this per chunk, so only complete
/// rows ever reach the cache.
fn score_via<'rt>(
    cache: Option<&ScoreCache>,
    batcher: Option<&Batcher<'rt>>,
    handle: &Arc<ModelHandle<'rt>>,
    rows: Vec<(Vec<i32>, Vec<f32>)>,
) -> Result<Vec<(f64, f64)>> {
    let key = handle.key();
    let mut lk = RowLookup::probe(cache, &key, rows, true);
    if !lk.is_complete() {
        let scored = match batcher {
            // The dispatcher re-probes and publishes on its side.
            Some(b) => b.submit(handle.clone(), std::mem::take(&mut lk.miss_rows))?,
            None => {
                let scored = handle.score_rows(&lk.miss_rows)?;
                if let Some(c) = cache {
                    lk.publish(c, &key, &scored);
                }
                scored
            }
        };
        lk.fill(scored);
    }
    Ok(lk.into_scores())
}

/// The per-row score-response object — the one shaping rule shared by the
/// legacy single-row `score` response, buffered multi-row responses, and
/// streamed chunk lines.
fn row_response(nll: f64, hits: f64, ntok: f64) -> Json {
    Json::obj(vec![
        ("nll", Json::num(nll)),
        ("tokens_scored", Json::num(ntok)),
        ("ce", Json::num(nll / ntok.max(1.0))),
        ("ppl", Json::num((nll / ntok.max(1.0)).exp().min(1e6))),
        ("greedy_hits", Json::num(hits)),
    ])
}

/// Parse, validate, and pad one scoring row against the addressed tier:
/// vocab-checked tokens, tier-aware tail padding, and the masked token
/// count the response reports.
fn shape_row(v: &Json, tier: &TierManifest) -> Result<((Vec<i32>, Vec<f32>), f64)> {
    let tokens = tokens_of(v, tier.vocab)?;
    if tokens.is_empty() {
        bail!("empty token list");
    }
    // Pad to the **addressed tier's** seq: a registry hosting tiers with
    // different sequence lengths scores each against its own geometry.
    let (row, mask) = crate::data::corpus::pad_score_row(&tokens, tier.seq);
    let ntok = mask.iter().sum::<f32>() as f64;
    Ok(((row, mask), ntok))
}

/// Shape + score one group of raw token rows: validate (all rows before
/// any scoring), pad, score through the cache/batcher stack, and build
/// the per-row response objects plus the group's `(nll, token)` totals.
/// The one scoring seam under both the buffered response and every
/// streamed chunk, so the two can never diverge.
fn score_rows_shaped<'rt>(
    cache: Option<&ScoreCache>,
    batcher: Option<&Batcher<'rt>>,
    handle: &Arc<ModelHandle<'rt>>,
    group: &[&Json],
) -> Result<(Vec<Json>, f64, f64)> {
    let mut rows = Vec::with_capacity(group.len());
    let mut ntoks = Vec::with_capacity(group.len());
    for v in group {
        let (row, ntok) = shape_row(v, &handle.tier)?;
        rows.push(row);
        ntoks.push(ntok);
    }
    let scored = score_via(cache, batcher, handle, rows)?;
    let mut nll_sum = 0.0;
    let mut tok_sum = 0.0;
    let rows_json: Vec<Json> = scored
        .iter()
        .zip(&ntoks)
        .map(|(&(nll, hits), &ntok)| {
            nll_sum += nll;
            tok_sum += ntok;
            row_response(nll, hits, ntok)
        })
        .collect();
    Ok((rows_json, nll_sum, tok_sum))
}

/// Shape + score one streamed chunk; returns the chunk line and its
/// `(nll, token)` totals. Row validation happens per chunk, not up
/// front — earlier chunks are already on the wire when a bad row or a
/// model fault surfaces mid-stream.
fn score_chunk<'rt>(
    cache: Option<&ScoreCache>,
    batcher: Option<&Batcher<'rt>>,
    handle: &Arc<ModelHandle<'rt>>,
    chunk: &[&Json],
    index: usize,
    first_row: usize,
) -> Result<(Json, f64, f64)> {
    let (rows_json, nll_sum, tok_sum) = score_rows_shaped(cache, batcher, handle, chunk)?;
    let line = Json::obj(vec![
        ("chunk", Json::num(index as f64)),
        ("first_row", Json::num(first_row as f64)),
        ("rows", Json::Arr(rows_json)),
    ]);
    Ok((line, nll_sum, tok_sum))
}

/// Drive one streamed `score` request: emit a chunk line per scored row
/// group through `sink`, then return the terminal summary line (every
/// streamed response ends in a `"done":true` line). A mid-stream fault —
/// bad row, model error — becomes a terminal `done`+`error` line; the
/// chunks already emitted stand and the connection survives.
fn stream_score<'rt>(
    cache: Option<&ScoreCache>,
    batcher: Option<&Batcher<'rt>>,
    handle: &Arc<ModelHandle<'rt>>,
    raw: &[&Json],
    chunk_rows: usize,
    sink: &mut EmitSink<'_>,
) -> Json {
    let mut chunks = 0usize;
    let mut done_rows = 0usize;
    let mut total_nll = 0.0f64;
    let mut total_tok = 0.0f64;
    for chunk in raw.chunks(chunk_rows) {
        match score_chunk(cache, batcher, handle, chunk, chunks, done_rows) {
            Ok((line, nll, tok)) => {
                if let Err(e) = sink(Emit::Line(&line)) {
                    // The client is gone; there is no one to stream to.
                    return Json::obj(vec![
                        ("done", Json::Bool(true)),
                        ("error", Json::str(format!("stream write failed: {e:#}"))),
                    ]);
                }
                chunks += 1;
                done_rows += chunk.len();
                total_nll += nll;
                total_tok += tok;
            }
            Err(e) => {
                return Json::obj(vec![
                    ("done", Json::Bool(true)),
                    ("error", Json::str(format!("{e:#}"))),
                    ("rows_scored", Json::num(done_rows as f64)),
                    ("chunks", Json::num(chunks as f64)),
                ]);
            }
        }
    }
    Json::obj(vec![
        ("done", Json::Bool(true)),
        ("rows_scored", Json::num(done_rows as f64)),
        ("chunks", Json::num(chunks as f64)),
        ("nll", Json::num(total_nll)),
        ("ce", Json::num(total_nll / total_tok.max(1.0))),
    ])
}

fn try_handle<'rt>(
    registry: &ModelRegistry<'rt>,
    batcher: Option<&Batcher<'rt>>,
    core: &mut ConnCore,
    req: &Json,
    sink: Option<&mut EmitSink<'_>>,
) -> Result<Json> {
    match req.get("op")?.as_str()? {
        "ping" => {
            // Health probe: cheap, allocation-light, and deliberately
            // free of LRU/TTL side effects — a fleet router polling every
            // worker must never keep an idle variant warm or trip an
            // eviction sweep.
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("models", Json::num(registry.len() as f64)),
                ("resident_bytes_total", Json::num(registry.resident_bytes_total() as f64)),
            ]))
        }
        "info" => {
            // Peek, not get: metadata polling must not refresh LRU/TTL
            // state or count as a hit (matching `models`/`stats`).
            let h = resolve(registry, core, req, false)?;
            let (cached, cache_hits, cache_misses, cache_rows) = cache_counters(registry);
            Ok(Json::obj(vec![
                ("model", Json::str(&h.model_key)),
                ("tier", Json::str(&h.tier.name)),
                ("params", Json::num(h.tier.param_count as f64)),
                ("quant", Json::str(h.spec.key())),
                ("bits_per_param", Json::num(bits_per_param(&h.spec))),
                ("requests", Json::num(core.requests as f64)),
                // Residency accounting: packed host bytes vs what a
                // dequantized f32 copy of the same tensors would cost,
                // plus the paper's analytic total (bitcost).
                ("resident_bytes", Json::num(h.resident_bytes() as f64)),
                ("quantized_f32_bytes", Json::num(h.quantized_f32_bytes() as f64)),
                ("total_bits", Json::num(h.ideal_total_bits())),
                ("measured_total_bits", Json::num(h.measured_total_bits())),
                ("entropy_coded", Json::Bool(h.entropy_coded())),
                ("models", Json::num(registry.len() as f64)),
                ("stages", Json::num(h.n_stages() as f64)),
                ("batched", Json::Bool(batcher.is_some())),
                ("cached", Json::Bool(cached)),
                ("cache_hits", Json::num(cache_hits as f64)),
                ("cache_misses", Json::num(cache_misses as f64)),
                ("cache_rows", Json::num(cache_rows as f64)),
            ]))
        }
        "models" => {
            // `list` takes no LRU touch: enumerating the registry must
            // not make every variant look recently used to eviction.
            let entries: Vec<Json> = registry
                .list()
                .into_iter()
                .map(|(k, h)| {
                    Json::obj(vec![
                        ("key", Json::str(k)),
                        ("tier", Json::str(&h.tier.name)),
                        ("quant", Json::str(h.spec.key())),
                        ("resident_bytes", Json::num(h.resident_bytes() as f64)),
                    ])
                })
                .collect();
            Ok(Json::obj(vec![("models", Json::Arr(entries))]))
        }
        "stats" => {
            let variants: Vec<Json> = registry
                .stats()
                .into_iter()
                .map(|v| {
                    // Per-stage packed-byte breakdown: governance sees
                    // where a sharded variant's residency lives.
                    let stages: Vec<Json> = v
                        .stage_bytes
                        .iter()
                        .map(|(name, bytes)| {
                            Json::obj(vec![
                                ("name", Json::str(name)),
                                ("resident_bytes", Json::num(*bytes as f64)),
                            ])
                        })
                        .collect();
                    Json::obj(vec![
                        ("key", Json::str(v.key)),
                        ("resident_bytes", Json::num(v.resident_bytes as f64)),
                        ("stages", Json::Arr(stages)),
                        ("hits", Json::num(v.hits as f64)),
                        ("idle_ms", Json::num(v.idle.as_secs_f64() * 1e3)),
                        ("pinned", Json::Bool(v.pinned)),
                        // Entropy-coded variants report how far the coder
                        // compressed below the fixed-k floor — and how
                        // close it got to the Shannon bound.
                        (
                            "entropy",
                            match v.entropy {
                                Some((coded, nominal, bound, total)) => Json::obj(vec![
                                    ("coded_payload_bits", Json::num(coded as f64)),
                                    ("nominal_payload_bits", Json::num(nominal as f64)),
                                    ("entropy_bound_bits", Json::num(bound)),
                                    ("measured_total_bits", Json::num(total)),
                                ]),
                                None => Json::Null,
                            },
                        ),
                    ])
                })
                .collect();
            let (_, cache_hits, cache_misses, cache_rows) = cache_counters(registry);
            Ok(Json::obj(vec![
                ("models", Json::Arr(variants)),
                // Sliding-window request latency (score/choose ops) — the
                // same histogram the fleet governor consumes, inspectable
                // whether or not a governor is driving this worker.
                ("latency", registry.latency_snapshot().to_json()),
                ("resident_bytes_total", Json::num(registry.resident_bytes_total() as f64)),
                (
                    "budget_bytes",
                    match registry.memory_budget() {
                        Some(b) => Json::num(b as f64),
                        None => Json::Null,
                    },
                ),
                (
                    "ttl_secs",
                    match registry.ttl() {
                        Some(t) => Json::num(t.as_secs_f64()),
                        None => Json::Null,
                    },
                ),
                ("evictions", Json::num(registry.evictions() as f64)),
                ("cache_hits", Json::num(cache_hits as f64)),
                ("cache_misses", Json::num(cache_misses as f64)),
                ("cache_rows", Json::num(cache_rows as f64)),
                // Active policy identity (entry count + content hash +
                // artifact source): fleet-wide stats aggregation compares
                // these across workers to detect policy skew.
                (
                    "policy",
                    match registry.policy() {
                        Some(p) => Json::obj(vec![
                            ("entries", Json::num(p.entries.len() as f64)),
                            ("suite", Json::str(&p.suite)),
                            ("hash", Json::str(p.fingerprint())),
                            (
                                "source",
                                match registry.policy_source() {
                                    Some(s) => Json::str(s),
                                    None => Json::Null,
                                },
                            ),
                        ]),
                        None => Json::Null,
                    },
                ),
            ]))
        }
        "governor" => {
            // Workers do not run a governor — the fleet router does. A
            // worker answers with `"governor": false` plus its local
            // latency window so the op degrades gracefully when pointed
            // at a single worker instead of a router.
            Ok(Json::obj(vec![
                ("governor", Json::Bool(false)),
                ("latency", registry.latency_snapshot().to_json()),
            ]))
        }
        "unload" => {
            let key = req.get("model")?.as_str()?;
            let full = registry.unload(key)?;
            if core.current.as_deref() == Some(full.as_str()) {
                core.current = None;
            }
            Ok(Json::obj(vec![
                ("unloaded", Json::str(full)),
                ("models", Json::num(registry.len() as f64)),
            ]))
        }
        "load" => {
            // Policy-driven variant: {"op":"load","auto":true} lets the
            // active tuned policy pick the config for the byte headroom.
            let auto = match req.opt("auto") {
                Some(v) => v.as_bool()?,
                None => false,
            };
            if auto {
                for k in ["bits", "dtype", "block", "pipeline", "stage_bits", "fused", "entropy"] {
                    if req.opt(k).is_some() {
                        bail!(r#""auto":true picks the config from the policy; drop {k:?}"#);
                    }
                }
                let (family, tier) = model_identity(registry, core, req)?;
                let class = match req.opt("class") {
                    Some(v) => Some(v.as_str()?.to_string()),
                    None => None,
                };
                let (h, entry) = registry.load_auto_class(&family, &tier, class.as_deref())?;
                core.current = Some(h.key());
                return Ok(Json::obj(vec![
                    ("model", Json::str(h.key())),
                    ("auto", Json::Bool(true)),
                    ("policy_metric", Json::num(entry.metric)),
                    (
                        "stage_bits",
                        match &entry.stage_bits {
                            Some(v) => {
                                Json::Arr(v.iter().map(|&b| Json::num(b as f64)).collect())
                            }
                            None => Json::Null,
                        },
                    ),
                    ("models", Json::num(registry.len() as f64)),
                    ("resident_bytes", Json::num(h.resident_bytes() as f64)),
                    ("stages", Json::num(h.n_stages() as f64)),
                ]));
            }
            let family = req.get("family")?.as_str()?;
            let tier = req.get("tier")?.as_str()?;
            let bits = match req.opt("bits") {
                Some(v) => v.as_usize()?,
                None => 4,
            };
            let dtype = match req.opt("dtype") {
                Some(v) => DataType::parse(v.as_str()?)?,
                None => DataType::Fp,
            };
            let block = match req.opt("block") {
                Some(v) => match v.as_usize()? {
                    0 => None,
                    b => Some(b),
                },
                None => Some(64),
            };
            let spec = registry::spec_from_parts(bits, dtype, block)?;
            // Plan shape: pipeline sharding + optional per-stage bit
            // widths (mixed precision), e.g. {"pipeline":true,
            // "stage_bits":[16,4]}, the native fused dequant×matmul
            // execution backend ({"fused":true}), and/or entropy-coded
            // residency ({"entropy":true}).
            let plan = PlanRequest {
                pipeline: match req.opt("pipeline") {
                    Some(v) => v.as_bool()?,
                    None => false,
                },
                stage_bits: match req.opt("stage_bits") {
                    Some(v) => Some(v.usizes()?),
                    None => None,
                },
                fused: match req.opt("fused") {
                    Some(v) => v.as_bool()?,
                    None => false,
                },
                entropy: match req.opt("entropy") {
                    Some(v) => v.as_bool()?,
                    None => false,
                },
            };
            let h = registry.load_plan(family, tier, spec, &plan)?;
            core.current = Some(h.key());
            Ok(Json::obj(vec![
                ("model", Json::str(h.key())),
                ("models", Json::num(registry.len() as f64)),
                ("resident_bytes", Json::num(h.resident_bytes() as f64)),
                ("stages", Json::num(h.n_stages() as f64)),
            ]))
        }
        "score" => {
            let h = resolve(registry, core, req, true)?;
            let multi = req.opt("rows").is_some();
            if multi && req.opt("tokens").is_some() {
                bail!(r#"give "tokens" or "rows", not both"#);
            }
            // One row ("tokens") or many ("rows": an array of token rows).
            let raw: Vec<&Json> = if multi {
                req.get("rows")?.as_arr()?.iter().collect()
            } else {
                vec![req.get("tokens")?]
            };
            if raw.is_empty() {
                bail!("empty rows list");
            }
            let stream = match req.opt("stream") {
                Some(v) => v.as_bool()?,
                None => false,
            };
            // Streamed responses chunk at the forward-batch granularity
            // by default; "chunk" overrides (rows per chunk, >= 1).
            let chunk_rows = match req.opt("chunk") {
                Some(v) => v.as_usize()?.max(1),
                None => h.tier.batch_eval.max(1),
            };
            let cache = registry.score_cache();
            if stream {
                let Some(sink) = sink else {
                    bail!("streaming requires a line transport (stdin or TCP serving)")
                };
                return Ok(stream_score(
                    cache.as_deref(),
                    batcher,
                    &h,
                    &raw,
                    chunk_rows,
                    sink,
                ));
            }
            // Buffered path: the whole request is one shaped group
            // (validating every row before any scoring), one response.
            let (mut rows_json, total_nll, total_tok) =
                score_rows_shaped(cache.as_deref(), batcher, &h, &raw)?;
            if !multi {
                return Ok(rows_json.remove(0));
            }
            Ok(Json::obj(vec![
                ("rows_scored", Json::num(rows_json.len() as f64)),
                ("rows", Json::Arr(rows_json)),
                ("nll", Json::num(total_nll)),
                ("ce", Json::num(total_nll / total_tok.max(1.0))),
            ]))
        }
        "choose" => {
            let h = resolve(registry, core, req, true)?;
            let context = tokens_of(req.get("context")?, h.tier.vocab)?;
            let choices: Vec<Vec<i32>> = req
                .get("choices")?
                .as_arr()?
                .iter()
                .map(|c| tokens_of(c, h.tier.vocab))
                .collect::<Result<_>>()?;
            if choices.is_empty() {
                bail!("no choices given");
            }
            let ex = crate::data::tasks::Example { context, choices, answer: 0 };
            let rows_raw = crate::data::tasks::scoring_rows(&ex);
            let seq = h.tier.seq;
            let mut rows = Vec::new();
            let mut lens = Vec::new();
            for (toks, mask, clen) in rows_raw {
                rows.push(crate::eval::pad_row(&toks, &mask, seq));
                lens.push(clen.max(1));
            }
            let cache = registry.score_cache();
            let scored = score_via(cache.as_deref(), batcher, &h, rows)?;
            let norm: Vec<f64> = scored
                .iter()
                .zip(&lens)
                .map(|((nll, _), &l)| -nll / l as f64)
                .collect();
            // NaN-last argmax: a NaN NLL from the executable must become
            // an error response, not a worker-thread panic.
            let (best, best_score) = norm
                .iter()
                .enumerate()
                .max_by(|a, b| crate::util::order::nan_last_cmp(*a.1, *b.1))
                .map(|(i, &v)| (i, v))
                .ok_or_else(|| anyhow!("no choices to rank"))?;
            if best_score.is_nan() {
                bail!("model produced non-finite scores for every choice");
            }
            Ok(Json::obj(vec![
                ("best", Json::num(best as f64)),
                ("scores", Json::arr_f64(&norm)),
            ]))
        }
        "tune" => {
            // Run a precision search against a resident model's weights
            // (pulled through the registry's checkpoint loader) on a
            // calibration slice, and install the resulting Pareto policy.
            let (family, tier) = model_identity(registry, core, req)?;
            let mut cfg = tune::TuneConfig::default();
            if let Some(v) = req.opt("bits") {
                cfg.bits = v.usizes()?;
            }
            if let Some(v) = req.opt("dtypes") {
                cfg.dtypes = v
                    .as_arr()?
                    .iter()
                    .map(|d| DataType::parse(d.as_str()?))
                    .collect::<Result<_>>()?;
            }
            if let Some(v) = req.opt("blocks") {
                cfg.blocks = v
                    .as_arr()?
                    .iter()
                    .map(|b| {
                        Ok(match b.as_usize()? {
                            0 => None,
                            n => Some(n),
                        })
                    })
                    .collect::<Result<_>>()?;
            }
            if let Some(v) = req.opt("stage_mixes") {
                cfg.stage_mixes = v.as_bool()?;
            }
            if let Some(v) = req.opt("entropy") {
                cfg.entropy = v.as_bool()?;
            }
            if let Some(v) = req.opt("ppl_sequences") {
                cfg.eval.ppl_sequences = v.as_usize()?.max(1);
            }
            if let Some(v) = req.opt("zs_examples") {
                cfg.eval.zs_examples = v.as_usize()?.max(1);
            }
            if let Some(v) = req.opt("zero_shot") {
                if v.as_bool()? {
                    cfg.suite = EvalSuite::PplZeroShot;
                }
            }
            if let Some(v) = req.opt("threads") {
                cfg.threads = v.as_usize()?.max(1);
            } else if registry.memory_budget().is_some() {
                // The search's transient working set (one full candidate
                // build per worker + the pinned checkpoint) lives outside
                // the registry's packed-byte governance. A budgeted
                // server declared itself memory-constrained, so keep the
                // builds serial unless the operator explicitly asks.
                cfg.threads = 1;
            }
            let install = match req.opt("install") {
                Some(v) => v.as_bool()?,
                None => true,
            };
            // The one manifest-geometry corpus construction — tuning and
            // sweeping score the same held-out distribution.
            let corpus = Corpus::for_geometry(registry.manifest.vocab, registry.manifest.seq);
            let targets = vec![tune::TuneTarget::new(family, tier)];
            let report = tune::search(
                registry.runtime(),
                &registry.manifest,
                &corpus,
                &|f: &str, t: &str| registry.checkpoint(f, t),
                &targets,
                &cfg,
                None,
            )?;
            let policy_json = report.policy.to_json();
            if install {
                registry.set_policy(Some(report.policy));
            }
            Ok(Json::obj(vec![
                ("tuned", Json::num(report.points.len() as f64)),
                ("evaluated", Json::num(report.fresh as f64)),
                ("skipped", Json::num(report.skipped as f64)),
                ("installed", Json::Bool(install)),
                ("policy", policy_json),
            ]))
        }
        "policy" => {
            // Inspect / swap / clear the active tuned policy.
            if let Some(v) = req.opt("set") {
                registry.set_policy(Some(TunedPolicy::from_json(v)?));
            } else if let Some(v) = req.opt("clear") {
                if v.as_bool()? {
                    registry.set_policy(None);
                }
            }
            Ok(Json::obj(vec![(
                "policy",
                match registry.policy() {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            )]))
        }
        op => bail!(
            "unknown op {op:?} (ping|info|models|stats|governor|load|unload|score|choose|tune|policy)"
        ),
    }
}

/// Parse a token array, validating every value against the addressed
/// tier's vocabulary. An unchecked `f64 as i32` cast would silently
/// saturate (`3e9` → `i32::MAX`) and score garbage; out-of-vocab tokens
/// are an error response instead.
fn tokens_of(v: &Json, vocab: usize) -> Result<Vec<i32>> {
    v.as_arr()?
        .iter()
        .map(|x| {
            let n = x.as_f64()?;
            // NaN/±inf fail the fract test (`inf.fract()` is NaN).
            if n < 0.0 || n.fract() != 0.0 {
                bail!("token {n} is not a non-negative integer");
            }
            if n >= vocab as f64 {
                bail!("token {n} out of range for vocab {vocab}");
            }
            Ok(n as i32)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Transports
// ---------------------------------------------------------------------------

/// Upper bound on one request line. A client streaming a single giant
/// line gets an error response and the line is discarded **without
/// buffering it**, so it cannot OOM a connection worker.
pub const MAX_REQUEST_LINE: usize = 1 << 20;

enum LineRead {
    Eof,
    Line,
    Oversized,
}

/// Read one `\n`-terminated line into `buf`, never holding more than
/// `max` bytes: once a line crosses the cap, its remaining bytes are
/// consumed chunk by chunk without buffering and `Oversized` is returned
/// when the terminating newline (or EOF) arrives.
fn read_line_capped<R: BufRead>(
    r: &mut R,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    let mut overflowed = false;
    loop {
        // (bytes to consume, Some(hit_eof) once the line is complete)
        let (consumed, done) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                (0usize, Some(true))
            } else {
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        if !overflowed && buf.len() + pos <= max {
                            // lint: allow(panic-path) — pos comes from position() over this same chunk
                            buf.extend_from_slice(&chunk[..pos]);
                        } else {
                            overflowed = true;
                        }
                        (pos + 1, Some(false))
                    }
                    None => {
                        if !overflowed && buf.len() + chunk.len() <= max {
                            buf.extend_from_slice(chunk);
                        } else {
                            overflowed = true;
                        }
                        (chunk.len(), None)
                    }
                }
            }
        };
        r.consume(consumed);
        if let Some(eof) = done {
            if overflowed {
                return Ok(LineRead::Oversized);
            }
            if eof && buf.is_empty() {
                return Ok(LineRead::Eof);
            }
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(LineRead::Line);
        }
    }
}

/// `{"op":"hello","frames":"bin1"}` → the negotiated per-connection frame
/// mode and the reply line. Unknown (or absent) formats downgrade to
/// JSON, so an old client talking to a new server loses nothing.
fn hello_response(req: &Json) -> (bool, Json) {
    let bin = req
        .opt("frames")
        .and_then(|v| v.as_str().ok())
        .is_some_and(|f| f == "bin1");
    let reply = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("frames", Json::str(if bin { "bin1" } else { "json" })),
    ]);
    (bin, reply)
}

/// Pump one line-based transport through a request handler until EOF.
/// Request lines are capped at [`MAX_REQUEST_LINE`] bytes. The handler
/// gets a **sink** that writes streamed partial-response units straight
/// to the transport (flushed per unit, so chunks reach the client before
/// scoring finishes); the handler's return value is the terminal line.
///
/// Frame negotiation lives here, not in the handlers: an
/// `{"op":"hello"}` line is answered directly (the handler never sees
/// it), and the negotiated mode shapes how sink units hit the wire —
/// `bin1` encodes chunk [`Emit::Line`]s as binary frames and forwards
/// [`Emit::Raw`] frames verbatim; JSON mode (the default) writes lines
/// as-is and decodes forwarded frames back to text. Requests and
/// terminal lines are JSON in both modes.
///
/// Public: this is the connection-handoff seam the fleet router
/// ([`crate::fleet`]) reuses to drive its own per-client proxy loop over
/// the identical line protocol, and the seam the protocol fuzz harness
/// (`tests/fuzz_protocol.rs`) drives with hostile byte streams — any
/// input, however malformed, must produce error lines, never a panic.
pub fn pump<R: BufRead, W: Write>(
    mut handle: impl FnMut(&Json, &mut EmitSink<'_>) -> Json,
    mut reader: R,
    mut writer: W,
) -> Result<u64> {
    let mut served = 0;
    let mut bin = false;
    let mut buf: Vec<u8> = Vec::new();
    let mut frame: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        let resp = match read_line_capped(&mut reader, &mut buf, MAX_REQUEST_LINE)? {
            LineRead::Eof => break,
            LineRead::Oversized => Json::obj(vec![(
                "error",
                Json::str(format!("request line exceeds {MAX_REQUEST_LINE} bytes")),
            )]),
            LineRead::Line => match std::str::from_utf8(&buf) {
                Ok(line) if line.trim().is_empty() => continue,
                Ok(line) => match Json::parse(line) {
                    Ok(req) if req.opt("op").and_then(|v| v.as_str().ok()) == Some("hello") => {
                        let (mode, reply) = hello_response(&req);
                        bin = mode;
                        reply
                    }
                    Ok(req) => {
                        let w = &mut writer;
                        let fr = &mut frame;
                        let mut sink = |e: Emit<'_>| -> Result<()> {
                            match e {
                                Emit::Line(j) => {
                                    if bin && frames::is_chunk_line(j) {
                                        frames::encode_chunk_into(j, fr)?;
                                        w.write_all(fr)?;
                                    } else {
                                        writeln!(w, "{}", j.dump())?;
                                    }
                                }
                                Emit::Raw(bytes) => {
                                    if bin {
                                        w.write_all(bytes)?;
                                    } else {
                                        writeln!(w, "{}", frames::decode_chunk(bytes)?.dump())?;
                                    }
                                }
                            }
                            w.flush()?;
                            Ok(())
                        };
                        handle(&req, &mut sink)
                    }
                    Err(e) => {
                        Json::obj(vec![("error", Json::str(format!("bad request: {e:#}")))])
                    }
                },
                Err(e) => {
                    Json::obj(vec![("error", Json::str(format!("bad request: {e:#}")))])
                }
            },
        };
        writeln!(writer, "{}", resp.dump())?;
        writer.flush()?;
        served += 1;
    }
    Ok(served)
}

/// Drive a single-model session over any line-based transport until EOF
/// (streaming-capable: chunked responses go straight to `writer`).
pub fn serve_lines<R: BufRead, W: Write>(
    session: &mut Session<'_>,
    reader: R,
    writer: W,
) -> Result<u64> {
    pump(|req, sink| session.handle_streaming(req, sink), reader, writer)
}

/// Serve a registry over stdin/stdout (the CLI's non-TCP mode; direct
/// scoring, no batcher — there is only one client).
pub fn serve_stdin(registry: &ModelRegistry<'_>) -> Result<u64> {
    let mut conn = Connection::new(registry, None);
    let stdin = std::io::stdin();
    pump(|req, sink| conn.handle_streaming(req, sink), stdin.lock(), std::io::stdout())
}

/// Concurrency/batching knobs for the TCP server.
pub struct ServeOpts {
    /// Connection worker threads (each serves one client at a time).
    pub workers: usize,
    /// Micro-batch flush window; how long the dispatcher waits for
    /// co-batchable rows from other clients once it holds work.
    pub flush: Duration,
    /// Cross-client micro-batching on/off (off = each worker executes
    /// directly, the pre-registry behavior).
    pub batching: bool,
    /// Stop accepting after this many connections (tests and benches;
    /// `None` = serve forever).
    pub max_conns: Option<u64>,
    /// Socket read/write timeout on accepted TCP connections (`None` =
    /// off, the default — and stdin serving never times out). Without
    /// one, a client that stalls mid-line (or goes silent while holding
    /// the socket open) pins a `serve_listener` worker thread forever;
    /// with one, the blocked read errors out, the connection is dropped
    /// and logged, and the worker moves on. This is an **idle** timeout:
    /// any completed request/response resets it.
    pub io_timeout: Option<Duration>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            workers: pool::default_threads().min(8),
            flush: Duration::from_millis(2),
            batching: true,
            max_conns: None,
            io_timeout: None,
        }
    }
}

/// Bind a TCP listener and serve clients concurrently.
pub fn serve_tcp(registry: &ModelRegistry<'_>, addr: &str, opts: &ServeOpts) -> Result<()> {
    let listener =
        std::net::TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    log::info!(
        "serving {} model(s) on {addr} ({} workers, batching {})",
        registry.len(),
        opts.workers.max(1),
        if opts.batching { "on" } else { "off" }
    );
    serve_listener(registry, listener, opts)
}

/// Serve an already-bound listener: a fixed worker pool consumes accepted
/// sockets from a bounded queue while the accept loop stays free, and all
/// workers score through one shared micro-batcher.
///
/// Fault isolation: a failed accept or a per-connection I/O error is
/// logged and the server keeps accepting — a single broken client can no
/// longer tear down the listener loop.
pub fn serve_listener(
    registry: &ModelRegistry<'_>,
    listener: std::net::TcpListener,
    opts: &ServeOpts,
) -> Result<()> {
    // Persistent accept failures (e.g. EMFILE under fd exhaustion) must
    // not become a 100%-CPU busy loop: back off per error and give up
    // after this many consecutive failures.
    const MAX_CONSECUTIVE_ACCEPT_ERRORS: u32 = 32;
    let workers = opts.workers.max(1);
    let batcher = Batcher::new(opts.flush).with_cache(registry.score_cache());
    let conns: pool::BoundedQueue<std::net::TcpStream> = pool::BoundedQueue::new(workers * 2);
    let accept_err = std::thread::scope(|s| {
        let dispatcher = opts.batching.then(|| s.spawn(|| batcher.run()));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(s.spawn(|| {
                while let Some(stream) = conns.pop() {
                    let peer =
                        stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
                    // A failed timeout configuration is a broken socket;
                    // drop the connection rather than serve it unbounded.
                    if let Some(t) = opts.io_timeout {
                        let set = stream
                            .set_read_timeout(Some(t))
                            .and_then(|_| stream.set_write_timeout(Some(t)));
                        if let Err(e) = set {
                            log::warn!("client {peer}: cannot set io timeout: {e:#}");
                            continue;
                        }
                    }
                    let served = serve_stream(registry, opts.batching.then_some(&batcher), stream);
                    match served {
                        Ok(n) => log::info!("client {peer}: {n} requests"),
                        Err(e) => log::warn!("client {peer}: connection error: {e:#}"),
                    }
                }
            }));
        }
        let mut accepted = 0u64;
        let mut consecutive_errors = 0u32;
        let mut accept_err: Option<anyhow::Error> = None;
        for stream in listener.incoming() {
            match stream {
                Ok(stm) => {
                    consecutive_errors = 0;
                    if !conns.push(stm) {
                        break;
                    }
                    accepted += 1;
                }
                Err(e) => {
                    consecutive_errors += 1;
                    log::warn!("accept error ({consecutive_errors} consecutive): {e:#}");
                    if consecutive_errors >= MAX_CONSECUTIVE_ACCEPT_ERRORS {
                        accept_err = Some(anyhow::Error::new(e).context(format!(
                            "{consecutive_errors} consecutive accept failures; shutting down"
                        )));
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            }
            if opts.max_conns.is_some_and(|m| accepted >= m) {
                break;
            }
        }
        conns.close();
        for h in handles {
            let _ = h.join();
        }
        batcher.shutdown();
        if let Some(d) = dispatcher {
            let _ = d.join();
        }
        accept_err
    });
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Serve one accepted socket until the client hangs up.
fn serve_stream<'rt>(
    registry: &ModelRegistry<'rt>,
    batcher: Option<&Batcher<'rt>>,
    stream: std::net::TcpStream,
) -> Result<u64> {
    let mut conn = Connection::new(registry, batcher);
    let reader = std::io::BufReader::new(stream.try_clone()?);
    pump(|req, sink| conn.handle_streaming(req, sink), reader, stream)
}
