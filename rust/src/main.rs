//! `kbitscale` — leader binary of the k-bit inference scaling-law stack.
//!
//! Thin wrapper over [`kbitscale::cli`]; see `kbitscale <cmd> --help` and
//! README.md for usage.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = kbitscale::cli::main_with_args(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
