//! Sweep grids: declarative cell enumeration for every experiment.
//!
//! A [`Cell`] is one point of the paper's grid — (family, tier, quant
//! spec, eval suite). Builders below produce the exact grids each figure
//! needs; the runner dedupes against the results store, so overlapping
//! grids (Fig 1 ⊂ Fig 7, etc.) cost nothing extra.

use crate::eval::EvalSuite;
use crate::quant::codebook::DataType;
use crate::quant::QuantSpec;

/// One sweep cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub family: &'static str,
    pub tier: String,
    pub spec: QuantSpec,
    pub suite: EvalSuite,
}

impl Cell {
    pub fn new(family: &'static str, tier: &str, spec: QuantSpec, suite: EvalSuite) -> Self {
        Cell { family, tier: tier.to_string(), spec, suite }
    }
}

/// The paper's default method choice for headline bit-level plots:
/// float data type with block size 64 for k < 16 (§7 recommendations),
/// plain 16-bit baseline otherwise.
pub fn headline_spec(bits: usize) -> QuantSpec {
    if bits >= 16 {
        QuantSpec::baseline16()
    } else {
        QuantSpec::new(DataType::Fp, bits, Some(64))
    }
}

/// Grid builders, one per experiment family (DESIGN.md §4).
pub struct GridBuilder {
    pub tiers: Vec<String>,
    pub families: Vec<&'static str>,
}

impl GridBuilder {
    pub fn new(families: Vec<&'static str>, tiers: Vec<String>) -> Self {
        GridBuilder { tiers, families }
    }

    fn cells(
        &self,
        specs: impl IntoIterator<Item = QuantSpec> + Clone,
        suite: EvalSuite,
    ) -> Vec<Cell> {
        let mut out = Vec::new();
        for family in &self.families {
            for tier in &self.tiers {
                for spec in specs.clone() {
                    out.push(Cell::new(family, tier, spec, suite));
                }
            }
        }
        out
    }

    /// E1/E2/E6 (Figs 1, 2, 7): bit-level scaling, k ∈ given set,
    /// headline method per k.
    pub fn bit_scaling(&self, ks: &[usize]) -> Vec<Cell> {
        self.cells(
            ks.iter().map(|&k| headline_spec(k)).collect::<Vec<_>>(),
            EvalSuite::PplZeroShot,
        )
    }

    /// E3/E8 (Figs 3, 8): block-size sweep at fixed k.
    pub fn blocksize_sweep(&self, k: usize, blocks: &[Option<usize>]) -> Vec<Cell> {
        self.cells(
            blocks
                .iter()
                .map(|&b| QuantSpec::new(DataType::Fp, k, b))
                .collect::<Vec<_>>(),
            EvalSuite::PplZeroShot,
        )
    }

    /// E3/E9/E10 (Figs 3, 9, 10): data-type sweep at fixed k, block 64.
    pub fn datatype_sweep(&self, k: usize) -> Vec<Cell> {
        self.cells(
            DataType::ALL
                .iter()
                .map(|&dt| QuantSpec::new(dt, k, Some(64)))
                .collect::<Vec<_>>(),
            EvalSuite::PplZeroShot,
        )
    }

    /// E4 (Fig 4): proxy quantization on/off at k ∈ {3, 4}.
    pub fn proxy_sweep(&self, pct: f64) -> Vec<Cell> {
        let mut specs = Vec::new();
        for k in [3usize, 4] {
            specs.push(QuantSpec::new(DataType::Fp, k, Some(64)));
            specs.push(QuantSpec::new(DataType::Fp, k, Some(64)).with_proxy(pct));
        }
        specs.push(QuantSpec::baseline16());
        self.cells(specs, EvalSuite::PplZeroShot)
    }

    /// E10 (Fig 12): float exponent-bit sweep per precision, block 64.
    pub fn exponent_sweep(&self, ks: &[usize]) -> Vec<Cell> {
        let mut specs = Vec::new();
        for &k in ks {
            for e in 1..k.saturating_sub(1) {
                specs.push(QuantSpec::new(DataType::Fp, k, Some(64)).with_exponent_bits(e));
            }
        }
        self.cells(specs, EvalSuite::Ppl)
    }

    /// E13 (App. B): centering on/off across data types at fixed k.
    pub fn centering_sweep(&self, k: usize) -> Vec<Cell> {
        let mut specs = Vec::new();
        for dt in DataType::ALL {
            specs.push(QuantSpec::new(dt, k, Some(64)));
            specs.push(QuantSpec::new(dt, k, Some(64)).with_centering());
        }
        self.cells(specs, EvalSuite::Ppl)
    }

    /// E11 (Figs 13–15): perplexity-based scaling (cheap suite) across
    /// precisions, data types, and block sizes.
    pub fn perplexity_scaling(&self) -> Vec<Cell> {
        let mut specs = vec![QuantSpec::baseline16()];
        for k in [3usize, 4, 5, 6, 8] {
            specs.push(headline_spec(k));
        }
        for dt in DataType::ALL {
            specs.push(QuantSpec::new(dt, 4, Some(64)));
        }
        for b in [Some(32), Some(256), Some(1024), None] {
            specs.push(QuantSpec::new(DataType::Fp, 4, b));
        }
        specs.dedup_by_key(|s| s.key());
        self.cells(specs, EvalSuite::Ppl)
    }
}

/// Dedupe cells by their full configuration key, preferring the richer
/// eval suite when both appear.
pub fn dedupe(cells: Vec<Cell>) -> Vec<Cell> {
    use std::collections::BTreeMap;
    let mut by_key: BTreeMap<String, Cell> = BTreeMap::new();
    for c in cells {
        let key = format!("{}|{}|{}", c.family, c.tier, c.spec.key());
        match by_key.get(&key) {
            Some(prev) if prev.suite == EvalSuite::PplZeroShot => {}
            _ => {
                by_key.insert(key, c);
            }
        }
    }
    by_key.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gb() -> GridBuilder {
        GridBuilder::new(vec!["optlike", "gpt2like"], vec!["t0".into(), "t1".into()])
    }

    #[test]
    fn bit_scaling_grid_size() {
        let cells = gb().bit_scaling(&[3, 4, 8, 16]);
        assert_eq!(cells.len(), 2 * 2 * 4);
        // 16-bit cells use the baseline spec.
        assert!(cells.iter().any(|c| c.spec.is_baseline()));
    }

    #[test]
    fn headline_spec_matches_recommendations() {
        let s = headline_spec(4);
        assert_eq!(s.dtype, DataType::Fp);
        assert_eq!(s.block, Some(64));
        assert!(headline_spec(16).is_baseline());
    }

    #[test]
    fn exponent_sweep_covers_valid_layouts() {
        let cells = gb().exponent_sweep(&[3, 4]);
        // k=3: e=1; k=4: e∈{1,2} → 3 specs per (family, tier).
        assert_eq!(cells.len(), 2 * 2 * 3);
        for c in &cells {
            assert!(c.spec.exponent_bits.is_some());
        }
    }

    #[test]
    fn dedupe_prefers_zero_shot_suite() {
        let a = Cell::new("optlike", "t0", headline_spec(4), EvalSuite::Ppl);
        let b = Cell::new("optlike", "t0", headline_spec(4), EvalSuite::PplZeroShot);
        let out = dedupe(vec![a, b]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].suite, EvalSuite::PplZeroShot);
    }

    #[test]
    fn proxy_sweep_contains_on_off_pairs() {
        let cells = gb().proxy_sweep(0.02);
        let with: usize = cells.iter().filter(|c| c.spec.proxy_outlier_pct.is_some()).count();
        let without = cells.len() - with;
        assert!(with > 0 && without > 0);
    }
}
