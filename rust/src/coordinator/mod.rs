//! The sweep coordinator — Layer 3's core loop.
//!
//! Orchestrates the paper's grid: for each [`grid::Cell`], load the
//! trained checkpoint, apply the quantization spec (the Rust hot path),
//! run the evaluation suite through the AOT forward executable, account
//! total model bits, and persist to the [`store::ResultsStore`].
//!
//! Concurrency model: cells fan out across a worker pool
//! (`util::pool::parallel_map`); each worker shares the process-wide PJRT
//! runtime (thread-safe) and compiled-executable cache. Checkpoints are
//! read-only and cached in memory per (family, tier). The store dedupes:
//! already-evaluated cells are skipped, making every figure bench
//! incremental.

pub mod grid;
pub mod store;

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::data::corpus::Corpus;
use crate::eval::{EvalConfig, EvalSuite, Evaluator};
use crate::models::checkpoint::CheckpointStore;
use crate::models::manifest::Manifest;
use crate::models::ModelId;
use crate::quant;
use crate::tensor::Tensor;
use crate::util::pool;

pub use grid::{dedupe, Cell, GridBuilder};
pub use store::{cell_key, CellResult, ResultsStore};

/// Workload bump this when corpus/eval semantics change incompatibly.
pub const DATA_VERSION: u32 = 1;

/// Shared context for a sweep run.
pub struct Coordinator<'a> {
    pub rt: &'a crate::runtime::Runtime,
    pub manifest: &'a Manifest,
    pub corpus: &'a Corpus,
    pub checkpoints: &'a CheckpointStore,
    pub results: &'a ResultsStore,
    pub eval_cfg: EvalConfig,
    pub threads: usize,
    /// In-memory checkpoint cache (family_tier -> params).
    param_cache: Mutex<HashMap<String, std::sync::Arc<Vec<(String, Tensor)>>>>,
}

impl<'a> Coordinator<'a> {
    pub fn new(
        rt: &'a crate::runtime::Runtime,
        manifest: &'a Manifest,
        corpus: &'a Corpus,
        checkpoints: &'a CheckpointStore,
        results: &'a ResultsStore,
    ) -> Self {
        Coordinator {
            rt,
            manifest,
            corpus,
            checkpoints,
            results,
            eval_cfg: EvalConfig::default(),
            threads: 2, // PJRT CPU is itself multithreaded; 2 keeps it fed
            param_cache: Mutex::new(HashMap::new()),
        }
    }

    fn suite_name(suite: EvalSuite) -> &'static str {
        match suite {
            EvalSuite::Ppl => "ppl",
            EvalSuite::PplZeroShot => "ppl_zs",
        }
    }

    fn key_for(&self, cell: &Cell) -> String {
        cell_key(
            cell.family,
            &cell.tier,
            &cell.spec.key(),
            Self::suite_name(cell.suite),
            self.eval_cfg.ppl_sequences,
            self.eval_cfg.zs_examples,
            self.corpus.cfg.seed,
            DATA_VERSION,
        )
    }

    fn load_params(&self, cell: &Cell) -> Result<std::sync::Arc<Vec<(String, Tensor)>>> {
        let id = ModelId::new(cell.family, cell.tier.clone());
        let ck = id.key();
        if let Some(hit) = self.param_cache.lock().unwrap().get(&ck) {
            return Ok(hit.clone());
        }
        let (params, _) = self.checkpoints.load(&id)?;
        let arc = std::sync::Arc::new(params);
        self.param_cache.lock().unwrap().insert(ck, arc.clone());
        Ok(arc)
    }

    /// Evaluate one cell (no store interaction).
    pub fn run_cell(&self, cell: &Cell) -> Result<CellResult> {
        let t0 = std::time::Instant::now();
        let tier = self.manifest.tier(&cell.tier)?;
        let params = self.load_params(cell)?;

        // The hot path: quantize→dequantize the checkpoint under the spec.
        // The Cow variant borrows pass-through tensors (embeddings,
        // LayerNorm), so workers never hold a second f32 copy of the
        // unquantized majority of small-tier checkpoints.
        let qparams =
            quant::quantize_checkpoint_cow(&params, &tier.quantized_params, &cell.spec);

        let ev = Evaluator::new(self.rt, self.manifest, tier)?;
        let r = ev.run(&qparams, self.corpus, cell.suite, &self.eval_cfg)?;

        let bpp = quant::bits_per_param(&cell.spec);
        let total_bits = quant::bitcost::total_model_bits(
            &tier.param_sizes(),
            &tier.quantized_params,
            &cell.spec,
        );

        Ok(CellResult {
            key: self.key_for(cell),
            family: cell.family.to_string(),
            tier: cell.tier.clone(),
            spec_key: cell.spec.key(),
            suite: Self::suite_name(cell.suite).to_string(),
            ce: r.ce,
            ppl: r.ppl,
            zs_acc: r.zs_acc,
            zs_mean: r.zs_mean,
            top1: r.top1,
            total_bits,
            bits_per_param: bpp,
            param_count: tier.param_count,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run a whole grid with caching + worker pool. Returns results in the
    /// input cell order.
    pub fn run_grid(&self, cells: &[Cell]) -> Result<Vec<CellResult>> {
        // Partition into cached / to-run.
        let mut cached: Vec<Option<CellResult>> = Vec::with_capacity(cells.len());
        let mut todo: Vec<usize> = Vec::new();
        for (i, cell) in cells.iter().enumerate() {
            match self.results.get(&self.key_for(cell)) {
                Some(hit) => cached.push(Some(hit)),
                None => {
                    cached.push(None);
                    todo.push(i);
                }
            }
        }
        if !todo.is_empty() {
            log::info!(
                "sweep: {} cells ({} cached, {} to run) on {} workers",
                cells.len(),
                cells.len() - todo.len(),
                todo.len(),
                self.threads
            );
            // Pre-compile each tier's forward executable serially: PJRT
            // compilation is not profitably concurrent and this keeps
            // worker wall-times flat.
            let mut tiers: Vec<&str> = todo.iter().map(|&i| cells[i].tier.as_str()).collect();
            tiers.sort_unstable();
            tiers.dedup();
            for t in tiers {
                let tier = self.manifest.tier(t)?;
                self.rt.load(&self.manifest.hlo_path(&tier.fwd_hlo))?;
            }
            let fresh = pool::parallel_map(todo.len(), self.threads, |j| {
                let cell = &cells[todo[j]];
                self.run_cell(cell)
                    .with_context(|| format!("cell {}/{} {}", cell.family, cell.tier, cell.spec))
            });
            for (j, res) in fresh.into_iter().enumerate() {
                let r = res?;
                self.results.put(r.clone())?;
                cached[todo[j]] = Some(r);
            }
        }
        Ok(cached.into_iter().map(|c| c.unwrap()).collect())
    }
}

#[cfg(test)]
mod tests {
    //! Grid/store logic is covered in `grid.rs`/`store.rs`; the full
    //! coordinator path (PJRT + artifacts + checkpoints) is exercised by
    //! `rust/tests/e2e_sweep.rs` and the figure benches.
    use super::*;
    use crate::prop_assert;
    use crate::quant::codebook::DataType;
    use crate::quant::QuantSpec;
    use crate::util::proptest::check;

    #[test]
    fn prop_grid_dedupe_idempotent_and_complete() {
        check("grid-dedupe", 30, |rng, _| {
            // Random grids with duplicates must dedupe to the set of
            // distinct (family, tier, spec) triples and be idempotent.
            let families = ["optlike", "gpt2like"];
            let tiers = ["t0", "t1", "t2"];
            let n = 1 + rng.below(40);
            let mut cells = Vec::new();
            for _ in 0..n {
                let spec = QuantSpec::new(
                    DataType::ALL[rng.below(4)],
                    3 + rng.below(6),
                    Some([32usize, 64, 128][rng.below(3)]),
                );
                let suite = if rng.below(2) == 0 { EvalSuite::Ppl } else { EvalSuite::PplZeroShot };
                cells.push(Cell::new(
                    families[rng.below(2)],
                    tiers[rng.below(3)],
                    spec,
                    suite,
                ));
            }
            let mut distinct: Vec<String> = cells
                .iter()
                .map(|c| format!("{}|{}|{}", c.family, c.tier, c.spec.key()))
                .collect();
            distinct.sort();
            distinct.dedup();
            let d1 = dedupe(cells);
            prop_assert!(d1.len() == distinct.len(), "dedupe size {} != {}", d1.len(), distinct.len());
            let d2 = dedupe(d1.clone());
            prop_assert!(d2.len() == d1.len(), "dedupe not idempotent");
            Ok(())
        });
    }
}
