//! Results store: an append-only JSONL database of evaluated sweep cells.
//!
//! Every cell is keyed by a stable hash of its full configuration (model,
//! quant spec, eval suite, workload sizes, data seed). Reruns and the
//! per-figure benches share the store, so a cell is evaluated **once**
//! across the whole reproduction — the same economics that let the paper
//! amortize 35,000 experiments.

use std::collections::HashMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::fnv1a;
use crate::util::json::Json;

/// Everything stored for one evaluated cell.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    pub key: String,
    pub family: String,
    pub tier: String,
    pub spec_key: String,
    pub suite: String,
    /// Cross entropy (nats/token), perplexity (clamped at 100).
    pub ce: f64,
    pub ppl: f64,
    /// Per-task zero-shot accuracy (may be empty for ppl-only cells).
    pub zs_acc: Vec<f64>,
    pub zs_mean: f64,
    pub top1: f64,
    /// Bits accounting for the x-axis.
    pub total_bits: f64,
    pub bits_per_param: f64,
    pub param_count: usize,
    pub wall_s: f64,
}

impl CellResult {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::str(&self.key)),
            ("family", Json::str(&self.family)),
            ("tier", Json::str(&self.tier)),
            ("spec", Json::str(&self.spec_key)),
            ("suite", Json::str(&self.suite)),
            ("ce", Json::num(self.ce)),
            ("ppl", Json::num(self.ppl)),
            ("zs_acc", Json::arr_f64(&self.zs_acc)),
            ("zs_mean", Json::num(self.zs_mean)),
            ("top1", Json::num(self.top1)),
            ("total_bits", Json::num(self.total_bits)),
            ("bits_per_param", Json::num(self.bits_per_param)),
            ("param_count", Json::num(self.param_count as f64)),
            ("wall_s", Json::num(self.wall_s)),
        ])
    }

    fn from_json(j: &Json) -> Result<CellResult> {
        Ok(CellResult {
            key: j.get("key")?.as_str()?.to_string(),
            family: j.get("family")?.as_str()?.to_string(),
            tier: j.get("tier")?.as_str()?.to_string(),
            spec_key: j.get("spec")?.as_str()?.to_string(),
            suite: j.get("suite")?.as_str()?.to_string(),
            ce: j.get("ce")?.as_f64()?,
            ppl: j.get("ppl")?.as_f64()?,
            zs_acc: j.get("zs_acc")?.f64s()?,
            zs_mean: match j.get("zs_mean")? {
                Json::Null => f64::NAN,
                v => v.as_f64()?,
            },
            top1: j.get("top1")?.as_f64()?,
            total_bits: j.get("total_bits")?.as_f64()?,
            bits_per_param: j.get("bits_per_param")?.as_f64()?,
            param_count: j.get("param_count")?.as_usize()?,
            wall_s: j.get("wall_s")?.as_f64()?,
        })
    }
}

/// Build the stable cell key. `data_version` bumps when corpus/eval
/// workloads change incompatibly.
pub fn cell_key(
    family: &str,
    tier: &str,
    spec_key: &str,
    suite: &str,
    ppl_sequences: usize,
    zs_examples: usize,
    corpus_seed: u64,
    data_version: u32,
) -> String {
    let raw = format!(
        "{family}|{tier}|{spec_key}|{suite}|p{ppl_sequences}|z{zs_examples}|s{corpus_seed}|v{data_version}"
    );
    format!("{:016x}", fnv1a(raw.as_bytes()))
}

/// JSONL-backed store with an in-memory index; thread safe.
pub struct ResultsStore {
    path: PathBuf,
    inner: Mutex<HashMap<String, CellResult>>,
}

impl ResultsStore {
    /// Open (or create) a store, loading all prior results.
    pub fn open(path: impl Into<PathBuf>) -> Result<ResultsStore> {
        let path = path.into();
        let mut map = HashMap::new();
        if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let j = Json::parse(line)
                    .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
                let r = CellResult::from_json(&j)?;
                map.insert(r.key.clone(), r);
            }
        }
        Ok(ResultsStore { path, inner: Mutex::new(map) })
    }

    pub fn get(&self, key: &str) -> Option<CellResult> {
        self.inner.lock().unwrap().get(key).cloned()
    }

    /// A ppl-only result can be upgraded by a zero-shot run; the richer
    /// record wins on key collision.
    pub fn put(&self, r: CellResult) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.insert(r.key.clone(), r.clone());
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", r.to_json().dump())?;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all results (analysis passes iterate this).
    pub fn all(&self) -> Vec<CellResult> {
        self.inner.lock().unwrap().values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kbt_store_{tag}_{}.jsonl", std::process::id()))
    }

    fn sample(key: &str) -> CellResult {
        CellResult {
            key: key.to_string(),
            family: "optlike".into(),
            tier: "t0".into(),
            spec_key: "int:4:b64".into(),
            suite: "ppl_zs".into(),
            ce: 1.5,
            ppl: 4.48,
            zs_acc: vec![0.5, 0.6, 0.4, 0.55],
            zs_mean: 0.5125,
            top1: 0.3,
            total_bits: 1.0e6,
            bits_per_param: 4.25,
            param_count: 43328,
            wall_s: 1.25,
        }
    }

    #[test]
    fn roundtrip_and_reload() {
        let path = tmp("rt");
        std::fs::remove_file(&path).ok();
        {
            let s = ResultsStore::open(&path).unwrap();
            s.put(sample("aaa")).unwrap();
            s.put(sample("bbb")).unwrap();
            assert_eq!(s.len(), 2);
        }
        let s2 = ResultsStore::open(&path).unwrap();
        assert_eq!(s2.len(), 2);
        assert_eq!(s2.get("aaa").unwrap(), sample("aaa"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn last_write_wins_on_rekey() {
        let path = tmp("lww");
        std::fs::remove_file(&path).ok();
        let s = ResultsStore::open(&path).unwrap();
        s.put(sample("k")).unwrap();
        let mut richer = sample("k");
        richer.zs_mean = 0.9;
        s.put(richer.clone()).unwrap();
        assert_eq!(s.get("k").unwrap().zs_mean, 0.9);
        // Reload also favours the later line.
        let s2 = ResultsStore::open(&path).unwrap();
        assert_eq!(s2.get("k").unwrap().zs_mean, 0.9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn nan_zs_mean_survives_roundtrip() {
        let path = tmp("nan");
        std::fs::remove_file(&path).ok();
        let s = ResultsStore::open(&path).unwrap();
        let mut r = sample("n");
        r.zs_acc = vec![];
        r.zs_mean = f64::NAN;
        s.put(r).unwrap();
        let s2 = ResultsStore::open(&path).unwrap();
        assert!(s2.get("n").unwrap().zs_mean.is_nan());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn keys_are_stable_and_distinct() {
        let a = cell_key("optlike", "t0", "int:4:b64", "ppl", 48, 48, 7, 1);
        let b = cell_key("optlike", "t0", "int:4:b64", "ppl", 48, 48, 7, 1);
        let c = cell_key("optlike", "t0", "fp:4:b64", "ppl", 48, 48, 7, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
