//! Entropy-coded residency: canonical Huffman coding of packed k-bit indices.
//!
//! A [`PackedTensor`] spends exactly `bits` per weight. But the quantized
//! index distribution is far from uniform for most dtypes (an `fp4` codebook
//! over blockwise-normalized weights concentrates mass near zero), so the
//! Shannon entropy of the index stream sits well below `k`. This module
//! re-encodes the index stream with per-segment canonical Huffman tables,
//! buying *measured* bits/param below the fixed-k floor while decoding to
//! bit-identical indices — and therefore bit-identical dequantized floats.
//!
//! # Coding format
//!
//! An [`EncodedTensor`] carries the same `absmax`/`means`/`codebook`/`bits`
//! side channels as its [`PackedTensor`] twin, plus:
//!
//! - **Segments.** The index stream is cut into coding segments of
//!   [`SEGMENT_LEN`] (4096) indices; the final segment may be ragged.
//!   Segmentation is independent of the quantization block size. Each
//!   segment records its element length, its starting bit offset into the
//!   shared bitstream, and its coding mode.
//! - **Coding modes.** `Raw` stores each index as a fixed `k`-bit field
//!   (identical layout to `PackedTensor`, minus the 32-bit word padding);
//!   `Table(t)` Huffman-codes the segment with table `t`. The encoder picks
//!   per segment: Huffman wins only if `huffman_bits (+ table_bits if the
//!   table is new) < raw_bits`, so the coded payload is never larger than
//!   the nominal `n * k` payload.
//! - **Tables.** A [`HuffTable`] is built over the full `1 << k` alphabet
//!   from the segment's index histogram, code lengths limited to
//!   [`MAX_CODE_LEN`] (15) with Kraft repair, canonical code assignment
//!   (symbols ordered by (length, symbol)). A table serializes as a list of
//!   4-bit lengths, charged at `16 + 4 * n_sym` bits; identical length
//!   lists are deduplicated across segments.
//! - **Bitstream.** LSB-first within little-endian `u32` words — the same
//!   convention as [`packing::bit_window`]. Huffman codes are emitted
//!   bit-reversed so that an LSB-first `N`-bit peek holds the first `N`
//!   transmitted bits in its low bits; the decoder resolves codes of length
//!   ≤ [`LUT_BITS`] (9) with a single `1 << LUT_BITS` table lookup and
//!   falls back to classic canonical bit-by-bit decode for longer codes.
//!
//! # Accounting
//!
//! [`EncodedTensor::measured_bits`] = coded payload bits + 32 bits per
//! stored `absmax`/`means` entry (they are held as `f32`).
//! [`EncodedTensor::resident_bytes`] charges the bitstream, the serialized
//! tables, and the side channels; like `PackedTensor::resident_bytes` it
//! excludes the shared dtype codebook. `entropy_bits` carries the Shannon
//! lower bound of the index stream for the coded-vs-bound gap in
//! `{"op":"stats"}`.
//!
//! # Invariants
//!
//! - Decode is lossless: indices (hence dequantized floats) are
//!   bit-identical to the `PackedTensor` the encoder consumed.
//! - `payload_bits <= n * bits` (raw fallback guarantees it).
//! - The decoder is total on untrusted input: truncated streams, invalid
//!   codes, and lying metadata are typed errors, never panics — this module
//!   is under the same panic-path lint rule as `server/` and `fleet/`, and
//!   contains no `unsafe`.
//!
//! [`packing::bit_window`]: super::packing::bit_window

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::fused::{self, Backend};
use super::packing::{bit_window, PackedTensor};
use super::PackedParam;

/// Indices per coding segment (independent of the quantization block size).
pub const SEGMENT_LEN: usize = 4096;
/// Longest permitted Huffman code: lengths fit a 4-bit nibble when tables
/// serialize as length lists.
pub const MAX_CODE_LEN: u32 = 15;
/// The accelerated decoder resolves codes of length <= LUT_BITS with one
/// table lookup (the SNIPPETS `HuffmanDecoder::builder(9)` idiom).
pub const LUT_BITS: u32 = 9;

/// Serialized size of a table: a 16-bit header plus one 4-bit length nibble
/// per symbol of the `1 << k` alphabet.
fn table_bits(n_sym: usize) -> u64 {
    16 + 4 * n_sym as u64
}

// ---------------------------------------------------------------------------
// Bit I/O (LSB-first in u32 words, matching `packing::bit_window`)
// ---------------------------------------------------------------------------

/// LSB-first bit writer over `u32` words.
struct BitWriter {
    words: Vec<u32>,
    /// Bits used in the last word (0 means the next `put` opens a new word).
    off: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { words: Vec::new(), off: 0 }
    }

    fn bit_len(&self) -> u64 {
        if self.off == 0 {
            self.words.len() as u64 * 32
        } else {
            (self.words.len() as u64 - 1) * 32 + self.off as u64
        }
    }

    /// Append the low `nbits` of `v` (nbits <= 24), LSB first.
    fn put(&mut self, v: u32, nbits: u32) {
        debug_assert!(nbits <= 24);
        let v = if nbits >= 32 { v } else { v & ((1u32 << nbits) - 1) };
        if self.off == 0 {
            self.words.push(v);
            self.off = nbits.min(32);
            if self.off == 32 {
                self.off = 0;
            }
            return;
        }
        let off = self.off;
        if let Some(last) = self.words.last_mut() {
            *last |= v << off;
        }
        if off + nbits > 32 {
            // Spill the high part into a fresh word. off >= 9 here since
            // nbits <= 24, so the shift amount 32 - off is in 1..=23.
            self.words.push(v >> (32 - off));
        }
        self.off = (off + nbits) % 32;
    }
}

/// LSB-first bit reader with zero-padded peeks past the end.
struct BitReader<'a> {
    words: &'a [u32],
    /// Absolute bit position of the next unread bit.
    pos: u64,
    /// Total valid bits in the stream; `consume` may not move past this.
    end: u64,
}

impl<'a> BitReader<'a> {
    fn new(words: &'a [u32], end: u64) -> Self {
        BitReader { words, pos: 0, end }
    }

    fn seek(&mut self, bitpos: u64) {
        self.pos = bitpos;
    }

    /// Peek the next `nbits` (<= 24) without consuming; bits past `end`
    /// read as zero (truncation is caught by `consume`, not `peek`).
    fn peek(&self, nbits: u32) -> u32 {
        debug_assert!(nbits <= 24);
        let word = (self.pos / 32) as usize;
        let off = (self.pos % 32) as u32;
        let lo = self.words.get(word).copied().unwrap_or(0) >> off;
        let v = if off + nbits > 32 {
            lo | self.words.get(word + 1).copied().unwrap_or(0) << (32 - off)
        } else {
            lo
        };
        if nbits >= 32 { v } else { v & ((1u32 << nbits) - 1) }
    }

    /// Advance by `nbits`, erroring if that would pass the end of stream.
    fn consume(&mut self, nbits: u32) -> Result<()> {
        let next = self.pos + nbits as u64;
        if next > self.end {
            bail!(
                "bitstream truncated: need bit {} but stream holds {}",
                next,
                self.end
            );
        }
        self.pos = next;
        Ok(())
    }

    /// Read `nbits` (<= 24) LSB-first.
    fn read(&mut self, nbits: u32) -> Result<u32> {
        let v = self.peek(nbits);
        self.consume(nbits)?;
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// Canonical Huffman table
// ---------------------------------------------------------------------------

/// Reverse the low `len` bits of `code`.
fn rev_bits(code: u32, len: u32) -> u32 {
    if len == 0 {
        return 0;
    }
    code.reverse_bits() >> (32 - len)
}

/// A canonical Huffman table over the full `1 << k` index alphabet.
///
/// Constructed only through the validating entry points
/// ([`HuffTable::from_histogram`], [`HuffTable::from_lengths`]), so a table
/// held by an [`EncodedTensor`] is always internally consistent — hostile
/// tensors can lie about *metadata* (segment offsets, table indices) but not
/// carry a structurally invalid table. Serializes as its [`lengths`] list.
///
/// [`lengths`]: HuffTable::lengths
#[derive(Clone, Debug, PartialEq)]
pub struct HuffTable {
    /// Code length per symbol (0 = symbol absent from the table).
    lengths: Vec<u8>,
    /// Per-symbol (bit-reversed code, length) for the encoder.
    enc: Vec<(u32, u32)>,
    /// First-`LUT_BITS` lookup: `(len << 16) | sym`, 0 = invalid or long.
    lut: Vec<u32>,
    /// Canonical decode state for codes longer than `LUT_BITS`:
    /// `first_code[l]`, `count[l]`, `sym_base[l]` (into `syms`) per length.
    first_code: Vec<u32>,
    count: Vec<u32>,
    sym_base: Vec<u32>,
    /// Symbols ordered by (length, symbol).
    syms: Vec<u16>,
}

impl HuffTable {
    /// Build from an index histogram over the full alphabet. `hist.len()`
    /// must be `1 << k` for some k in 1..=8.
    pub fn from_histogram(hist: &[u64]) -> Result<HuffTable> {
        let n_sym = hist.len();
        if !(2..=256).contains(&n_sym) || !n_sym.is_power_of_two() {
            bail!("huffman alphabet size {n_sym} is not a power of two in 2..=256");
        }
        let live: Vec<usize> = hist
            .iter()
            .enumerate()
            .filter(|(_, &h)| h > 0)
            .map(|(s, _)| s)
            .collect();
        if live.is_empty() {
            bail!("huffman histogram is empty");
        }
        let mut lengths = vec![0u8; n_sym];
        if live.len() == 1 {
            // A single distinct symbol still needs one bit on the wire so
            // the decoder can count elements.
            if let Some(slot) = live.first().and_then(|&s| lengths.get_mut(s)) {
                *slot = 1;
            }
        } else {
            // Package the live symbols with a classic heap Huffman build
            // over a flat parent-pointer forest; (count, node) ordering
            // keeps the tree deterministic.
            let mut parent = vec![usize::MAX; live.len() * 2 - 1];
            let mut heap: BinaryHeap<Reverse<(u64, usize)>> = live
                .iter()
                .enumerate()
                .map(|(node, &s)| Reverse((hist.get(s).copied().unwrap_or(0), node)))
                .collect();
            let mut next_node = live.len();
            while heap.len() > 1 {
                let Some(Reverse((ca, a))) = heap.pop() else { break };
                let Some(Reverse((cb, b))) = heap.pop() else { break };
                for child in [a, b] {
                    if let Some(p) = parent.get_mut(child) {
                        *p = next_node;
                    }
                }
                heap.push(Reverse((ca + cb, next_node)));
                next_node += 1;
            }
            let root = next_node.saturating_sub(1);
            for (node, &s) in live.iter().enumerate() {
                let mut depth = 0u32;
                let mut at = node;
                while at != root {
                    let Some(&p) = parent.get(at) else { break };
                    if p == usize::MAX {
                        break;
                    }
                    at = p;
                    depth += 1;
                }
                if let Some(slot) = lengths.get_mut(s) {
                    *slot = depth.min(MAX_CODE_LEN) as u8;
                }
            }
            kraft_repair(&mut lengths);
        }
        HuffTable::from_lengths(&lengths)
    }

    /// Build from a code-length list (the serialized form). Validates the
    /// alphabet size, the per-symbol length bound, and the Kraft
    /// inequality, so untrusted length lists cannot yield an ambiguous or
    /// over-subscribed table.
    pub fn from_lengths(lengths: &[u8]) -> Result<HuffTable> {
        let n_sym = lengths.len();
        if !(2..=256).contains(&n_sym) || !n_sym.is_power_of_two() {
            bail!("huffman alphabet size {n_sym} is not a power of two in 2..=256");
        }
        let mut count = vec![0u32; MAX_CODE_LEN as usize + 1];
        let mut live = 0usize;
        for (s, &l) in lengths.iter().enumerate() {
            if l as u32 > MAX_CODE_LEN {
                bail!("huffman code length {l} for symbol {s} exceeds max {MAX_CODE_LEN}");
            }
            if l > 0 {
                live += 1;
                if let Some(c) = count.get_mut(l as usize) {
                    *c += 1;
                }
            }
        }
        if live == 0 {
            bail!("huffman length list has no coded symbols");
        }
        // Kraft: sum over coded symbols of 2^(MAX - l) must not exceed 2^MAX.
        let mut kraft: u64 = 0;
        for (l, &c) in count.iter().enumerate().skip(1) {
            kraft += (c as u64) << (MAX_CODE_LEN as usize - l);
        }
        if kraft > 1u64 << MAX_CODE_LEN {
            bail!("huffman length list violates the Kraft inequality (sum {kraft})");
        }
        // Canonical first codes per length, MSB-first convention.
        let mut first_code = vec![0u32; MAX_CODE_LEN as usize + 2];
        let mut sym_base = vec![0u32; MAX_CODE_LEN as usize + 2];
        let mut code = 0u32;
        let mut base = 0u32;
        for l in 1..=MAX_CODE_LEN as usize {
            code <<= 1;
            if let Some(fc) = first_code.get_mut(l) {
                *fc = code;
            }
            if let Some(sb) = sym_base.get_mut(l) {
                *sb = base;
            }
            let c = count.get(l).copied().unwrap_or(0);
            code += c;
            base += c;
        }
        // Symbols ordered by (length, symbol): a stable walk over lengths
        // grouped by length gives canonical order directly.
        let mut syms: Vec<u16> = Vec::with_capacity(live);
        let mut enc = vec![(0u32, 0u32); n_sym];
        let mut next = first_code.clone();
        for l in 1..=MAX_CODE_LEN as usize {
            for (s, &sl) in lengths.iter().enumerate() {
                if sl as usize != l {
                    continue;
                }
                syms.push(s as u16);
                let c = next.get(l).copied().unwrap_or(0);
                if let Some(nx) = next.get_mut(l) {
                    *nx = c + 1;
                }
                if let Some(e) = enc.get_mut(s) {
                    *e = (rev_bits(c, l as u32), l as u32);
                }
            }
        }
        // First-LUT_BITS lookup: every window whose low bits spell a short
        // code maps straight to (len, sym).
        let mut lut = vec![0u32; 1usize << LUT_BITS];
        for (s, &(rcode, len)) in enc.iter().enumerate() {
            if len == 0 || len > LUT_BITS {
                continue;
            }
            let entry = (len << 16) | s as u32;
            let mut w = 0u32;
            while w < 1u32 << (LUT_BITS - len) {
                if let Some(slot) = lut.get_mut(((w << len) | rcode) as usize) {
                    *slot = entry;
                }
                w += 1;
            }
        }
        Ok(HuffTable { lengths: lengths.to_vec(), enc, lut, first_code, count, sym_base, syms })
    }

    /// The serialized form: one code length per symbol of the alphabet.
    pub fn lengths(&self) -> &[u8] {
        &self.lengths
    }

    /// Alphabet size (`1 << k`).
    pub fn n_sym(&self) -> usize {
        self.lengths.len()
    }

    /// Total coded bits this table spends on a histogram.
    fn cost_bits(&self, hist: &[u64]) -> u64 {
        hist.iter()
            .zip(self.enc.iter())
            .map(|(&h, &(_, len))| h * len as u64)
            .sum()
    }

    fn put_sym(&self, w: &mut BitWriter, sym: usize) {
        if let Some(&(rcode, len)) = self.enc.get(sym) {
            w.put(rcode, len);
        }
    }

    /// Decode one symbol from `r`. Errors on invalid codes and truncation.
    fn read_sym(&self, r: &mut BitReader<'_>) -> Result<u32> {
        let window = r.peek(LUT_BITS);
        let entry = self.lut.get(window as usize).copied().unwrap_or(0);
        if entry != 0 {
            r.consume(entry >> 16)?;
            return Ok(entry & 0xFFFF);
        }
        // Slow path: accumulate the code MSB-first one transmitted bit at
        // a time (the first transmitted bit is the code's MSB).
        let mut code = 0u32;
        for l in 1..=MAX_CODE_LEN {
            code = (code << 1) | (r.peek(l) >> (l - 1));
            let li = l as usize;
            let first = self.first_code.get(li).copied().unwrap_or(0);
            let n_here = self.count.get(li).copied().unwrap_or(0);
            if n_here > 0 && code >= first && code < first + n_here {
                let base = self.sym_base.get(li).copied().unwrap_or(0);
                let Some(&sym) = self.syms.get((base + (code - first)) as usize) else {
                    bail!("huffman decode state out of range at length {l}");
                };
                r.consume(l)?;
                return Ok(sym as u32);
            }
        }
        bail!("invalid huffman code in bitstream")
    }
}

/// Limit lengths to `MAX_CODE_LEN` and restore the Kraft inequality by
/// lengthening the cheapest (shortest over-budget) codes. Terminates: every
/// step strictly decreases the Kraft sum, which is bounded below.
fn kraft_repair(lengths: &mut [u8]) {
    for l in lengths.iter_mut() {
        if *l as u32 > MAX_CODE_LEN {
            *l = MAX_CODE_LEN as u8;
        }
    }
    let kraft = |ls: &[u8]| -> u64 {
        ls.iter()
            .filter(|&&l| l > 0)
            .map(|&l| 1u64 << (MAX_CODE_LEN - l as u32))
            .sum()
    };
    while kraft(lengths) > 1u64 << MAX_CODE_LEN {
        // Lengthen the largest length still below the cap: cheapest loss
        // of code space per step.
        let mut best: Option<usize> = None;
        for (s, &l) in lengths.iter().enumerate() {
            if l == 0 || l as u32 >= MAX_CODE_LEN {
                continue;
            }
            match best {
                Some(b) if lengths.get(b).copied().unwrap_or(0) >= l => {}
                _ => best = Some(s),
            }
        }
        let Some(s) = best else { break };
        if let Some(l) = lengths.get_mut(s) {
            *l += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Encoded tensor
// ---------------------------------------------------------------------------

/// How one coding segment's indices are stored in the bitstream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Coding {
    /// Fixed `bits`-wide fields, LSB-first (no table).
    Raw,
    /// Huffman-coded with `tables[i]`.
    Table(usize),
}

/// One coding segment: `len` consecutive indices starting at bit `bit_off`.
#[derive(Clone, Debug, PartialEq)]
pub struct Segment {
    pub len: usize,
    pub bit_off: u64,
    pub coding: Coding,
}

/// Entropy-coded residency form of a [`PackedTensor`].
///
/// Carries the identical dequantization side channels (`absmax`, `means`,
/// `codebook`, `bits`, `block`), so decoding the index stream and applying
/// `values[idx] * absmax + mean` reproduces the packed twin's floats
/// bit-for-bit. Fields are public (and `Clone`) so the fuzz harness can
/// construct hostile variants by struct update; [`EncodedTensor::validate`]
/// and the decoder reject every inconsistent shape with an error.
#[derive(Clone, Debug)]
pub struct EncodedTensor {
    /// Element count.
    pub n: usize,
    /// Nominal index width in bits (1..=8).
    pub bits: usize,
    /// Quantization block size (elements per absmax entry).
    pub block: usize,
    pub absmax: Vec<f32>,
    pub means: Option<Vec<f32>>,
    pub codebook: super::codebook::Codebook,
    /// Deduplicated Huffman tables referenced by `Coding::Table`.
    pub tables: Vec<HuffTable>,
    pub segments: Vec<Segment>,
    /// LSB-first coded payload.
    pub stream: Vec<u32>,
    /// Valid bits in `stream` (trailing bits of the last word are padding).
    pub stream_bits: u64,
    /// Shannon lower bound of the index stream, in bits (informational).
    pub entropy_bits: f64,
}

impl EncodedTensor {
    /// Losslessly re-encode a packed tensor. The result decodes to
    /// bit-identical indices; `payload_bits() <= n * bits` always holds
    /// because each segment falls back to raw fields when Huffman (plus any
    /// new table) would not pay for itself.
    pub fn encode(p: &PackedTensor) -> Result<EncodedTensor> {
        p.validate().context("cannot entropy-code an invalid packed tensor")?;
        let k = p.bits as u32;
        let mask = if p.bits >= 8 { 0xFF } else { (1u32 << k) - 1 };
        let n_sym = 1usize << p.bits;

        let mut w = BitWriter::new();
        let mut tables: Vec<HuffTable> = Vec::new();
        let mut dedup: HashMap<Vec<u8>, usize> = HashMap::new();
        let mut segments: Vec<Segment> = Vec::new();
        let mut entropy_bits = 0.0f64;

        let mut idx_buf: Vec<u32> = Vec::with_capacity(SEGMENT_LEN);
        let mut start = 0usize;
        while start < p.n {
            let len = SEGMENT_LEN.min(p.n - start);
            idx_buf.clear();
            let mut hist = vec![0u64; n_sym];
            for e in start..start + len {
                let idx = bit_window(&p.packed, e * p.bits, p.bits, mask);
                idx_buf.push(idx);
                if let Some(h) = hist.get_mut(idx as usize) {
                    *h += 1;
                }
            }
            // Shannon bound over this segment (the coded-vs-bound gap the
            // stats op reports).
            entropy_bits += super::bitcost::index_entropy_bits(&hist);
            let table = HuffTable::from_histogram(&hist)?;
            let huff_bits = table.cost_bits(&hist);
            let (table_idx, new_table_bits) = match dedup.get(table.lengths()) {
                Some(&t) => (t, 0),
                None => (tables.len(), table_bits(n_sym)),
            };
            let raw_bits = len as u64 * k as u64;
            let bit_off = w.bit_len();
            if huff_bits + new_table_bits < raw_bits {
                if table_idx == tables.len() {
                    dedup.insert(table.lengths().to_vec(), table_idx);
                    tables.push(table.clone());
                }
                let Some(t) = tables.get(table_idx) else {
                    bail!("internal: table index out of range during encode");
                };
                for &idx in idx_buf.iter() {
                    t.put_sym(&mut w, idx as usize);
                }
                segments.push(Segment { len, bit_off, coding: Coding::Table(table_idx) });
            } else {
                for &idx in idx_buf.iter() {
                    w.put(idx, k);
                }
                segments.push(Segment { len, bit_off, coding: Coding::Raw });
            }
            start += len;
        }

        let enc = EncodedTensor {
            n: p.n,
            bits: p.bits,
            block: p.block,
            absmax: p.absmax.clone(),
            means: p.means.clone(),
            codebook: p.codebook.clone(),
            tables,
            segments,
            stream_bits: w.bit_len(),
            stream: w.words,
            entropy_bits,
        };
        enc.validate().context("internal: freshly encoded tensor failed validation")?;
        Ok(enc)
    }

    /// Structural validation of (possibly untrusted) fields. The decoder
    /// additionally catches truncation and invalid codes at decode time.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 {
            bail!("encoded tensor has no elements");
        }
        if !(1..=8).contains(&self.bits) {
            bail!("encoded tensor bits {} out of range 1..=8", self.bits);
        }
        if self.block == 0 {
            bail!("encoded tensor block size must be nonzero");
        }
        let n_blocks = self.n.div_ceil(self.block);
        if self.absmax.len() != n_blocks {
            bail!(
                "encoded tensor absmax table has {} entries, expected {}",
                self.absmax.len(),
                n_blocks
            );
        }
        if let Some(m) = &self.means {
            if m.len() != n_blocks {
                bail!(
                    "encoded tensor means table has {} entries, expected {}",
                    m.len(),
                    n_blocks
                );
            }
        }
        if self.codebook.len() > (1usize << self.bits) {
            bail!(
                "encoded tensor codebook has {} entries, more than 2^{}",
                self.codebook.len(),
                self.bits
            );
        }
        if self.stream_bits > self.stream.len() as u64 * 32 {
            bail!(
                "encoded tensor claims {} stream bits but holds {} words",
                self.stream_bits,
                self.stream.len()
            );
        }
        let want_segs = self.n.div_ceil(SEGMENT_LEN);
        if self.segments.len() != want_segs {
            bail!(
                "encoded tensor has {} segments, expected {} for {} elements",
                self.segments.len(),
                want_segs,
                self.n
            );
        }
        let n_sym = 1usize << self.bits;
        for (t, table) in self.tables.iter().enumerate() {
            if table.n_sym() != n_sym {
                bail!(
                    "table {t} covers a {}-symbol alphabet, expected {}",
                    table.n_sym(),
                    n_sym
                );
            }
        }
        let mut prev_off = 0u64;
        let mut total = 0usize;
        for (i, seg) in self.segments.iter().enumerate() {
            let want_len = if i + 1 == self.segments.len() {
                self.n - i * SEGMENT_LEN
            } else {
                SEGMENT_LEN
            };
            if seg.len != want_len {
                bail!("segment {i} has length {}, expected {}", seg.len, want_len);
            }
            if seg.bit_off < prev_off || seg.bit_off > self.stream_bits {
                bail!("segment {i} bit offset {} is out of order or range", seg.bit_off);
            }
            prev_off = seg.bit_off;
            match seg.coding {
                Coding::Raw => {
                    let need = (seg.len as u64)
                        .checked_mul(self.bits as u64)
                        .and_then(|b| seg.bit_off.checked_add(b));
                    match need {
                        Some(need) if need <= self.stream_bits => {}
                        _ => bail!("raw segment {i} overruns the bitstream"),
                    }
                }
                Coding::Table(t) => {
                    if t >= self.tables.len() {
                        bail!("segment {i} references missing table {t}");
                    }
                }
            }
            total += seg.len;
        }
        if total != self.n {
            bail!("segments cover {total} elements, expected {}", self.n);
        }
        Ok(())
    }

    /// Coded payload bits actually spent on the index stream.
    pub fn payload_bits(&self) -> u64 {
        self.stream_bits
    }

    /// What the packed twin spends on the same indices: `n * bits`.
    pub fn nominal_payload_bits(&self) -> u64 {
        self.n as u64 * self.bits as u64
    }

    /// Measured total bits: coded payload plus 32 bits per stored
    /// `absmax`/`means` entry (held as `f32`). Serialized tables are part
    /// of `resident_bytes` but charged here too so the frontier sees the
    /// whole cost.
    pub fn measured_bits(&self) -> u64 {
        let side = 32 * (self.absmax.len() as u64
            + self.means.as_ref().map_or(0, |m| m.len() as u64));
        let tables: u64 = self
            .tables
            .iter()
            .map(|t| table_bits(t.n_sym()))
            .sum();
        self.stream_bits + side + tables
    }

    /// Resident bytes: bitstream words, serialized tables, and the f32 side
    /// channels. Excludes the shared dtype codebook, like
    /// `PackedTensor::resident_bytes`.
    pub fn resident_bytes(&self) -> usize {
        let tables: usize = self
            .tables
            .iter()
            .map(|t| (table_bits(t.n_sym()) as usize).div_ceil(8))
            .sum();
        self.stream.len() * 4
            + tables
            + self.absmax.len() * 4
            + self.means.as_ref().map_or(0, |m| m.len() * 4)
    }

    /// Decode elements `lo..hi` into `out` (dequantized floats),
    /// bit-identical to `PackedTensor::dequantize_into` over the same span.
    pub fn decode_range(&self, lo: usize, hi: usize, out: &mut [f32]) -> Result<()> {
        self.validate()?;
        if lo > hi || hi > self.n {
            bail!("decode_range {lo}..{hi} out of bounds for {} elements", self.n);
        }
        if out.len() != hi - lo {
            bail!(
                "decode_range output holds {} slots for {} elements",
                out.len(),
                hi - lo
            );
        }
        if lo == hi {
            return Ok(());
        }
        let mut d = Decoder::new(self)?;
        d.seek(lo)?;
        d.decode_into(out)
    }

    /// Decode the whole tensor (the scratch-path entry point).
    pub fn dequantize_into(&self, out: &mut [f32]) -> Result<()> {
        self.decode_range(0, self.n, out)
    }
}

/// Streaming decoder over an [`EncodedTensor`]: decodes forward from a
/// seekable element position without materializing the full index stream.
pub struct Decoder<'a> {
    t: &'a EncodedTensor,
    r: BitReader<'a>,
    /// Next element to decode.
    elem: usize,
    /// Index of the segment containing `elem` (== segments.len() at end).
    seg: usize,
    /// First element of segment `seg`.
    seg_start: usize,
}

impl<'a> Decoder<'a> {
    /// Positioned at element 0. The tensor must already be `validate()`d.
    pub fn new(t: &'a EncodedTensor) -> Result<Decoder<'a>> {
        let mut r = BitReader::new(&t.stream, t.stream_bits);
        if let Some(seg0) = t.segments.first() {
            r.seek(seg0.bit_off);
        }
        Ok(Decoder { t, r, elem: 0, seg: 0, seg_start: 0 })
    }

    /// Jump to element `elem`: re-seek to the owning segment's bit offset,
    /// then skip forward (raw segments skip in O(1); coded segments decode
    /// and discard).
    pub fn seek(&mut self, elem: usize) -> Result<()> {
        if elem > self.t.n {
            bail!("seek to element {elem} past end {}", self.t.n);
        }
        let seg = elem / SEGMENT_LEN;
        let seg_start = seg * SEGMENT_LEN;
        if let Some(s) = self.t.segments.get(seg) {
            self.r.seek(s.bit_off);
            self.seg = seg;
            self.seg_start = seg_start;
            self.elem = seg_start;
            match s.coding {
                Coding::Raw => {
                    let skip = (elem - seg_start) as u64 * self.t.bits as u64;
                    self.r.seek(s.bit_off + skip);
                    self.elem = elem;
                }
                Coding::Table(t) => {
                    let Some(table) = self.t.tables.get(t) else {
                        bail!("segment {seg} references missing table {t}");
                    };
                    for _ in seg_start..elem {
                        table.read_sym(&mut self.r)?;
                    }
                    self.elem = elem;
                }
            }
        } else {
            // elem == n exactly: position at end.
            self.seg = self.t.segments.len();
            self.seg_start = elem;
            self.elem = elem;
        }
        Ok(())
    }

    /// Decode the next `out.len()` elements as dequantized floats.
    pub fn decode_into(&mut self, out: &mut [f32]) -> Result<()> {
        let t = self.t;
        if self.elem + out.len() > t.n {
            bail!(
                "decode of {} elements at {} overruns tensor of {}",
                out.len(),
                self.elem,
                t.n
            );
        }
        let values = t.codebook.values();
        let k = t.bits as u32;
        let mask = if t.bits >= 8 { 0xFF } else { (1u32 << k) - 1 };
        let mut written = 0usize;
        while written < out.len() {
            let Some(seg) = t.segments.get(self.seg) else {
                bail!("decoder ran past the last segment");
            };
            let seg_end = self.seg_start + seg.len;
            let take = (out.len() - written).min(seg_end - self.elem);
            let Some(span) = out.get_mut(written..written + take) else {
                bail!("internal: decode output window out of range");
            };
            match seg.coding {
                Coding::Raw => {
                    for o in span.iter_mut() {
                        let idx = self.r.read(k)? & mask;
                        *o = self.dequant_one(values, idx, self.elem)?;
                        self.elem += 1;
                    }
                }
                Coding::Table(ti) => {
                    let Some(table) = t.tables.get(ti) else {
                        bail!("segment {} references missing table {ti}", self.seg);
                    };
                    for o in span.iter_mut() {
                        let idx = table.read_sym(&mut self.r)?;
                        *o = self.dequant_one(values, idx, self.elem)?;
                        self.elem += 1;
                    }
                }
            }
            written += take;
            if self.elem == seg_end {
                self.seg += 1;
                self.seg_start = seg_end;
                if let Some(next) = t.segments.get(self.seg) {
                    self.r.seek(next.bit_off);
                }
            }
        }
        Ok(())
    }

    /// Dequantize one decoded index at absolute element position `e` —
    /// the exact arithmetic of `PackedTensor::dequantize_into`.
    #[inline]
    fn dequant_one(&self, values: &[f32], idx: u32, e: usize) -> Result<f32> {
        let t = self.t;
        let b = e / t.block;
        let Some(&amax) = t.absmax.get(b) else {
            bail!("block {b} out of range for absmax table");
        };
        let mean = t
            .means
            .as_ref()
            .and_then(|m| m.get(b).copied())
            .unwrap_or(0.0);
        let Some(&val) = values.get(idx as usize) else {
            bail!(
                "bitstream index {idx} out of range for {}-entry codebook",
                values.len()
            );
        };
        Ok(val * amax + mean)
    }
}

// ---------------------------------------------------------------------------
// Parameter-level wrapper (mirrors PackedParam)
// ---------------------------------------------------------------------------

/// Entropy-coded form of a [`PackedParam`]: the same leading-axis slices,
/// each re-encoded as an [`EncodedTensor`].
#[derive(Clone, Debug)]
pub struct EncodedParam {
    pub shape: Vec<usize>,
    pub slices: Vec<EncodedTensor>,
}

impl EncodedParam {
    /// Losslessly re-encode every slice of a packed parameter.
    pub fn encode(p: &PackedParam) -> Result<EncodedParam> {
        let slices = p
            .slices
            .iter()
            .map(EncodedTensor::encode)
            .collect::<Result<Vec<_>>>()?;
        Ok(EncodedParam { shape: p.shape.clone(), slices })
    }

    /// Total element count across slices.
    pub fn len(&self) -> usize {
        self.slices.iter().map(|s| s.n).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode all slices back-to-back into `out` — bit-identical to
    /// `PackedParam::dequantize_into` on the packed twin.
    pub fn dequantize_into(&self, out: &mut [f32]) -> Result<()> {
        if out.len() != self.len() {
            bail!(
                "dequantize output holds {} slots for {} elements",
                out.len(),
                self.len()
            );
        }
        let mut off = 0usize;
        for s in self.slices.iter() {
            let Some(span) = out.get_mut(off..off + s.n) else {
                bail!("internal: slice window out of range during dequantize");
            };
            s.dequantize_into(span)?;
            off += s.n;
        }
        Ok(())
    }

    /// Actual coded residency in bytes (streams + tables + side channels).
    pub fn resident_bytes(&self) -> usize {
        self.slices.iter().map(|s| s.resident_bytes()).sum()
    }

    /// Measured total bits across slices (payload + tables + f32 side).
    pub fn measured_bits(&self) -> u64 {
        self.slices.iter().map(|s| s.measured_bits()).sum()
    }

    /// Nominal `n * bits` payload the packed twin would spend.
    pub fn nominal_payload_bits(&self) -> u64 {
        self.slices.iter().map(|s| s.nominal_payload_bits()).sum()
    }

    /// Coded payload bits actually spent.
    pub fn payload_bits(&self) -> u64 {
        self.slices.iter().map(|s| s.payload_bits()).sum()
    }

    /// Shannon lower bound of the index streams, in bits.
    pub fn entropy_bits(&self) -> f64 {
        self.slices.iter().map(|s| s.entropy_bits).sum()
    }
}

// ---------------------------------------------------------------------------
// Fused scoring over encoded weights
// ---------------------------------------------------------------------------

/// Fused matmul over an entropy-coded weight matrix, accumulating into
/// `out` like `fused::fused_matmul`: stream-decode one weight row at a
/// time into `wrow` and axpy it across the input rows — the same k-outer
/// order as `fused::fused_matmul_untiled`, so scores are bit-identical to
/// the packed fused path. Variable-length decode is inherently sequential,
/// so this path is single-threaded regardless of `KBITSCALE_THREADS`
/// (callers pass geometry, not a thread count).
pub fn fused_matmul_encoded(
    x: &[f32],
    t: &EncodedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    wrow: &mut [f32],
) -> Result<()> {
    let backend = fused::active_backend();
    fused_matmul_encoded_with(backend, x, t, out, m, kd, n, wrow)
}

/// Backend-explicit variant of [`fused_matmul_encoded`] (for tests).
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_encoded_with(
    backend: Backend,
    x: &[f32],
    t: &EncodedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    wrow: &mut [f32],
) -> Result<()> {
    let numel = kd
        .checked_mul(n)
        .with_context(|| format!("fused geometry {kd}x{n} overflows"))?;
    if t.n != numel {
        bail!(
            "encoded tensor has {} elements, fused geometry wants {kd}x{n}",
            t.n
        );
    }
    if x.len() != m * kd {
        bail!("input has {} elements, expected {}x{}", x.len(), m, kd);
    }
    if out.len() != m * n {
        bail!("output has {} elements, expected {}x{}", out.len(), m, n);
    }
    if wrow.len() < n {
        bail!("row scratch holds {} slots, need {}", wrow.len(), n);
    }
    t.validate()?;
    let Some(wrow) = wrow.get_mut(..n) else {
        bail!("internal: row scratch window out of range");
    };
    let mut d = Decoder::new(t)?;
    for r in 0..kd {
        d.decode_into(wrow)?;
        for (xrow, orow) in x.chunks_exact(kd).zip(out.chunks_exact_mut(n)) {
            let Some(&a) = xrow.get(r) else {
                bail!("internal: input row window out of range");
            };
            fused::axpy(backend, a, wrow, orow);
        }
    }
    Ok(())
}

/// Convenience: encode a packed param and keep it behind an `Arc` (the
/// registry's residency unit).
pub fn encode_param(p: &PackedParam) -> Result<Arc<EncodedParam>> {
    Ok(Arc::new(EncodedParam::encode(p)?))
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::quant::codebook::DataType;
    use crate::quant::spec::QuantSpec;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    fn indices_of(p: &PackedTensor) -> Vec<u32> {
        let mask = if p.bits >= 8 { 0xFF } else { (1u32 << p.bits) - 1 };
        (0..p.n)
            .map(|e| bit_window(&p.packed, e * p.bits, p.bits, mask))
            .collect()
    }

    fn decode_indices(t: &EncodedTensor) -> Result<Vec<u32>> {
        // Recover indices by decoding floats per segment through a raw
        // symbol walk: re-run the decoder at the symbol level.
        let mut r = BitReader::new(&t.stream, t.stream_bits);
        let mut out = Vec::with_capacity(t.n);
        let k = t.bits as u32;
        for seg in t.segments.iter() {
            r.seek(seg.bit_off);
            match seg.coding {
                Coding::Raw => {
                    for _ in 0..seg.len {
                        out.push(r.read(k)?);
                    }
                }
                Coding::Table(ti) => {
                    let table = t.tables.get(ti).unwrap();
                    for _ in 0..seg.len {
                        out.push(table.read_sym(&mut r)?);
                    }
                }
            }
        }
        Ok(out)
    }

    #[test]
    fn bitwriter_reader_roundtrip() {
        let mut rng = Rng::new(0x5eed);
        let mut w = BitWriter::new();
        let mut expect = Vec::new();
        for _ in 0..2000 {
            let nbits = 1 + (rng.next_u64() % 24) as u32;
            let v = (rng.next_u64() as u32) & ((1u32 << nbits) - 1);
            w.put(v, nbits);
            expect.push((v, nbits));
        }
        let end = w.bit_len();
        let mut r = BitReader::new(&w.words, end);
        for &(v, nbits) in &expect {
            assert_eq!(r.read(nbits).unwrap(), v);
        }
        // One more bit past the end must error.
        assert!(r.read(1).is_err());
    }

    #[test]
    fn roundtrip_is_bit_identical_across_bits_and_blocks() {
        check("entropy_roundtrip", 24, |rng, _case| {
            let w = gen::weights(rng, 6000);
            let bits = gen::bits(rng).max(3);
            let block = gen::block(rng);
            let spec = QuantSpec::new(DataType::Int, bits, Some(block));
            let p = PackedTensor::quantize(&w, &spec).map_err(|e| e.to_string())?;
            let e = EncodedTensor::encode(&p).map_err(|e| e.to_string())?;
            prop_assert!(e.validate().is_ok(), "fresh encode validates");
            let want = indices_of(&p);
            let got = decode_indices(&e).map_err(|e| e.to_string())?;
            prop_assert!(want == got, "decoded indices bit-identical");
            // Float path: dequantize_into must match the packed twin.
            let mut pf = vec![0.0f32; p.n];
            let mut ef = vec![0.0f32; p.n];
            p.dequantize_into(&mut pf).map_err(|e| e.to_string())?;
            e.dequantize_into(&mut ef).map_err(|e| e.to_string())?;
            prop_assert!(
                pf.iter().zip(ef.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "dequantized floats bit-identical"
            );
            // Measured <= nominal invariant: every Huffman segment paid for
            // its table out of its own savings, so even payload + tables
            // stays within the packed twin's n*k.
            let tbl: u64 = e.tables.iter().map(|t| table_bits(t.n_sym())).sum();
            prop_assert!(
                e.payload_bits() + tbl <= e.nominal_payload_bits(),
                "payload {} + tables {tbl} exceeds nominal {}",
                e.payload_bits(),
                e.nominal_payload_bits()
            );
            Ok(())
        });
    }

    #[test]
    fn payload_never_exceeds_nominal() {
        // The per-segment raw fallback guarantees stream bits <= n*k even
        // on incompressible (uniform) index streams.
        let mut rng = Rng::new(0xfeed);
        for &bits in &[3usize, 4, 5, 8] {
            let w: Vec<f32> = (0..9000).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let spec = QuantSpec::new(DataType::Int, bits, Some(64));
            let p = PackedTensor::quantize(&w, &spec).unwrap();
            let e = EncodedTensor::encode(&p).unwrap();
            assert!(
                e.payload_bits() <= e.nominal_payload_bits(),
                "bits={bits}: payload {} > nominal {}",
                e.payload_bits(),
                e.nominal_payload_bits()
            );
        }
    }

    #[test]
    fn decode_range_matches_full_decode_across_segments() {
        check("entropy_decode_range", 12, |rng, _case| {
            let w = gen::weights(rng, 9500);
            let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
            let p = PackedTensor::quantize(&w, &spec).map_err(|e| e.to_string())?;
            let e = EncodedTensor::encode(&p).map_err(|e| e.to_string())?;
            let mut full = vec![0.0f32; p.n];
            e.dequantize_into(&mut full).map_err(|e| e.to_string())?;
            for _ in 0..8 {
                let lo = (rng.next_u64() as usize) % (p.n + 1);
                let hi = lo + (rng.next_u64() as usize) % (p.n - lo + 1);
                let mut part = vec![0.0f32; hi - lo];
                e.decode_range(lo, hi, &mut part).map_err(|e| e.to_string())?;
                prop_assert!(
                    part.iter()
                        .zip(full.get(lo..hi).unwrap_or(&[]))
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "range {lo}..{hi} matches full decode"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn fused_matmul_encoded_matches_packed_fused() {
        let mut rng = Rng::new(0xabcd);
        let (m, kd, n) = (3usize, 32usize, 96usize);
        let w: Vec<f32> = (0..kd * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let x: Vec<f32> = (0..m * kd).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let e = EncodedTensor::encode(&p).unwrap();
        let mut wrow = vec![0.0f32; n];
        let mut untiled_row = Vec::new();
        let mut out_p = vec![0.0f32; m * n];
        let mut out_e = vec![0.0f32; m * n];
        let backend = fused::active_backend();
        fused::fused_matmul_untiled(backend, &x, &p, &mut out_p, m, kd, n, &mut untiled_row)
            .unwrap();
        fused_matmul_encoded(&x, &e, &mut out_e, m, kd, n, &mut wrow).unwrap();
        assert!(
            out_p.iter().zip(out_e.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "encoded fused scores bit-identical to packed fused"
        );
    }

    #[test]
    fn single_symbol_and_zero_blocks_roundtrip() {
        // All-zero weights quantize to one repeated index; the 1-bit
        // degenerate table must still count elements on the wire.
        let w = vec![0.0f32; 5000];
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let e = EncodedTensor::encode(&p).unwrap();
        assert_eq!(decode_indices(&e).unwrap(), indices_of(&p));
        // ~1 bit/elem (plus table), far below nominal 4.
        assert!(e.payload_bits() <= e.nominal_payload_bits() / 2);
    }

    #[test]
    fn fp4_gaussian_measures_below_four_bits_per_param() {
        // Acceptance pin: a 4-bit fp variant on gaussian-ish weights must
        // measure strictly below 4.0 bits/param including side channels.
        let mut rng = Rng::new(0x60a1);
        let n = 1usize << 16;
        let w: Vec<f32> = (0..n)
            .map(|_| {
                // Sum of uniforms ~ gaussian enough for a concentration
                // profile similar to trained weights.
                let s: f32 = (0..6).map(|_| rng.f32() - 0.5).sum();
                s * 0.5
            })
            .collect();
        let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let e = EncodedTensor::encode(&p).unwrap();
        let bpp = e.measured_bits() as f64 / n as f64;
        assert!(bpp < 4.0, "measured {bpp:.3} bits/param not below 4.0");
        assert!(e.entropy_bits / n as f64 <= e.payload_bits() as f64 / n as f64 + 1e-9);
    }

    #[test]
    fn hostile_length_lists_error_not_panic() {
        // Kraft violation: every symbol length 1.
        assert!(HuffTable::from_lengths(&[1u8; 16]).is_err());
        // Over-long code.
        let mut l = vec![0u8; 16];
        if let Some(s) = l.get_mut(0) {
            *s = 16;
        }
        assert!(HuffTable::from_lengths(&l).is_err());
        // Empty alphabet / non-power-of-two / oversized.
        assert!(HuffTable::from_lengths(&[]).is_err());
        assert!(HuffTable::from_lengths(&[1u8; 3]).is_err());
        assert!(HuffTable::from_lengths(&[1u8; 512]).is_err());
        // All-zero lengths: nothing coded.
        assert!(HuffTable::from_lengths(&[0u8; 16]).is_err());
        // A legal list round-trips through lengths().
        let t = HuffTable::from_lengths(&[1, 2, 3, 3, 0, 0, 0, 0]).unwrap();
        assert_eq!(t.lengths(), &[1, 2, 3, 3, 0, 0, 0, 0]);
    }

    #[test]
    fn truncated_stream_is_an_error() {
        let mut rng = Rng::new(0x7777);
        let w: Vec<f32> = (0..600).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let mut e = EncodedTensor::encode(&p).unwrap();
        // Chop the stream: decode must error, not panic.
        e.stream_bits = e.stream_bits.saturating_sub(e.stream_bits / 2);
        e.stream.truncate(e.stream_bits.div_ceil(32) as usize);
        let mut out = vec![0.0f32; e.n];
        assert!(e.dequantize_into(&mut out).is_err());
    }

    #[test]
    fn encoded_param_mirrors_packed_param() {
        let mut rng = Rng::new(0x2222);
        let w: Vec<f32> = (0..2 * 40 * 30).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let spec = QuantSpec::new(DataType::Int, 4, Some(32));
        let pp = PackedParam::quantize_slice(&[2, 40, 30], &w, &spec).unwrap();
        let ep = EncodedParam::encode(&pp).unwrap();
        assert_eq!(ep.len(), pp.len());
        let mut a = vec![0.0f32; pp.len()];
        let mut b = vec![0.0f32; ep.len()];
        pp.dequantize_into(&mut a).unwrap();
        ep.dequantize_into(&mut b).unwrap();
        assert!(a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(ep.resident_bytes() > 0);
        assert!(ep.payload_bits() <= ep.nominal_payload_bits());
    }
}
