//! Block-wise quantization (Section 2.3, Eq. 1) — the hot path.
//!
//! The tensor is viewed as a 1-D sequence split into blocks of `block`
//! values; each block is normalized by its own absmax and every value maps
//! to the nearest codebook entry. Small blocks confine outliers and cost
//! `16 / block` extra bits/parameter for the f32-stored-as-16-bit
//! normalization constant (the paper's accounting; see `bitcost`).
//!
//! Performance notes (EXPERIMENTS.md §Perf): assignment is a linear
//! boundary scan for k ≤ 4 codebooks and a branchless binary search above;
//! both avoid the per-value argmin of the naive formulation. The sweep
//! coordinator additionally parallelizes across parameter tensors.

use super::codebook::{Codebook, DataType};
use super::spec::QuantSpec;

/// The codebook-defining subset of a [`QuantSpec`]: data type, bit width,
/// and exponent split. Block size, centering, and proxy settings do not
/// change the codebook, so they are deliberately absent.
type CodebookKey = (DataType, usize, Option<usize>);

/// Process-wide codebook cache: specs are reused across thousands of
/// sweep cells and tensors, and quantile construction sorts a 64k sample —
/// rebuilding per tensor cost ~25% of quantize() (§Perf L3 step 6).
/// Keyed on the full [`CodebookKey`] so new dtypes can never silently
/// collide (the old key truncated the dtype to its first letter).
fn cached_codebook(spec: &QuantSpec) -> Codebook {
    use std::collections::HashMap;
    use std::sync::Mutex;
    static CACHE: Mutex<Option<HashMap<CodebookKey, Codebook>>> = Mutex::new(None);
    let key: CodebookKey = (spec.dtype, spec.bits, spec.exponent_bits);
    if let Some(hit) = CACHE.lock().unwrap().as_ref().and_then(|m| m.get(&key).cloned()) {
        return hit;
    }
    // Build outside the lock: a panic on an invalid spec (callers validate
    // at their boundaries) must not poison the process-wide cache, and
    // quantile construction sorts a 64k sample — no reason to serialize it.
    let cb = spec.codebook().expect("invalid quant spec");
    CACHE
        .lock()
        .unwrap()
        .get_or_insert_with(HashMap::new)
        .entry(key)
        .or_insert_with(|| cb.clone());
    cb
}

/// A quantized tensor in the paper's flat-block layout.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// One codebook index per value (stored unpacked; `packing` produces
    /// the k-bit wire format when storage is the point).
    pub idx: Vec<u8>,
    /// One absmax per block.
    pub absmax: Vec<f32>,
    /// Per-block means when distribution centering is enabled (App. B).
    pub means: Option<Vec<f32>>,
    pub block: usize,
    pub codebook: Codebook,
    pub bits: usize,
}

/// Quantize `data` under `spec` (flat block layout).
///
/// Tensor-wise quantization (`spec.block == None`) is a single block the
/// size of the tensor.
pub fn quantize(data: &[f32], spec: &QuantSpec) -> QuantizedTensor {
    let codebook = cached_codebook(spec);
    // Int codebooks are uniform grids: `m` levels per sign, value i maps
    // to (i - m) / m. Enables the arithmetic fast path below.
    let int_levels = (spec.dtype == DataType::Int).then(|| (1i32 << (spec.bits - 1)) - 1);
    let block = spec.block.unwrap_or(data.len().max(1));
    let nblocks = data.len().div_ceil(block);
    let mut idx = vec![0u8; data.len()];
    let mut absmax = vec![0.0f32; nblocks];
    let mut means = spec.centering.then(|| vec![0.0f32; nblocks]);

    for b in 0..nblocks {
        let lo = b * block;
        let hi = (lo + block).min(data.len());
        let chunk = &data[lo..hi];
        let mean = if let Some(ms) = means.as_mut() {
            let m = chunk.iter().sum::<f32>() / chunk.len() as f32;
            ms[b] = m;
            m
        } else {
            0.0
        };
        let mut amax = 0.0f32;
        for &x in chunk {
            amax = amax.max((x - mean).abs());
        }
        // A zero block quantizes to zeros with any positive scale.
        let amax = if amax == 0.0 { 1.0 } else { amax };
        absmax[b] = amax;
        let inv = 1.0 / amax;
        let out = &mut idx[lo..hi];
        if let Some(m) = int_levels {
            // Perf fast path (EXPERIMENTS.md §Perf L3 step 4): the Int
            // codebook is uniform, so nearest-value assignment is a single
            // scale+round instead of a boundary scan — ~8x throughput.
            let mf = m as f32;
            for (o, &x) in out.iter_mut().zip(chunk) {
                let v = ((x - mean) * inv).clamp(-1.0, 1.0);
                // +0.5 then truncate == round-to-nearest for the
                // non-negative shifted value; avoids the libm round call
                // and autovectorizes (§Perf L3 step 5).
                *o = (v * mf + mf + 0.5) as u8;
            }
        } else {
            for (o, &x) in out.iter_mut().zip(chunk) {
                *o = codebook.assign((x - mean) * inv);
            }
        }
    }

    QuantizedTensor { idx, absmax, means, block, codebook, bits: spec.bits }
}

impl QuantizedTensor {
    /// Convert to the packed k-bit residency form (`quant::packing`).
    pub fn pack(&self) -> anyhow::Result<super::packing::PackedTensor> {
        super::packing::PackedTensor::from_quantized(self)
    }
}

/// Dequantize into `out` (must have the original length).
pub fn dequantize(q: &QuantizedTensor, out: &mut [f32]) {
    assert_eq!(out.len(), q.idx.len());
    let values = q.codebook.values();
    for b in 0..q.absmax.len() {
        let lo = b * q.block;
        let hi = (lo + q.block).min(out.len());
        let amax = q.absmax[b];
        let mean = q.means.as_ref().map_or(0.0, |m| m[b]);
        for (o, &i) in out[lo..hi].iter_mut().zip(&q.idx[lo..hi]) {
            *o = values[i as usize] * amax + mean;
        }
    }
}

/// Round-trip helper: quantize then dequantize into a fresh vector.
pub fn simulate_slice(data: &[f32], spec: &QuantSpec) -> Vec<f32> {
    let q = quantize(data, spec);
    let mut out = vec![0.0f32; data.len()];
    dequantize(&q, &mut out);
    out
}

/// Root-mean-square quantization error of a spec on a slice — used by the
/// ablation benches and tests to compare configurations cheaply.
pub fn rms_error(data: &[f32], spec: &QuantSpec) -> f64 {
    let back = simulate_slice(data, spec);
    let se: f64 = data
        .iter()
        .zip(&back)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum();
    (se / data.len().max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::quant::codebook::DataType;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64, std: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, std);
        v
    }

    #[test]
    fn roundtrip_error_bounded_by_half_bin() {
        // For int quantization the worst-case error after normalization is
        // half the bin width times the block absmax.
        let data = randn(4096, 1, 0.1);
        for &k in &[3usize, 4, 8] {
            let spec = QuantSpec::new(DataType::Int, k, Some(64));
            let q = quantize(&data, &spec);
            let mut back = vec![0.0; data.len()];
            dequantize(&q, &mut back);
            let bin = 1.0 / ((1usize << (k - 1)) - 1) as f32;
            for b in 0..q.absmax.len() {
                let lo = b * 64;
                let hi = (lo + 64).min(data.len());
                let bound = 0.5 * bin * q.absmax[b] + 1e-6;
                for i in lo..hi {
                    assert!(
                        (data[i] - back[i]).abs() <= bound,
                        "k={k} i={i}: |{} - {}| > {bound}",
                        data[i],
                        back[i]
                    );
                }
            }
        }
    }

    #[test]
    fn zero_block_roundtrips_exactly() {
        let data = vec![0.0f32; 128];
        let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
        assert_eq!(simulate_slice(&data, &spec), data);
    }

    #[test]
    fn partial_trailing_block() {
        let data = randn(100, 2, 1.0); // 100 = 64 + 36
        let spec = QuantSpec::new(DataType::Int, 8, Some(64));
        let q = quantize(&data, &spec);
        assert_eq!(q.absmax.len(), 2);
        let mut back = vec![0.0; 100];
        dequantize(&q, &mut back);
        let rms = rms_error(&data, &spec);
        assert!(rms < 0.02, "rms {rms}");
    }

    #[test]
    fn small_blocks_confine_outliers() {
        // One huge outlier; with tensor-wise quantization everything else
        // collapses, with block-64 only the outlier's block suffers. This
        // is the mechanism behind Figure 3.
        let mut data = randn(1024, 3, 0.05);
        data[0] = 50.0;
        let spec_t = QuantSpec::new(DataType::Int, 4, None);
        let spec_b = QuantSpec::new(DataType::Int, 4, Some(64));
        let rms_t = rms_error(&data[64..], &spec_t.clone()); // unaffected region only
        // Compare the error over the non-outlier region under each scheme.
        let back_t = simulate_slice(&data, &spec_t);
        let back_b = simulate_slice(&data, &spec_b);
        let err_t: f64 = data[64..].iter().zip(&back_t[64..]).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let err_b: f64 = data[64..].iter().zip(&back_b[64..]).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(err_b * 10.0 < err_t, "blocked {err_b} vs tensorwise {err_t} (rms_t={rms_t})");
    }

    #[test]
    fn centering_helps_shifted_distributions() {
        let mut rng = Rng::new(4);
        // Strongly asymmetric data (ReLU-ish): all positive around 1.0.
        let data: Vec<f32> = (0..2048).map(|_| 1.0 + rng.normal().abs() as f32 * 0.1).collect();
        let plain = QuantSpec::new(DataType::Int, 4, Some(64));
        let centered = plain.clone().with_centering();
        assert!(rms_error(&data, &centered) < rms_error(&data, &plain));
    }

    #[test]
    fn prop_roundtrip_error_below_bin_width() {
        check("quantize-roundtrip-bounded", 60, |rng, _| {
            let data = gen::weights(rng, 512);
            let block = gen::block(rng);
            let bits = 3 + rng.below(6);
            let dtype = DataType::ALL[rng.below(4)];
            let spec = QuantSpec::new(dtype, bits, Some(block));
            let q = quantize(&data, &spec);
            let mut back = vec![0.0; data.len()];
            dequantize(&q, &mut back);
            // Generic bound: interior error <= max adjacent gap / 2; at the
            // edges an asymmetric codebook (quantile) may not reach ±1, so
            // the clamp error can be up to 1 - |extreme value|.
            let vals = q.codebook.values();
            let max_gap = vals.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
            let lo_clamp = (1.0 - vals[0].abs()).max(0.0);
            let hi_clamp = (1.0 - vals.last().unwrap().abs()).max(0.0);
            let worst = (max_gap * 0.5).max(lo_clamp).max(hi_clamp);
            for b in 0..q.absmax.len() {
                let lo = b * block;
                let hi = (lo + block).min(data.len());
                let bound = q.absmax[b] * worst + q.absmax[b] * 1e-5 + 1e-6;
                for i in lo..hi {
                    prop_assert!(
                        (data[i] - back[i]).abs() <= bound,
                        "{dtype:?} k={bits} block={block} i={i}: |{} - {}| > {bound}",
                        data[i],
                        back[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_indices_within_codebook() {
        check("indices-in-range", 40, |rng, _| {
            let data = gen::weights(rng, 300);
            let spec = QuantSpec::new(DataType::ALL[rng.below(4)], gen::bits(rng).max(3), Some(gen::block(rng)));
            let q = quantize(&data, &spec);
            let n = q.codebook.len();
            prop_assert!(q.idx.iter().all(|&i| (i as usize) < n), "index out of range");
            Ok(())
        });
    }

    #[test]
    fn codebook_cache_distinguishes_specs() {
        // Same bits, different dtype / exponent split must yield distinct
        // codebooks out of the process-wide cache.
        let fp_e2 = QuantSpec::new(DataType::Fp, 4, Some(64)).with_exponent_bits(2);
        let fp_e3 = QuantSpec::new(DataType::Fp, 4, Some(64)).with_exponent_bits(3);
        let int4 = QuantSpec::new(DataType::Int, 4, Some(64));
        let data = randn(256, 9, 0.1);
        let a = quantize(&data, &fp_e2);
        let b = quantize(&data, &fp_e3);
        let c = quantize(&data, &int4);
        assert_ne!(a.codebook.values(), b.codebook.values(), "exponent split ignored");
        assert_ne!(a.codebook.values(), c.codebook.values(), "dtype ignored");
        // And the cache is stable: same spec twice -> identical values.
        let a2 = quantize(&data, &fp_e2);
        assert_eq!(a.codebook.values(), a2.codebook.values());
    }

    #[test]
    fn prop_dequantize_deterministic() {
        check("roundtrip-deterministic", 20, |rng, _| {
            let data = gen::weights(rng, 256);
            let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
            prop_assert!(
                simulate_slice(&data, &spec) == simulate_slice(&data, &spec),
                "nondeterministic round trip"
            );
            Ok(())
        });
    }
}
