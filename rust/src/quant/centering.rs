//! Distribution centering (Appendix B) — a documented negative result.
//!
//! Centering subtracts the per-block mean before quantization (Eq. 7) and
//! adds it back on dequantization (Eq. 8). The mechanism is folded into
//! `blockwise::quantize` via `QuantSpec::centering`; this module carries
//! the standalone analysis utilities the Appendix-B ablation bench (E13)
//! uses to show the effect is a wash for near-symmetric weight
//! distributions while costing an extra 16/B bits per parameter.

use super::blockwise::rms_error;
use super::spec::QuantSpec;

/// Compare quantization RMS error with and without centering on one slice.
/// Returns `(plain_rms, centered_rms)`.
pub fn centering_ablation(data: &[f32], spec: &QuantSpec) -> (f64, f64) {
    let plain = QuantSpec { centering: false, ..spec.clone() };
    let centered = QuantSpec { centering: true, ..spec.clone() };
    (rms_error(data, &plain), rms_error(data, &centered))
}

/// Summary statistic for the E13 bench: relative RMS change from centering
/// (< 0 means centering helped) and the bits/param it cost.
pub struct CenteringReport {
    pub plain_rms: f64,
    pub centered_rms: f64,
    pub rel_change: f64,
    pub extra_bits_per_param: f64,
}

pub fn report(data: &[f32], spec: &QuantSpec) -> CenteringReport {
    let (plain_rms, centered_rms) = centering_ablation(data, spec);
    let block = spec.block.unwrap_or(data.len().max(1)) as f64;
    CenteringReport {
        plain_rms,
        centered_rms,
        rel_change: (centered_rms - plain_rms) / plain_rms.max(1e-30),
        extra_bits_per_param: 16.0 / block,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::DataType;
    use crate::util::rng::Rng;

    #[test]
    fn centering_is_a_wash_on_symmetric_weights() {
        // Near-zero-mean weights (what transformer projections look like):
        // centering changes error by only a small relative amount — the
        // Appendix-B negative result.
        let mut rng = Rng::new(8);
        let data: Vec<f32> = (0..8192).map(|_| rng.normal() as f32 * 0.02).collect();
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let r = report(&data, &spec);
        assert!(
            r.rel_change.abs() < 0.15,
            "centering changed symmetric-data RMS by {:.1}%",
            r.rel_change * 100.0
        );
    }

    #[test]
    fn centering_helps_asymmetric_activations() {
        // The case centering was designed for (ReLU-style outputs).
        let mut rng = Rng::new(9);
        let data: Vec<f32> = (0..8192).map(|_| 2.0 + rng.normal().abs() as f32).collect();
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let r = report(&data, &spec);
        assert!(r.centered_rms < r.plain_rms, "{} !< {}", r.centered_rms, r.plain_rms);
    }

    #[test]
    fn report_accounts_extra_bits() {
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let r = report(&[0.5, -0.25, 0.125, 1.0], &spec);
        assert!((r.extra_bits_per_param - 0.25).abs() < 1e-12);
    }
}
