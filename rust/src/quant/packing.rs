//! k-bit index packing: the storage wire format and residency layer.
//!
//! The scaling-law sweep uses simulated quantization (indices stay
//! unpacked), but the *bits on the x-axis* and the serving/latency paths
//! are about real storage: this module packs k-bit codebook indices
//! (3 ≤ k ≤ 8) into a dense little-endian `u32` bitstream and back, plus
//! the two-nibbles-per-byte layout the `packed4` Pallas kernel consumes.
//!
//! [`PackedTensor`] is the first-class **residency format** built on that
//! bitstream: a quantized tensor held as packed indices plus per-block
//! absmax (and means, when centering is on). It converts to/from
//! [`QuantizedTensor`] losslessly, and [`PackedTensor::dequantize_into`]
//! streams f32 weights straight out of the packed words into a
//! caller-owned scratch buffer — the serving layer never materializes an
//! unpacked `Vec<u8>` index copy or keeps duplicate f32 weights alive.

use anyhow::{bail, Context, Result};

use super::blockwise::QuantizedTensor;
use super::codebook::Codebook;
use super::spec::QuantSpec;

/// Densely pack `k`-bit values into a `u32` bitstream (little-endian bit
/// order within and across words).
pub fn pack_bits(idx: &[u8], k: usize) -> Result<Vec<u32>> {
    if !(1..=8).contains(&k) {
        bail!("pack_bits supports 1..=8 bits, got {k}");
    }
    let limit = if k == 8 { 255u16 } else { (1u16 << k) - 1 };
    let words = (idx.len() * k).div_ceil(32);
    let mut out = vec![0u32; words];
    let mut bitpos = 0usize;
    for &v in idx {
        if v as u16 > limit {
            bail!("index {v} does not fit in {k} bits");
        }
        let word = bitpos / 32;
        let off = bitpos % 32;
        out[word] |= (v as u32) << off;
        let spill = off + k;
        if spill > 32 {
            out[word + 1] |= (v as u32) >> (32 - off);
        }
        bitpos += k;
    }
    Ok(out)
}

/// Inverse of [`pack_bits`]; `n` is the original element count.
pub fn unpack_bits(packed: &[u32], k: usize, n: usize) -> Result<Vec<u8>> {
    if !(1..=8).contains(&k) {
        bail!("unpack_bits supports 1..=8 bits, got {k}");
    }
    if packed.len() * 32 < n * k {
        bail!("packed stream too short: {} words for {n} x {k}-bit", packed.len());
    }
    let mask = if k == 8 { 0xFFu32 } else { (1u32 << k) - 1 };
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        out.push(bit_window(packed, bitpos, k, mask) as u8);
        bitpos += k;
    }
    Ok(out)
}

/// Extract the `k`-bit value starting at absolute bit `bitpos` of a
/// little-endian packed word stream — the one bit-window read every
/// decoder in the crate shares ([`unpack_bits`],
/// [`PackedTensor::dequantize_into`], and the fused kernels' scalar and
/// AVX2 span decoders), so their extraction arithmetic cannot diverge.
/// `k <= 8` means a value spans at most two words; callers guarantee the
/// stream covers `bitpos + k` bits (see [`PackedTensor::validate`]).
#[inline(always)]
pub fn bit_window(packed: &[u32], bitpos: usize, k: usize, mask: u32) -> u32 {
    let word = bitpos / 32;
    let off = bitpos % 32;
    let mut v = packed[word] >> off;
    if off + k > 32 {
        v |= packed[word + 1] << (32 - off);
    }
    v & mask
}

/// Pack 4-bit indices two-per-byte along rows of a `(K, N)` index matrix:
/// row `2r` → low nibble, row `2r+1` → high nibble of output row `r`.
/// Mirrors `ref.pack4` for the `packed4` fused kernel.
pub fn pack4_rows(idx: &[u8], rows: usize, cols: usize) -> Result<Vec<u8>> {
    if rows % 2 != 0 || idx.len() != rows * cols {
        bail!("pack4_rows needs even rows ({rows}) and matching len");
    }
    if idx.iter().any(|&v| v > 15) {
        bail!("pack4_rows given indices wider than 4 bits");
    }
    let mut out = vec![0u8; rows / 2 * cols];
    for r in 0..rows / 2 {
        for c in 0..cols {
            let lo = idx[(2 * r) * cols + c];
            let hi = idx[(2 * r + 1) * cols + c];
            out[r * cols + c] = lo | (hi << 4);
        }
    }
    Ok(out)
}

/// Inverse of [`pack4_rows`].
pub fn unpack4_rows(packed: &[u8], half_rows: usize, cols: usize) -> Result<Vec<u8>> {
    if packed.len() != half_rows * cols {
        bail!("unpack4_rows length mismatch");
    }
    let mut out = vec![0u8; half_rows * 2 * cols];
    for r in 0..half_rows {
        for c in 0..cols {
            let b = packed[r * cols + c];
            out[(2 * r) * cols + c] = b & 0xF;
            out[(2 * r + 1) * cols + c] = b >> 4;
        }
    }
    Ok(out)
}

/// Exact storage size in bytes of a packed k-bit stream of `n` indices.
pub fn packed_bytes(n: usize, k: usize) -> usize {
    (n * k).div_ceil(32) * 4
}

/// A quantized tensor in packed k-bit residency form — what a server keeps
/// resident instead of unpacked `u8` indices or dequantized f32 weights.
///
/// Layout mirrors [`QuantizedTensor`] block-for-block; only the index
/// storage differs (dense [`pack_bits`] stream vs one byte per value), so
/// conversion in either direction is exact.
#[derive(Debug, Clone)]
pub struct PackedTensor {
    /// k-bit indices, densely packed little-endian into `u32` words.
    pub packed: Vec<u32>,
    /// Logical element count of the packed stream.
    pub n: usize,
    /// One absmax per block.
    pub absmax: Vec<f32>,
    /// Per-block means when distribution centering is enabled (App. B).
    pub means: Option<Vec<f32>>,
    pub block: usize,
    pub codebook: Codebook,
    pub bits: usize,
}

impl PackedTensor {
    /// Quantize a slice under `spec` directly into packed residency form.
    /// The intermediate unpacked index vector is dropped before returning.
    pub fn quantize(data: &[f32], spec: &QuantSpec) -> Result<PackedTensor> {
        if spec.is_baseline() {
            bail!("baseline (>=16-bit) specs have no packed representation");
        }
        PackedTensor::from_quantized(&super::blockwise::quantize(data, spec))
    }

    /// Pack an unpacked [`QuantizedTensor`].
    pub fn from_quantized(q: &QuantizedTensor) -> Result<PackedTensor> {
        Ok(PackedTensor {
            packed: pack_bits(&q.idx, q.bits)?,
            n: q.idx.len(),
            absmax: q.absmax.clone(),
            means: q.means.clone(),
            block: q.block,
            codebook: q.codebook.clone(),
            bits: q.bits,
        })
    }

    /// Inverse of [`PackedTensor::from_quantized`]; exact.
    pub fn unpack(&self) -> Result<QuantizedTensor> {
        Ok(QuantizedTensor {
            idx: unpack_bits(&self.packed, self.bits, self.n)?,
            absmax: self.absmax.clone(),
            means: self.means.clone(),
            block: self.block,
            codebook: self.codebook.clone(),
            bits: self.bits,
        })
    }

    /// Check the cross-field invariants every bitstream decoder relies on.
    ///
    /// `PackedTensor` fields are public (serving and test code builds them
    /// directly), so a decoder cannot assume they are mutually consistent:
    /// a hand-built or corrupted tensor must surface as an error from the
    /// decode entry points — never a panic, out-of-bounds index, or
    /// divide-by-zero on a serving thread.
    pub fn validate(&self) -> Result<()> {
        if !(1..=8).contains(&self.bits) {
            bail!("unsupported bit width {} (want 1..=8)", self.bits);
        }
        if self.block == 0 {
            bail!("block size must be >= 1");
        }
        let blocks = self.n.div_ceil(self.block);
        if self.absmax.len() != blocks {
            bail!(
                "absmax has {} entries; {} elements in blocks of {} need {}",
                self.absmax.len(),
                self.n,
                self.block,
                blocks
            );
        }
        if let Some(m) = &self.means {
            if m.len() != blocks {
                bail!("means has {} entries for {} blocks", m.len(), blocks);
            }
        }
        let need = self
            .n
            .checked_mul(self.bits)
            .with_context(|| format!("bitstream length overflows: {} x {}-bit", self.n, self.bits))?;
        if self.packed.len().saturating_mul(32) < need {
            bail!(
                "packed stream too short: {} words for {} x {}-bit",
                self.packed.len(),
                self.n,
                self.bits
            );
        }
        Ok(())
    }

    /// Streaming dequantize: decode k-bit indices word-by-word straight
    /// into `out` (length must equal `self.n`) without materializing the
    /// unpacked index vector. `out` is typically a reusable scratch buffer
    /// owned by the caller.
    pub fn dequantize_into(&self, out: &mut [f32]) -> Result<()> {
        if out.len() != self.n {
            bail!("dequantize_into: buffer len {} != element count {}", out.len(), self.n);
        }
        self.validate()?;
        let values = self.codebook.values();
        let k = self.bits;
        let mask = if k >= 8 { 0xFFu32 } else { (1u32 << k) - 1 };
        let mut bitpos = 0usize;
        for b in 0..self.absmax.len() {
            let lo = b * self.block;
            let hi = (lo + self.block).min(self.n);
            let amax = self.absmax[b];
            let mean = self.means.as_ref().map_or(0.0, |m| m[b]);
            for o in out[lo..hi].iter_mut() {
                // Codebooks may hold fewer than 2^k values (int codebooks
                // drop one), so a corrupt bitstream can encode an index
                // past the table: reject it, don't index past the slice.
                let idx = bit_window(&self.packed, bitpos, k, mask) as usize;
                let Some(&val) = values.get(idx) else {
                    bail!("bitstream index {idx} out of range for {}-entry codebook", values.len());
                };
                *o = val * amax + mean;
                bitpos += k;
            }
        }
        Ok(())
    }

    /// Bytes of the packed index stream alone (word granularity).
    pub fn packed_index_bytes(&self) -> usize {
        self.packed.len() * 4
    }

    /// Total resident bytes: packed indices + per-block constants. This is
    /// the quantity `{"op":"info"}` reports and the serve bench compares
    /// against the f32 footprint.
    pub fn resident_bytes(&self) -> usize {
        self.packed_index_bytes()
            + self.absmax.len() * 4
            + self.means.as_ref().map_or(0, |m| m.len() * 4)
    }

    /// Measured total bits this tensor stores: the exact `n * k` index
    /// payload (no u32 word padding) plus 32 bits per stored f32 block
    /// constant — the honest counterpart of the paper-ideal
    /// [`super::bitcost::bits_per_param`] accounting, and the uncoded
    /// baseline `quant::entropy` measures its coded streams against.
    pub fn measured_bits(&self) -> u64 {
        self.n as u64 * self.bits as u64
            + 32 * (self.absmax.len() as u64
                + self.means.as_ref().map_or(0, |m| m.len() as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    #[test]
    fn roundtrip_all_widths() {
        for k in 1..=8usize {
            let limit = (1u16 << k).min(256) as usize;
            let idx: Vec<u8> = (0..1000).map(|i| (i % limit) as u8).collect();
            let packed = pack_bits(&idx, k).unwrap();
            assert_eq!(packed.len(), (1000 * k).div_ceil(32));
            let back = unpack_bits(&packed, k, 1000).unwrap();
            assert_eq!(back, idx, "k={k}");
        }
    }

    #[test]
    fn pack_rejects_overwide_values() {
        assert!(pack_bits(&[8], 3).is_err());
        assert!(pack_bits(&[7], 3).is_ok());
    }

    #[test]
    fn bit_window_crosses_word_boundaries() {
        // k=3 doesn't divide 32, so every ~10th element straddles a word
        // boundary (element 10 spans bits 30..33); all must read back.
        let idx: Vec<u8> = (0..40).map(|i| (i % 8) as u8).collect();
        let packed = pack_bits(&idx, 3).unwrap();
        for (i, &v) in idx.iter().enumerate() {
            assert_eq!(bit_window(&packed, i * 3, 3, 0b111), v as u32, "elem {i}");
        }
    }

    #[test]
    fn unpack_rejects_short_streams() {
        assert!(unpack_bits(&[0u32], 8, 5).is_err());
    }

    #[test]
    fn pack4_rows_matches_python_layout() {
        // 4x2 matrix, distinct nibbles.
        let idx = [1u8, 2, 3, 4, 5, 6, 7, 8];
        let packed = pack4_rows(&idx, 4, 2).unwrap();
        // row0=[1,2] row1=[3,4] -> out row0 = [1|3<<4, 2|4<<4]
        assert_eq!(packed, vec![0x31, 0x42, 0x75, 0x86]);
        assert_eq!(unpack4_rows(&packed, 2, 2).unwrap(), idx);
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        check("pack-roundtrip", 50, |rng, _| {
            let k = 1 + rng.below(8);
            let n = 1 + rng.below(2000);
            let limit = (1usize << k).min(256);
            let idx: Vec<u8> = (0..n).map(|_| rng.below(limit) as u8).collect();
            let back = unpack_bits(&pack_bits(&idx, k).unwrap(), k, n).unwrap();
            prop_assert!(back == idx, "k={k} n={n} roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn packed_bytes_accounting() {
        assert_eq!(packed_bytes(64, 4), 32);
        assert_eq!(packed_bytes(64, 3), 24);
        assert_eq!(packed_bytes(1, 3), 4); // word granularity
    }

    #[test]
    fn prop_packed_tensor_roundtrip_exact() {
        use crate::quant::blockwise::{dequantize, quantize};
        use crate::quant::codebook::DataType;
        use crate::quant::spec::QuantSpec;
        use crate::util::proptest::gen;

        // Exhaustive (bits 3..=8) x (block 32|64|4096|None) grid, two
        // random lengths per combination so ragged tail blocks (n not a
        // multiple of the block) and sub-block tensors are both hit.
        const BLOCKS: [Option<usize>; 4] = [Some(32), Some(64), Some(4096), None];
        check("packed-tensor-roundtrip", 48, |rng, case| {
            let bits = 3 + case % 6;
            let block = BLOCKS[(case / 6) % 4];
            let data = gen::weights(rng, 9000);
            let n = data.len();
            let mut spec = QuantSpec::new(DataType::ALL[rng.below(4)], bits, block);
            if rng.below(2) == 0 {
                spec = spec.with_centering();
            }
            let q = quantize(&data, &spec);
            let p = q.pack().map_err(|e| format!("pack: {e:#}"))?;
            let back = p.unpack().map_err(|e| format!("unpack: {e:#}"))?;
            prop_assert!(
                back.idx == q.idx && back.absmax == q.absmax && back.means == q.means,
                "bits={bits} block={block:?} n={n}: pack→unpack not exact"
            );
            let mut d_ref = vec![0.0f32; n];
            dequantize(&q, &mut d_ref);
            let mut d_packed = vec![0.0f32; n];
            p.dequantize_into(&mut d_packed).map_err(|e| format!("dequantize_into: {e:#}"))?;
            prop_assert!(
                d_ref == d_packed,
                "bits={bits} block={block:?} n={n}: streaming dequant != reference"
            );
            prop_assert!(
                p.packed_index_bytes() == packed_bytes(n, bits),
                "bits={bits} n={n}: packed byte accounting off"
            );
            Ok(())
        });
    }

    #[test]
    fn packed_tensor_rejects_baseline_and_bad_buffers() {
        use crate::quant::blockwise::quantize;
        use crate::quant::codebook::DataType;
        use crate::quant::spec::QuantSpec;

        assert!(PackedTensor::quantize(&[1.0, 2.0], &QuantSpec::baseline16()).is_err());
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let p = PackedTensor::from_quantized(&quantize(&[1.0f32; 100], &spec)).unwrap();
        let mut short = vec![0.0f32; 99];
        assert!(p.dequantize_into(&mut short).is_err());
    }
}
