//! k-bit index packing: the storage wire format.
//!
//! The scaling-law sweep uses simulated quantization (indices stay
//! unpacked), but the *bits on the x-axis* and the fused-kernel latency
//! path are about real storage: this module packs k-bit codebook indices
//! (3 ≤ k ≤ 8) into a dense little-endian `u32` bitstream and back, plus
//! the two-nibbles-per-byte layout the `packed4` Pallas kernel consumes.

use anyhow::{bail, Result};

/// Densely pack `k`-bit values into a `u32` bitstream (little-endian bit
/// order within and across words).
pub fn pack_bits(idx: &[u8], k: usize) -> Result<Vec<u32>> {
    if !(1..=8).contains(&k) {
        bail!("pack_bits supports 1..=8 bits, got {k}");
    }
    let limit = if k == 8 { 255u16 } else { (1u16 << k) - 1 };
    let words = (idx.len() * k).div_ceil(32);
    let mut out = vec![0u32; words];
    let mut bitpos = 0usize;
    for &v in idx {
        if v as u16 > limit {
            bail!("index {v} does not fit in {k} bits");
        }
        let word = bitpos / 32;
        let off = bitpos % 32;
        out[word] |= (v as u32) << off;
        let spill = off + k;
        if spill > 32 {
            out[word + 1] |= (v as u32) >> (32 - off);
        }
        bitpos += k;
    }
    Ok(out)
}

/// Inverse of [`pack_bits`]; `n` is the original element count.
pub fn unpack_bits(packed: &[u32], k: usize, n: usize) -> Result<Vec<u8>> {
    if !(1..=8).contains(&k) {
        bail!("unpack_bits supports 1..=8 bits, got {k}");
    }
    if packed.len() * 32 < n * k {
        bail!("packed stream too short: {} words for {n} x {k}-bit", packed.len());
    }
    let mask = if k == 8 { 0xFFu32 } else { (1u32 << k) - 1 };
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let word = bitpos / 32;
        let off = bitpos % 32;
        let mut v = packed[word] >> off;
        if off + k > 32 {
            v |= packed[word + 1] << (32 - off);
        }
        out.push((v & mask) as u8);
        bitpos += k;
    }
    Ok(out)
}

/// Pack 4-bit indices two-per-byte along rows of a `(K, N)` index matrix:
/// row `2r` → low nibble, row `2r+1` → high nibble of output row `r`.
/// Mirrors `ref.pack4` for the `packed4` fused kernel.
pub fn pack4_rows(idx: &[u8], rows: usize, cols: usize) -> Result<Vec<u8>> {
    if rows % 2 != 0 || idx.len() != rows * cols {
        bail!("pack4_rows needs even rows ({rows}) and matching len");
    }
    if idx.iter().any(|&v| v > 15) {
        bail!("pack4_rows given indices wider than 4 bits");
    }
    let mut out = vec![0u8; rows / 2 * cols];
    for r in 0..rows / 2 {
        for c in 0..cols {
            let lo = idx[(2 * r) * cols + c];
            let hi = idx[(2 * r + 1) * cols + c];
            out[r * cols + c] = lo | (hi << 4);
        }
    }
    Ok(out)
}

/// Inverse of [`pack4_rows`].
pub fn unpack4_rows(packed: &[u8], half_rows: usize, cols: usize) -> Result<Vec<u8>> {
    if packed.len() != half_rows * cols {
        bail!("unpack4_rows length mismatch");
    }
    let mut out = vec![0u8; half_rows * 2 * cols];
    for r in 0..half_rows {
        for c in 0..cols {
            let b = packed[r * cols + c];
            out[(2 * r) * cols + c] = b & 0xF;
            out[(2 * r + 1) * cols + c] = b >> 4;
        }
    }
    Ok(out)
}

/// Exact storage size in bytes of a packed k-bit stream of `n` indices.
pub fn packed_bytes(n: usize, k: usize) -> usize {
    (n * k).div_ceil(32) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    #[test]
    fn roundtrip_all_widths() {
        for k in 1..=8usize {
            let limit = (1u16 << k).min(256) as usize;
            let idx: Vec<u8> = (0..1000).map(|i| (i % limit) as u8).collect();
            let packed = pack_bits(&idx, k).unwrap();
            assert_eq!(packed.len(), (1000 * k).div_ceil(32));
            let back = unpack_bits(&packed, k, 1000).unwrap();
            assert_eq!(back, idx, "k={k}");
        }
    }

    #[test]
    fn pack_rejects_overwide_values() {
        assert!(pack_bits(&[8], 3).is_err());
        assert!(pack_bits(&[7], 3).is_ok());
    }

    #[test]
    fn unpack_rejects_short_streams() {
        assert!(unpack_bits(&[0u32], 8, 5).is_err());
    }

    #[test]
    fn pack4_rows_matches_python_layout() {
        // 4x2 matrix, distinct nibbles.
        let idx = [1u8, 2, 3, 4, 5, 6, 7, 8];
        let packed = pack4_rows(&idx, 4, 2).unwrap();
        // row0=[1,2] row1=[3,4] -> out row0 = [1|3<<4, 2|4<<4]
        assert_eq!(packed, vec![0x31, 0x42, 0x75, 0x86]);
        assert_eq!(unpack4_rows(&packed, 2, 2).unwrap(), idx);
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        check("pack-roundtrip", 50, |rng, _| {
            let k = 1 + rng.below(8);
            let n = 1 + rng.below(2000);
            let limit = (1usize << k).min(256);
            let idx: Vec<u8> = (0..n).map(|_| rng.below(limit) as u8).collect();
            let back = unpack_bits(&pack_bits(&idx, k).unwrap(), k, n).unwrap();
            prop_assert!(back == idx, "k={k} n={n} roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn packed_bytes_accounting() {
        assert_eq!(packed_bytes(64, 4), 32);
        assert_eq!(packed_bytes(64, 3), 24);
        assert_eq!(packed_bytes(1, 3), 4); // word granularity
    }
}
