//! [`QuantSpec`]: the full description of one quantization configuration —
//! one cell of the paper's 35,000-experiment grid.

use anyhow::Result;

use super::codebook::{Codebook, DataType};

/// Everything the paper varies about zero-shot quantization.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    pub dtype: DataType,
    /// Bit width `k` (3..=8). `16` means the unquantized baseline.
    pub bits: usize,
    /// Block size for block-wise quantization; `None` = tensor-wise (one
    /// absmax for the whole tensor, the paper's "no blocking" case).
    pub block: Option<usize>,
    /// Float exponent bits (Fp only; `None` = paper default heuristic).
    pub exponent_bits: Option<usize>,
    /// Distribution centering (Appendix B; a negative result).
    pub centering: bool,
    /// Outlier-dependent proxy quantization: keep this fraction of input
    /// dimensions in 16-bit, selected by previous-layer weight std (Eq. 2).
    pub proxy_outlier_pct: Option<f64>,
}

impl QuantSpec {
    pub fn new(dtype: DataType, bits: usize, block: Option<usize>) -> Self {
        QuantSpec {
            dtype,
            bits,
            block,
            exponent_bits: None,
            centering: false,
            proxy_outlier_pct: None,
        }
    }

    /// The unquantized 16-bit reference point of every scaling plot.
    pub fn baseline16() -> Self {
        QuantSpec::new(DataType::Fp, 16, None)
    }

    pub fn with_exponent_bits(mut self, e: usize) -> Self {
        self.exponent_bits = Some(e);
        self
    }

    pub fn with_centering(mut self) -> Self {
        self.centering = true;
        self
    }

    pub fn with_proxy(mut self, pct: f64) -> Self {
        self.proxy_outlier_pct = Some(pct);
        self
    }

    pub fn is_baseline(&self) -> bool {
        self.bits >= 16
    }

    pub fn codebook(&self) -> Result<Codebook> {
        Codebook::build(self.dtype, self.bits, self.exponent_bits)
    }

    /// Stable cell-key string; the results store hashes this (together with
    /// model identity) to cache sweep cells across benches and reruns.
    pub fn key(&self) -> String {
        let block = self.block.map(|b| b.to_string()).unwrap_or_else(|| "none".into());
        let mut s = format!("{}:{}:b{}", self.dtype.name(), self.bits, block);
        if let Some(e) = self.exponent_bits {
            s.push_str(&format!(":e{e}"));
        }
        if self.centering {
            s.push_str(":c");
        }
        if let Some(p) = self.proxy_outlier_pct {
            s.push_str(&format!(":p{p}"));
        }
        s
    }
}

impl std::fmt::Display for QuantSpec {
    /// `Display` == `key()`: the stable cell-key is also the human-readable
    /// form used in logs and figure legends.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.key())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_unique_per_config() {
        let a = QuantSpec::new(DataType::Int, 4, Some(64));
        let b = QuantSpec::new(DataType::Fp, 4, Some(64));
        let c = QuantSpec::new(DataType::Int, 4, Some(128));
        let d = QuantSpec::new(DataType::Int, 4, None);
        let e = QuantSpec::new(DataType::Int, 4, Some(64)).with_centering();
        let f = QuantSpec::new(DataType::Int, 4, Some(64)).with_proxy(0.02);
        let g = QuantSpec::new(DataType::Fp, 4, Some(64)).with_exponent_bits(2);
        let keys: Vec<String> = [a, b, c, d, e, f, g].iter().map(|s| s.key()).collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len(), "{keys:?}");
    }

    #[test]
    fn baseline_detection() {
        assert!(QuantSpec::baseline16().is_baseline());
        assert!(!QuantSpec::new(DataType::Int, 8, None).is_baseline());
    }

    #[test]
    fn display_matches_key() {
        let s = QuantSpec::new(DataType::Quantile, 3, Some(64)).with_proxy(0.02);
        assert_eq!(format!("{s}"), s.key());
    }
}
