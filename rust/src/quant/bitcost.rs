//! Bits-per-parameter accounting — the x-axis of every scaling plot.
//!
//! Section 5.2 of the paper: a block size `B` with 16-bit normalization
//! constants costs `16 / B` extra bits per parameter; centering adds
//! another `16 / B`; proxy quantization with outlier fraction `p` costs
//! `p * (16 - k)` extra bits. Unquantized tensors (embeddings, LayerNorm)
//! count at 16 bits per parameter.

use super::spec::QuantSpec;

/// Effective bits per parameter of `spec` applied to a weight tensor.
pub fn bits_per_param(spec: &QuantSpec) -> f64 {
    if spec.is_baseline() {
        return 16.0;
    }
    let mut bits = spec.bits as f64;
    if let Some(b) = spec.block {
        bits += 16.0 / b as f64; // absmax constant
        if spec.centering {
            bits += 16.0 / b as f64; // per-block mean
        }
    } else if spec.centering {
        // Tensor-wise constants amortize to ~0 for any real tensor size;
        // keep a tiny epsilon so centering is never free on paper.
        bits += 1e-6;
    }
    if let Some(p) = spec.proxy_outlier_pct {
        bits += p * (16.0 - spec.bits as f64);
    }
    bits
}

/// Bits per parameter a packed variant actually *stores*, as opposed to
/// the paper-ideal [`bits_per_param`]: block constants are held as `f32`
/// (32 bits each), not the 16-bit figure the paper accounts, so honest
/// total-bits for an uncoded packed entry is `k + 32/B` (+ `32/B` when
/// centered). Proxy and baseline specs have no packed form and keep the
/// analytic accounting.
pub fn stored_bits_per_param(spec: &QuantSpec) -> f64 {
    if spec.is_baseline() || spec.proxy_outlier_pct.is_some() {
        return bits_per_param(spec);
    }
    let mut bits = spec.bits as f64;
    if let Some(b) = spec.block {
        bits += 32.0 / b as f64; // absmax stored as f32
        if spec.centering {
            bits += 32.0 / b as f64; // per-block mean stored as f32
        }
    } else if spec.centering {
        bits += 1e-6;
    }
    bits
}

/// Shannon lower bound, in bits, of an index stream with histogram `hist`
/// (`hist[s]` = occurrences of symbol `s`): `Σ h · log2(n/h)`. This is the
/// floor any entropy coder (`quant::entropy`) can approach but not beat;
/// `{"op":"stats"}` reports it next to the coded and nominal bits so the
/// gap to the bound is observable per variant.
pub fn index_entropy_bits(hist: &[u64]) -> f64 {
    let n: u64 = hist.iter().sum();
    if n == 0 {
        return 0.0;
    }
    hist.iter()
        .filter(|&&h| h > 0)
        .map(|&h| h as f64 * (n as f64 / h as f64).log2())
        .sum()
}

/// Total model bits for a checkpoint: quantized tensors at
/// `bits_per_param(spec)`, everything else at 16.
pub fn total_model_bits(
    param_sizes: &[(String, usize)],
    quantized_names: &[String],
    spec: &QuantSpec,
) -> f64 {
    let bpp = bits_per_param(spec);
    param_sizes
        .iter()
        .map(|(name, n)| {
            if quantized_names.iter().any(|q| q == name) {
                bpp * *n as f64
            } else {
                16.0 * *n as f64
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::DataType;

    #[test]
    fn paper_examples() {
        // "a block size of 64 ... 16/64 = 0.25 additional bits" (§5.2)
        let s = QuantSpec::new(DataType::Fp, 4, Some(64));
        assert!((bits_per_param(&s) - 4.25).abs() < 1e-12);
        // "for p=0.02 and k=4, the additional memory footprint is 0.24 bits"
        let s = QuantSpec::new(DataType::Fp, 4, None).with_proxy(0.02);
        assert!((bits_per_param(&s) - 4.24).abs() < 1e-12);
        // Both combined.
        let s = QuantSpec::new(DataType::Fp, 4, Some(64)).with_proxy(0.02);
        assert!((bits_per_param(&s) - 4.49).abs() < 1e-12);
    }

    #[test]
    fn baseline_is_16() {
        assert_eq!(bits_per_param(&QuantSpec::baseline16()), 16.0);
    }

    #[test]
    fn centering_doubles_block_overhead() {
        let plain = QuantSpec::new(DataType::Int, 4, Some(64));
        let centered = plain.clone().with_centering();
        assert!((bits_per_param(&centered) - bits_per_param(&plain) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn blocksize_ordering() {
        // Smaller blocks -> more bits, monotone (Figure 3's x-offsets).
        let mut prev = f64::INFINITY;
        for b in [16usize, 64, 128, 256, 1024] {
            let bits = bits_per_param(&QuantSpec::new(DataType::Int, 4, Some(b)));
            assert!(bits < prev);
            prev = bits;
        }
    }

    #[test]
    fn stored_bits_charge_f32_side_channels() {
        // Stored accounting doubles the paper's 16-bit block-constant
        // figure: fp4/b64 stores 4 + 32/64 = 4.5 bits/param.
        let s = QuantSpec::new(DataType::Fp, 4, Some(64));
        assert!((stored_bits_per_param(&s) - 4.5).abs() < 1e-12);
        let c = s.clone().with_centering();
        assert!((stored_bits_per_param(&c) - 5.0).abs() < 1e-12);
        // Baseline/proxy fall back to the analytic figure.
        assert_eq!(stored_bits_per_param(&QuantSpec::baseline16()), 16.0);
        let p = QuantSpec::new(DataType::Fp, 4, None).with_proxy(0.02);
        assert_eq!(stored_bits_per_param(&p), bits_per_param(&p));
    }

    #[test]
    fn index_entropy_matches_closed_forms() {
        // Uniform over 16 symbols: exactly 4 bits/symbol.
        let hist = vec![8u64; 16];
        assert!((index_entropy_bits(&hist) - 4.0 * 128.0).abs() < 1e-9);
        // Single symbol: zero bits (and the empty stream is zero, not NaN).
        assert_eq!(index_entropy_bits(&[42, 0, 0, 0]), 0.0);
        assert_eq!(index_entropy_bits(&[]), 0.0);
        // Fair coin: 1 bit/symbol.
        assert!((index_entropy_bits(&[5, 5]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn total_bits_mixes_quantized_and_not() {
        let sizes = vec![("embed".to_string(), 100usize), ("qkv".to_string(), 100)];
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let total = total_model_bits(&sizes, &["qkv".to_string()], &spec);
        assert!((total - (16.0 * 100.0 + 4.25 * 100.0)).abs() < 1e-9);
    }
}
