//! Bits-per-parameter accounting — the x-axis of every scaling plot.
//!
//! Section 5.2 of the paper: a block size `B` with 16-bit normalization
//! constants costs `16 / B` extra bits per parameter; centering adds
//! another `16 / B`; proxy quantization with outlier fraction `p` costs
//! `p * (16 - k)` extra bits. Unquantized tensors (embeddings, LayerNorm)
//! count at 16 bits per parameter.

use super::spec::QuantSpec;

/// Effective bits per parameter of `spec` applied to a weight tensor.
pub fn bits_per_param(spec: &QuantSpec) -> f64 {
    if spec.is_baseline() {
        return 16.0;
    }
    let mut bits = spec.bits as f64;
    if let Some(b) = spec.block {
        bits += 16.0 / b as f64; // absmax constant
        if spec.centering {
            bits += 16.0 / b as f64; // per-block mean
        }
    } else if spec.centering {
        // Tensor-wise constants amortize to ~0 for any real tensor size;
        // keep a tiny epsilon so centering is never free on paper.
        bits += 1e-6;
    }
    if let Some(p) = spec.proxy_outlier_pct {
        bits += p * (16.0 - spec.bits as f64);
    }
    bits
}

/// Total model bits for a checkpoint: quantized tensors at
/// `bits_per_param(spec)`, everything else at 16.
pub fn total_model_bits(
    param_sizes: &[(String, usize)],
    quantized_names: &[String],
    spec: &QuantSpec,
) -> f64 {
    let bpp = bits_per_param(spec);
    param_sizes
        .iter()
        .map(|(name, n)| {
            if quantized_names.iter().any(|q| q == name) {
                bpp * *n as f64
            } else {
                16.0 * *n as f64
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::DataType;

    #[test]
    fn paper_examples() {
        // "a block size of 64 ... 16/64 = 0.25 additional bits" (§5.2)
        let s = QuantSpec::new(DataType::Fp, 4, Some(64));
        assert!((bits_per_param(&s) - 4.25).abs() < 1e-12);
        // "for p=0.02 and k=4, the additional memory footprint is 0.24 bits"
        let s = QuantSpec::new(DataType::Fp, 4, None).with_proxy(0.02);
        assert!((bits_per_param(&s) - 4.24).abs() < 1e-12);
        // Both combined.
        let s = QuantSpec::new(DataType::Fp, 4, Some(64)).with_proxy(0.02);
        assert!((bits_per_param(&s) - 4.49).abs() < 1e-12);
    }

    #[test]
    fn baseline_is_16() {
        assert_eq!(bits_per_param(&QuantSpec::baseline16()), 16.0);
    }

    #[test]
    fn centering_doubles_block_overhead() {
        let plain = QuantSpec::new(DataType::Int, 4, Some(64));
        let centered = plain.clone().with_centering();
        assert!((bits_per_param(&centered) - bits_per_param(&plain) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn blocksize_ordering() {
        // Smaller blocks -> more bits, monotone (Figure 3's x-offsets).
        let mut prev = f64::INFINITY;
        for b in [16usize, 64, 128, 256, 1024] {
            let bits = bits_per_param(&QuantSpec::new(DataType::Int, 4, Some(b)));
            assert!(bits < prev);
            prev = bits;
        }
    }

    #[test]
    fn total_bits_mixes_quantized_and_not() {
        let sizes = vec![("embed".to_string(), 100usize), ("qkv".to_string(), 100)];
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let total = total_model_bits(&sizes, &["qkv".to_string()], &spec);
        assert!((total - (16.0 * 100.0 + 4.25 * 100.0)).abs() < 1e-9);
    }
}
