//! Outlier-dependent quantization through **proxy quantization** (§3).
//!
//! Emergent outlier features make some hidden dimensions carry values up
//! to 20x larger than the rest; at 3-bit they destroy the quantization of
//! every block they touch. Proxy quantization is the paper's
//! input-independent fix: use the **standard deviation of each hidden
//! unit's weights in the previous layer** (Eq. 2) as a proxy for which
//! *input* dimensions of the next layer host outlier features, and keep
//! the top `p`% of those input rows in 16-bit while quantizing the rest to
//! k-bit. Cost: `p * (16 - k)` extra bits/param (`bitcost`).
//!
//! Wiring for this repo's stacked parameter layout (per transformer block,
//! residual width `d`, FFN width `f = 4d`):
//!
//! * `qkv[l]`, `fc1[l]` read the residual stream → proxy stds come from
//!   the previous block's residual writers (`wo[l-1]`, `fc2[l-1]` column
//!   stds, elementwise max), or the embedding column stds for block 0.
//! * `wo[l]` reads the attention context → proxy stds from the
//!   V-projection columns of `qkv[l]`.
//! * `fc2[l]` reads the FFN activation → proxy stds from `fc1[l]` columns.

use std::collections::BTreeMap;

use crate::tensor::Tensor;

use super::blockwise::{dequantize, quantize};
use super::spec::QuantSpec;

/// Per-column standard deviation of a row-major `(rows, cols)` matrix.
pub fn column_stds(data: &[f32], rows: usize, cols: usize) -> Vec<f64> {
    assert_eq!(data.len(), rows * cols);
    let mut mean = vec![0.0f64; cols];
    for r in 0..rows {
        for c in 0..cols {
            mean[c] += data[r * cols + c] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= rows as f64;
    }
    let mut var = vec![0.0f64; cols];
    for r in 0..rows {
        for c in 0..cols {
            let d = data[r * cols + c] as f64 - mean[c];
            var[c] += d * d;
        }
    }
    var.into_iter().map(|v| (v / rows as f64).sqrt()).collect()
}

/// Indices of the top `ceil(pct * n)` entries by value.
pub fn top_pct_indices(scores: &[f64], pct: f64) -> Vec<usize> {
    let k = ((scores.len() as f64 * pct).ceil() as usize).min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut out = order[..k].to_vec();
    out.sort_unstable();
    out
}

/// Standalone-tensor fallback: flag the top `pct` input rows by the
/// tensor's own column std mapped back onto rows via magnitude. Used when
/// a tensor is quantized outside a checkpoint context.
pub fn column_outliers_by_std(t: &Tensor, pct: f64) -> Vec<usize> {
    let shape = t.shape();
    let (rows, cols) = match shape.len() {
        2 => (shape[0], shape[1]),
        3 => (shape[1], shape[2]),
        _ => return Vec::new(),
    };
    // Row scores: per-row max |w| (a row hosting outliers has large values).
    let data = &t.data()[..rows * cols];
    let scores: Vec<f64> = (0..rows)
        .map(|r| {
            data[r * cols..(r + 1) * cols]
                .iter()
                .fold(0.0f64, |acc, &x| acc.max(x.abs() as f64))
        })
        .collect();
    top_pct_indices(&scores, pct)
}

/// Quantize a `(rows, cols)` matrix slice keeping `outlier_rows` in 16-bit.
///
/// Outlier rows are excluded from the quantization path entirely (they do
/// not pollute block absmax values) and restored verbatim afterwards —
/// the "quantize weights to higher precision for outlier dimensions"
/// mechanism of §3.
pub fn simulate_mixed_slice(
    data: &[f32],
    _rows: usize,
    cols: usize,
    spec: &QuantSpec,
    outlier_rows: &[usize],
) -> Vec<f32> {
    let mut masked = data.to_vec();
    for &r in outlier_rows {
        masked[r * cols..(r + 1) * cols].fill(0.0);
    }
    let base = QuantSpec { proxy_outlier_pct: None, ..spec.clone() };
    let q = quantize(&masked, &base);
    let mut out = vec![0.0f32; data.len()];
    dequantize(&q, &mut out);
    for &r in outlier_rows {
        out[r * cols..(r + 1) * cols].copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
    out
}

/// [`simulate_mixed_slice`] over a whole tensor (rank 2, or rank 3 with
/// the same outlier set per layer slice).
pub fn simulate_mixed(t: &Tensor, spec: &QuantSpec, outlier_rows: &[usize]) -> Tensor {
    let shape = t.shape().to_vec();
    match shape.len() {
        2 => {
            let out = simulate_mixed_slice(t.data(), shape[0], shape[1], spec, outlier_rows);
            Tensor::new(shape, out)
        }
        3 => {
            let (l, r, c) = (shape[0], shape[1], shape[2]);
            let per = r * c;
            let mut out = vec![0.0f32; t.len()];
            for li in 0..l {
                let s = simulate_mixed_slice(&t.data()[li * per..(li + 1) * per], r, c, spec, outlier_rows);
                out[li * per..(li + 1) * per].copy_from_slice(&s);
            }
            Tensor::new(shape, out)
        }
        _ => t.clone(),
    }
}

/// Full checkpoint proxy quantization with the §3 wiring described in the
/// module docs. `quantized_names` must include the four projections.
pub fn quantize_checkpoint_proxy(
    params: &[(String, Tensor)],
    quantized_names: &[String],
    spec: &QuantSpec,
) -> Vec<(String, Tensor)> {
    let pct = spec.proxy_outlier_pct.unwrap_or(0.0);
    let by_name: BTreeMap<&str, &Tensor> =
        params.iter().map(|(n, t)| (n.as_str(), t)).collect();

    // Fall back to per-tensor magnitude proxies if the checkpoint does not
    // carry the expected transformer layout.
    let (Some(embed), Some(qkv), Some(wo), Some(fc1), Some(fc2)) = (
        by_name.get("embed"),
        by_name.get("qkv"),
        by_name.get("wo"),
        by_name.get("fc1"),
        by_name.get("fc2"),
    ) else {
        return params
            .iter()
            .map(|(name, t)| {
                if quantized_names.iter().any(|q| q == name) {
                    let idx = column_outliers_by_std(t, pct);
                    (name.clone(), simulate_mixed(t, spec, &idx))
                } else {
                    (name.clone(), t.clone())
                }
            })
            .collect();
    };

    let l = qkv.shape()[0];
    let d = qkv.shape()[1];
    let f = fc1.shape()[2];
    let (vocab, _) = embed.dims2().expect("embed is rank 2");

    // Residual-stream outlier dims per block boundary.
    let embed_stds = column_stds(embed.data(), vocab, d);
    let mut resid_outliers: Vec<Vec<usize>> = Vec::with_capacity(l);
    for li in 0..l {
        let stds = if li == 0 {
            embed_stds.clone()
        } else {
            let per_wo = d * d;
            let per_fc2 = f * d;
            let wo_stds = column_stds(&wo.data()[(li - 1) * per_wo..li * per_wo], d, d);
            let fc2_stds = column_stds(&fc2.data()[(li - 1) * per_fc2..li * per_fc2], f, d);
            wo_stds
                .iter()
                .zip(&fc2_stds)
                .map(|(a, b)| a.max(*b))
                .collect()
        };
        resid_outliers.push(top_pct_indices(&stds, pct));
    }

    let mut out = Vec::with_capacity(params.len());
    for (name, t) in params {
        if !quantized_names.iter().any(|q| q == name) {
            out.push((name.clone(), t.clone()));
            continue;
        }
        let shape = t.shape().to_vec();
        let per = shape[1] * shape[2];
        let mut data = vec![0.0f32; t.len()];
        for li in 0..l {
            let slice = &t.data()[li * per..(li + 1) * per];
            let rows_set: Vec<usize> = match name.as_str() {
                "qkv" | "fc1" => resid_outliers[li].clone(),
                "wo" => {
                    // V-projection columns of qkv[l] are cols 2d..3d.
                    let per_qkv = d * 3 * d;
                    let stds = column_stds(&qkv.data()[li * per_qkv..(li + 1) * per_qkv], d, 3 * d);
                    top_pct_indices(&stds[2 * d..3 * d], pct)
                }
                "fc2" => {
                    let per_fc1 = d * f;
                    let stds = column_stds(&fc1.data()[li * per_fc1..(li + 1) * per_fc1], d, f);
                    top_pct_indices(&stds, pct)
                }
                _ => Vec::new(),
            };
            let s = simulate_mixed_slice(slice, shape[1], shape[2], spec, &rows_set);
            data[li * per..(li + 1) * per].copy_from_slice(&s);
        }
        out.push((name.clone(), Tensor::new(shape, data)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::DataType;
    use crate::util::rng::Rng;

    fn randn(shape: Vec<usize>, seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let n = shape.iter().product();
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, std);
        Tensor::new(shape, v)
    }

    #[test]
    fn column_stds_detects_planted_outlier_unit() {
        let mut t = randn(vec![32, 16], 1, 0.02);
        // Hidden unit 5 has 20x std (the paper's §3 observation).
        for r in 0..32 {
            t.data_mut()[r * 16 + 5] *= 20.0;
        }
        let stds = column_stds(t.data(), 32, 16);
        let top = top_pct_indices(&stds, 0.07); // top ~7% of 16 = 2 dims
        assert!(top.contains(&5), "top dims {top:?} missing planted outlier");
    }

    #[test]
    fn top_pct_edge_cases() {
        let s = vec![1.0, 3.0, 2.0];
        assert!(top_pct_indices(&s, 0.0).is_empty());
        assert_eq!(top_pct_indices(&s, 0.4), vec![1, 2]); // ceil(1.2)=2 -> idx 1,2
        assert_eq!(top_pct_indices(&s, 1.0), vec![0, 1, 2]);
    }

    #[test]
    fn mixed_quantization_protects_outlier_rows() {
        let mut t = randn(vec![64, 32], 2, 0.02);
        for c in 0..32 {
            t.data_mut()[7 * 32 + c] = 2.0; // huge outlier row
        }
        // Block 64 spans two rows, so the outlier row shares blocks with
        // its neighbours — the pollution case proxy quantization fixes.
        let spec = QuantSpec::new(DataType::Int, 3, Some(64)).with_proxy(0.02);
        let out = simulate_mixed(&t, &spec, &[7]);
        // Outlier row survives exactly.
        for c in 0..32 {
            assert_eq!(out.data()[7 * 32 + c], 2.0);
        }
        // And its magnitude no longer pollutes neighbours: compare error
        // against quantizing with the outlier in-band.
        let naive = crate::quant::simulate(&t, &QuantSpec::new(DataType::Int, 3, Some(64)));
        let err_mixed: f32 = (0..t.len())
            .filter(|i| i / 32 != 7)
            .map(|i| (out.data()[i] - t.data()[i]).abs())
            .fold(0.0, f32::max);
        let err_naive: f32 = (0..t.len())
            .filter(|i| i / 32 != 7)
            .map(|i| (naive.data()[i] - t.data()[i]).abs())
            .fold(0.0, f32::max);
        assert!(err_mixed < err_naive, "{err_mixed} !< {err_naive}");
    }

    #[test]
    fn checkpoint_proxy_runs_on_transformer_layout() {
        let (l, d, f, v) = (2usize, 8usize, 32usize, 64usize);
        let params = vec![
            ("embed".to_string(), randn(vec![v, d], 3, 0.02)),
            ("qkv".to_string(), randn(vec![l, d, 3 * d], 4, 0.02)),
            ("wo".to_string(), randn(vec![l, d, d], 5, 0.02)),
            ("fc1".to_string(), randn(vec![l, d, f], 6, 0.02)),
            ("fc2".to_string(), randn(vec![l, f, d], 7, 0.02)),
        ];
        let qn: Vec<String> = ["qkv", "wo", "fc1", "fc2"].iter().map(|s| s.to_string()).collect();
        let spec = QuantSpec::new(DataType::Int, 3, Some(32)).with_proxy(0.05);
        let out = quantize_checkpoint_proxy(&params, &qn, &spec);
        assert_eq!(out.len(), params.len());
        assert_eq!(out[0].1, params[0].1, "embed untouched");
        for i in 1..5 {
            assert_eq!(out[i].1.shape(), params[i].1.shape());
            assert!(out[i].1.max_abs_diff(&params[i].1) > 0.0, "{} unchanged", out[i].0);
        }
    }

    #[test]
    fn proxy_pct_zero_equals_plain_quantization() {
        let t = randn(vec![32, 16], 8, 0.05);
        let spec = QuantSpec::new(DataType::Int, 4, Some(16));
        let mixed = simulate_mixed(&t, &spec.clone().with_proxy(0.0), &[]);
        let plain = crate::quant::simulate(&t, &spec);
        assert_eq!(mixed, plain);
    }
}
