//! Quantization data types as codebooks (Appendix A of the paper).
//!
//! A k-bit data type is the sorted set `F` of `2^k` values in `[-1, 1]`
//! that integer indices map onto. This module mirrors
//! `python/compile/kernels/codebooks.py` exactly — the pytest/cargo parity
//! suite asserts bit-identical vectors via `artifacts/codebooks.json`.
//!
//! Assignment (Eq. 1/3: nearest codebook value) is the innermost loop of
//! the whole study, so a [`Codebook`] precomputes the **decision
//! boundaries** (midpoints between adjacent entries): assignment becomes a
//! branchless binary search over boundaries instead of an argmin over the
//! set, and for the common k ≤ 5 sizes a linear SIMD-friendly scan.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// The four data types studied by the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    /// Symmetric linear integer quantization.
    Int,
    /// ExMy floating point (FP8-style, no NaN/Inf patterns).
    Fp,
    /// Information-theoretically optimal quantile quantization.
    Quantile,
    /// Dynamic-exponent data type (Dettmers, 2016).
    DynExp,
}

impl DataType {
    pub fn name(self) -> &'static str {
        match self {
            DataType::Int => "int",
            DataType::Fp => "fp",
            DataType::Quantile => "quantile",
            DataType::DynExp => "dynexp",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "int" => DataType::Int,
            "fp" | "float" => DataType::Fp,
            "quantile" => DataType::Quantile,
            "dynexp" | "dynamic" => DataType::DynExp,
            _ => bail!("unknown data type {s:?} (int|fp|quantile|dynexp)"),
        })
    }

    pub const ALL: [DataType; 4] = [DataType::Int, DataType::Fp, DataType::Quantile, DataType::DynExp];
}

/// Paper heuristic (Appendix C.4): 3-bit exponent for k in 4..8, 2-bit for
/// k = 3.
pub fn default_exponent_bits(k: usize) -> usize {
    if k <= 3 {
        2
    } else {
        3
    }
}

/// A sorted codebook with precomputed assignment boundaries.
#[derive(Debug, Clone)]
pub struct Codebook {
    values: Vec<f32>,
    /// `boundaries[i]` = midpoint between `values[i]` and `values[i+1]`;
    /// a normalized input `x` maps to index `partition_point(b < x)`.
    boundaries: Vec<f32>,
}

impl Codebook {
    pub fn from_values(mut values: Vec<f32>) -> Self {
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        values.dedup();
        assert!(values.len() >= 2, "codebook needs at least 2 values");
        let boundaries = values
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect();
        Codebook { values, boundaries }
    }

    /// Build the codebook for a data type at `k` bits.
    ///
    /// `exponent_bits` applies to `Fp` only (None = paper default).
    /// `Quantile` uses the same fixed standard-normal sample (seed
    /// `0x5EED`, 65536 draws) as the python side, making the "generic"
    /// quantile data type deterministic and input independent.
    pub fn build(dtype: DataType, k: usize, exponent_bits: Option<usize>) -> Result<Self> {
        let values = match dtype {
            DataType::Int => int_values(k)?,
            DataType::Fp => fp_values(k, exponent_bits.unwrap_or(default_exponent_bits(k)))?,
            DataType::DynExp => dynexp_values(k)?,
            DataType::Quantile => quantile_values(k, &normal_sample())?,
        };
        Ok(Codebook::from_values(values))
    }

    /// Data-dependent quantile codebook estimated from `sample` (Eq. 6).
    pub fn quantile_from_sample(k: usize, sample: &[f32]) -> Result<Self> {
        Ok(Codebook::from_values(quantile_values(k, sample)?))
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Nearest-value index for a normalized input in `[-1, 1]`.
    ///
    /// Boundary semantics match the python oracle: values exactly on a
    /// midpoint take the lower index (strict `<` comparison).
    #[inline]
    pub fn assign(&self, x: f32) -> u8 {
        if self.boundaries.len() <= 16 {
            // Linear scan beats binary search for tiny codebooks and
            // autovectorizes; this covers k <= 4 plus int5.
            let mut idx = 0usize;
            for &b in &self.boundaries {
                idx += (b < x) as usize;
            }
            idx as u8
        } else {
            self.boundaries.partition_point(|&b| b < x) as u8
        }
    }

    #[inline]
    pub fn value(&self, idx: u8) -> f32 {
        self.values[idx as usize]
    }

    /// Padded copy of the values for the fused-kernel artifact (codebook
    /// argument is fixed at 256 entries; padding repeats the max value and
    /// is never indexed).
    pub fn padded_values(&self, pad_to: usize) -> Vec<f32> {
        let mut v = self.values.clone();
        let last = *v.last().unwrap();
        v.resize(pad_to, last);
        v
    }
}

fn int_values(k: usize) -> Result<Vec<f32>> {
    if !(2..=8).contains(&k) {
        bail!("int codebook needs 2 <= k <= 8, got {k}");
    }
    let m = (1i32 << (k - 1)) - 1;
    Ok((-m..=m).map(|i| i as f32 / m as f32).collect())
}

fn fp_values(k: usize, e: usize) -> Result<Vec<f32>> {
    let m_bits = k.checked_sub(1 + e).filter(|_| e >= 1);
    let Some(m_bits) = m_bits else {
        bail!("invalid fp layout: k={k} exponent_bits={e}");
    };
    let bias = 1i32 << (e - 1);
    let mut vals: Vec<f64> = Vec::new();
    for sign in [1.0f64, -1.0] {
        for exp_field in 0..(1u32 << e) {
            for man_field in 0..(1u32 << m_bits) {
                let frac = man_field as f64 / (1u64 << m_bits) as f64;
                let v = if exp_field == 0 {
                    sign * 2f64.powi(1 - bias) * frac
                } else {
                    sign * 2f64.powi(exp_field as i32 - bias) * (1.0 + frac)
                };
                vals.push(v);
            }
        }
    }
    sort_dedup_normalize(vals)
}

fn dynexp_values(k: usize) -> Result<Vec<f32>> {
    if !(3..=8).contains(&k) {
        bail!("dynexp codebook needs 3 <= k <= 8, got {k}");
    }
    let mut vals: Vec<f64> = vec![0.0];
    for sign in [1.0f64, -1.0] {
        for z in 0..(k - 1) {
            let f = k - 2 - z;
            let n = 1usize << f;
            for i in 0..n {
                let frac = 0.1 + (0.9 - 0.1) * (i + 1) as f64 / n as f64;
                vals.push(sign * 10f64.powi(-(z as i32)) * frac);
            }
        }
    }
    sort_dedup_normalize(vals)
}

fn quantile_values(k: usize, sample: &[f32]) -> Result<Vec<f32>> {
    let n = 1usize << k;
    if sample.len() < n {
        bail!("need at least {n} samples for a {k}-bit quantile codebook");
    }
    let mut sorted: Vec<f64> = sample.iter().map(|&x| x as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut q: Vec<f64> = (0..n)
        .map(|i| {
            let lo = quantile_interp(&sorted, i as f64 / (n + 1) as f64);
            let hi = quantile_interp(&sorted, (i + 1) as f64 / (n + 1) as f64);
            0.5 * (lo + hi)
        })
        .collect();
    q.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Anchor an exact zero on the entry nearest to it (python parity).
    let zi = q
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    q[zi] = 0.0;
    let amax = q.iter().fold(0.0f64, |acc, &v| acc.max(v.abs()));
    if amax == 0.0 {
        bail!("degenerate sample: all quantiles are zero");
    }
    Ok(q.into_iter().map(|v| (v / amax) as f32).collect())
}

/// Linear-interpolation quantile matching `numpy.quantile`'s default.
fn quantile_interp(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    let h = p * (n - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (h - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

fn sort_dedup_normalize(mut vals: Vec<f64>) -> Result<Vec<f32>> {
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    vals.dedup();
    let amax = vals.iter().fold(0.0f64, |acc, &v| acc.max(v.abs()));
    if amax == 0.0 {
        bail!("degenerate codebook");
    }
    Ok(vals.into_iter().map(|v| (v / amax) as f32).collect())
}

/// The fixed standard-normal sample shared with the python side for the
/// generic quantile data type. Seed and count must match
/// `codebooks.make_codebook` — but note the *sampler* differs (numpy
/// Philox vs xoshiro), so parity for quantile codebooks is asserted at the
/// distribution level (golden test tolerance) rather than bit level.
fn normal_sample() -> Vec<f32> {
    let mut rng = Rng::new(0x5EED);
    let mut v = vec![0.0f32; 65536];
    rng.fill_normal(&mut v, 1.0);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_sorted_symmetric(cb: &Codebook, tol: f32) {
        let v = cb.values();
        for w in v.windows(2) {
            assert!(w[0] < w[1], "not strictly sorted: {w:?}");
        }
        // Max |v| is 1 and the set is ~symmetric around 0.
        let amax = v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        assert!((amax - 1.0).abs() < 1e-6);
        let min = v[0];
        let max = *v.last().unwrap();
        assert!((min + max).abs() <= tol, "asymmetric: min={min} max={max}");
    }

    #[test]
    fn int_codebook_matches_formula() {
        let cb = Codebook::build(DataType::Int, 4, None).unwrap();
        assert_eq!(cb.len(), 15); // 2^4 - 1 (symmetric truncation)
        assert_eq!(cb.value(7), 0.0);
        assert_eq!(cb.value(14), 1.0);
        assert_eq!(cb.value(0), -1.0);
        assert_sorted_symmetric(&cb, 0.0);
    }

    #[test]
    fn fp_codebook_properties() {
        for k in 3..=8 {
            for e in 1..k - 1 {
                let cb = Codebook::build(DataType::Fp, k, Some(e)).unwrap();
                assert_sorted_symmetric(&cb, 1e-6);
                assert!(cb.values().contains(&0.0), "fp k={k} e={e} missing zero");
                // Dedup removes the double-counted ±0 pattern.
                assert!(cb.len() <= (1 << k) && cb.len() >= (1 << k) - 2);
            }
        }
    }

    #[test]
    fn dynexp_codebook_properties() {
        for k in 3..=8 {
            let cb = Codebook::build(DataType::DynExp, k, None).unwrap();
            assert_sorted_symmetric(&cb, 1e-6);
            assert!(cb.values().contains(&0.0));
            // Spans k-2 decades: smallest positive value is 10^-(k-2)
            // (the all-exponent pattern's fraction, normalized by 0.9).
            let smallest_nonzero = cb
                .values()
                .iter()
                .filter(|v| **v > 0.0)
                .fold(f32::INFINITY, |a, &b| a.min(b));
            let want = 10f32.powi(-(k as i32 - 2));
            assert!(
                (smallest_nonzero - want).abs() < want * 0.01,
                "k={k}: {smallest_nonzero} vs {want}"
            );
        }
    }

    #[test]
    fn quantile_codebook_equalizes_mass() {
        let cb = Codebook::build(DataType::Quantile, 4, None).unwrap();
        assert_eq!(cb.len(), 16);
        assert!(cb.values().contains(&0.0));
        // Each bin should hold roughly equal mass of a fresh normal sample.
        let mut rng = Rng::new(99);
        let mut counts = vec![0usize; cb.len()];
        let n = 100_000;
        for _ in 0..n {
            // normalize by ~max|sample| the way blockwise would
            let x = (rng.normal() / 4.5) as f32;
            counts[cb.assign(x) as usize] += 1;
        }
        let expect = n / cb.len();
        let within = counts.iter().filter(|&&c| c > expect / 3 && c < expect * 3).count();
        assert!(within >= cb.len() - 2, "counts too skewed: {counts:?}");
    }

    #[test]
    fn assign_picks_nearest() {
        let cb = Codebook::from_values(vec![-1.0, -0.25, 0.0, 0.5, 1.0]);
        assert_eq!(cb.assign(-2.0), 0);
        // midpoint(-1.0, -0.25) = -0.625; -0.6 is above it -> index 1
        assert_eq!(cb.value(cb.assign(-0.6)), -0.25);
        assert_eq!(cb.value(cb.assign(-0.7)), -1.0);
        assert_eq!(cb.value(cb.assign(0.24)), 0.0);
        assert_eq!(cb.value(cb.assign(0.26)), 0.5);
        assert_eq!(cb.assign(2.0), 4);
    }

    #[test]
    fn assign_matches_argmin_for_all_dtypes() {
        let mut rng = Rng::new(5);
        for dtype in DataType::ALL {
            for k in 3..=8 {
                let cb = Codebook::build(dtype, k, None).unwrap();
                for _ in 0..500 {
                    let x = (rng.f64() * 2.2 - 1.1) as f32;
                    let fast = cb.assign(x) as usize;
                    let brute = cb
                        .values()
                        .iter()
                        .enumerate()
                        .min_by(|a, b| {
                            (a.1 - x).abs().partial_cmp(&(b.1 - x).abs()).unwrap()
                        })
                        .unwrap()
                        .0;
                    let d_fast = (cb.values()[fast] - x).abs();
                    let d_brute = (cb.values()[brute] - x).abs();
                    assert!(
                        (d_fast - d_brute).abs() < 1e-7,
                        "{dtype:?} k={k} x={x}: fast={fast} brute={brute}"
                    );
                }
            }
        }
    }

    #[test]
    fn padded_values_never_change_prefix() {
        let cb = Codebook::build(DataType::Fp, 4, None).unwrap();
        let p = cb.padded_values(256);
        assert_eq!(p.len(), 256);
        assert_eq!(&p[..cb.len()], cb.values());
    }

    #[test]
    fn exponent_heuristic() {
        assert_eq!(default_exponent_bits(3), 2);
        for k in 4..=8 {
            assert_eq!(default_exponent_bits(k), 3);
        }
    }
}
