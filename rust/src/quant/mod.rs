//! The native quantization library — the paper's subject matter and the
//! run-time hot path of the study.
//!
//! Everything the paper varies is expressed as a [`spec::QuantSpec`]:
//! data type ([`codebook`]), bit width, block size ([`blockwise`]),
//! distribution centering ([`centering`], Appendix B), and
//! outlier-dependent proxy quantization ([`proxy`], Section 3). The sweep
//! coordinator applies a spec to a checkpoint via [`quantize_checkpoint`]
//! and feeds the simulated (quantize→dequantize) weights to the AOT forward
//! executable — the paper's exact protocol: 16-bit inputs, k-bit weights,
//! dequantized before the matmul.
//!
//! [`packing`] provides the storage-layer bit packing used by the fused
//! kernel path and the bits-accounting ([`bitcost`]) that the scaling-law
//! x-axis ("total model bits") is built from. Its [`packing::PackedTensor`]
//! is the k-bit **residency** format ([`PackedParam`] lifts it to whole
//! checkpoint tensors) that the serving stack keeps resident instead of
//! f32 weight copies.

pub mod bitcost;
pub mod blockwise;
pub mod centering;
pub mod codebook;
pub mod entropy;
pub mod fused;
pub mod packing;
pub mod proxy;
pub mod spec;

pub use bitcost::bits_per_param;
pub use blockwise::{dequantize, quantize, QuantizedTensor};
pub use codebook::{Codebook, DataType};
pub use entropy::{EncodedParam, EncodedTensor};
pub use packing::PackedTensor;
pub use spec::QuantSpec;

use std::borrow::Cow;

use anyhow::Result;

use crate::tensor::Tensor;

/// Quantize→dequantize a single tensor under `spec` (simulated k-bit
/// weights). This is what the evaluation path calls per parameter tensor.
pub fn simulate(t: &Tensor, spec: &QuantSpec) -> Tensor {
    if spec.is_baseline() {
        return t.clone();
    }
    if let Some(pct) = spec.proxy_outlier_pct {
        // Proxy quantization needs the outlier index set, which depends on
        // the *previous* layer's weights; `quantize_checkpoint` handles it.
        // For a standalone tensor, fall back to magnitude-proxy on columns.
        let idx = proxy::column_outliers_by_std(t, pct);
        return proxy::simulate_mixed(t, spec, &idx);
    }
    let q = quantize(t.data(), spec);
    let mut out = vec![0.0f32; t.len()];
    dequantize(&q, &mut out);
    Tensor::new(t.shape().to_vec(), out)
}

/// Apply `spec` to every quantizable tensor of a checkpoint (the four
/// projection matrices; embeddings/LayerNorm stay in 16-bit, Section 4).
///
/// `quantized_names` comes from the artifact manifest. When proxy
/// quantization is active, outlier input dimensions are derived from the
/// previous layer's per-hidden-unit weight std (Eq. 2) by [`proxy`].
pub fn quantize_checkpoint(
    params: &[(String, Tensor)],
    quantized_names: &[String],
    spec: &QuantSpec,
) -> Vec<(String, Tensor)> {
    quantize_checkpoint_cow(params, quantized_names, spec)
        .into_iter()
        .map(|(name, t)| (name, t.into_owned()))
        .collect()
}

/// Copy-avoiding variant of [`quantize_checkpoint`]: pass-through tensors
/// (embeddings, LayerNorm — the bulk of small-tier checkpoints) are
/// borrowed instead of cloned, so the sweep hot path never holds a second
/// f32 copy of unquantized weights. The evaluator accepts any
/// `Borrow<Tensor>`, so the result feeds [`crate::eval::Evaluator::run`]
/// directly.
pub fn quantize_checkpoint_cow<'p>(
    params: &'p [(String, Tensor)],
    quantized_names: &[String],
    spec: &QuantSpec,
) -> Vec<(String, Cow<'p, Tensor>)> {
    if spec.is_baseline() {
        return params.iter().map(|(n, t)| (n.clone(), Cow::Borrowed(t))).collect();
    }
    if spec.proxy_outlier_pct.is_some() {
        return proxy::quantize_checkpoint_proxy(params, quantized_names, spec)
            .into_iter()
            .map(|(n, t)| (n, Cow::Owned(t)))
            .collect();
    }
    params
        .iter()
        .map(|(name, t)| {
            if quantized_names.iter().any(|q| q == name) {
                // Stacked per-layer tensors (L, r, c): each layer's matrix
                // is quantized independently, like the paper treats each
                // linear layer separately.
                (name.clone(), Cow::Owned(simulate_stacked(t, spec)))
            } else {
                (name.clone(), Cow::Borrowed(t))
            }
        })
        .collect()
}

/// A checkpoint tensor in packed k-bit residency form. Stacked `(L, r, c)`
/// tensors pack each leading-axis slice independently, mirroring
/// [`simulate_stacked`]'s per-layer treatment, so the dequantized weights
/// are bit-identical to the simulated-quantization evaluation path.
#[derive(Debug, Clone)]
pub struct PackedParam {
    pub shape: Vec<usize>,
    pub slices: Vec<PackedTensor>,
}

impl PackedParam {
    /// Quantize a tensor under `spec` straight into packed residency.
    pub fn quantize(t: &Tensor, spec: &QuantSpec) -> Result<PackedParam> {
        Self::quantize_slice(t.shape(), t.data(), spec)
    }

    /// Quantize borrowed `(shape, data)` without an intermediate `Tensor`
    /// — the serving path quantizes layer slices of checkpoint tensors
    /// (pipeline stages) straight from the source tensor's storage, so no
    /// transient f32 copy is made on the load path.
    pub fn quantize_slice(shape: &[usize], data: &[f32], spec: &QuantSpec) -> Result<PackedParam> {
        anyhow::ensure!(
            shape.iter().product::<usize>() == data.len(),
            "quantize_slice: shape {shape:?} does not match {} elements",
            data.len()
        );
        let slices = if shape.len() == 3 {
            let l = shape[0];
            let per = data.len() / l.max(1);
            (0..l)
                .map(|li| PackedTensor::quantize(&data[li * per..(li + 1) * per], spec))
                .collect::<Result<Vec<_>>>()?
        } else {
            vec![PackedTensor::quantize(data, spec)?]
        };
        Ok(PackedParam { shape: shape.to_vec(), slices })
    }

    /// Total element count across slices.
    pub fn len(&self) -> usize {
        self.slices.iter().map(|s| s.n).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Streaming dequantize of the whole tensor into `out` (length must
    /// equal [`PackedParam::len`]); slices land in leading-axis order.
    pub fn dequantize_into(&self, out: &mut [f32]) -> Result<()> {
        anyhow::ensure!(
            out.len() == self.len(),
            "dequantize_into: buffer {} != packed elements {}",
            out.len(),
            self.len()
        );
        let mut off = 0;
        for s in &self.slices {
            s.dequantize_into(&mut out[off..off + s.n])?;
            off += s.n;
        }
        Ok(())
    }

    /// Host-resident bytes: packed indices + per-block constants.
    pub fn resident_bytes(&self) -> usize {
        self.slices.iter().map(|s| s.resident_bytes()).sum()
    }

    /// Measured stored bits across slices (exact `n*k` payload + 32-bit
    /// f32 block constants) — see [`PackedTensor::measured_bits`].
    pub fn measured_bits(&self) -> u64 {
        self.slices.iter().map(|s| s.measured_bits()).sum()
    }
}

/// Resolve the per-stage quantization specs of a pipeline plan: stage
/// `i` uses `stage_bits[i]` over the base spec's dtype/block/centering
/// (`>= 16` keeps that stage unquantized — the mixed-precision deployment
/// shape where, say, the embedding-heavy first stage stays 16-bit while
/// later stages pack to 4). `None` repeats the base spec for every stage.
///
/// Validated here — stage bit widths come off the wire (the serve `load`
/// op) and must fail as an error response, not a quantizer panic.
pub fn stage_specs(
    base: &QuantSpec,
    n_stages: usize,
    stage_bits: Option<&[usize]>,
) -> Result<Vec<QuantSpec>> {
    let Some(bits) = stage_bits else {
        return Ok(vec![base.clone(); n_stages]);
    };
    anyhow::ensure!(
        bits.len() == n_stages,
        "got {} stage bit widths for a {n_stages}-stage plan",
        bits.len()
    );
    bits.iter()
        .map(|&k| {
            if k >= 16 {
                return Ok(QuantSpec::baseline16());
            }
            anyhow::ensure!(
                (1..=8).contains(&k),
                "unsupported stage bit width {k} (1..=8, or >=16 for the baseline)"
            );
            let mut s = base.clone();
            s.bits = k;
            s.codebook().map_err(|e| {
                anyhow::anyhow!("unsupported stage quantization config {}: {e:#}", s.key())
            })?;
            Ok(s)
        })
        .collect()
}

/// Quantize each leading-axis slice of a stacked (L, ...) tensor
/// independently; rank-2 tensors quantize whole.
pub fn simulate_stacked(t: &Tensor, spec: &QuantSpec) -> Tensor {
    if t.shape().len() != 3 {
        return simulate(t, spec);
    }
    let l = t.shape()[0];
    let per = t.len() / l;
    let mut out = vec![0.0f32; t.len()];
    for li in 0..l {
        let slice = &t.data()[li * per..(li + 1) * per];
        let q = quantize(slice, spec);
        dequantize(&q, &mut out[li * per..(li + 1) * per]);
    }
    Tensor::new(t.shape().to_vec(), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(shape: Vec<usize>, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n = shape.iter().product();
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.05);
        Tensor::new(shape, v)
    }

    #[test]
    fn simulate_baseline_is_identity() {
        let t = randn(vec![8, 8], 0);
        let spec = QuantSpec::baseline16();
        assert_eq!(simulate(&t, &spec), t);
    }

    #[test]
    fn simulate_reduces_with_more_bits() {
        let t = randn(vec![64, 64], 1);
        let err8 = simulate(&t, &QuantSpec::new(DataType::Int, 8, Some(64))).max_abs_diff(&t);
        let err4 = simulate(&t, &QuantSpec::new(DataType::Int, 4, Some(64))).max_abs_diff(&t);
        let err3 = simulate(&t, &QuantSpec::new(DataType::Int, 3, Some(64))).max_abs_diff(&t);
        assert!(err8 < err4, "8-bit {err8} !< 4-bit {err4}");
        assert!(err4 < err3, "4-bit {err4} !< 3-bit {err3}");
    }

    #[test]
    fn checkpoint_quantizes_only_listed_tensors() {
        let params = vec![
            ("embed".to_string(), randn(vec![16, 8], 2)),
            ("qkv".to_string(), randn(vec![2, 8, 24], 3)),
        ];
        let spec = QuantSpec::new(DataType::Int, 3, Some(16));
        let out = quantize_checkpoint(&params, &["qkv".to_string()], &spec);
        assert_eq!(out[0].1, params[0].1, "embed must pass through");
        assert!(out[1].1.max_abs_diff(&params[1].1) > 0.0, "qkv must change");
    }

    #[test]
    fn packed_param_matches_simulated_path() {
        // The serving residency format must dequantize bit-identically to
        // the sweep's simulate_stacked path, including stacked tensors.
        for shape in [vec![64, 24], vec![3, 16, 24]] {
            let t = randn(shape, 7);
            let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
            let sim = simulate_stacked(&t, &spec);
            let p = PackedParam::quantize(&t, &spec).unwrap();
            assert_eq!(p.len(), t.len());
            let mut out = vec![0.0f32; t.len()];
            p.dequantize_into(&mut out).unwrap();
            assert_eq!(out, sim.data(), "shape {:?}", t.shape());
            assert!(p.resident_bytes() < t.len() * 4, "packed not smaller than f32");
        }
    }

    #[test]
    fn cow_checkpoint_borrows_passthrough_tensors() {
        let params = vec![
            ("embed".to_string(), randn(vec![16, 8], 11)),
            ("qkv".to_string(), randn(vec![2, 8, 24], 12)),
        ];
        let spec = QuantSpec::new(DataType::Int, 4, Some(16));
        let out = quantize_checkpoint_cow(&params, &["qkv".to_string()], &spec);
        assert!(matches!(out[0].1, std::borrow::Cow::Borrowed(_)), "embed must borrow");
        assert!(matches!(out[1].1, std::borrow::Cow::Owned(_)), "qkv must own");
        // Baseline borrows everything.
        let base = quantize_checkpoint_cow(&params, &["qkv".to_string()], &QuantSpec::baseline16());
        assert!(base.iter().all(|(_, t)| matches!(t, std::borrow::Cow::Borrowed(_))));
    }

    #[test]
    fn quantize_slice_matches_tensor_path_and_validates() {
        let t = randn(vec![2, 4, 4], 7);
        let spec = QuantSpec::new(DataType::Int, 4, Some(16));
        let a = PackedParam::quantize(&t, &spec).unwrap();
        let b = PackedParam::quantize_slice(t.shape(), t.data(), &spec).unwrap();
        let (mut da, mut db) = (vec![0.0; t.len()], vec![0.0; t.len()]);
        a.dequantize_into(&mut da).unwrap();
        b.dequantize_into(&mut db).unwrap();
        assert_eq!(da, db, "borrowed-slice quantization must match the Tensor path");
        assert!(PackedParam::quantize_slice(&[3, 3], t.data(), &spec).is_err());
    }

    #[test]
    fn stage_specs_resolve_and_validate() {
        let base = QuantSpec::new(DataType::Fp, 4, Some(64));
        // No overrides: the base spec repeats per stage.
        let s = stage_specs(&base, 2, None).unwrap();
        assert_eq!(s, vec![base.clone(), base.clone()]);
        // Mixed precision: 16 = unquantized stage, others override bits.
        let s = stage_specs(&base, 2, Some(&[16, 3])).unwrap();
        assert!(s[0].is_baseline());
        assert_eq!((s[1].bits, s[1].dtype, s[1].block), (3, DataType::Fp, Some(64)));
        // Length mismatch and unbuildable widths are errors, not panics.
        assert!(stage_specs(&base, 2, Some(&[4])).is_err());
        assert!(stage_specs(&base, 2, Some(&[4, 9])).is_err());
        assert!(stage_specs(&base, 2, Some(&[0, 4])).is_err());
    }

    #[test]
    fn stage_specs_empty_stage_list() {
        // A zero-stage plan is degenerate but must resolve to an empty
        // spec list (with or without an empty override vector), never
        // panic or fabricate specs.
        let base = QuantSpec::new(DataType::Fp, 4, Some(64));
        assert!(stage_specs(&base, 0, None).unwrap().is_empty());
        assert!(stage_specs(&base, 0, Some(&[])).unwrap().is_empty());
        // A non-empty override against zero stages is a count mismatch.
        assert!(stage_specs(&base, 0, Some(&[4])).is_err());
    }

    #[test]
    fn stage_specs_all_16_is_full_passthrough() {
        // Every stage at >= 16 bits: all-baseline specs, so nothing packs
        // anywhere — the "serve unquantized through the pipeline" shape.
        let base = QuantSpec::new(DataType::Int, 4, Some(64));
        let s = stage_specs(&base, 3, Some(&[16, 16, 16])).unwrap();
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(QuantSpec::is_baseline));
        // Widths past 16 mean the same thing (>= 16 = baseline).
        let s = stage_specs(&base, 2, Some(&[32, 16])).unwrap();
        assert!(s.iter().all(QuantSpec::is_baseline));
    }

    #[test]
    fn stage_specs_base_block_override_carries_per_stage() {
        // Only the bit width is per-stage; a base spec carrying a
        // non-default block size (or tensor-wise blocking) must hand that
        // block to every overridden stage unchanged.
        let blocked = QuantSpec::new(DataType::Fp, 4, Some(32));
        let s = stage_specs(&blocked, 2, Some(&[3, 8])).unwrap();
        assert_eq!((s[0].bits, s[0].block), (3, Some(32)));
        assert_eq!((s[1].bits, s[1].block), (8, Some(32)));
        let tensorwise = QuantSpec::new(DataType::Int, 4, None);
        let s = stage_specs(&tensorwise, 2, Some(&[3, 4])).unwrap();
        assert!(s.iter().all(|sp| sp.block.is_none()));
        // ...but a baseline (16) stage drops the block: there is nothing
        // to block-quantize in a passthrough stage.
        let s = stage_specs(&blocked, 2, Some(&[16, 4])).unwrap();
        assert!(s[0].is_baseline());
        assert_eq!(s[1].block, Some(32));
    }

    #[test]
    fn stacked_slices_quantized_independently() {
        // Put an outlier in layer 0; layer 1 must be unaffected by it.
        let mut t = randn(vec![2, 4, 4], 4);
        t.data_mut()[0] = 100.0;
        let spec = QuantSpec::new(DataType::Int, 4, None); // tensor-wise absmax
        let out = simulate_stacked(&t, &spec);
        let l1_err: f32 = out.data()[16..]
            .iter()
            .zip(&t.data()[16..])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        // With per-slice quantization layer 1 keeps a sane scale.
        assert!(l1_err < 0.05, "layer-1 error {l1_err} polluted by layer-0 outlier");
    }
}
