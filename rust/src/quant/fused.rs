//! Fused dequantize×matmul: the packed-residency scoring kernel.
//!
//! The classic serving path expands every [`PackedTensor`] into a full f32
//! tensor (`dequantize_into` → GEMM), paying the dequantized footprint once
//! per parameter per load and shipping f32 weights into the executable. This
//! module fuses the two steps: the matmul inner loop walks the packed k-bit
//! bitstream directly, decoding **one weight row at a time** into a small
//! reusable scratch row and accumulating it into the output — packed
//! parameters never materialize as full f32 tensors on the score path.
//!
//! Numerical contract: the fused kernel is **bit-identical** to the
//! `dequantize_into` → reference-GEMM composition. Both share one
//! accumulation order (k-outer axpy: `out[i][c] += x[i][r] * w[r][c]`, `r`
//! ascending, `c` ascending) and the row decoder reproduces
//! [`PackedTensor::dequantize_into`]'s exact arithmetic
//! (`values[idx] * absmax + mean`, f32 ops in the same order). The AVX2 path
//! uses only `_mm256_mul_ps`/`_mm256_add_ps` — deliberately **no FMA**, which
//! would skip the intermediate rounding step and break bit-identity with the
//! scalar fallback.
//!
//! Backend selection is automatic (runtime `is_x86_feature_detected!`) with
//! an escape hatch: setting `KBITSCALE_FORCE_SCALAR` in the environment pins
//! the scalar fallback, which CI uses to prove the scalar path passes the
//! same suite (the selection is latched on first use, so set it before any
//! scoring happens).

use std::sync::OnceLock;

use anyhow::{ensure, Result};

use super::packing::PackedTensor;

/// Which inner-loop implementation a fused matmul runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Plain f32 loop — the portable fallback, and the bit-identity
    /// reference for the SIMD path.
    Scalar,
    /// AVX2 `std::arch` path (mul + add only; no FMA).
    Avx2,
}

/// Whether AVX2 is usable on this machine (compile-target and runtime
/// feature detection; always false off x86_64).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The backend fused matmuls dispatch to: AVX2 when the CPU has it, unless
/// `KBITSCALE_FORCE_SCALAR` is set. Latched once per process.
pub fn active_backend() -> Backend {
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        if std::env::var_os("KBITSCALE_FORCE_SCALAR").is_some() || !avx2_available() {
            Backend::Scalar
        } else {
            Backend::Avx2
        }
    })
}

/// Decode packed elements `[lo, hi)` straight into `out` (length `hi - lo`)
/// — the row-granular form of [`PackedTensor::dequantize_into`], and
/// bit-identical to the slice `full[lo..hi]` of a full decode: same codebook
/// lookup, same `value * absmax + mean` f32 arithmetic per element.
pub fn decode_range(p: &PackedTensor, lo: usize, hi: usize, out: &mut [f32]) -> Result<()> {
    ensure!(lo <= hi && hi <= p.n, "decode_range {lo}..{hi} out of bounds for {} elements", p.n);
    ensure!(out.len() == hi - lo, "decode_range: buffer {} != span {}", out.len(), hi - lo);
    // Cross-field invariants (block >= 1, absmax/means table lengths,
    // stream length): a hand-built tensor must error here, not panic in
    // the decode loop below.
    p.validate()?;
    let values = p.codebook.values();
    let k = p.bits;
    let mask = if k >= 8 { 0xFFu32 } else { (1u32 << k) - 1 };
    let mut bitpos = lo * k;
    let mut i = lo;
    for o in out.iter_mut() {
        let b = i / p.block;
        let amax = p.absmax[b];
        let mean = p.means.as_ref().map_or(0.0, |m| m[b]);
        let word = bitpos / 32;
        let off = bitpos % 32;
        let mut v = p.packed[word] >> off;
        if off + k > 32 {
            v |= p.packed[word + 1] << (32 - off);
        }
        // Codebooks may hold fewer than 2^k values (int codebooks drop
        // one), so a corrupt bitstream can encode an index past the
        // table: reject it, don't index past the slice.
        let idx = (v & mask) as usize;
        let Some(&val) = values.get(idx) else {
            anyhow::bail!("bitstream index {idx} out of range for {}-entry codebook", values.len());
        };
        *o = val * amax + mean;
        bitpos += k;
        i += 1;
    }
    Ok(())
}

/// Reference dense GEMM accumulating into `out`: `out[m,n] += x[m,k] @
/// w[k,n]`, row-major, k-outer axpy order. This exact loop order is the
/// bit-identity baseline the fused and SIMD paths are tested against.
pub fn matmul_f32(x: &[f32], w: &[f32], out: &mut [f32], m: usize, kd: usize, n: usize) {
    debug_assert_eq!(x.len(), m * kd);
    debug_assert_eq!(w.len(), kd * n);
    debug_assert_eq!(out.len(), m * n);
    matmul_f32_with(active_backend(), x, w, out, m, kd, n)
}

/// [`matmul_f32`] with an explicit backend (parity tests drive both).
pub fn matmul_f32_with(
    backend: Backend,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
) {
    for r in 0..kd {
        let wrow = &w[r * n..(r + 1) * n];
        for i in 0..m {
            axpy(backend, x[i * kd + r], wrow, &mut out[i * n..(i + 1) * n]);
        }
    }
}

/// Fused dequantize×matmul accumulating into `out`: `out[m,n] += x[m,k] @
/// W[k,n]` where `W` is `p`'s packed k-bit payload, decoded one row at a
/// time into `wrow` (resized to `n`; pass the same buffer across calls so
/// the score path allocates the scratch row once). Never materializes the
/// full f32 weight tensor.
pub fn fused_matmul(
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    wrow: &mut Vec<f32>,
) -> Result<()> {
    fused_matmul_with(active_backend(), x, p, out, m, kd, n, wrow)
}

/// [`fused_matmul`] with an explicit backend (parity tests drive both).
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_with(
    backend: Backend,
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    wrow: &mut Vec<f32>,
) -> Result<()> {
    ensure!(p.n == kd * n, "packed tensor has {} elements, matmul wants {}x{}", p.n, kd, n);
    ensure!(x.len() == m * kd, "fused_matmul: x has {} elements, want {}", x.len(), m * kd);
    ensure!(out.len() == m * n, "fused_matmul: out has {} elements, want {}", out.len(), m * n);
    wrow.resize(n, 0.0);
    for r in 0..kd {
        decode_range(p, r * n, (r + 1) * n, wrow)?;
        for i in 0..m {
            axpy(backend, x[i * kd + r], wrow, &mut out[i * n..(i + 1) * n]);
        }
    }
    Ok(())
}

/// `out[c] += a * w[c]` — the one inner loop every matmul here reduces to.
#[inline]
fn axpy(backend: Backend, a: f32, w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    match backend {
        Backend::Scalar => axpy_scalar(a, w, out),
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Backend::Avx2 is only ever selected after
            // `is_x86_feature_detected!("avx2")` (active_backend), or by a
            // test that checked `avx2_available()` first.
            unsafe {
                axpy_avx2(a, w, out)
            }
            #[cfg(not(target_arch = "x86_64"))]
            axpy_scalar(a, w, out)
        }
    }
}

#[inline]
fn axpy_scalar(a: f32, w: &[f32], out: &mut [f32]) {
    for (o, &wv) in out.iter_mut().zip(w) {
        *o += a * wv;
    }
}

/// AVX2 axpy: 8 lanes of `out += a * w` per iteration, scalar tail. Uses
/// separate mul + add (not `_mm256_fmadd_ps`): FMA skips the intermediate
/// rounding and would diverge from the scalar path in the last bit.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: callers must ensure AVX2 is available (checked via
// `is_x86_feature_detected!` before [`Backend::Avx2`] is ever selected);
// all loads/stores are unaligned intrinsics over in-bounds slice ranges.
unsafe fn axpy_avx2(a: f32, w: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = w.len();
    let va = _mm256_set1_ps(a);
    let mut c = 0usize;
    while c + 8 <= n {
        let vw = _mm256_loadu_ps(w.as_ptr().add(c));
        let vo = _mm256_loadu_ps(out.as_ptr().add(c));
        let sum = _mm256_add_ps(vo, _mm256_mul_ps(va, vw));
        _mm256_storeu_ps(out.as_mut_ptr().add(c), sum);
        c += 8;
    }
    while c < n {
        *out.get_unchecked_mut(c) += a * *w.get_unchecked(c);
        c += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::quant::codebook::DataType;
    use crate::quant::spec::QuantSpec;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    fn backends() -> Vec<Backend> {
        let mut b = vec![Backend::Scalar];
        if avx2_available() {
            b.push(Backend::Avx2);
        }
        b
    }

    #[test]
    fn decode_range_matches_full_decode() {
        check("decode-range-parity", 48, |rng, case| {
            let bits = 3 + case % 6;
            let block = [Some(16), Some(64), Some(256), None][(case / 6) % 4];
            let data = gen::weights(rng, 4000);
            let n = data.len();
            let mut spec = QuantSpec::new(DataType::ALL[rng.below(4)], bits, block);
            if rng.below(2) == 0 {
                spec = spec.with_centering();
            }
            let p = PackedTensor::quantize(&data, &spec).map_err(|e| format!("{e:#}"))?;
            let mut full = vec![0.0f32; n];
            p.dequantize_into(&mut full).map_err(|e| format!("{e:#}"))?;
            // A handful of random spans, plus the degenerate edges.
            let mut spans = vec![(0, n), (0, 0), (n, n)];
            for _ in 0..8 {
                let a = rng.below(n + 1);
                let b = a + rng.below(n - a + 1);
                spans.push((a, b));
            }
            for (lo, hi) in spans {
                let mut got = vec![0.0f32; hi - lo];
                decode_range(&p, lo, hi, &mut got).map_err(|e| format!("{e:#}"))?;
                prop_assert!(
                    got == full[lo..hi],
                    "bits={bits} block={block:?} n={n} span {lo}..{hi}: range decode \
                     != full decode slice"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn decode_range_validates_bounds() {
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let p = PackedTensor::quantize(&[1.0f32; 100], &spec).unwrap();
        let mut buf = vec![0.0f32; 10];
        assert!(decode_range(&p, 95, 105, &mut buf).is_err(), "hi past n");
        assert!(decode_range(&p, 0, 5, &mut buf).is_err(), "buffer/span mismatch");
        assert!(decode_range(&p, 0, 10, &mut buf).is_ok());
    }

    #[test]
    fn prop_fused_matmul_bit_identical_to_dequant_gemm() {
        // The tentpole invariant: scalar fused, SIMD fused, and the
        // dequantize_into→GEMM composition agree to the bit across bits
        // 3..=8 × block sizes (ragged tails included) × codebook dtypes.
        check("fused-matmul-parity", 48, |rng, case| {
            let bits = 3 + case % 6;
            let block = [Some(16), Some(32), Some(64), None][(case / 6) % 4];
            let m = 1 + rng.below(6);
            let kd = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let mut w = vec![0.0f32; kd * n];
            let std = 0.5;
            for v in w.iter_mut() {
                *v = (rng.normal() * std) as f32;
            }
            let x: Vec<f32> = (0..m * kd).map(|_| (rng.normal()) as f32).collect();
            let mut spec = QuantSpec::new(DataType::ALL[rng.below(4)], bits, block);
            if rng.below(2) == 0 {
                spec = spec.with_centering();
            }
            let p = PackedTensor::quantize(&w, &spec).map_err(|e| format!("{e:#}"))?;
            // Reference: full dequantize, then the same-order GEMM.
            let mut wd = vec![0.0f32; kd * n];
            p.dequantize_into(&mut wd).map_err(|e| format!("{e:#}"))?;
            let mut reference = vec![0.0f32; m * n];
            matmul_f32_with(Backend::Scalar, &x, &wd, &mut reference, m, kd, n);
            for backend in backends() {
                let mut got = vec![0.0f32; m * n];
                let mut wrow = Vec::new();
                fused_matmul_with(backend, &x, &p, &mut got, m, kd, n, &mut wrow)
                    .map_err(|e| format!("{e:#}"))?;
                prop_assert!(
                    got == reference,
                    "bits={bits} block={block:?} m={m} k={kd} n={n} {backend:?}: \
                     fused != dequantize_into+GEMM"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_simd_dense_matmul_matches_scalar() {
        if !avx2_available() {
            return; // nothing to compare on this host
        }
        check("dense-axpy-simd-parity", 32, |rng, _| {
            let m = 1 + rng.below(5);
            let kd = 1 + rng.below(50);
            let n = 1 + rng.below(70); // crosses the 8-lane boundary + tail
            let x: Vec<f32> = (0..m * kd).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..kd * n).map(|_| rng.normal() as f32).collect();
            let mut a = vec![0.0f32; m * n];
            let mut b = vec![0.0f32; m * n];
            matmul_f32_with(Backend::Scalar, &x, &w, &mut a, m, kd, n);
            matmul_f32_with(Backend::Avx2, &x, &w, &mut b, m, kd, n);
            prop_assert!(a == b, "m={m} k={kd} n={n}: AVX2 dense GEMM != scalar");
            Ok(())
        });
    }

    #[test]
    fn fused_matmul_accumulates_into_out() {
        // `out +=`, not `out =`: the transformer residual path relies on it.
        let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
        let w = vec![1.0f32; 8];
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let x = vec![1.0f32; 2];
        let mut out = vec![10.0f32; 4];
        let mut wrow = Vec::new();
        fused_matmul(&x, &p, &mut out, 1, 2, 4, &mut wrow).unwrap();
        let mut wd = vec![0.0f32; 8];
        p.dequantize_into(&mut wd).unwrap();
        let mut expect = vec![10.0f32; 4];
        matmul_f32_with(Backend::Scalar, &x, &wd, &mut expect, 1, 2, 4);
        assert_eq!(out, expect);
    }

    #[test]
    fn fused_matmul_rejects_geometry_mismatch() {
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let p = PackedTensor::quantize(&[0.5f32; 12], &spec).unwrap();
        let mut wrow = Vec::new();
        let x = vec![1.0f32; 3];
        let mut out = vec![0.0f32; 4];
        // p.n = 12 != 3*5
        assert!(fused_matmul(&x, &p, &mut out, 1, 3, 5, &mut wrow).is_err());
        // x too short for m=2
        assert!(fused_matmul(&x, &p, &mut out, 2, 3, 4, &mut wrow).is_err());
        assert!(fused_matmul(&x, &p, &mut out, 1, 3, 4, &mut wrow).is_ok());
    }

    #[test]
    fn zero_inputs_preserve_signed_zero_semantics() {
        // x = 0 rows must still run the axpy (skipping would turn -0.0
        // outputs into +0.0 and break bit-identity with the reference).
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let spec = QuantSpec::new(DataType::Fp, 4, Some(16));
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let x = vec![0.0f32; 4];
        let mut wd = vec![0.0f32; 16];
        p.dequantize_into(&mut wd).unwrap();
        for backend in backends() {
            let mut got = vec![-0.0f32; 4];
            let mut expect = vec![-0.0f32; 4];
            let mut wrow = Vec::new();
            fused_matmul_with(backend, &x, &p, &mut got, 1, 4, 4, &mut wrow).unwrap();
            matmul_f32_with(Backend::Scalar, &x, &wd, &mut expect, 1, 4, 4);
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            let eb: Vec<u32> = expect.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, eb, "{backend:?}");
        }
    }
}
