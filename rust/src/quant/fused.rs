//! Fused dequantize×matmul: the packed-residency scoring kernel.
//!
//! The classic serving path expands every [`PackedTensor`] into a full f32
//! tensor (`dequantize_into` → GEMM), paying the dequantized footprint once
//! per parameter per load and shipping f32 weights into the executable. This
//! module fuses the two steps: the matmul walks the packed k-bit bitstream
//! directly, decoding small weight **panels** into a reusable scratch buffer
//! and accumulating them into the output — packed parameters never
//! materialize as full f32 tensors on the score path.
//!
//! # Kernel design
//!
//! Three layers, composed bottom-up:
//!
//! 1. **Vectorized decode** ([`decode_range_with`]): the AVX2 path unpacks
//!    eight k-bit indices at a time, range-checks them against the codebook,
//!    gathers the table entries with `_mm256_i32gather_ps`, and applies the
//!    broadcast per-block `absmax`/`mean` with one vector mul + add. The
//!    scalar path is the portable fallback and the bit-identity reference.
//! 2. **Cache blocking** ([`fused_matmul_tiled`]): the k×n loop nest is
//!    tiled so each decoded `tile.rows × tile.cols` weight panel stays
//!    L2-resident while it is swept across all `m` input rows, instead of
//!    re-decoding per row. [`Tiling::for_geometry`] derives tile sizes from
//!    the payload geometry ([`PANEL_BUDGET_BYTES`] / [`TILE_COLS`]); the
//!    panel buffer reuses the scratch-row convention (callers pass one
//!    `&mut Vec<f32>` across calls, so the score path allocates it once).
//! 3. **Column-parallel execution** ([`fused_matmul_parallel`]): output
//!    columns are partitioned into one contiguous span per
//!    `util::pool` worker. The split is deterministic, each column is
//!    written by exactly one thread, and every worker accumulates into a
//!    thread-local output panel seeded from `out` — so `+=` semantics,
//!    signed zeros, and the per-element accumulation order are all
//!    preserved and results are bit-identical to the single-threaded
//!    kernel for every thread count. Serving reads the worker count from
//!    `KBITSCALE_THREADS` once per process
//!    ([`crate::util::pool::scoring_threads`]).
//!
//! # Numerical contract
//!
//! The fused kernel is **bit-identical** to the `dequantize_into` →
//! reference-GEMM composition. Both share one accumulation order (k-outer
//! axpy: `out[i][c] += x[i][r] * w[r][c]`, `r` ascending, `c` ascending) and
//! the panel decoder reproduces [`PackedTensor::dequantize_into`]'s exact
//! arithmetic (`values[idx] * absmax + mean`, f32 ops in the same order).
//! Tiling only regroups the `(r, c)` iteration space — each output element
//! still sees `r` in ascending order — and the column split never moves an
//! element between threads mid-sum, so neither changes a single bit. The
//! AVX2 paths use only `_mm256_mul_ps`/`_mm256_add_ps` — deliberately **no
//! FMA**, which would skip the intermediate rounding step and break
//! bit-identity with the scalar fallback.
//!
//! Backend selection is automatic (runtime `is_x86_feature_detected!`) with
//! an escape hatch: setting `KBITSCALE_FORCE_SCALAR` in the environment pins
//! the scalar fallback, which CI uses to prove the scalar path passes the
//! same suite at 1 and 4 scoring threads (the selection is latched on first
//! use, so set it before any scoring happens).

use std::sync::OnceLock;

use anyhow::{ensure, Result};

use super::packing::{self, PackedTensor};

/// Which inner-loop implementation a fused matmul runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Plain f32 loop — the portable fallback, and the bit-identity
    /// reference for the SIMD path.
    Scalar,
    /// AVX2 `std::arch` path (mul + add only; no FMA).
    Avx2,
}

/// Whether AVX2 is usable on this machine (compile-target and runtime
/// feature detection; always false off x86_64).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The backend fused matmuls dispatch to: AVX2 when the CPU has it, unless
/// `KBITSCALE_FORCE_SCALAR` is set. Latched once per process.
pub fn active_backend() -> Backend {
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        if std::env::var_os("KBITSCALE_FORCE_SCALAR").is_some() || !avx2_available() {
            Backend::Scalar
        } else {
            Backend::Avx2
        }
    })
}

/// Column width of an auto-derived tile panel: wide enough to amortize the
/// per-span decode setup and keep the axpy sweep in full 8-lane strides,
/// narrow enough that `m` output-row slices of it stay cache-resident.
pub const TILE_COLS: usize = 512;

/// Budget for one decoded weight panel (`tile.rows × tile.cols` f32s) —
/// half of a conservative 256 KiB L2, leaving the other half for the
/// output panel and the `x` column slice the sweep touches.
pub const PANEL_BUDGET_BYTES: usize = 128 * 1024;

/// Cache-blocking geometry for [`fused_matmul_tiled`]: a decoded weight
/// panel covers `rows` weight rows (the k dimension) × `cols` output
/// columns. Tiling regroups the loop nest but never reorders any output
/// element's accumulation over `r`, so every tiling is bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiling {
    /// Weight rows (k dimension) decoded per panel.
    pub rows: usize,
    /// Output columns covered per panel.
    pub cols: usize,
}

impl Tiling {
    /// The degenerate row-streaming tiling: one decoded row at a time,
    /// all `n` columns — the pre-tiling fused loop, kept as the tiled
    /// path's bit-identity baseline ([`fused_matmul_untiled`]).
    pub fn row_streaming(n: usize) -> Tiling {
        Tiling { rows: 1, cols: n.max(1) }
    }

    /// Derive a tile from the payload geometry: columns capped at
    /// [`TILE_COLS`], then as many rows as fit [`PANEL_BUDGET_BYTES`], so
    /// one decoded panel stays L2-resident across all `m` input rows.
    /// Deterministic in the geometry (no runtime probing).
    pub fn for_geometry(_m: usize, kd: usize, n: usize) -> Tiling {
        let cols = n.clamp(1, TILE_COLS);
        let rows = (PANEL_BUDGET_BYTES / 4 / cols).clamp(1, kd.max(1));
        Tiling { rows, cols }
    }
}

/// Decode packed elements `[lo, hi)` straight into `out` (length `hi - lo`)
/// — the panel-granular form of [`PackedTensor::dequantize_into`], and
/// bit-identical to the slice `full[lo..hi]` of a full decode: same codebook
/// lookup, same `value * absmax + mean` f32 arithmetic per element.
/// Dispatches to [`active_backend`].
pub fn decode_range(p: &PackedTensor, lo: usize, hi: usize, out: &mut [f32]) -> Result<()> {
    decode_range_with(active_backend(), p, lo, hi, out)
}

/// [`decode_range`] with an explicit backend (parity tests and benches
/// drive both). The span is walked block-by-block so the per-block
/// `absmax`/`mean` are hoisted (and, on AVX2, broadcast) once per block
/// sub-span rather than re-fetched per element.
pub fn decode_range_with(
    backend: Backend,
    p: &PackedTensor,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> Result<()> {
    ensure!(lo <= hi && hi <= p.n, "decode_range {lo}..{hi} out of bounds for {} elements", p.n);
    ensure!(out.len() == hi - lo, "decode_range: buffer {} != span {}", out.len(), hi - lo);
    // Cross-field invariants (block >= 1, absmax/means table lengths,
    // stream length): a hand-built tensor must error here, not panic in
    // the decode loop below.
    p.validate()?;
    let values = p.codebook.values();
    let k = p.bits;
    let mask = if k >= 8 { 0xFFu32 } else { (1u32 << k) - 1 };
    let mut i = lo;
    let mut done = 0usize;
    while i < hi {
        let b = i / p.block;
        let end = hi.min((b + 1) * p.block);
        let amax = p.absmax[b];
        let mean = p.means.as_ref().map_or(0.0, |m| m[b]);
        let span = &mut out[done..done + (end - i)];
        decode_span(backend, &p.packed, values, k, mask, i, amax, mean, span)?;
        done += end - i;
        i = end;
    }
    Ok(())
}

/// Decode one within-block span (uniform `absmax`/`mean`) starting at
/// packed element `start`.
#[allow(clippy::too_many_arguments)]
fn decode_span(
    backend: Backend,
    packed: &[u32],
    values: &[f32],
    k: usize,
    mask: u32,
    start: usize,
    amax: f32,
    mean: f32,
    out: &mut [f32],
) -> Result<()> {
    match backend {
        Backend::Scalar => decode_span_scalar(packed, values, k, mask, start, amax, mean, out),
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Backend::Avx2 is only ever selected after
            // `is_x86_feature_detected!("avx2")` (active_backend), or by a
            // test/bench that checked `avx2_available()` first.
            unsafe {
                decode_span_avx2(packed, values, k, mask, start, amax, mean, out)
            }
            #[cfg(not(target_arch = "x86_64"))]
            decode_span_scalar(packed, values, k, mask, start, amax, mean, out)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn decode_span_scalar(
    packed: &[u32],
    values: &[f32],
    k: usize,
    mask: u32,
    start: usize,
    amax: f32,
    mean: f32,
    out: &mut [f32],
) -> Result<()> {
    let mut bitpos = start * k;
    for o in out.iter_mut() {
        // Codebooks may hold fewer than 2^k values (int codebooks drop
        // one), so a corrupt bitstream can encode an index past the
        // table: reject it, don't index past the slice.
        let idx = packing::bit_window(packed, bitpos, k, mask) as usize;
        let Some(&val) = values.get(idx) else {
            anyhow::bail!("bitstream index {idx} out of range for {}-entry codebook", values.len());
        };
        *o = val * amax + mean;
        bitpos += k;
    }
    Ok(())
}

/// AVX2 span decode: eight k-bit indices are unpacked and range-checked,
/// gathered from the codebook in one `_mm256_i32gather_ps`, and scaled
/// with broadcast `absmax`/`mean` as one vector mul + add (not
/// `_mm256_fmadd_ps` — FMA skips the intermediate rounding and would
/// diverge from the scalar path in the last bit). Scalar tail for the
/// final `< 8` elements uses the identical per-element arithmetic.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
// SAFETY: callers must ensure AVX2 is available (checked via
// `is_x86_feature_detected!` before [`Backend::Avx2`] is ever selected);
// every gather lane index is range-checked against the codebook table
// before the gather executes, and all loads/stores are unaligned
// intrinsics over in-bounds slice ranges.
unsafe fn decode_span_avx2(
    packed: &[u32],
    values: &[f32],
    k: usize,
    mask: u32,
    start: usize,
    amax: f32,
    mean: f32,
    out: &mut [f32],
) -> Result<()> {
    use std::arch::x86_64::*;
    let n = out.len();
    let vamax = _mm256_set1_ps(amax);
    let vmean = _mm256_set1_ps(mean);
    let mut idx = [0i32; 8];
    let mut e = 0usize;
    while e + 8 <= n {
        let mut hi = 0u32;
        for (j, slot) in idx.iter_mut().enumerate() {
            let v = packing::bit_window(packed, (start + e + j) * k, k, mask);
            hi = hi.max(v);
            *slot = v as i32;
        }
        // Gathering with an out-of-table lane would read past the
        // codebook slice, so a corrupt bitstream must bail before the
        // gather, exactly like the scalar path's per-element check.
        if hi as usize >= values.len() {
            anyhow::bail!("bitstream index {hi} out of range for {}-entry codebook", values.len());
        }
        let vidx = _mm256_loadu_si256(idx.as_ptr() as *const __m256i);
        let vals = _mm256_i32gather_ps::<4>(values.as_ptr(), vidx);
        let dq = _mm256_add_ps(_mm256_mul_ps(vals, vamax), vmean);
        _mm256_storeu_ps(out.as_mut_ptr().add(e), dq);
        e += 8;
    }
    for (j, o) in out.iter_mut().enumerate().skip(e) {
        let i = packing::bit_window(packed, (start + j) * k, k, mask) as usize;
        let Some(&val) = values.get(i) else {
            anyhow::bail!("bitstream index {i} out of range for {}-entry codebook", values.len());
        };
        *o = val * amax + mean;
    }
    Ok(())
}

/// Reference dense GEMM accumulating into `out`: `out[m,n] += x[m,k] @
/// w[k,n]`, row-major, k-outer axpy order. This exact loop order is the
/// bit-identity baseline the fused, tiled, and parallel paths are tested
/// against.
pub fn matmul_f32(x: &[f32], w: &[f32], out: &mut [f32], m: usize, kd: usize, n: usize) {
    debug_assert_eq!(x.len(), m * kd);
    debug_assert_eq!(w.len(), kd * n);
    debug_assert_eq!(out.len(), m * n);
    matmul_f32_with(active_backend(), x, w, out, m, kd, n)
}

/// [`matmul_f32`] with an explicit backend (parity tests drive both).
pub fn matmul_f32_with(
    backend: Backend,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
) {
    for r in 0..kd {
        let wrow = &w[r * n..(r + 1) * n];
        for i in 0..m {
            axpy(backend, x[i * kd + r], wrow, &mut out[i * n..(i + 1) * n]);
        }
    }
}

/// Column-parallel [`matmul_f32`]: the same deterministic span split and
/// seeded thread-local panels as [`fused_matmul_parallel`], so dense
/// projections scale with the same bit-identity guarantee. `threads <= 1`
/// runs the single-threaded loop in place.
pub fn matmul_f32_parallel(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(x.len(), m * kd);
    debug_assert_eq!(w.len(), kd * n);
    debug_assert_eq!(out.len(), m * n);
    let backend = active_backend();
    let spans = column_spans(n, threads);
    if spans.len() <= 1 {
        return matmul_f32_with(backend, x, w, out, m, kd, n);
    }
    let seed: &[f32] = out;
    let panels = crate::util::pool::parallel_map(spans.len(), spans.len(), |ti| {
        let (c0, c1) = spans[ti];
        let wd = c1 - c0;
        let mut local = vec![0.0f32; m * wd];
        for i in 0..m {
            local[i * wd..(i + 1) * wd].copy_from_slice(&seed[i * n + c0..i * n + c1]);
        }
        for r in 0..kd {
            let wrow = &w[r * n + c0..r * n + c1];
            for i in 0..m {
                axpy(backend, x[i * kd + r], wrow, &mut local[i * wd..(i + 1) * wd]);
            }
        }
        local
    });
    for (&(c0, c1), local) in spans.iter().zip(panels) {
        let wd = c1 - c0;
        for i in 0..m {
            out[i * n + c0..i * n + c1].copy_from_slice(&local[i * wd..(i + 1) * wd]);
        }
    }
}

/// Fused dequantize×matmul accumulating into `out`: `out[m,n] += x[m,k] @
/// W[k,n]` where `W` is `p`'s packed k-bit payload, decoded panel-by-panel
/// into `panel` (pass the same buffer across calls so the score path
/// allocates the scratch once; tile sizes come from
/// [`Tiling::for_geometry`]). Never materializes the full f32 weight
/// tensor.
pub fn fused_matmul(
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    panel: &mut Vec<f32>,
) -> Result<()> {
    fused_matmul_with(active_backend(), x, p, out, m, kd, n, panel)
}

/// [`fused_matmul`] with an explicit backend (parity tests drive both);
/// geometry-derived tiling.
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_with(
    backend: Backend,
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    panel: &mut Vec<f32>,
) -> Result<()> {
    fused_matmul_tiled(backend, Tiling::for_geometry(m, kd, n), x, p, out, m, kd, n, panel)
}

/// The untiled row-streaming fused loop (decode row `r`, sweep it across
/// all `m` inputs): the pre-tiling baseline, kept as the reference the
/// tiled and parallel paths are benched and parity-tested against.
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_untiled(
    backend: Backend,
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    wrow: &mut Vec<f32>,
) -> Result<()> {
    check_geometry(p, x, out, m, kd, n)?;
    wrow.resize(n, 0.0);
    for r in 0..kd {
        decode_range_with(backend, p, r * n, (r + 1) * n, wrow)?;
        for i in 0..m {
            axpy(backend, x[i * kd + r], wrow, &mut out[i * n..(i + 1) * n]);
        }
    }
    Ok(())
}

/// Cache-blocked fused matmul with an explicit [`Tiling`] (tests force
/// tiny tiles whose edges straddle quantization blocks; production goes
/// through [`fused_matmul`] / [`Tiling::for_geometry`]).
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_tiled(
    backend: Backend,
    tile: Tiling,
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    panel: &mut Vec<f32>,
) -> Result<()> {
    check_geometry(p, x, out, m, kd, n)?;
    fused_matmul_cols(backend, tile, x, p, out, m, kd, n, 0, n, n, panel)
}

/// Column-parallel fused matmul: output columns are split into one
/// contiguous span per worker (deterministic split; each column written by
/// exactly one thread), every worker runs the tiled kernel over its span
/// into a thread-local panel seeded from `out`, and panels are copied back
/// in span order — bit-identical to the single-threaded tiled kernel for
/// every thread count. `threads <= 1` (or a single span) runs in place
/// with the caller's `panel` scratch.
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_parallel(
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    threads: usize,
    panel: &mut Vec<f32>,
) -> Result<()> {
    fused_matmul_parallel_with(active_backend(), x, p, out, m, kd, n, threads, panel)
}

/// [`fused_matmul_parallel`] with an explicit backend (parity tests drive
/// scalar and AVX2 across thread counts).
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_parallel_with(
    backend: Backend,
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    threads: usize,
    panel: &mut Vec<f32>,
) -> Result<()> {
    check_geometry(p, x, out, m, kd, n)?;
    let spans = column_spans(n, threads);
    if spans.len() <= 1 {
        let tile = Tiling::for_geometry(m, kd, n);
        return fused_matmul_cols(backend, tile, x, p, out, m, kd, n, 0, n, n, panel);
    }
    let seed: &[f32] = out;
    let results = crate::util::pool::parallel_map_init(
        spans.len(),
        spans.len(),
        Vec::new,
        |scratch: &mut Vec<f32>, ti| -> Result<Vec<f32>> {
            let (c0, c1) = spans[ti];
            let w = c1 - c0;
            let mut local = vec![0.0f32; m * w];
            // Seed from the shared output so `+=` semantics (and signed
            // zeros) survive the round-trip through the local panel.
            for i in 0..m {
                local[i * w..(i + 1) * w].copy_from_slice(&seed[i * n + c0..i * n + c1]);
            }
            let tile = Tiling::for_geometry(m, kd, w);
            fused_matmul_cols(backend, tile, x, p, &mut local, m, kd, n, c0, c1, w, scratch)?;
            Ok(local)
        },
    );
    for (&(c0, c1), res) in spans.iter().zip(results) {
        let local = res?;
        let w = c1 - c0;
        for i in 0..m {
            out[i * n + c0..i * n + c1].copy_from_slice(&local[i * w..(i + 1) * w]);
        }
    }
    Ok(())
}

fn check_geometry(
    p: &PackedTensor,
    x: &[f32],
    out: &[f32],
    m: usize,
    kd: usize,
    n: usize,
) -> Result<()> {
    ensure!(p.n == kd * n, "packed tensor has {} elements, matmul wants {}x{}", p.n, kd, n);
    ensure!(x.len() == m * kd, "fused_matmul: x has {} elements, want {}", x.len(), m * kd);
    ensure!(out.len() == m * n, "fused_matmul: out has {} elements, want {}", out.len(), m * n);
    Ok(())
}

/// Split `0..n` into at most `parts` contiguous, near-equal spans
/// (deterministic; empty when `n == 0`).
fn column_spans(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, n.max(1));
    let per = n.div_ceil(parts);
    (0..parts)
        .filter_map(|t| {
            let lo = t * per;
            let hi = ((t + 1) * per).min(n);
            (lo < hi).then_some((lo, hi))
        })
        .collect()
}

/// The tiled accumulation core over output columns `c0..c1` of `p`'s
/// `kd × n` payload: each `tile.rows × span` weight panel is decoded once
/// into `panel`, then swept across all `m` input rows before the next
/// panel is decoded. Output element `(i, c)` lives at
/// `out[i * out_stride + (c - c0)]`, so the same core serves the in-place
/// full-width kernel (`out_stride = n`) and the parallel workers' local
/// panels (`out_stride = c1 - c0`). Column tiles advance outermost and
/// row tiles ascend inside them, so each output element accumulates `r`
/// in ascending order — the bit-identity invariant.
#[allow(clippy::too_many_arguments)]
fn fused_matmul_cols(
    backend: Backend,
    tile: Tiling,
    x: &[f32],
    p: &PackedTensor,
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    c0: usize,
    c1: usize,
    out_stride: usize,
    panel: &mut Vec<f32>,
) -> Result<()> {
    if c0 >= c1 {
        return Ok(());
    }
    let tw = tile.cols.max(1).min(c1 - c0);
    let tr = tile.rows.max(1);
    panel.resize(tr * tw, 0.0);
    let mut cs = c0;
    while cs < c1 {
        let ce = (cs + tw).min(c1);
        let w = ce - cs;
        let mut rs = 0usize;
        while rs < kd {
            let re = (rs + tr).min(kd);
            for r in rs..re {
                let dst = &mut panel[(r - rs) * w..(r - rs) * w + w];
                decode_range_with(backend, p, r * n + cs, r * n + ce, dst)?;
            }
            for i in 0..m {
                let o0 = i * out_stride + (cs - c0);
                let orow = &mut out[o0..o0 + w];
                for r in rs..re {
                    axpy(backend, x[i * kd + r], &panel[(r - rs) * w..(r - rs) * w + w], orow);
                }
            }
            rs = re;
        }
        cs = ce;
    }
    Ok(())
}

/// `out[c] += a * w[c]` — the one inner loop every matmul here reduces to.
/// `pub(crate)` so the entropy-coded fused path (`quant::entropy`) shares
/// the exact same accumulation kernel and stays bit-identical.
#[inline]
pub(crate) fn axpy(backend: Backend, a: f32, w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    match backend {
        Backend::Scalar => axpy_scalar(a, w, out),
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Backend::Avx2 is only ever selected after
            // `is_x86_feature_detected!("avx2")` (active_backend), or by a
            // test that checked `avx2_available()` first.
            unsafe {
                axpy_avx2(a, w, out)
            }
            #[cfg(not(target_arch = "x86_64"))]
            axpy_scalar(a, w, out)
        }
    }
}

#[inline]
fn axpy_scalar(a: f32, w: &[f32], out: &mut [f32]) {
    for (o, &wv) in out.iter_mut().zip(w) {
        *o += a * wv;
    }
}

/// AVX2 axpy: 8 lanes of `out += a * w` per iteration, scalar tail. Uses
/// separate mul + add (not `_mm256_fmadd_ps`): FMA skips the intermediate
/// rounding and would diverge from the scalar path in the last bit.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: callers must ensure AVX2 is available (checked via
// `is_x86_feature_detected!` before [`Backend::Avx2`] is ever selected);
// all loads/stores are unaligned intrinsics over in-bounds slice ranges.
unsafe fn axpy_avx2(a: f32, w: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = w.len();
    let va = _mm256_set1_ps(a);
    let mut c = 0usize;
    while c + 8 <= n {
        let vw = _mm256_loadu_ps(w.as_ptr().add(c));
        let vo = _mm256_loadu_ps(out.as_ptr().add(c));
        let sum = _mm256_add_ps(vo, _mm256_mul_ps(va, vw));
        _mm256_storeu_ps(out.as_mut_ptr().add(c), sum);
        c += 8;
    }
    while c < n {
        *out.get_unchecked_mut(c) += a * *w.get_unchecked(c);
        c += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::quant::codebook::DataType;
    use crate::quant::spec::QuantSpec;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    fn backends() -> Vec<Backend> {
        let mut b = vec![Backend::Scalar];
        if avx2_available() {
            b.push(Backend::Avx2);
        }
        b
    }

    #[test]
    fn decode_range_matches_full_decode() {
        check("decode-range-parity", 48, |rng, case| {
            let bits = 3 + case % 6;
            let block = [Some(16), Some(64), Some(256), None][(case / 6) % 4];
            let data = gen::weights(rng, 4000);
            let n = data.len();
            let mut spec = QuantSpec::new(DataType::ALL[rng.below(4)], bits, block);
            if rng.below(2) == 0 {
                spec = spec.with_centering();
            }
            let p = PackedTensor::quantize(&data, &spec).map_err(|e| format!("{e:#}"))?;
            let mut full = vec![0.0f32; n];
            p.dequantize_into(&mut full).map_err(|e| format!("{e:#}"))?;
            // A handful of random spans, plus the degenerate edges.
            let mut spans = vec![(0, n), (0, 0), (n, n)];
            for _ in 0..8 {
                let a = rng.below(n + 1);
                let b = a + rng.below(n - a + 1);
                spans.push((a, b));
            }
            for (lo, hi) in spans {
                for backend in backends() {
                    let mut got = vec![0.0f32; hi - lo];
                    decode_range_with(backend, &p, lo, hi, &mut got)
                        .map_err(|e| format!("{e:#}"))?;
                    prop_assert!(
                        got == full[lo..hi],
                        "bits={bits} block={block:?} n={n} span {lo}..{hi} {backend:?}: \
                         range decode != full decode slice"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decode_range_validates_bounds() {
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let p = PackedTensor::quantize(&[1.0f32; 100], &spec).unwrap();
        let mut buf = vec![0.0f32; 10];
        for backend in backends() {
            assert!(decode_range_with(backend, &p, 95, 105, &mut buf).is_err(), "hi past n");
            assert!(decode_range_with(backend, &p, 0, 5, &mut buf).is_err(), "buffer mismatch");
            assert!(decode_range_with(backend, &p, 0, 10, &mut buf).is_ok());
        }
    }

    #[test]
    fn tiling_for_geometry_is_sane() {
        for (m, kd, n) in [(1, 1, 1), (8, 768, 768), (32, 4096, 4096), (4, 3, 100_000)] {
            let t = Tiling::for_geometry(m, kd, n);
            assert!(t.rows >= 1 && t.rows <= kd.max(1), "{m}x{kd}x{n}: rows {}", t.rows);
            assert!(t.cols >= 1 && t.cols <= n.max(1).max(TILE_COLS), "cols {}", t.cols);
            assert!(t.rows * t.cols * 4 <= PANEL_BUDGET_BYTES.max(4 * t.cols));
        }
        assert_eq!(Tiling::row_streaming(40), Tiling { rows: 1, cols: 40 });
    }

    #[test]
    fn prop_fused_matmul_bit_identical_to_dequant_gemm() {
        // The tentpole invariant: scalar fused, SIMD fused, and the
        // dequantize_into→GEMM composition agree to the bit across bits
        // 3..=8 × block sizes (ragged tails included) × codebook dtypes.
        check("fused-matmul-parity", 48, |rng, case| {
            let bits = 3 + case % 6;
            let block = [Some(16), Some(32), Some(64), None][(case / 6) % 4];
            let m = 1 + rng.below(6);
            let kd = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let mut w = vec![0.0f32; kd * n];
            let std = 0.5;
            for v in w.iter_mut() {
                *v = (rng.normal() * std) as f32;
            }
            let x: Vec<f32> = (0..m * kd).map(|_| (rng.normal()) as f32).collect();
            let mut spec = QuantSpec::new(DataType::ALL[rng.below(4)], bits, block);
            if rng.below(2) == 0 {
                spec = spec.with_centering();
            }
            let p = PackedTensor::quantize(&w, &spec).map_err(|e| format!("{e:#}"))?;
            // Reference: full dequantize, then the same-order GEMM.
            let mut wd = vec![0.0f32; kd * n];
            p.dequantize_into(&mut wd).map_err(|e| format!("{e:#}"))?;
            let mut reference = vec![0.0f32; m * n];
            matmul_f32_with(Backend::Scalar, &x, &wd, &mut reference, m, kd, n);
            for backend in backends() {
                let mut got = vec![0.0f32; m * n];
                let mut wrow = Vec::new();
                fused_matmul_with(backend, &x, &p, &mut got, m, kd, n, &mut wrow)
                    .map_err(|e| format!("{e:#}"))?;
                prop_assert!(
                    got == reference,
                    "bits={bits} block={block:?} m={m} k={kd} n={n} {backend:?}: \
                     fused != dequantize_into+GEMM"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tiled_and_parallel_bit_identical_to_untiled() {
        // Backend × tiling × thread-count cross-parity: forced tiny tiles
        // whose edges straddle quantization blocks, and 1/2/4-way column
        // splits, must all reproduce the untiled scalar loop to the bit.
        check("fused-tiling-thread-parity", 32, |rng, case| {
            let bits = 3 + case % 6;
            let block = [Some(16), Some(32), None][(case / 6) % 3];
            let m = 1 + rng.below(4);
            let kd = 1 + rng.below(24);
            let n = 1 + rng.below(48);
            let mut w = vec![0.0f32; kd * n];
            for v in w.iter_mut() {
                *v = (rng.normal() * 0.5) as f32;
            }
            let x: Vec<f32> = (0..m * kd).map(|_| rng.normal() as f32).collect();
            let mut spec = QuantSpec::new(DataType::ALL[rng.below(4)], bits, block);
            if rng.below(2) == 0 {
                spec = spec.with_centering();
            }
            let p = PackedTensor::quantize(&w, &spec).map_err(|e| format!("{e:#}"))?;
            // Seed out with a prior accumulation so += survives every path.
            let seed: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let mut reference = seed.clone();
            let mut wrow = Vec::new();
            fused_matmul_untiled(Backend::Scalar, &x, &p, &mut reference, m, kd, n, &mut wrow)
                .map_err(|e| format!("{e:#}"))?;
            let tiles = [
                Tiling { rows: 1 + rng.below(5), cols: 1 + rng.below(9) },
                Tiling::row_streaming(n),
                Tiling::for_geometry(m, kd, n),
            ];
            for backend in backends() {
                for tile in tiles {
                    let mut got = seed.clone();
                    let mut panel = Vec::new();
                    fused_matmul_tiled(backend, tile, &x, &p, &mut got, m, kd, n, &mut panel)
                        .map_err(|e| format!("{e:#}"))?;
                    prop_assert!(
                        got == reference,
                        "bits={bits} block={block:?} m={m} k={kd} n={n} {backend:?} \
                         {tile:?}: tiled != untiled scalar"
                    );
                }
                for threads in [1usize, 2, 4] {
                    let mut got = seed.clone();
                    let mut panel = Vec::new();
                    fused_matmul_parallel_with(
                        backend, &x, &p, &mut got, m, kd, n, threads, &mut panel,
                    )
                    .map_err(|e| format!("{e:#}"))?;
                    prop_assert!(
                        got == reference,
                        "bits={bits} block={block:?} m={m} k={kd} n={n} {backend:?} \
                         threads={threads}: parallel != untiled scalar"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_parallel_dense_matmul_matches_scalar() {
        check("dense-parallel-parity", 24, |rng, _| {
            let m = 1 + rng.below(4);
            let kd = 1 + rng.below(30);
            let n = 1 + rng.below(60);
            let x: Vec<f32> = (0..m * kd).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..kd * n).map(|_| rng.normal() as f32).collect();
            let seed: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let mut reference = seed.clone();
            matmul_f32_with(Backend::Scalar, &x, &w, &mut reference, m, kd, n);
            let mut simd = seed.clone();
            matmul_f32(&x, &w, &mut simd, m, kd, n);
            prop_assert!(simd == reference, "m={m} k={kd} n={n}: active dense != scalar");
            for threads in [2usize, 3, 4] {
                let mut got = seed.clone();
                matmul_f32_parallel(&x, &w, &mut got, m, kd, n, threads);
                prop_assert!(
                    got == reference,
                    "m={m} k={kd} n={n} threads={threads}: parallel dense != scalar"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_simd_dense_matmul_matches_scalar() {
        if !avx2_available() {
            return; // nothing to compare on this host
        }
        check("dense-axpy-simd-parity", 32, |rng, _| {
            let m = 1 + rng.below(5);
            let kd = 1 + rng.below(50);
            let n = 1 + rng.below(70); // crosses the 8-lane boundary + tail
            let x: Vec<f32> = (0..m * kd).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..kd * n).map(|_| rng.normal() as f32).collect();
            let mut a = vec![0.0f32; m * n];
            let mut b = vec![0.0f32; m * n];
            matmul_f32_with(Backend::Scalar, &x, &w, &mut a, m, kd, n);
            matmul_f32_with(Backend::Avx2, &x, &w, &mut b, m, kd, n);
            prop_assert!(a == b, "m={m} k={kd} n={n}: AVX2 dense GEMM != scalar");
            Ok(())
        });
    }

    #[test]
    fn fused_matmul_accumulates_into_out() {
        // `out +=`, not `out =`: the transformer residual path relies on it.
        let spec = QuantSpec::new(DataType::Fp, 4, Some(64));
        let w = vec![1.0f32; 8];
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let x = vec![1.0f32; 2];
        let mut out = vec![10.0f32; 4];
        let mut wrow = Vec::new();
        fused_matmul(&x, &p, &mut out, 1, 2, 4, &mut wrow).unwrap();
        let mut wd = vec![0.0f32; 8];
        p.dequantize_into(&mut wd).unwrap();
        let mut expect = vec![10.0f32; 4];
        matmul_f32_with(Backend::Scalar, &x, &wd, &mut expect, 1, 2, 4);
        assert_eq!(out, expect);
    }

    #[test]
    fn fused_matmul_rejects_geometry_mismatch() {
        let spec = QuantSpec::new(DataType::Int, 4, Some(64));
        let p = PackedTensor::quantize(&[0.5f32; 12], &spec).unwrap();
        let mut wrow = Vec::new();
        let x = vec![1.0f32; 3];
        let mut out = vec![0.0f32; 4];
        // p.n = 12 != 3*5
        assert!(fused_matmul(&x, &p, &mut out, 1, 3, 5, &mut wrow).is_err());
        // x too short for m=2
        assert!(fused_matmul(&x, &p, &mut out, 2, 3, 4, &mut wrow).is_err());
        assert!(fused_matmul(&x, &p, &mut out, 1, 3, 4, &mut wrow).is_ok());
        // The parallel entry enforces the same geometry checks.
        let mut panel = Vec::new();
        assert!(fused_matmul_parallel(&x, &p, &mut out, 1, 3, 5, 4, &mut panel).is_err());
        assert!(fused_matmul_parallel(&x, &p, &mut out, 1, 3, 4, 4, &mut panel).is_ok());
    }

    #[test]
    fn column_spans_partition_exactly() {
        for (n, parts) in [(0usize, 4usize), (1, 4), (7, 3), (8, 8), (100, 7), (5, 1)] {
            let spans = column_spans(n, parts);
            let mut next = 0usize;
            for &(lo, hi) in &spans {
                assert_eq!(lo, next, "n={n} parts={parts}: gap or overlap");
                assert!(hi > lo);
                next = hi;
            }
            assert_eq!(next, n, "n={n} parts={parts}: columns not covered");
            assert!(spans.len() <= parts.max(1));
        }
    }

    #[test]
    fn zero_inputs_preserve_signed_zero_semantics() {
        // x = 0 rows must still run the axpy (skipping would turn -0.0
        // outputs into +0.0 and break bit-identity with the reference).
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let spec = QuantSpec::new(DataType::Fp, 4, Some(16));
        let p = PackedTensor::quantize(&w, &spec).unwrap();
        let x = vec![0.0f32; 4];
        let mut wd = vec![0.0f32; 16];
        p.dequantize_into(&mut wd).unwrap();
        for backend in backends() {
            for threads in [1usize, 2, 4] {
                let mut got = vec![-0.0f32; 4];
                let mut expect = vec![-0.0f32; 4];
                let mut wrow = Vec::new();
                fused_matmul_parallel_with(backend, &x, &p, &mut got, 1, 4, 4, threads, &mut wrow)
                    .unwrap();
                matmul_f32_with(Backend::Scalar, &x, &wd, &mut expect, 1, 4, 4);
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let eb: Vec<u32> = expect.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, eb, "{backend:?} threads={threads}");
            }
        }
    }
}
