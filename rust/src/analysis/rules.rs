//! The lint rules: panic paths, unsafe discipline, lock order, protocol
//! doc exhaustiveness, and the `lint: allow` escape hatch.
//!
//! Every rule works on the flat token stream from [`super::lexer`] — no
//! AST, no name resolution. That keeps the pass dependency-free and fast,
//! at the price of being syntactic: the lock-order rule, for instance,
//! keys on *receiver field names* (`self.models.lock()` → class
//! `registry.models`), which works because this crate names its mutexes
//! uniquely per subsystem. The tables below are the crate's declared
//! invariants; a new mutex field must be registered here (and its
//! ordering edges declared) before the tree lints clean.

use std::collections::{HashMap, HashSet};

use super::lexer::{Comment, Tok, TokKind};

/// Rule identifiers — the names `lint: allow(<rule>)` accepts.
pub const RULE_PANIC: &str = "panic-path";
pub const RULE_UNSAFE: &str = "unsafe-discipline";
pub const RULE_LOCK: &str = "lock-order";
pub const RULE_PROTOCOL: &str = "protocol-doc";
pub const RULE_ALLOW: &str = "lint-allow";

pub const RULES: &[&str] = &[RULE_PANIC, RULE_UNSAFE, RULE_LOCK, RULE_PROTOCOL, RULE_ALLOW];

/// One lint violation, pointing at a repo-relative file and 1-based line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

// ---------------------------------------------------------------- config

/// Mutex/Condvar receiver field name → lock class. The class is the unit
/// the declared partial order ranks; several fields may share one class
/// (a Condvar and the Mutex it pairs with).
const LOCK_CLASSES: &[(&str, &str)] = &[
    ("models", "registry.models"),
    ("default_key", "registry.default"),
    ("loading", "registry.flight"),
    ("loaded_cv", "registry.flight"),
    ("policy", "policy"),
    ("policy_source", "policy"),
    ("shards", "cache.shard"),
    ("shard", "cache.shard"),
    ("shard_for", "cache.shard"),
    ("stop", "pool.latch"),
    ("slots", "pool.slot"),
    ("cache", "runtime.cache"),
    ("compiling", "runtime.flight"),
    ("compiled_cv", "runtime.flight"),
    ("workers", "fleet.roster"),
    ("inner", "store.inner"),
    ("not_full", "store.inner"),
    ("not_empty", "store.inner"),
    ("state", "pool.latch"),
    ("cv", "pool.latch"),
    ("param_cache", "coordinator.params"),
    ("params_cache", "coordinator.params"),
    ("CACHE", "quant.codebooks"),
    ("window", "fleet.telemetry"),
    ("govstate", "fleet.governor"),
];

/// Receivers whose `.lock()` is not a Mutex (stdio handles).
const LOCK_IGNORE: &[&str] = &["stdin", "stdout", "stderr"];

/// The declared lock partial order: `(held, acquired)` pairs that may
/// nest, outermost first. Checked under transitive closure; any observed
/// nesting not reachable from these edges is an undeclared-edge finding.
pub const DECLARED_ORDER: &[(&str, &str)] = &[
    ("registry.models", "registry.default"),
    ("registry.models", "cache.shard"),
    ("cache.shard", "registry.flight"),
    ("registry.models", "runtime.cache"),
    ("runtime.cache", "runtime.flight"),
    ("fleet.roster", "fleet.conn"),
];

/// Modules allowed to contain `unsafe` (each use still needs `// SAFETY:`).
const UNSAFE_ALLOWED: &[&str] = &["quant/fused.rs", "runtime/mod.rs"];

/// Macros that abort the thread — banned on network paths.
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Keywords that may directly precede `[` without it being an index
/// expression (`match x[..]` never parses; `&mut [u8]` does).
const NONINDEX_KEYWORDS: &[&str] = &[
    "mut", "return", "in", "as", "dyn", "box", "static", "const", "let", "ref", "move", "else",
    "match", "if",
];

fn lock_class(recv: &str) -> Option<&'static str> {
    LOCK_CLASSES.iter().find(|(f, _)| *f == recv).map(|(_, c)| *c)
}

/// Transitive closure of [`DECLARED_ORDER`].
fn declared_closure() -> HashSet<(&'static str, &'static str)> {
    let mut cl: HashSet<(&'static str, &'static str)> = DECLARED_ORDER.iter().copied().collect();
    loop {
        let mut added = Vec::new();
        for &(a, b) in &cl {
            for &(c, d) in &cl {
                if b == c && !cl.contains(&(a, d)) {
                    added.push((a, d));
                }
            }
        }
        if added.is_empty() {
            return cl;
        }
        cl.extend(added);
    }
}

// --------------------------------------------------------------- helpers

/// Parse `lint: allow(<rule>) — <reason>` annotations out of the comment
/// list. Returns the `(line, rule)` suppression set; malformed
/// annotations (unknown rule, missing justification) become `lint-allow`
/// findings — the escape hatch itself is linted.
fn parse_allows(
    comments: &[Comment],
    toks: &[Tok],
    findings: &mut Vec<Finding>,
    relpath: &str,
) -> HashSet<(usize, &'static str)> {
    let mut allows = HashSet::new();
    let mut tok_lines: Vec<usize> = toks.iter().map(|t| t.line).collect();
    tok_lines.sort_unstable();
    tok_lines.dedup();
    const MARK: &str = "lint: allow(";
    for c in comments {
        // Annotations live in plain `//` comments only: doc comments
        // (`///`, `//!`, `/** */`) describe the convention, never carry it.
        if c.text.starts_with(['/', '!', '*']) {
            continue;
        }
        let Some(idx) = c.text.find(MARK) else { continue };
        let rest = &c.text[idx + MARK.len()..];
        let Some(close) = rest.find(')') else {
            findings.push(Finding {
                file: relpath.to_string(),
                line: c.start_line,
                rule: RULE_ALLOW,
                msg: "malformed allow annotation (no closing `)`)".to_string(),
            });
            continue;
        };
        let rule_name = rest[..close].trim();
        let mut reason = rest[close + 1..].trim();
        for sep in ["—", "--", "-", ":"] {
            if let Some(r) = reason.strip_prefix(sep) {
                reason = r.trim();
                break;
            }
        }
        let Some(rule) = RULES.iter().copied().find(|r| *r == rule_name) else {
            findings.push(Finding {
                file: relpath.to_string(),
                line: c.start_line,
                rule: RULE_ALLOW,
                msg: format!("allow names unknown rule `{rule_name}`"),
            });
            continue;
        };
        if reason.len() < 3 {
            findings.push(Finding {
                file: relpath.to_string(),
                line: c.start_line,
                rule: RULE_ALLOW,
                msg: format!("allow({rule}) carries no justification"),
            });
            continue;
        }
        if c.own_line {
            // Own-line annotation suppresses the next line holding code.
            if let Some(&target) = tok_lines.iter().find(|&&l| l > c.end_line) {
                allows.insert((target, rule));
            }
        } else {
            allows.insert((c.start_line, rule));
        }
    }
    allows
}

/// Token index ranges `[a, b]` covered by `#[cfg(test)] mod/fn { … }` —
/// test code may unwrap freely.
fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let n = toks.len();
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < n {
        if !(toks[i].is("#") && i + 1 < n && toks[i + 1].is("[")) {
            i += 1;
            continue;
        }
        // Scan the attribute to its matching `]`, collecting idents.
        let mut depth = 0usize;
        let mut j = i + 1;
        let mut saw_cfg = false;
        let mut saw_test = false;
        while j < n {
            if toks[j].is("[") {
                depth += 1;
            } else if toks[j].is("]") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if toks[j].kind == TokKind::Ident {
                saw_cfg |= toks[j].text == "cfg";
                saw_test |= toks[j].text == "test";
            }
            j += 1;
        }
        if saw_cfg && saw_test {
            let mut k = j + 1;
            // Skip any further attributes between cfg(test) and the item.
            while k < n && toks[k].is("#") && k + 1 < n && toks[k + 1].is("[") {
                let mut d2 = 0usize;
                k += 1;
                while k < n {
                    if toks[k].is("[") {
                        d2 += 1;
                    } else if toks[k].is("]") {
                        d2 -= 1;
                        if d2 == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                k += 1;
            }
            if k < n && (toks[k].is_ident("mod") || toks[k].is_ident("fn")) {
                while k < n && !toks[k].is("{") {
                    k += 1;
                }
                let body_start = k;
                let mut d2 = 0usize;
                while k < n {
                    if toks[k].is("{") {
                        d2 += 1;
                    } else if toks[k].is("}") {
                        d2 -= 1;
                        if d2 == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                ranges.push((body_start, k));
            }
        }
        i = j + 1;
    }
    ranges
}

fn in_ranges(idx: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(a, b)| a <= idx && idx <= b)
}

/// Walk back over one or more `(...)` / `[...]` groups ending at `j`,
/// returning the index of the token before the outermost group — the
/// receiver position for a chained call like `self.shard_for(h).lock()`.
fn back_over_groups(toks: &[Tok], mut j: usize) -> Option<usize> {
    loop {
        let t = &toks[j];
        let (close, open) = match t.text.as_str() {
            ")" => (")", "("),
            "]" => ("]", "["),
            _ => return Some(j),
        };
        let mut depth = 0usize;
        loop {
            if toks[j].is(close) {
                depth += 1;
            } else if toks[j].is(open) {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            j = j.checked_sub(1)?;
        }
        j = j.checked_sub(1)?;
    }
}

// ----------------------------------------------------------------- rules

/// Rule 1 — panic paths: in network-facing modules (`server/`, `fleet/`)
/// and the untrusted-bitstream decoder (`quant/entropy.rs`, which parses
/// Huffman tables and coded streams that arrive wire-adjacent) no
/// `.unwrap()` / `.expect()`, no aborting macros, no unchecked slice
/// indexing. Exemption: `.lock().unwrap()` / `.wait(..).unwrap()` — the
/// crate-wide convention for propagating mutex poisoning (a poisoned lock
/// means another thread already panicked; unwrapping re-raises instead of
/// serving with torn state).
fn rule_panic(relpath: &str, toks: &[Tok], ranges: &[(usize, usize)], findings: &mut Vec<Finding>) {
    if !(relpath.starts_with("server/")
        || relpath.starts_with("fleet/")
        || relpath == "quant/entropy.rs")
    {
        return;
    }
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if in_ranges(i, ranges) {
            i += 1;
            continue;
        }
        let t = &toks[i];
        let method_call = t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && i + 1 < n
            && toks[i + 1].is("(")
            && i >= 1
            && toks[i - 1].is(".");
        if method_call {
            // Poisoning-propagation exemption: receiver is a lock()/wait()
            // call directly.
            let exempt = t.text == "unwrap"
                && i >= 2
                && toks[i - 2].is(")")
                && back_over_groups(toks, i - 2)
                    .is_some_and(|j| matches!(toks[j].text.as_str(), "lock" | "wait" | "wait_timeout") && toks[j].kind == TokKind::Ident);
            if !exempt {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: t.line,
                    rule: RULE_PANIC,
                    msg: format!("`.{}()` on a network path", t.text),
                });
            }
        } else if t.kind == TokKind::Ident
            && PANIC_MACROS.contains(&t.text.as_str())
            && i + 1 < n
            && toks[i + 1].is("!")
        {
            findings.push(Finding {
                file: relpath.to_string(),
                line: t.line,
                rule: RULE_PANIC,
                msg: format!("`{}!` on a network path", t.text),
            });
        } else if t.is("[") && i >= 1 {
            let prev = &toks[i - 1];
            let indexable = matches!(prev.kind, TokKind::Ident | TokKind::Str)
                || prev.is(")")
                || prev.is("]");
            let keyword =
                prev.kind == TokKind::Ident && NONINDEX_KEYWORDS.contains(&prev.text.as_str());
            if indexable && !keyword {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: t.line,
                    rule: RULE_PANIC,
                    msg: "unchecked slice/array index on a network path".to_string(),
                });
            }
        }
        i += 1;
    }
}

/// Rule 2 — unsafe discipline: `unsafe` only in the allowlisted kernel
/// modules, and every use immediately preceded by (or sharing a line
/// with) a comment run containing `SAFETY:`.
fn rule_unsafe(relpath: &str, toks: &[Tok], comments: &[Comment], findings: &mut Vec<Finding>) {
    let mut comment_lines: HashMap<usize, Vec<&str>> = HashMap::new();
    for c in comments {
        for l in c.start_line..=c.end_line {
            comment_lines.entry(l).or_default().push(&c.text);
        }
    }
    for t in toks {
        if !t.is_ident("unsafe") {
            continue;
        }
        if !UNSAFE_ALLOWED.contains(&relpath) {
            findings.push(Finding {
                file: relpath.to_string(),
                line: t.line,
                rule: RULE_UNSAFE,
                msg: "`unsafe` outside the allowlisted kernel modules".to_string(),
            });
            continue;
        }
        // Collect the same-line comment plus the contiguous run of
        // comment lines directly above.
        let mut seen: Vec<&str> = comment_lines.get(&t.line).cloned().unwrap_or_default();
        let mut l = t.line - 1;
        while let Some(texts) = comment_lines.get(&l) {
            seen.extend(texts.iter().copied());
            if l == 0 {
                break;
            }
            l -= 1;
        }
        if !seen.iter().any(|s| s.contains("SAFETY:")) {
            findings.push(Finding {
                file: relpath.to_string(),
                line: t.line,
                rule: RULE_UNSAFE,
                msg: "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            });
        }
    }
}

/// One lock guard the walker currently believes is held.
struct Held {
    cls: &'static str,
    depth: usize,
    let_bound: bool,
    var: Option<String>,
}

/// Rule 3 — lock order: walk each function, track which lock classes are
/// held (let-bound guards live until their scope closes, expression
/// temporaries until the end of the statement, `drop(g)` releases early),
/// and flag (a) locks on unregistered receiver fields and (b) nesting
/// edges absent from the declared order's transitive closure.
fn rule_lock(relpath: &str, toks: &[Tok], ranges: &[(usize, usize)], findings: &mut Vec<Finding>) {
    let declared = declared_closure();
    let n = toks.len();
    let mut depth = 0usize;
    let mut held: Vec<Held> = Vec::new();
    let mut cur_fn = String::from("?");
    let mut stmt_start = true;
    let mut stmt_let = false;
    let mut reported: HashSet<String> = HashSet::new();
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => {
                    held.retain(|h| h.let_bound || h.depth != depth);
                    depth += 1;
                    stmt_start = true;
                    stmt_let = false;
                    i += 1;
                    continue;
                }
                "}" => {
                    depth = depth.saturating_sub(1);
                    held.retain(|h| h.depth <= depth);
                    stmt_start = true;
                    stmt_let = false;
                    i += 1;
                    continue;
                }
                ";" => {
                    held.retain(|h| h.let_bound || h.depth != depth);
                    stmt_start = true;
                    stmt_let = false;
                    i += 1;
                    continue;
                }
                _ => {}
            }
        }
        if t.is_ident("fn") && i + 1 < n && toks[i + 1].kind == TokKind::Ident {
            cur_fn = toks[i + 1].text.clone();
        }
        if stmt_start && t.kind == TokKind::Ident {
            stmt_let = t.text == "let";
            stmt_start = false;
        }
        if t.is_ident("drop")
            && i + 2 < n
            && toks[i + 1].is("(")
            && toks[i + 2].kind == TokKind::Ident
        {
            let name = toks[i + 2].text.clone();
            held.retain(|h| h.var.as_deref() != Some(name.as_str()));
        }
        let is_acquire = t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "lock" | "wait" | "wait_timeout")
            && i + 1 < n
            && toks[i + 1].is("(")
            && i >= 2
            && toks[i - 1].is(".");
        if is_acquire {
            let recv = back_over_groups(toks, i - 2)
                .filter(|&j| toks[j].kind == TokKind::Ident)
                .map(|j| toks[j].text.clone());
            let recv_name = recv.as_deref().unwrap_or("<expr>");
            if LOCK_IGNORE.contains(&recv_name) || in_ranges(i, ranges) {
                i += 1;
                continue;
            }
            let Some(cls) = lock_class(recv_name) else {
                let key = format!("unreg:{cur_fn}:{recv_name}");
                if reported.insert(key) {
                    findings.push(Finding {
                        file: relpath.to_string(),
                        line: t.line,
                        rule: RULE_LOCK,
                        msg: format!(
                            "lock on unregistered field `{recv_name}` (fn {cur_fn}) — add a lock class"
                        ),
                    });
                }
                i += 1;
                continue;
            };
            for h in &held {
                if h.cls != cls && !declared.contains(&(h.cls, cls)) {
                    let key = format!("edge:{cur_fn}:{}:{cls}", h.cls);
                    if reported.insert(key) {
                        findings.push(Finding {
                            file: relpath.to_string(),
                            line: t.line,
                            rule: RULE_LOCK,
                            msg: format!(
                                "undeclared lock-order edge {} -> {cls} in fn {cur_fn}",
                                h.cls
                            ),
                        });
                    }
                }
            }
            // Condvar waits release and reacquire; they check ordering
            // (above) but do not add a held guard.
            if t.text == "lock" {
                held.push(Held {
                    cls,
                    depth,
                    let_bound: stmt_let,
                    var: let_var(toks, i),
                });
            }
        }
        i += 1;
    }
}

/// The `let [mut] NAME` binding of the statement containing token `i`,
/// if any — how `drop(name)` is matched back to its guard.
fn let_var(toks: &[Tok], i: usize) -> Option<String> {
    let mut j = i;
    while !matches!(toks[j].text.as_str(), ";" | "{" | "}") {
        j = j.checked_sub(1)?;
    }
    j += 1;
    if !toks.get(j)?.is_ident("let") {
        return None;
    }
    j += 1;
    if toks.get(j)?.is_ident("mut") {
        j += 1;
    }
    let t = toks.get(j)?;
    (t.kind == TokKind::Ident).then(|| t.text.clone())
}

/// Rule 4 — protocol exhaustiveness: every op dispatched in
/// `server/mod.rs` (`try_handle` match arms plus the `hello` literal in
/// `pump`) must appear in the `//!` protocol doc block and vice versa;
/// and the bin1 wire constants stay single-sourced in `server/frames.rs`
/// (no stray `0xB1` magic or layout-constant redefinitions elsewhere).
fn rule_protocol(relpath: &str, toks: &[Tok], comments: &[Comment], findings: &mut Vec<Finding>) {
    let n = toks.len();
    if relpath != "server/frames.rs" {
        let mut i = 0usize;
        while i < n {
            let t = &toks[i];
            if t.kind == TokKind::Num && t.text.eq_ignore_ascii_case("0xb1") {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: t.line,
                    rule: RULE_PROTOCOL,
                    msg: "bin1 magic literal outside server/frames.rs".to_string(),
                });
            }
            if t.is_ident("const")
                && i + 1 < n
                && matches!(toks[i + 1].text.as_str(), "HEADER_BYTES" | "ROW_BYTES" | "PREFIX_BYTES")
            {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: t.line,
                    rule: RULE_PROTOCOL,
                    msg: "bin1 layout constant redefined outside server/frames.rs".to_string(),
                });
            }
            i += 1;
        }
    }
    if relpath != "server/mod.rs" {
        return;
    }
    // Documented ops: `"op":"NAME"` occurrences in `//!` doc comments.
    let mut documented: HashSet<String> = HashSet::new();
    for c in comments {
        if !c.text.starts_with('!') {
            continue;
        }
        let mut rest = c.text.as_str();
        const MARK: &str = "\"op\":\"";
        while let Some(idx) = rest.find(MARK) {
            rest = &rest[idx + MARK.len()..];
            let Some(close) = rest.find('"') else { break };
            documented.insert(rest[..close].to_string());
            rest = &rest[close..];
        }
    }
    // Dispatched ops: string-literal match arms one brace level inside the
    // `match` of `fn try_handle`, plus the `hello` literal in `fn pump`.
    let mut dispatched: HashSet<String> = HashSet::new();
    let mut f = 0usize;
    while f + 1 < n {
        if toks[f].is_ident("fn") && toks[f + 1].is_ident("try_handle") {
            let mut m = f;
            while m < n && !toks[m].is_ident("match") {
                m += 1;
            }
            while m < n && !toks[m].is("{") {
                m += 1;
            }
            let mut d = 0usize;
            let mut j = m;
            while j < n {
                if toks[j].is("{") {
                    d += 1;
                } else if toks[j].is("}") {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                } else if toks[j].kind == TokKind::Str && d == 1 {
                    let nxt = toks.get(j + 1).map(|t| t.text.as_str()).unwrap_or("");
                    let nxt2 = toks.get(j + 2).map(|t| t.text.as_str()).unwrap_or("");
                    let prv = if j >= 1 { toks[j - 1].text.as_str() } else { "" };
                    if (nxt == "=" && nxt2 == ">") || nxt == "|" || prv == "|" {
                        dispatched.insert(toks[j].text.clone());
                    }
                }
                j += 1;
            }
            break;
        }
        f += 1;
    }
    let mut f = 0usize;
    while f + 1 < n {
        if toks[f].is_ident("fn") && toks[f + 1].is_ident("pump") {
            let mut j = f;
            while j < n && !toks[j].is("{") {
                j += 1;
            }
            let mut d = 0usize;
            while j < n {
                if toks[j].is("{") {
                    d += 1;
                } else if toks[j].is("}") {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                } else if toks[j].kind == TokKind::Str && toks[j].text == "hello" {
                    dispatched.insert("hello".to_string());
                }
                j += 1;
            }
            break;
        }
        f += 1;
    }
    if documented.is_empty() || dispatched.is_empty() {
        findings.push(Finding {
            file: relpath.to_string(),
            line: 1,
            rule: RULE_PROTOCOL,
            msg: "could not locate protocol doc block or dispatch table".to_string(),
        });
        return;
    }
    let mut missing_doc: Vec<&String> = dispatched.difference(&documented).collect();
    missing_doc.sort();
    for op in missing_doc {
        findings.push(Finding {
            file: relpath.to_string(),
            line: 1,
            rule: RULE_PROTOCOL,
            msg: format!("op `{op}` dispatched but missing from the protocol doc block"),
        });
    }
    let mut missing_dispatch: Vec<&String> = documented.difference(&dispatched).collect();
    missing_dispatch.sort();
    for op in missing_dispatch {
        findings.push(Finding {
            file: relpath.to_string(),
            line: 1,
            rule: RULE_PROTOCOL,
            msg: format!("op `{op}` documented but not dispatched"),
        });
    }
}

// ---------------------------------------------------------------- driver

/// Per-file lint result: surviving findings plus how many annotations
/// suppressed one.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub allows: usize,
}

/// Run every rule over one file. `relpath` is the path relative to the
/// source root with `/` separators (`server/frames.rs`) — it selects
/// which rules apply.
pub fn analyze_file(relpath: &str, src: &str) -> FileReport {
    let (toks, comments) = super::lexer::scan(src);
    let mut findings: Vec<Finding> = Vec::new();
    let allows = parse_allows(&comments, &toks, &mut findings, relpath);
    let ranges = test_regions(&toks);
    rule_panic(relpath, &toks, &ranges, &mut findings);
    rule_unsafe(relpath, &toks, &comments, &mut findings);
    rule_lock(relpath, &toks, &ranges, &mut findings);
    rule_protocol(relpath, &toks, &comments, &mut findings);
    let n_allows = allows.len();
    findings.retain(|f| !allows.contains(&(f.line, f.rule)));
    FileReport { findings, allows: n_allows }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(relpath: &str, src: &str) -> Vec<Finding> {
        analyze_file(relpath, src).findings
    }

    #[test]
    fn unwrap_on_network_path_is_flagged_and_lock_unwrap_is_not() {
        let src = "fn f(v: Vec<u32>) { v.first().unwrap(); }";
        assert_eq!(lint("server/x.rs", src).len(), 1);
        assert!(lint("quant/x.rs", src).is_empty(), "rule scoped to server//fleet/");
        let poisoning = "fn f(m: &Mutex<u32>) { m.lock().unwrap(); }";
        assert!(
            lint("server/x.rs", poisoning).iter().all(|f| f.rule != RULE_PANIC),
            "lock().unwrap() is the poisoning-propagation convention"
        );
    }

    #[test]
    fn entropy_decoder_is_held_to_the_network_path_rule() {
        // quant/entropy.rs parses untrusted Huffman tables and coded
        // bitstreams, so it is gated like server//fleet/ — unlike the
        // rest of quant/, which only sees data this process produced.
        let index = "fn f(v: &[u32], i: usize) -> u32 { v[i] }";
        assert!(
            lint("quant/entropy.rs", index).iter().any(|f| f.rule == RULE_PANIC),
            "unchecked indexing in the entropy decoder must be flagged"
        );
        assert!(lint("quant/packing.rs", index).is_empty(), "the gate names one quant file");
        let unwrap = "fn f(v: Vec<u32>) { v.first().unwrap(); }";
        assert!(lint("quant/entropy.rs", unwrap).iter().any(|f| f.rule == RULE_PANIC));
    }

    #[test]
    fn governor_and_telemetry_are_on_the_network_path() {
        // fleet/governor.rs acts on worker responses and fleet/telemetry.rs
        // aggregates untrusted request timings — both ride the fleet/
        // prefix gate, so panic paths are findings there too.
        let index = "fn f(v: &[u32], i: usize) -> u32 { v[i] }";
        let unwrap = "fn f(v: Vec<u32>) { v.first().unwrap(); }";
        for file in ["fleet/governor.rs", "fleet/telemetry.rs"] {
            assert!(
                lint(file, index).iter().any(|f| f.rule == RULE_PANIC),
                "unchecked indexing in {file} must be flagged"
            );
            assert!(
                lint(file, unwrap).iter().any(|f| f.rule == RULE_PANIC),
                "unwrap in {file} must be flagged"
            );
        }
        // Their mutexes are registered lock classes: single acquires
        // pass, and the governor's state nesting under the telemetry
        // window would be an undeclared edge.
        let single = "fn f(&self) { let g = self.govstate.lock().unwrap(); }";
        assert!(lint("fleet/governor.rs", single).iter().all(|f| f.rule != RULE_LOCK));
        let nested = "fn f(&self) { let g = self.window.lock().unwrap(); let h = self.govstate.lock().unwrap(); }";
        assert!(
            lint("fleet/telemetry.rs", nested)
                .iter()
                .any(|f| f.rule == RULE_LOCK && f.msg.contains("fleet.telemetry")),
            "telemetry -> governor nesting is not a declared edge"
        );
    }

    #[test]
    fn allow_annotation_needs_a_reason() {
        let flagged = "fn f(v: &[u32]) {\n    // lint: allow(panic-path)\n    v.first().unwrap();\n}";
        let fs = lint("server/x.rs", flagged);
        assert!(fs.iter().any(|f| f.rule == RULE_ALLOW), "reasonless allow is itself flagged");
        assert!(fs.iter().any(|f| f.rule == RULE_PANIC), "and does not suppress");
        let ok = "fn f(v: &[u32]) {\n    // lint: allow(panic-path) — invariant: v is non-empty here\n    v.first().unwrap();\n}";
        assert!(lint("server/x.rs", ok).is_empty());
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}";
        assert!(lint("server/x.rs", src).is_empty());
    }

    #[test]
    fn undeclared_lock_edge_is_flagged_and_declared_edge_is_not() {
        let bad = "fn f(&self) { let g = self.workers.lock().unwrap(); let h = self.models.lock().unwrap(); }";
        let fs = lint("fleet/x.rs", bad);
        assert!(
            fs.iter().any(|f| f.rule == RULE_LOCK && f.msg.contains("fleet.roster")),
            "roster -> registry.models is not a declared edge: {fs:?}"
        );
        let ok = "fn f(&self) { let g = self.models.lock().unwrap(); let h = self.default_key.lock().unwrap(); }";
        assert!(lint("server/x.rs", ok).iter().all(|f| f.rule != RULE_LOCK));
    }

    #[test]
    fn drop_releases_a_guard() {
        let src = "fn f(&self) { let g = self.workers.lock().unwrap(); drop(g); let h = self.models.lock().unwrap(); }";
        assert!(lint("fleet/x.rs", src).iter().all(|f| f.rule != RULE_LOCK));
    }

    #[test]
    fn unsafe_rules() {
        let outside = "fn f() { unsafe { core::hint::unreachable_unchecked() } }";
        assert!(lint("server/x.rs", outside).iter().any(|f| f.rule == RULE_UNSAFE));
        let no_comment = "fn f() { unsafe { g() } }";
        assert!(lint("runtime/mod.rs", no_comment).iter().any(|f| f.rule == RULE_UNSAFE));
        let ok = "fn f() {\n    // SAFETY: g has no preconditions\n    unsafe { g() }\n}";
        assert!(lint("runtime/mod.rs", ok).is_empty());
    }

    #[test]
    fn protocol_doc_mismatch_both_directions() {
        let src = "//! `{\"op\":\"ping\"}` and `{\"op\":\"ghost\"}`\nfn try_handle(op: &str) {\n    match op {\n        \"ping\" => {}\n        \"extra\" => {}\n        _ => {}\n    }\n}\n";
        let fs = lint("server/mod.rs", src);
        assert!(fs.iter().any(|f| f.msg.contains("`extra` dispatched but missing")));
        assert!(fs.iter().any(|f| f.msg.contains("`ghost` documented but not dispatched")));
    }

    #[test]
    fn declared_order_closure_is_transitive() {
        let cl = declared_closure();
        assert!(cl.contains(&("registry.models", "registry.flight")), "models -> shard -> flight");
    }
}
