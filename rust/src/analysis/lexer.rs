//! A minimal Rust lexer for the lint pass — tokens plus comments.
//!
//! This is not a parser: the rules in [`super::rules`] work on the flat
//! token stream (with byte-accurate line numbers) and the comment list.
//! The lexer therefore only has to get *boundaries* right — where strings,
//! comments, lifetimes, and char literals start and end — so that rule
//! pattern-matching never fires inside a string literal or doc comment,
//! and so every finding points at the true source line. It handles the
//! constructs that actually appear in this crate: line and nested block
//! comments, strings with escapes (including backslash-newline
//! continuations, which still advance the line counter), raw strings
//! (`r"…"`, `r#"…"#`), byte strings/chars, raw identifiers (`r#fn`),
//! char-vs-lifetime disambiguation, and numeric literals kept verbatim
//! (`0xB1` stays `0xB1`).
//!
//! In the spirit of [`crate::util::json`]: a small hand-rolled scanner
//! with zero dependencies, built for exactly the job the crate needs.

/// Token classes the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// String literal (content without quotes); raw and byte strings too.
    Str,
    /// Char or byte-char literal (content without quotes).
    Char,
    /// Lifetime (content without the leading `'`).
    Lifetime,
    /// Numeric literal, verbatim (suffixes and `0x` prefixes included).
    Num,
    /// Single-byte punctuation, verbatim.
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }
    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }
}

/// One comment (line or block). `own_line` is true when no token precedes
/// it on its starting line — an own-line `lint: allow` annotation applies
/// to the next code line, a trailing one to its own line.
#[derive(Debug, Clone)]
pub struct Comment {
    pub start_line: usize,
    pub end_line: usize,
    /// Text after `//` (so doc comments keep their `/` or `!` marker) or
    /// between `/*` and `*/`.
    pub text: String,
    pub own_line: bool,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

fn text_of(src: &[u8], a: usize, b: usize) -> String {
    String::from_utf8_lossy(&src[a.min(src.len())..b.min(src.len())]).into_owned()
}

/// Lex `src` into `(tokens, comments)`. Never fails: unterminated
/// constructs extend to end-of-file, unknown bytes become punctuation —
/// lint input is untrusted text, and the worst outcome must be an odd
/// token, not a crash.
pub fn scan(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let src = src.as_bytes();
    let n = src.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut last_tok_line = 0usize;

    macro_rules! push {
        ($kind:expr, $text:expr, $ln:expr) => {{
            last_tok_line = $ln;
            toks.push(Tok { kind: $kind, text: $text, line: $ln });
        }};
    }

    while i < n {
        let c = src[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // Line comment (doc comments included; text keeps the marker).
        if c == b'/' && src.get(i + 1) == Some(&b'/') {
            let j = src[i..].iter().position(|&b| b == b'\n').map_or(n, |p| i + p);
            comments.push(Comment {
                start_line: line,
                end_line: line,
                text: text_of(src, i + 2, j),
                own_line: last_tok_line != line,
            });
            i = j;
            continue;
        }
        // Block comment, nesting respected.
        if c == b'/' && src.get(i + 1) == Some(&b'*') {
            let start = line;
            let own = last_tok_line != start;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if src[j] == b'\n' {
                    line += 1;
                    j += 1;
                } else if src[j] == b'/' && src.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if src[j] == b'*' && src.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let text_end = if j >= 2 { j - 2 } else { i + 2 };
            comments.push(Comment {
                start_line: start,
                end_line: line,
                text: text_of(src, i + 2, text_end.max(i + 2)),
                own_line: own,
            });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            // Raw string r"…" / r#"…"# and raw ident r#name.
            if c == b'r' && matches!(src.get(i + 1), Some(&b'"') | Some(&b'#')) {
                let mut j = i + 1;
                let mut hashes = 0usize;
                while j < n && src[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && src[j] == b'"' {
                    let mut term = String::from("\"");
                    term.push_str(&"#".repeat(hashes));
                    let term = term.as_bytes();
                    let mut k = j + 1;
                    while k < n && !src[k..].starts_with(term) {
                        k += 1;
                    }
                    let ln = line;
                    line += src[j + 1..k.min(n)].iter().filter(|&&b| b == b'\n').count();
                    push!(TokKind::Str, text_of(src, j + 1, k), ln);
                    i = (k + term.len()).min(n);
                    continue;
                }
                if hashes == 1 && j < n && is_ident_start(src[j]) {
                    let mut k = j;
                    while k < n && is_ident_cont(src[k]) {
                        k += 1;
                    }
                    push!(TokKind::Ident, text_of(src, j, k), line);
                    i = k;
                    continue;
                }
            }
            // Byte string b"…" / byte char b'…'.
            if c == b'b' && matches!(src.get(i + 1), Some(&b'"') | Some(&b'\'')) {
                let q = src[i + 1];
                let mut k = i + 2;
                while k < n && src[k] != q {
                    if src[k] == b'\\' {
                        k += 1;
                        if k < n && src[k] == b'\n' {
                            line += 1;
                        }
                    } else if src[k] == b'\n' {
                        line += 1;
                    }
                    k += 1;
                }
                let kind = if q == b'"' { TokKind::Str } else { TokKind::Char };
                push!(kind, text_of(src, i + 2, k), line);
                i = (k + 1).min(n);
                continue;
            }
            let mut k = i;
            while k < n && is_ident_cont(src[k]) {
                k += 1;
            }
            push!(TokKind::Ident, text_of(src, i, k), line);
            i = k;
            continue;
        }
        if c == b'"' {
            let ln = line;
            let mut k = i + 1;
            while k < n && src[k] != b'"' {
                if src[k] == b'\\' {
                    // Escapes, including backslash-newline continuation:
                    // the skipped byte may itself be a newline and must
                    // still advance the line counter.
                    k += 1;
                    if k < n && src[k] == b'\n' {
                        line += 1;
                    }
                } else if src[k] == b'\n' {
                    line += 1;
                }
                k += 1;
            }
            push!(TokKind::Str, text_of(src, i + 1, k), ln);
            i = (k + 1).min(n);
            continue;
        }
        if c == b'\'' {
            // Escaped char: '\n', '\\', '\u{..}'.
            if src.get(i + 1) == Some(&b'\\') {
                let mut k = i + 3;
                while k < n && src[k] != b'\'' {
                    k += 1;
                }
                push!(TokKind::Char, text_of(src, i + 1, k), line);
                i = (k + 1).min(n);
                continue;
            }
            let mut k = i + 1;
            while k < n && is_ident_cont(src[k]) {
                k += 1;
            }
            if k > i + 1 && k < n && src[k] == b'\'' {
                // 'x' (multi-byte chars land here too) — a char literal.
                push!(TokKind::Char, text_of(src, i + 1, k), line);
                i = k + 1;
                continue;
            }
            if k == i + 1 && k + 1 < n && src[k + 1] == b'\'' {
                // Single punctuation char like '.' or '{'.
                push!(TokKind::Char, text_of(src, k, k + 1), line);
                i = k + 2;
                continue;
            }
            // No closing quote: a lifetime ('a, 'static).
            push!(TokKind::Lifetime, text_of(src, i + 1, k), line);
            i = k;
            continue;
        }
        if c.is_ascii_digit() {
            let mut k = i;
            while k < n && is_ident_cont(src[k]) {
                k += 1;
            }
            // Float continuation: `1.5` but not `1.max(2)` or `0..n`.
            if k < n && src[k] == b'.' && src.get(k + 1).is_some_and(|b| b.is_ascii_digit()) {
                k += 1;
                while k < n && is_ident_cont(src[k]) {
                    k += 1;
                }
            }
            push!(TokKind::Num, text_of(src, i, k), line);
            i = k;
            continue;
        }
        push!(TokKind::Punct, text_of(src, i, i + 1), line);
        i += 1;
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String, usize)> {
        let (toks, _) = scan(src);
        toks.into_iter().map(|t| (t.kind, t.text, t.line)).collect()
    }

    #[test]
    fn idents_puncts_and_numbers() {
        let ts = kinds("let x = 0x2A + 2;");
        assert_eq!(ts[0], (TokKind::Ident, "let".into(), 1));
        assert_eq!(ts[1], (TokKind::Ident, "x".into(), 1));
        assert_eq!(ts[3], (TokKind::Num, "0x2A".into(), 1));
        assert_eq!(ts[5], (TokKind::Num, "2".into(), 1));
    }

    #[test]
    fn strings_do_not_leak_tokens() {
        let ts = kinds("let s = \"unwrap() panic! .lock()\";");
        assert!(ts.iter().all(|t| t.0 != TokKind::Ident || t.1 != "unwrap"));
        assert_eq!(ts.iter().filter(|t| t.0 == TokKind::Str).count(), 1);
    }

    #[test]
    fn escaped_newline_in_string_keeps_line_numbers() {
        let src = "let a = \"one \\\n  two\";\nlet b = 1;\n";
        let ts = kinds(src);
        let b = ts.iter().find(|t| t.1 == "b").expect("b token");
        assert_eq!(b.2, 3, "token after a continuation string sits on line 3");
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        let ts = kinds("let r = r#\"a \"quoted\" b\"#; let r#fn = 1;");
        assert!(ts.iter().any(|t| t.0 == TokKind::Str && t.1.contains("quoted")));
        assert!(ts.iter().any(|t| t.0 == TokKind::Ident && t.1 == "fn"));
    }

    #[test]
    fn lifetimes_vs_chars() {
        let ts = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        assert!(ts.iter().any(|t| t.0 == TokKind::Lifetime && t.1 == "a"));
        assert!(ts.iter().any(|t| t.0 == TokKind::Char && t.1 == "x"));
    }

    #[test]
    fn nested_block_comments_and_own_line_flag() {
        let src = "let a = 1; // trailing\n/* outer /* inner */ still */\nlet b = 2;\n";
        let (toks, comments) = scan(src);
        assert_eq!(comments.len(), 2);
        assert!(!comments[0].own_line, "trailing comment shares its line with code");
        assert!(comments[1].own_line, "block comment starts its own line");
        let b = toks.iter().find(|t| t.is_ident("b")).expect("b token");
        assert_eq!(b.line, 3);
    }

    #[test]
    fn doc_comment_text_keeps_marker() {
        let (_, comments) = scan("//! module docs\n/// item docs\n// plain\n");
        assert_eq!(comments[0].text, "! module docs");
        assert_eq!(comments[1].text, "/ item docs");
        assert_eq!(comments[2].text, " plain");
    }
}
