//! In-tree static analysis: the `kbitscale lint` pass.
//!
//! A dependency-free source scanner (no `syn`, no external crates — a
//! hand-rolled lexer in the idiom of [`crate::util::json`]) that walks
//! `rust/src/` and enforces the crate's serving-surface invariants:
//!
//! - **panic-path** — no `.unwrap()` / `.expect()`, aborting macros, or
//!   unchecked slice indexing in the network-facing modules (`server/`,
//!   `fleet/`). A panic on a connection or scatter thread tears down a
//!   worker mid-request; malformed input must surface as a protocol
//!   error line instead. Exemption: `.lock().unwrap()` — the crate's
//!   mutex-poisoning propagation convention.
//! - **unsafe-discipline** — `unsafe` only inside the allowlisted kernel
//!   modules (`quant/fused.rs`, `runtime/mod.rs`), each use immediately
//!   preceded by a `// SAFETY:` comment stating the invariant.
//! - **lock-order** — `.lock()` / `.wait()` nesting per function is
//!   checked against the declared partial order
//!   ([`rules::DECLARED_ORDER`]: registry → cache shard → flight;
//!   roster → worker conn). Undeclared edges and unregistered mutex
//!   fields are findings.
//! - **protocol-doc** — every `"op"` dispatched by `server::try_handle`
//!   (plus `hello` in `pump`) must appear in the protocol doc block of
//!   `server/mod.rs` and vice versa; the bin1 wire constants stay
//!   single-sourced in `server/frames.rs`.
//!
//! False positives are silenced in place with
//! `// lint: allow(<rule>) — <reason>`; the reason is mandatory and the
//! annotation itself is linted (unknown rule or missing justification is
//! a `lint-allow` finding). The pass runs blocking in CI, so the tree
//! lints clean by construction.

pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use rules::{analyze_file, FileReport, Finding};

/// Result of linting a whole source tree.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files: usize,
    /// `lint: allow` annotations that suppressed a finding.
    pub allows: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Recursively collect `.rs` files under `root`, sorted by path so runs
/// are deterministic.
fn rs_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
            .with_context(|| format!("reading {}", dir.display()))?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()
            .with_context(|| format!("listing {}", dir.display()))?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root` (typically `rust/src`). File paths
/// in findings are reported relative to `root` with `/` separators —
/// the same shape the rules key on (`server/frames.rs`).
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let mut report = LintReport::default();
    for path in rs_files(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let file = analyze_file(&rel, &src);
        report.findings.extend(file.findings);
        report.allows += file.allows;
        report.files += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tree lints itself clean — the same invariant CI enforces.
    /// (Skipped silently if the source tree is not present next to the
    /// test binary's working directory, e.g. in an installed context.)
    #[test]
    fn own_tree_is_clean() {
        let root = Path::new("src");
        if !root.join("lib.rs").exists() {
            return;
        }
        let report = lint_tree(root).expect("lint walks the tree");
        assert!(report.files > 40, "walked {} files — wrong root?", report.files);
        let msgs: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
        assert!(report.clean(), "lint findings in tree:\n{}", msgs.join("\n"));
    }
}
