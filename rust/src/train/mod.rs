//! Training driver: the Rust loop around the AOT fused-Adam train-step.
//!
//! Rust owns everything the paper's authors did with a training framework:
//! initialization (family recipes + outlier injection), the data order,
//! the learning-rate schedule (linear warmup → cosine decay), loss
//! logging, and checkpointing. The numerical step itself is one PJRT
//! execution of `train_<tier>.hlo.txt`: parameters, Adam moments, a token
//! batch, `lr` and step index go in; updated state and the loss come out.

use anyhow::{bail, Context, Result};

use crate::data::corpus::Corpus;
use crate::models::checkpoint::{CheckpointMeta, CheckpointStore};
use crate::models::families::Family;
use crate::models::init::init_params;
use crate::models::manifest::{Manifest, TierManifest};
use crate::models::ModelId;
use crate::runtime::{lit_f32, lit_i32, lit_scalar, to_tensor, to_vec_f32, Runtime};
use crate::tensor::Tensor;

/// Training hyperparameters (shared across families; families modulate
/// `lr` via `Family::lr_scale`).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub base_lr: f64,
    pub warmup_steps: usize,
    /// Cosine floor as a fraction of peak LR.
    pub min_lr_frac: f64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 300, base_lr: 3e-3, warmup_steps: 30, min_lr_frac: 0.1, log_every: 50 }
    }
}

/// Linear warmup then cosine decay to `min_lr_frac * peak`.
pub fn lr_at(cfg: &TrainConfig, family: &Family, step: usize) -> f64 {
    let peak = cfg.base_lr * family.lr_scale;
    if step < cfg.warmup_steps {
        return peak * (step + 1) as f64 / cfg.warmup_steps as f64;
    }
    let t = (step - cfg.warmup_steps) as f64 / (cfg.steps - cfg.warmup_steps).max(1) as f64;
    let floor = peak * cfg.min_lr_frac;
    floor + 0.5 * (peak - floor) * (1.0 + (std::f64::consts::PI * t).cos())
}

/// Loss trace of a completed run.
pub struct TrainReport {
    pub id: ModelId,
    pub losses: Vec<f64>,
    pub final_loss: f64,
    pub steps: usize,
    pub wall_s: f64,
}

/// Train one `(family, tier)` model and store its checkpoint.
///
/// Fine-tune families (`Family::finetune_of`) resume from the parent's
/// checkpoint, which must exist.
pub fn train_model(
    rt: &Runtime,
    manifest: &Manifest,
    tier: &TierManifest,
    family: &Family,
    corpus: &Corpus,
    cfg: &TrainConfig,
    store: &CheckpointStore,
) -> Result<TrainReport> {
    let id = ModelId::new(family.name, tier.name.clone());
    let exe = rt.load(&manifest.hlo_path(&tier.train_hlo))?;

    // Initial state: fresh init or parent checkpoint.
    let mut params: Vec<Tensor> = if let Some(parent) = family.finetune_of {
        let pid = ModelId::new(parent, tier.name.clone());
        let (loaded, _) = store
            .load(&pid)
            .with_context(|| format!("fine-tune parent {pid} missing; train it first"))?;
        if loaded.len() != tier.params.len() {
            bail!("parent checkpoint has {} tensors, expected {}", loaded.len(), tier.params.len());
        }
        loaded.into_iter().map(|(_, t)| t).collect()
    } else {
        init_params(tier, family).into_iter().map(|(_, t)| t).collect()
    };
    let mut m: Vec<Tensor> = tier.params.iter().map(|p| Tensor::zeros(p.shape.clone())).collect();
    let mut v: Vec<Tensor> = tier.params.iter().map(|p| Tensor::zeros(p.shape.clone())).collect();

    let timer = std::time::Instant::now();
    let mut losses = Vec::with_capacity(cfg.steps);
    let batch_shape = [tier.batch_train, tier.seq];
    let n = tier.params.len();

    for step in 0..cfg.steps {
        // Data order is derived from the family seed so each family sees
        // its own stream (like training different models on shuffles).
        let tokens = corpus.train_batch(step.wrapping_add(family.seed as usize * 100_003), tier.batch_train);

        let mut args = Vec::with_capacity(3 * n + 3);
        for t in params.iter().chain(m.iter()).chain(v.iter()) {
            args.push(lit_f32(t)?);
        }
        args.push(lit_i32(&batch_shape, &tokens)?);
        args.push(lit_scalar(lr_at(cfg, family, step) as f32));
        args.push(lit_scalar((step + 1) as f32));

        let out = rt.execute(&exe, &args)?;
        if out.len() != 3 * n + 1 {
            bail!("train step returned {} leaves, expected {}", out.len(), 3 * n + 1);
        }
        for (i, p) in tier.params.iter().enumerate() {
            params[i] = to_tensor(&out[i], p.shape.clone())?;
            m[i] = to_tensor(&out[n + i], p.shape.clone())?;
            v[i] = to_tensor(&out[2 * n + i], p.shape.clone())?;
        }
        let loss = to_vec_f32(&out[3 * n])?[0] as f64;
        if !loss.is_finite() {
            bail!("loss diverged (step {step}: {loss})");
        }
        losses.push(loss);
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log::info!("{id} step {step:>4} loss {loss:.4} lr {:.2e}", lr_at(cfg, family, step));
        }
    }

    // Smoothed final loss (mean of last 10 steps) for reporting stability.
    let tail = &losses[losses.len().saturating_sub(10)..];
    let final_loss = tail.iter().sum::<f64>() / tail.len() as f64;

    let named: Vec<(String, Tensor)> = tier
        .params
        .iter()
        .map(|p| p.name.clone())
        .zip(params)
        .collect();
    store.save(
        &id,
        &named,
        &CheckpointMeta { steps: cfg.steps, final_loss, corpus_seed: corpus.cfg.seed },
    )?;

    Ok(TrainReport { id, final_loss, steps: cfg.steps, wall_s: timer.elapsed().as_secs_f64(), losses })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrainConfig {
        TrainConfig { steps: 100, base_lr: 1e-3, warmup_steps: 10, min_lr_frac: 0.1, log_every: 1000 }
    }

    #[test]
    fn lr_schedule_shape() {
        let c = cfg();
        let f = Family::get("gpt2like").unwrap();
        // Warmup is increasing.
        assert!(lr_at(&c, f, 0) < lr_at(&c, f, 5));
        assert!(lr_at(&c, f, 5) < lr_at(&c, f, 9));
        // Peak at end of warmup.
        let peak = lr_at(&c, f, 10);
        assert!((peak - 1e-3).abs() < 1e-9);
        // Decays after.
        assert!(lr_at(&c, f, 50) < peak);
        assert!(lr_at(&c, f, 99) < lr_at(&c, f, 50));
        // Floor respected.
        assert!(lr_at(&c, f, 99) >= 1e-4 - 1e-12);
    }

    #[test]
    fn family_lr_scale_applies() {
        let c = cfg();
        let bloomz = Family::get("bloomzlike").unwrap();
        let gpt2 = Family::get("gpt2like").unwrap();
        assert!(lr_at(&c, bloomz, 20) < lr_at(&c, gpt2, 20));
    }
}
