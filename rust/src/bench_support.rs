//! Shared harness for the `benches/` reproduction targets.
//!
//! Every figure/table bench needs the same setup: artifacts present, the
//! relevant checkpoints trained, a coordinator over the shared results
//! store. [`BenchEnv`] provides that, training missing checkpoints on
//! first use (with the bench training profile) and caching everything
//! under `runs/`, so `cargo bench` is incremental after the first run.
//!
//! The vendored crate set has no criterion; benches use
//! `harness = false` mains and report wall-clock + the paper-shaped
//! tables/series through this module.

use anyhow::Result;

use crate::cli::{Ctx, Paths};
use crate::coordinator::{Cell, CellResult, Coordinator, ResultsStore};
use crate::models::checkpoint::CheckpointStore;
use crate::models::families::Family;
use crate::models::ModelId;
use crate::train::{train_model, TrainConfig};

/// Training profile used by benches: enough steps for clear scale
/// separation, small enough to run on the CPU backend.
pub fn bench_train_config() -> TrainConfig {
    TrainConfig { steps: 500, ..TrainConfig::default() }
}

/// The tiers benches sweep by default (t4/t5 join via --full runs).
pub fn default_tiers() -> Vec<String> {
    ["t0", "t1", "t2", "t3"].iter().map(|s| s.to_string()).collect()
}

pub struct BenchEnv {
    pub ctx: Ctx,
    pub checkpoints: CheckpointStore,
    pub results: ResultsStore,
}

impl BenchEnv {
    /// Open the environment rooted at the repo directory.
    pub fn open() -> Result<BenchEnv> {
        crate::util::progress::init_logging();
        let root = std::env::var("KBITSCALE_ROOT").unwrap_or_else(|_| ".".to_string());
        let ctx = Ctx::new(&root)?;
        let checkpoints = CheckpointStore::new(&ctx.paths.checkpoints);
        let results = ResultsStore::open(&ctx.paths.results)?;
        Ok(BenchEnv { ctx, checkpoints, results })
    }

    pub fn paths(&self) -> &Paths {
        &self.ctx.paths
    }

    /// Ensure checkpoints exist for `(families x tiers)`, training any
    /// missing ones (fine-tune parents first).
    pub fn ensure_trained(&self, families: &[&'static str], tiers: &[String]) -> Result<()> {
        let mut fams: Vec<&'static Family> =
            families.iter().map(|n| Family::get(n)).collect::<Result<_>>()?;
        fams.sort_by_key(|f| f.finetune_of.is_some());
        let cfg = bench_train_config();
        for family in fams {
            for tier_name in tiers {
                let id = ModelId::new(family.name, tier_name);
                if self.checkpoints.exists(&id) {
                    continue;
                }
                let tier = self.ctx.manifest.tier(tier_name)?;
                eprintln!("[bench-setup] training {id} ({} params)...", tier.param_count);
                let rep = train_model(
                    &self.ctx.rt,
                    &self.ctx.manifest,
                    tier,
                    family,
                    &self.ctx.corpus,
                    &cfg,
                    &self.checkpoints,
                )?;
                eprintln!(
                    "[bench-setup] {id}: loss {:.3} in {:.0}s",
                    rep.final_loss, rep.wall_s
                );
            }
        }
        Ok(())
    }

    pub fn coordinator(&self) -> Coordinator<'_> {
        Coordinator::new(
            &self.ctx.rt,
            &self.ctx.manifest,
            &self.ctx.corpus,
            &self.checkpoints,
            &self.results,
        )
    }

    /// Run a grid with setup + timing; prints the standard bench footer.
    pub fn run_grid_timed(&self, name: &str, cells: &[Cell]) -> Result<Vec<CellResult>> {
        let mut families: Vec<&'static str> = cells.iter().map(|c| c.family).collect();
        families.sort_unstable();
        families.dedup();
        let mut tiers: Vec<String> = cells.iter().map(|c| c.tier.clone()).collect();
        tiers.sort();
        tiers.dedup();
        self.ensure_trained(&families, &tiers)?;
        let t = std::time::Instant::now();
        let out = self.coordinator().run_grid(cells)?;
        eprintln!(
            "[{name}] {} cells in {:.1}s (store now {} cells)",
            out.len(),
            t.elapsed().as_secs_f64(),
            self.results.len()
        );
        Ok(out)
    }
}

/// Format helper used by bench mains for paper-shape summaries.
pub fn fmt_opt(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "-".to_string()
    }
}
