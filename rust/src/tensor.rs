//! Dense row-major f32 tensors and their binary serialization.
//!
//! Parameters, optimizer state, and calibration activations all live in
//! [`Tensor`]s on the Rust side; the runtime converts them to/from PJRT
//! literals at the executable boundary. Kept deliberately small: the heavy
//! math happens inside XLA, and the Rust-side hot path (quantization)
//! operates on raw `&[f32]` slices.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn ones(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![1.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D accessor `(rows, cols)`; errors on other ranks.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [r, c] => Ok((*r, *c)),
            s => bail!("expected rank-2 tensor, got shape {s:?}"),
        }
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        let cols = self.shape[self.shape.len() - 1];
        self.data[r * cols + c]
    }

    /// Frobenius norm (diagnostics / perf assertions).
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Result<Tensor> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.data.len(), shape);
        }
        self.shape = shape;
        Ok(self)
    }
}

/// Magic bytes of the checkpoint container format (`KBT1`).
const MAGIC: &[u8; 4] = b"KBT1";

/// Write a named list of tensors as a single binary checkpoint.
///
/// Layout: magic, u32 count, then per tensor: u32 name-len, name bytes,
/// u32 rank, u64 dims…, f32 data (little endian). Simple, versioned via the
/// magic, and memory-mappable in spirit (contiguous payloads).
pub fn save_tensors(path: &Path, named: &[(&str, &Tensor)]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    w.write_all(MAGIC)?;
    w.write_all(&(named.len() as u32).to_le_bytes())?;
    for (name, t) in named {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // Safe little-endian serialization of the payload.
        let mut buf = Vec::with_capacity(t.data.len() * 4);
        for &x in &t.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Read a checkpoint written by [`save_tensors`].
pub fn load_tensors(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a kbitscale checkpoint (bad magic)", path.display());
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let rank = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut buf = vec![0u8; n * 4];
        r.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((String::from_utf8(name)?, Tensor::new(shape, data)));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = Tensor::zeros(vec![3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.dims2().unwrap(), (3, 4));
        assert!(Tensor::zeros(vec![2, 2, 2]).dims2().is_err());
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn mismatched_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(vec![6]);
        assert!(t.clone().reshaped(vec![2, 3]).is_ok());
        assert!(t.reshaped(vec![4, 2]).is_err());
    }

    #[test]
    fn at2_row_major() {
        let t = Tensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kbt_test_{}", std::process::id()));
        let path = dir.join("ckpt.bin");
        let a = Tensor::new(vec![2, 2], vec![1.0, -2.5, 3.25, 0.0]);
        let b = Tensor::new(vec![3], vec![f32::MIN, 0.0, f32::MAX]);
        let s = Tensor::scalar(7.0);
        save_tensors(&path, &[("a", &a), ("b", &b), ("s", &s)]).unwrap();
        let loaded = load_tensors(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0], ("a".to_string(), a));
        assert_eq!(loaded[1], ("b".to_string(), b));
        assert_eq!(loaded[2].1.shape(), &[] as &[usize]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("kbt_badmagic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load_tensors(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
