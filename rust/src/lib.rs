//! # kbitscale
//!
//! A production-grade reproduction of *"The case for 4-bit precision: k-bit
//! Inference Scaling Laws"* (Dettmers & Zettlemoyer, ICML 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): Pallas fused block-wise
//!   dequantize+matmul kernels, validated against a pure-jnp oracle.
//! * **Layer 2** (`python/compile/model.py`): JAX transformer forward and
//!   fused-Adam train-step graphs, AOT-lowered once to HLO text.
//! * **Layer 3** (this crate): the experiment coordinator — everything that
//!   runs at request time. It owns corpus generation, model training (by
//!   driving the AOT train-step via PJRT), the native quantization library
//!   (the hot path of the study), the evaluation harness, the sweep
//!   scheduler, scaling-law fitting, and figure/table regeneration.
//!
//! Python never runs after `make artifacts`; the binary is self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | from-scratch substrates: JSON, RNG, thread pool (`parallel_map`/`parallel_map_init`, `KBITSCALE_THREADS` scoring pool) + bounded queue, CLI, property testing |
//! | [`tensor`] | dense f32 tensors + binary serialization |
//! | [`quant`] | codebooks, block-wise quantization, packed k-bit residency, centering, proxy quantization, fused dequantize-matmul kernel (`quant::fused`: AVX2 gather-based bitstream decode, cache-blocked tiling, column-parallel execution — all bit-identical to scalar dequantize→GEMM), entropy-coded residency (`quant::entropy`: per-segment canonical Huffman over the packed indices, lossless, measured bits below the fixed-k floor) |
//! | [`gptq`] | one-shot GPTQ (Hessian/Cholesky sequential rounding) |
//! | [`data`] | synthetic Zipf–Markov corpus + four zero-shot task generators |
//! | [`models`] | model zoo: families, tiers, init (incl. outlier injection), checkpoints |
//! | [`runtime`] | PJRT client wrapper: HLO-text loading, single-flight executable cache, literal conversion, pipeline-sharded execution plans (`runtime::plan`), native packed-residency scoring backend (`runtime::native`, column-parallel fused matmuls) |
//! | [`train`] | training driver over the AOT train-step executable |
//! | [`eval`] | perplexity + zero-shot evaluation harness, scored through execution plans |
//! | [`coordinator`] | sweep grid, scheduler, worker pool, results store |
//! | [`server`] | LRU/TTL-governed packed-model registry (monolithic, pipeline-sharded, fused-native, and entropy-coded `#ec` variants, per-stage mixed precision) + sharded score cache + concurrent micro-batched JSON-lines serving with chunked streaming responses, negotiated binary score frames (`server::frames`), and tuned-policy auto-loading |
//! | [`fleet`] | multi-node serving tier: worker roster with health/residency probes, policy-aware placement, a line-protocol router with scatter/gather scoring, streamed chunk reassembly (JSON lines or pass-through binary frames), and retry-on-next-worker failover, plus sliding-window latency telemetry (`fleet::telemetry`) and a live precision governor (`fleet::governor`: demote/promote bare-keyed traffic along the tuned frontier with pre-warm-before-cutover and anti-flap cooldown) |
//! | [`scaling`] | scaling curves, Pareto frontiers, bit-level optimality, correlations |
//! | [`tune`] | precision autotuner: candidate search over bits × block × dtype × per-stage widths (plus entropy-coded `#ec` twins scored at their measured bits), calibration eval, Pareto-frontier `TunedPolicy` artifacts with optional per-workload-class frontiers |
//! | [`report`] | ASCII figures and CSV emission for every paper table/figure |
//! | [`bench_support`] | shared harness for the `benches/` reproduction binaries |
//! | [`analysis`] | in-tree static analysis (`kbitscale lint`): panic-path, unsafe-discipline, lock-order, and protocol-doc rules over a hand-rolled lexer |
//!
//! The image's vendored crate set has no serde/clap/tokio/criterion, so the
//! JSON codec, CLI parser, thread pool, bench harness, and property-testing
//! helper are implemented in [`util`] from scratch (DESIGN.md §3).
//!
//! ## Static analysis & invariants
//!
//! `kbitscale lint` ([`analysis`]) runs blocking in CI and keeps four
//! serving-surface invariants machine-checked:
//!
//! * **Panic paths.** Nothing in `server/`, `fleet/`, or the
//!   untrusted-bitstream decoder `quant/entropy.rs` may `.unwrap()`,
//!   `.expect()`, call an aborting macro, or index a slice unchecked:
//!   malformed network input (or a hostile Huffman table / coded stream)
//!   must come back as a typed error with the connection (and worker)
//!   surviving. The one exemption is
//!   `.lock().unwrap()` / `.wait(..).unwrap()` — the crate-wide
//!   convention for propagating mutex poisoning (a poisoned lock means
//!   another thread already panicked; re-raising beats serving torn
//!   state).
//! * **Unsafe discipline.** `unsafe` lives only in `quant/fused.rs` and
//!   `runtime/mod.rs`, and every use is immediately preceded by a
//!   `// SAFETY:` comment stating the invariant it relies on.
//! * **Lock order.** Mutex/Condvar nesting is checked against the
//!   declared partial order ([`analysis::rules::DECLARED_ORDER`]):
//!   `registry.models → {registry.default, cache.shard → registry.flight,
//!   runtime.cache → runtime.flight}` and `fleet.roster → fleet.conn`.
//!   A new mutex field must be registered with a lock class (and any new
//!   nesting declared) before the tree lints clean.
//! * **Protocol doc.** The op table documented in `server`'s module docs
//!   is diffed against the ops `try_handle`/`pump` actually dispatch,
//!   and the bin1 wire-layout constants stay single-sourced in
//!   `server::frames`.
//!
//! False positives are silenced in place with
//! `// lint: allow(<rule>) — <reason>`; the justification is mandatory
//! and the annotation itself is linted.

pub mod util;
pub mod config;
pub mod tensor;
pub mod quant;
pub mod gptq;
pub mod data;
pub mod models;
pub mod runtime;
pub mod server;
pub mod fleet;
pub mod train;
pub mod eval;
pub mod coordinator;
pub mod scaling;
pub mod tune;
pub mod report;
pub mod bench_support;
pub mod analysis;
pub mod cli;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
