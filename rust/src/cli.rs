//! Command-line interface: the `kbitscale` binary's subcommands.
//!
//! ```text
//! kbitscale train    --families optlike,... --tiers t0,...   # train the zoo
//! kbitscale sweep    --grid headline|full|...                # populate results
//! kbitscale figures  --fig all|1|2|...                       # regenerate paper artifacts
//! kbitscale analyze  --pearson                               # cross-metric analyses
//! kbitscale quantize --tier t2 --family gpt2like --bits 4    # one-off cell
//! kbitscale tune     --families gpt2like --tiers t0,t1       # search the k-bit space,
//!                                                            # emit runs/policy.json
//! kbitscale serve    --policy runs/policy.json --tcp ...     # policy-driven serving
//! kbitscale fleet    --worker host:7878:10000000 --spawn 2   # multi-node router over
//!                    --policy runs/policy.json --tcp ...     # N serve workers
//! kbitscale demo     --tier t2                               # generate text, fp16 vs 4-bit
//! kbitscale status                                           # what exists on disk
//! kbitscale lint     [--path rust/src]                       # in-tree static analysis
//! ```

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::{Cell, Coordinator, GridBuilder, ResultsStore};
use crate::data::corpus::Corpus;
use crate::data::vocabulary::Vocabulary;
use crate::eval::EvalSuite;
use crate::fleet::WorkerSpec;
use crate::models::checkpoint::CheckpointStore;
use crate::models::families::Family;
use crate::models::manifest::Manifest;
use crate::quant::codebook::DataType;
use crate::quant::QuantSpec;
use crate::runtime::Runtime;
use crate::train::{train_model, TrainConfig};
use crate::tune::{self, TuneStore, TuneTarget, TunedPolicy};
use crate::util::argparse::{ArgSpec, Args};

/// Filesystem layout of a run directory.
pub struct Paths {
    pub artifacts: PathBuf,
    pub checkpoints: PathBuf,
    pub results: PathBuf,
    pub figures: PathBuf,
}

impl Paths {
    pub fn from_root(root: &str) -> Paths {
        let root = PathBuf::from(root);
        Paths {
            artifacts: root.join("artifacts"),
            checkpoints: root.join("runs/checkpoints"),
            results: root.join("runs/results.jsonl"),
            figures: root.join("results"),
        }
    }
}

/// Everything a subcommand needs.
pub struct Ctx {
    pub paths: Paths,
    pub rt: Runtime,
    pub manifest: Manifest,
    pub corpus: Corpus,
}

impl Ctx {
    pub fn new(root: &str) -> Result<Ctx> {
        let paths = Paths::from_root(root);
        let manifest = Manifest::load(&paths.artifacts)?;
        let corpus = Corpus::for_geometry(manifest.vocab, manifest.seq);
        Ok(Ctx { rt: Runtime::cpu()?, manifest, corpus, paths })
    }

    pub fn checkpoint_store(&self) -> CheckpointStore {
        CheckpointStore::new(&self.paths.checkpoints)
    }

    pub fn results_store(&self) -> Result<ResultsStore> {
        ResultsStore::open(&self.paths.results)
    }
}

pub fn main_with_args(argv: Vec<String>) -> Result<()> {
    crate::util::progress::init_logging();
    let Some(cmd) = argv.first().cloned() else {
        bail!("usage: kbitscale <train|sweep|figures|analyze|quantize|tune|demo|serve|fleet|status|lint> [options]\n(see README.md)");
    };
    let rest = argv[1..].to_vec();
    match cmd.as_str() {
        "train" => cmd_train(&rest),
        "sweep" => cmd_sweep(&rest),
        "figures" => cmd_figures(&rest),
        "analyze" => cmd_analyze(&rest),
        "quantize" => cmd_quantize(&rest),
        "tune" => cmd_tune(&rest),
        "demo" => cmd_demo(&rest),
        "serve" => cmd_serve(&rest),
        "fleet" => cmd_fleet(&rest),
        "status" => cmd_status(&rest),
        "lint" => cmd_lint(&rest),
        other => bail!("unknown subcommand {other:?}"),
    }
}

fn root_opt(spec: ArgSpec) -> ArgSpec {
    spec.opt("root", Some("."), "repo root (artifacts/, runs/ live under it)")
}

fn all_tier_names(ctx: &Ctx) -> Vec<String> {
    ctx.manifest.tiers.iter().map(|t| t.name.clone()).collect()
}

fn parse_tiers(ctx: &Ctx, args: &Args) -> Result<Vec<String>> {
    let t = args.get("tiers")?;
    if t == "all" {
        Ok(all_tier_names(ctx))
    } else {
        args.list("tiers")
    }
}

fn parse_families(args: &Args) -> Result<Vec<&'static Family>> {
    let f = args.get("families")?;
    if f == "all" {
        Ok(crate::models::families::FAMILIES.iter().collect())
    } else if f == "headline" {
        Ok(Family::headline())
    } else {
        args.list("families")?.iter().map(|n| Family::get(n)).collect()
    }
}

// ---------------------------------------------------------------------------

fn cmd_train(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("train", "train the model zoo via the AOT train-step executables")
            .opt("families", Some("headline"), "families (csv | headline | all)")
            .opt("tiers", Some("all"), "tiers (csv | all)")
            .opt("steps", Some("300"), "training steps per model")
            .flag("force", "retrain even if a checkpoint exists"),
    );
    let args = spec.parse(raw)?;
    let ctx = Ctx::new(args.get("root")?)?;
    let store = ctx.checkpoint_store();
    let cfg = TrainConfig { steps: args.usize("steps")?, ..TrainConfig::default() };

    // Fine-tune families must come after their parents.
    let mut families = parse_families(&args)?;
    families.sort_by_key(|f| f.finetune_of.is_some());

    for family in families {
        for tier_name in parse_tiers(&ctx, &args)? {
            let tier = ctx.manifest.tier(&tier_name)?;
            let id = crate::models::ModelId::new(family.name, &tier_name);
            if store.exists(&id) && !args.flag("force") {
                log::info!("{id}: checkpoint exists, skipping");
                continue;
            }
            let rep = train_model(&ctx.rt, &ctx.manifest, tier, family, &ctx.corpus, &cfg, &store)?;
            println!(
                "{id}: {} steps, final loss {:.4}, {:.1}s ({:.1} steps/s)",
                rep.steps,
                rep.final_loss,
                rep.wall_s,
                rep.steps as f64 / rep.wall_s
            );
        }
    }
    Ok(())
}

fn cmd_sweep(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("sweep", "evaluate quantization grids into the results store")
            .opt("grid", Some("headline"), "headline|datatypes|blocksizes|proxy|exponent|centering|perplexity")
            .opt("families", Some("headline"), "families (csv | headline | all)")
            .opt("tiers", Some("all"), "tiers (csv | all)")
            .opt("ks", Some("3,4,8,16"), "bit widths for the headline grid")
            .opt("threads", Some("2"), "sweep worker threads"),
    );
    let args = spec.parse(raw)?;
    let ctx = Ctx::new(args.get("root")?)?;
    let ckpt = ctx.checkpoint_store();
    let results = ctx.results_store()?;
    let mut coord = Coordinator::new(&ctx.rt, &ctx.manifest, &ctx.corpus, &ckpt, &results);
    coord.threads = args.usize("threads")?;

    let families: Vec<&'static str> = parse_families(&args)?.iter().map(|f| f.name).collect();
    let gb = GridBuilder::new(families, parse_tiers(&ctx, &args)?);
    let cells = match args.get("grid")? {
        "headline" => gb.bit_scaling(&args.usize_list("ks")?),
        "datatypes" => gb.datatype_sweep(4),
        "blocksizes" => gb.blocksize_sweep(4, &[Some(16), Some(64), Some(256), Some(1024), None]),
        "proxy" => gb.proxy_sweep(0.02),
        "exponent" => gb.exponent_sweep(&[3, 4, 5, 6, 7, 8]),
        "centering" => gb.centering_sweep(4),
        "perplexity" => gb.perplexity_scaling(),
        g => bail!("unknown grid {g:?}"),
    };
    let cells = crate::coordinator::dedupe(cells);
    let t = std::time::Instant::now();
    let out = coord.run_grid(&cells)?;
    println!(
        "swept {} cells in {:.1}s ({} total in store)",
        out.len(),
        t.elapsed().as_secs_f64(),
        results.len()
    );
    Ok(())
}

fn cmd_figures(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("figures", "regenerate paper figures/tables from the results store")
            .opt("fig", Some("all"), "all|1|2|3|4|7|13 (others via benches)"),
    );
    let args = spec.parse(raw)?;
    let ctx = Ctx::new(args.get("root")?)?;
    let results = ctx.results_store()?;
    if results.is_empty() {
        bail!("results store empty — run `kbitscale sweep` (or the benches) first");
    }
    let which = args.get("fig")?;
    let figs = crate::report::figures::render_known(&results, &ctx.paths.figures, which)?;
    for f in figs {
        println!("{f}");
    }
    Ok(())
}

fn cmd_analyze(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("analyze", "cross-metric analyses over the results store")
            .flag("pearson", "perplexity vs zero-shot Pearson correlation (paper: -0.94)")
            .flag("wins", "4-bit win-rate across bit budgets"),
    );
    let args = spec.parse(raw)?;
    let ctx = Ctx::new(args.get("root")?)?;
    let results = ctx.results_store()?;
    let all = results.all();
    if args.flag("pearson") || !args.flag("wins") {
        let pairs: Vec<(f64, f64)> = all
            .iter()
            .filter(|r| r.zs_mean.is_finite())
            .map(|r| (r.ce, r.zs_mean))
            .collect();
        if pairs.len() < 3 {
            bail!("not enough zero-shot cells for correlation ({}): sweep first", pairs.len());
        }
        let xs: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pairs.iter().map(|p| p.1).collect();
        let r = crate::scaling::pearson(&xs, &ys);
        println!(
            "Pearson(CE loss, mean zero-shot) = {r:.3} over {} cells  (paper: -0.94 vs ppl)",
            pairs.len()
        );
    }
    if args.flag("wins") {
        let curves = crate::report::figures::bit_curves(&all, None);
        let wins = crate::scaling::win_counts(&curves, 50);
        println!("win counts across 50 log-spaced bit budgets: {wins:?}");
    }
    Ok(())
}

fn cmd_quantize(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("quantize", "evaluate one quantization cell end to end")
            .opt("family", Some("gpt2like"), "model family")
            .opt("tier", Some("t0"), "model tier")
            .opt("bits", Some("4"), "bit width (16 = baseline)")
            .opt("dtype", Some("fp"), "int|fp|quantile|dynexp")
            .opt("block", Some("64"), "block size (0 = tensor-wise)")
            .flag("zero-shot", "also run the four zero-shot tasks"),
    );
    let args = spec.parse(raw)?;
    let ctx = Ctx::new(args.get("root")?)?;
    let ckpt = ctx.checkpoint_store();
    let results = ctx.results_store()?;
    let coord = Coordinator::new(&ctx.rt, &ctx.manifest, &ctx.corpus, &ckpt, &results);

    let bits = args.usize("bits")?;
    let block = match args.usize("block")? {
        0 => None,
        b => Some(b),
    };
    let qspec = if bits >= 16 {
        QuantSpec::baseline16()
    } else {
        QuantSpec::new(DataType::parse(args.get("dtype")?)?, bits, block)
    };
    let suite = if args.flag("zero-shot") { EvalSuite::PplZeroShot } else { EvalSuite::Ppl };
    let family = Family::get(args.get("family")?)?;
    let cell = Cell::new(family.name, args.get("tier")?, qspec, suite);
    let r = coord.run_cell(&cell)?;
    println!(
        "{}/{} {}: ce {:.4}  ppl {:.2}  zs_mean {}  bits/param {:.2}  total bits {:.3e}  ({:.2}s)",
        r.family,
        r.tier,
        r.spec_key,
        r.ce,
        r.ppl,
        if r.zs_mean.is_nan() { "-".to_string() } else { format!("{:.3}", r.zs_mean) },
        r.bits_per_param,
        r.total_bits,
        r.wall_s
    );
    results.put(r)?;
    Ok(())
}

fn cmd_tune(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("tune", "search the k-bit config space and emit a tuned serving policy")
            .opt("families", Some("headline"), "families (csv | headline | all)")
            .opt("tiers", Some("all"), "tiers (csv | all); untrained models are skipped")
            .opt("bits", Some("3,4,5,6,8"), "candidate bit widths")
            .opt("dtypes", Some("fp,int"), "candidate data types (csv of int|fp|quantile|dynexp)")
            .opt("blocks", Some("64"), "candidate block sizes (csv; 0 = tensor-wise)")
            .flag("no-stage-mixes", "skip per-stage mixed-precision candidates")
            .flag("entropy", "also tune entropy-coded twins of every quantized candidate (#ec)")
            .flag("zero-shot", "tune on mean zero-shot accuracy (default: CE loss)")
            .opt("ppl-seqs", Some("16"), "calibration perplexity sequences per cell")
            .opt("zs-examples", Some("16"), "calibration examples per zero-shot task")
            .opt("threads", Some("2"), "tuning worker threads")
            .opt("store", Some("runs/tune.jsonl"), "tuning store (dedupes measured cells)")
            .opt("out", Some("runs/policy.json"), "tuned policy output path"),
    );
    let args = spec.parse(raw)?;
    let root = args.get("root")?;
    let ctx = Ctx::new(root)?;
    let ckpt = ctx.checkpoint_store();

    let cfg = tune::TuneConfig {
        bits: args.usize_list("bits")?,
        dtypes: args
            .list("dtypes")?
            .iter()
            .map(|d| DataType::parse(d))
            .collect::<Result<_>>()?,
        blocks: args
            .usize_list("blocks")?
            .into_iter()
            .map(|b| if b == 0 { None } else { Some(b) })
            .collect(),
        stage_mixes: !args.flag("no-stage-mixes"),
        entropy: args.flag("entropy"),
        suite: if args.flag("zero-shot") { EvalSuite::PplZeroShot } else { EvalSuite::Ppl },
        eval: crate::eval::EvalConfig {
            ppl_sequences: args.usize("ppl-seqs")?.max(1),
            zs_examples: args.usize("zs-examples")?.max(1),
        },
        threads: args.usize("threads")?.max(1),
    };

    // Only trained models can be measured; skipping (with a note) keeps
    // `--tiers all` usable on a partially trained zoo.
    let mut targets = Vec::new();
    for family in parse_families(&args)? {
        for tier in parse_tiers(&ctx, &args)? {
            let id = crate::models::ModelId::new(family.name, &tier);
            if ckpt.exists(&id) {
                targets.push(TuneTarget::new(family.name, tier));
            } else {
                log::info!("tune: no checkpoint for {id}, skipping (run `kbitscale train`)");
            }
        }
    }
    if targets.is_empty() {
        bail!("no trained checkpoints among the requested models — run `kbitscale train` first");
    }

    let store = TuneStore::open(PathBuf::from(root).join(args.get("store")?))?;
    let out_path = PathBuf::from(root).join(args.get("out")?);
    let loader = |family: &str, tier: &str| -> Result<Vec<(String, crate::tensor::Tensor)>> {
        let fam = Family::get(family)?;
        Ok(ckpt.load(&crate::models::ModelId::new(fam.name, tier))?.0)
    };
    let t = std::time::Instant::now();
    let report =
        tune::search(&ctx.rt, &ctx.manifest, &ctx.corpus, &loader, &targets, &cfg, Some(&store))?;

    println!(
        "tuned {} cells in {:.1}s ({} fresh, {} cached, {} skipped; store {})",
        report.points.len(),
        t.elapsed().as_secs_f64(),
        report.fresh,
        report.cached,
        report.skipped,
        store.len()
    );
    println!("\nper-config scaling curves (x = resident model bits):");
    for c in &report.curves {
        let slope = c
            .mean_slope()
            .map(|s| format!("{s:+.4}/decade"))
            .unwrap_or_else(|| "-".to_string());
        println!("  {:<24} {} point(s), slope {}", c.label, c.points().len(), slope);
    }
    let wins = crate::scaling::win_counts(&report.curves, 40);
    if !wins.is_empty() {
        println!("win counts across 40 log-spaced bit budgets: {wins:?}");
    }
    println!("\nPareto frontier (the policy):");
    println!(
        "{:<28} {:>8} {:>12} {:>12}",
        "config", "bits/p", "metric", "est bytes/p"
    );
    for e in &report.policy.entries {
        println!(
            "{:<28} {:>8.3} {:>12.4} {:>12.3}",
            e.key(),
            e.bits_per_param,
            e.metric,
            e.bits_per_param / 8.0
        );
    }
    report.policy.save(&out_path)?;
    println!(
        "\npolicy: {} entries -> {} (serve with --policy, then {{\"op\":\"load\",\"auto\":true}})",
        report.policy.entries.len(),
        out_path.display()
    );
    Ok(())
}

fn cmd_demo(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("demo", "decode a held-out sequence and show fp16-vs-4bit token NLL")
            .opt("family", Some("gpt2like"), "model family")
            .opt("tier", Some("t0"), "model tier"),
    );
    let args = spec.parse(raw)?;
    let ctx = Ctx::new(args.get("root")?)?;
    let ckpt = ctx.checkpoint_store();
    let family = Family::get(args.get("family")?)?;
    let tier = ctx.manifest.tier(args.get("tier")?)?;
    let id = crate::models::ModelId::new(family.name, &tier.name);
    let (params, meta) = ckpt.load(&id)?;

    let vocab = Vocabulary::new(ctx.manifest.vocab);
    let seq = &ctx.corpus.eval_sequences(1)[0];
    println!("model {id} (trained {} steps, loss {:.3})", meta.steps, meta.final_loss);
    println!("held-out text: {}\n", vocab.decode(&seq[..24.min(seq.len())]));

    let ev = crate::eval::Evaluator::new(&ctx.rt, &ctx.manifest, tier)?;
    for (label, spec) in [
        ("16-bit baseline", QuantSpec::baseline16()),
        ("4-bit fp, block 64", QuantSpec::new(DataType::Fp, 4, Some(64))),
        ("3-bit fp, block 64", QuantSpec::new(DataType::Fp, 3, Some(64))),
    ] {
        let q = crate::quant::quantize_checkpoint(&params, &tier.quantized_params, &spec);
        let plits = ev.param_literals(&q)?;
        let (ce, ppl, top1) = ev.perplexity(&plits, &ctx.corpus, 16)?;
        println!("{label:<20} ce {ce:.4}  ppl {ppl:6.2}  greedy-acc {top1:.3}");
    }
    Ok(())
}

fn cmd_serve(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("serve", "serve quantized models over JSON lines (stdin or TCP)")
            .opt("family", Some("gpt2like"), "default model family")
            .opt("tier", Some("t0"), "default model tier")
            .opt("bits", Some("4"), "quantization bit width (16 = baseline)")
            .opt("dtype", Some("fp"), "int|fp|quantile|dynexp")
            .opt("block", Some("64"), "block size (0 = tensor-wise)")
            .flag("pipeline", "serve the default model pipeline-sharded (per-stage executables)")
            .opt("stage-bits", None, "per-stage bit widths for --pipeline, csv (16 = unquantized stage)")
            .flag("fused", "score the default model through the fused dequant-matmul backend")
            .flag("entropy", "hold the default model entropy-coded (Huffman over the k-bit indices; lossless)")
            .opt("preload", None, "extra variants, csv of family:tier[:bits[:dtype[:block]]]")
            .opt("workers", Some("0"), "connection worker threads (0 = auto)")
            .opt("flush-ms", Some("2"), "micro-batch flush window in milliseconds")
            .flag("no-batch", "disable cross-client micro-batching")
            .opt("max-resident-bytes", Some("0"), "evict LRU variants past this packed-byte budget (0 = unbounded)")
            .opt("ttl-secs", Some("0"), "evict variants idle longer than this (0 = no TTL)")
            .opt("cache-rows", Some("4096"), "score cache capacity in rows (0 = disabled)")
            .opt("policy", None, "tuned policy JSON from `kbitscale tune` (enables {\"op\":\"load\",\"auto\":true})")
            .opt("io-timeout-secs", Some("0"), "TCP read/write timeout per connection (0 = off; stdin never times out)")
            .opt("tcp", None, "listen address (e.g. 127.0.0.1:7878); default stdin/stdout"),
    );
    let args = spec.parse(raw)?;
    let ctx = Ctx::new(args.get("root")?)?;
    let family = Family::get(args.get("family")?)?;
    let block = match args.usize("block")? {
        0 => None,
        b => Some(b),
    };
    let qspec = crate::server::registry::spec_from_parts(
        args.usize("bits")?,
        DataType::parse(args.get("dtype")?)?,
        block,
    )?;
    // The registry pulls checkpoints on demand — at startup for the
    // default + preloads, later via `{"op":"load"}` from clients.
    let store = ctx.checkpoint_store();
    let loader: crate::server::ParamLoader<'static> = Box::new(move |family: &str, tier: &str| {
        let fam = Family::get(family)?;
        let id = crate::models::ModelId::new(fam.name, tier);
        Ok(store.load(&id)?.0)
    });
    let registry = crate::server::ModelRegistry::new(&ctx.rt, &ctx.manifest, loader)
        .with_memory_budget(match args.usize("max-resident-bytes")? {
            0 => None,
            b => Some(b),
        })
        .with_ttl(match args.usize("ttl-secs")? {
            0 => None,
            s => Some(std::time::Duration::from_secs(s as u64)),
        })
        .with_score_cache(args.usize("cache-rows")?);
    let registry = match args.opt_get("policy") {
        Some(p) => {
            // Like every other CLI path (tune --store/--out, runs/,
            // artifacts/): relative to --root, absolute passes through.
            let path = PathBuf::from(args.get("root")?).join(p);
            let policy = TunedPolicy::load(&path)?;
            log::info!(
                "policy: {} frontier entries from {p} (tuned on {}, hash {})",
                policy.entries.len(),
                policy.tuned_on.join(","),
                policy.fingerprint()
            );
            // Keep the artifact path as the policy's provenance so
            // {"op":"stats"} (and fleet skew reports) can name it.
            registry.with_policy_sourced(Some(policy), Some(p.to_string()))
        }
        None => registry,
    };
    let stage_bits = match args.opt_get("stage-bits") {
        Some(csv) => {
            let bits = csv
                .split(',')
                .map(|b| {
                    b.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("bad --stage-bits {csv:?}"))
                })
                .collect::<Result<Vec<_>>>()?;
            Some(bits)
        }
        None => None,
    };
    let plan = crate::server::PlanRequest {
        pipeline: args.flag("pipeline"),
        stage_bits,
        fused: args.flag("fused"),
        entropy: args.flag("entropy"),
    };
    let default = registry.load_plan(family.name, args.get("tier")?, qspec, &plan)?;
    log::info!(
        "resident {}: {} packed bytes across {} stage(s)",
        default.key(),
        default.resident_bytes(),
        default.n_stages()
    );
    // Only needed for the log line: holding the Arc for the whole serve
    // lifetime would report the default variant as pinned in `stats`.
    drop(default);
    if let Some(pre) = args.opt_get("preload") {
        for part in pre.split(',').filter(|p| !p.is_empty()) {
            let req = crate::server::ModelSpecReq::parse(part)?;
            let h = registry.load(&req.family, &req.tier, req.spec)?;
            log::info!("resident {}: {} packed bytes", h.key(), h.resident_bytes());
        }
    }

    match args.opt_get("tcp") {
        Some(addr) => {
            let mut opts = crate::server::ServeOpts::default();
            match args.usize("workers")? {
                0 => {}
                w => opts.workers = w,
            }
            opts.flush = std::time::Duration::from_millis(args.usize("flush-ms")? as u64);
            opts.batching = !args.flag("no-batch");
            opts.io_timeout = match args.usize("io-timeout-secs")? {
                0 => None,
                s => Some(std::time::Duration::from_secs(s as u64)),
            };
            crate::server::serve_tcp(&registry, addr, &opts)
        }
        None => {
            let n = crate::server::serve_stdin(&registry)?;
            log::info!("served {n} requests");
            Ok(())
        }
    }
}

fn cmd_fleet(raw: &[String]) -> Result<()> {
    let spec = root_opt(
        ArgSpec::new("fleet", "route N serve workers as one logical server over the line protocol")
            .multi("worker", "backend worker address, host:port[:budget-bytes]")
            .opt("spawn", Some("0"), "self-host this many in-process workers on ephemeral ports")
            .opt("max-resident-bytes", Some("0"), "packed-byte budget per *spawned* worker (0 = unbounded)")
            .opt("ttl-secs", Some("0"), "idle-eviction TTL per spawned worker (0 = none)")
            .opt("cache-rows", Some("4096"), "score cache rows per spawned worker (0 = disabled)")
            .opt("policy", None, "tuned policy JSON: drives placement and is pushed to skewed workers")
            .opt("workers", Some("0"), "router connection worker threads (0 = auto)")
            .opt("io-timeout-secs", Some("30"), "read/write timeout on client and worker sockets (0 = off)")
            .opt("probe-secs", Some("2"), "health/residency probe interval in seconds")
            .flag("no-push-policy", "report policy skew instead of healing it")
            .flag("govern", "enable the live precision governor (promote/demote along the frontier)")
            .opt("target-p99-ms", Some("250"), "governor p99 latency target in milliseconds")
            .opt("cooldown-ms", Some("10000"), "governor per-model migration cooldown in milliseconds")
            .opt("tcp", Some("127.0.0.1:7979"), "router listen address"),
    );
    let args = spec.parse(raw)?;
    let root = args.get("root")?;
    let ctx = Ctx::new(root)?;
    let policy = match args.opt_get("policy") {
        Some(p) => {
            let path = PathBuf::from(root).join(p);
            let policy = TunedPolicy::load(&path)?;
            log::info!(
                "fleet policy: {} frontier entries from {p} (hash {})",
                policy.entries.len(),
                policy.fingerprint()
            );
            Some(policy)
        }
        None => None,
    };
    let mut specs: Vec<WorkerSpec> = args
        .occurrences("worker")
        .iter()
        .map(|w| WorkerSpec::parse(w))
        .collect::<Result<_>>()?;
    let spawn = args.usize("spawn")?;
    if specs.is_empty() && spawn == 0 {
        bail!("no workers: give --worker host:port[:budget] (repeatable) and/or --spawn n");
    }
    let io_timeout = match args.usize("io-timeout-secs")? {
        0 => None,
        s => Some(std::time::Duration::from_secs(s as u64)),
    };
    let budget = match args.usize("max-resident-bytes")? {
        0 => None,
        b => Some(b),
    };
    let ttl = match args.usize("ttl-secs")? {
        0 => None,
        s => Some(std::time::Duration::from_secs(s as u64)),
    };

    // Self-hosted workers: each an independent registry with its own
    // budget and checkpoint loader, on an ephemeral local port — the
    // zero-infrastructure path for tests, benches, and demos. Production
    // fleets point --worker at `kbitscale serve --tcp` processes instead.
    let mut registries = Vec::new();
    let mut listeners = Vec::new();
    for _ in 0..spawn {
        let store = ctx.checkpoint_store();
        let loader: crate::server::ParamLoader<'static> =
            Box::new(move |family: &str, tier: &str| {
                let fam = Family::get(family)?;
                Ok(store.load(&crate::models::ModelId::new(fam.name, tier))?.0)
            });
        let reg = crate::server::ModelRegistry::new(&ctx.rt, &ctx.manifest, loader)
            .with_memory_budget(budget)
            .with_ttl(ttl)
            .with_score_cache(args.usize("cache-rows")?)
            .with_policy_sourced(policy.clone(), args.opt_get("policy").map(String::from));
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        log::info!("fleet: spawned in-process worker on {addr}");
        specs.push(WorkerSpec { addr, budget });
        registries.push(reg);
        listeners.push(listener);
    }

    let target_p99_ms = args.f64("target-p99-ms")?;
    if !target_p99_ms.is_finite() || target_p99_ms <= 0.0 {
        bail!("--target-p99-ms must be a finite number > 0");
    }
    let mut opts = crate::fleet::FleetOpts {
        io_timeout,
        probe_interval: std::time::Duration::from_secs(args.usize("probe-secs")?.max(1) as u64),
        push_policy: !args.flag("no-push-policy"),
        govern: args.flag("govern"),
        target_p99_ms,
        cooldown_ms: args.usize("cooldown-ms")? as u64,
        ..crate::fleet::FleetOpts::default()
    };
    match args.usize("workers")? {
        0 => {}
        w => opts.workers = w,
    }
    let fleet = crate::fleet::Fleet::new(&ctx.manifest, specs, policy, opts);
    let worker_opts =
        crate::server::ServeOpts { io_timeout, ..crate::server::ServeOpts::default() };
    // Bind the router port before the spawned workers start serving
    // forever: an already-taken --tcp address must fail the command, not
    // leave orphaned worker threads blocking exit.
    let addr = args.get("tcp")?;
    let router_listener = std::net::TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
    log::info!(
        "fleet router on {addr}: {} worker(s), policy {}",
        fleet.topology().len(),
        if fleet.has_policy() { "active" } else { "none" }
    );
    let gov_cfg = fleet.governor().config();
    if gov_cfg.enabled {
        log::info!(
            "fleet governor: target p99 {:.1} ms, cooldown {} ms",
            gov_cfg.target_p99_ms,
            gov_cfg.cooldown_ms
        );
    }
    std::thread::scope(|s| -> Result<()> {
        for (reg, listener) in registries.iter().zip(listeners) {
            let wo = &worker_opts;
            s.spawn(move || {
                if let Err(e) = crate::server::serve_listener(reg, listener, wo) {
                    log::error!("fleet: spawned worker failed: {e:#}");
                }
            });
        }
        let served = crate::fleet::serve_fleet(&fleet, router_listener);
        if spawn > 0 {
            // Spawned workers serve forever, so the scope can never
            // join them: once the router stops (error or otherwise),
            // report and exit the process instead of wedging silently.
            match &served {
                Ok(()) => log::info!("fleet router stopped"),
                Err(e) => log::error!("fleet router failed: {e:#}"),
            }
            std::process::exit(if served.is_ok() { 0 } else { 1 });
        }
        served
    })
}

/// `kbitscale lint`: run the in-tree static-analysis pass
/// ([`crate::analysis`]) over the crate's own sources (or `--path`).
/// Exits nonzero when any finding survives — the blocking CI contract.
fn cmd_lint(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("lint", "static analysis: panic paths, unsafe, lock order, protocol doc")
        .opt("path", None, "source root to lint (default: rust/src or src, whichever exists)");
    let args = spec.parse(raw)?;
    let root = match args.opt_get("path") {
        Some(p) => PathBuf::from(p),
        None => {
            let candidates = [PathBuf::from("rust/src"), PathBuf::from("src")];
            match candidates.iter().find(|p| p.join("lib.rs").exists()) {
                Some(p) => p.clone(),
                None => bail!(
                    "cannot find a source root (tried rust/src and src) — pass --path explicitly"
                ),
            }
        }
    };
    let report = crate::analysis::lint_tree(&root)?;
    for f in &report.findings {
        println!("{f}");
    }
    println!(
        "lint: {} finding(s) across {} files ({} allows)",
        report.findings.len(),
        report.files,
        report.allows
    );
    if !report.clean() {
        bail!("lint failed with {} finding(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_status(raw: &[String]) -> Result<()> {
    let spec = root_opt(ArgSpec::new("status", "inventory of artifacts, checkpoints, results"));
    let args = spec.parse(raw)?;
    let paths = Paths::from_root(args.get("root")?);
    match Manifest::load(&paths.artifacts) {
        Ok(m) => println!(
            "artifacts: {} tiers ({}), kernels {}x{}x{}",
            m.tiers.len(),
            m.tiers.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join(","),
            m.kernels.m,
            m.kernels.k,
            m.kernels.n
        ),
        Err(e) => println!("artifacts: MISSING ({e:#})"),
    }
    let ckpts = CheckpointStore::new(&paths.checkpoints).list();
    println!("checkpoints: {} ({})", ckpts.len(), ckpts.join(", "));
    match ResultsStore::open(&paths.results) {
        Ok(s) => println!("results: {} cells in {}", s.len(), paths.results.display()),
        Err(e) => println!("results: unreadable ({e:#})"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_subcommand_errors() {
        assert!(main_with_args(vec!["frobnicate".into()]).is_err());
        assert!(main_with_args(vec![]).is_err());
    }

    #[test]
    fn paths_layout() {
        let p = Paths::from_root("/x");
        assert_eq!(p.artifacts, PathBuf::from("/x/artifacts"));
        assert_eq!(p.results, PathBuf::from("/x/runs/results.jsonl"));
    }
}
