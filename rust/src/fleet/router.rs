//! The per-connection proxy loop: one logical server over N workers.
//!
//! Each client connection to the router gets a [`FleetConn`] driving the
//! same `pump` loop the single-process server uses — the router speaks
//! the identical line protocol upstream and downstream. Routing rules:
//!
//! * **`load`** — placement ([`super::placement`]) picks the worker
//!   (resident replica → headroom fit → frontier spill for `auto`);
//!   the request is forwarded verbatim and the worker's response
//!   (augmented with `"worker"`) becomes the client's. The connection
//!   then owns that `(worker, model)` pair for implicit routing.
//! * **`score`/`choose`** — forwarded to a replica with the target
//!   variant resident (round-robin across replicas for load spreading).
//!   Multi-row `score` requests **scatter**: rows split into contiguous
//!   blocks across all replicas, scored concurrently, and reassembled in
//!   request order — streamed requests interleave chunk lines in row
//!   order with one router-synthesized terminal summary.
//! * **Failover** — a worker that errors at the transport level is
//!   marked down and the request retries on the next candidate; if the
//!   variant is not resident there, the router replays a `load` derived
//!   from the registry key first, so failover is transparent to the
//!   client. A worker dying *mid-stream* terminates that stream with a
//!   `{"done":true,"error":...}` line (already-emitted chunks stand, the
//!   connection survives, and the next request fails over).
//! * **`info`/`stats`/`models`/`policy`/`unload`** — aggregated
//!   fleet-wide; `stats` additionally reports per-worker state, a
//!   `"policy_skew"` flag from the workers' policy fingerprints, and
//!   the router's latency/in-flight telemetry.
//! * **`governor`** — status/config of the fleet's precision governor
//!   ([`super::governor`]); bare-keyed (and `"class"`-tagged) scoring
//!   resolves through its installed targets, explicit variant keys
//!   never do.

use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::placement;
use super::topology::{WorkerClient, WorkerView};
use super::Fleet;
use crate::models::manifest::Manifest;
use crate::quant::DataType;
use crate::server::registry::spec_from_parts;
use crate::server::{frames, Emit, EmitSink, PlanRequest};
use crate::tune::{Candidate, TunedPolicy};
use crate::util::json::Json;
use crate::util::pool;

/// One client connection's router state: cached worker connections plus
/// the `(worker, model)` pair the last `load` selected.
pub struct FleetConn<'f> {
    fleet: &'f Fleet,
    clients: HashMap<usize, WorkerClient>,
    current: Option<(usize, String)>,
    requests: u64,
}

impl<'f> FleetConn<'f> {
    pub fn new(fleet: &'f Fleet) -> FleetConn<'f> {
        FleetConn { fleet, clients: HashMap::new(), current: None, requests: 0 }
    }

    /// Handle one request object (buffered responses only — streamed
    /// requests need [`FleetConn::handle_streaming`]).
    pub fn handle(&mut self, req: &Json) -> Json {
        self.dispatch(req, None)
    }

    /// Handle one request with streaming support: partial-response units
    /// (chunk lines, or forwarded worker frames) go through `sink`; the
    /// terminal line is the return value.
    pub fn handle_streaming(&mut self, req: &Json, sink: &mut EmitSink<'_>) -> Json {
        self.dispatch(req, Some(sink))
    }

    fn dispatch(&mut self, req: &Json, sink: Option<&mut EmitSink<'_>>) -> Json {
        self.requests += 1;
        // Scoring ops feed the router-side latency window the governor
        // watches; errors count too (a timing-out fleet should look
        // slow, not idle).
        let timed =
            matches!(req.opt("op").and_then(|v| v.as_str().ok()), Some("score") | Some("choose"));
        let started = timed.then(std::time::Instant::now);
        let resp = match self.try_handle(req, sink) {
            Ok(resp) => resp,
            Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
        };
        if let Some(t0) = started {
            self.fleet.telemetry().record_router((t0.elapsed().as_secs_f64() * 1e3) as f32);
        }
        resp
    }

    fn try_handle(&mut self, req: &Json, sink: Option<&mut EmitSink<'_>>) -> Result<Json> {
        match req.get("op")?.as_str()? {
            "ping" => {
                let snap = self.fleet.topology().snapshot();
                let up = snap.iter().filter(|w| w.up).count();
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("role", Json::str("router")),
                    ("workers", Json::num(snap.len() as f64)),
                    ("workers_up", Json::num(up as f64)),
                ]))
            }
            "models" => self.op_models(),
            "stats" => self.op_stats(),
            "info" => self.op_info(req),
            "load" => self.op_load(req),
            "unload" => self.op_unload(req),
            "policy" => self.op_policy(req),
            "tune" => self.op_tune(req),
            "score" => self.op_score(req, sink),
            "choose" => self.op_choose(req),
            "governor" => self.op_governor(req),
            op => bail!(
                "unknown op {op:?} (ping|info|models|stats|governor|load|unload|score|choose|tune|policy)"
            ),
        }
    }

    // -- worker connection plumbing --------------------------------------

    /// Run one attempt against worker `id`'s cached-or-fresh client. A
    /// transport failure on a **cached** connection gets one fresh
    /// reconnect (while `may_retry` allows) before the worker is marked
    /// down — backends legitimately close idle connections
    /// (`--io-timeout-secs`), and a stale socket must not condemn a
    /// healthy worker. A failure on a fresh connection marks down;
    /// semantic `{"error":...}` responses are returned as `Ok` and never
    /// mark down.
    fn with_reconnect(
        &mut self,
        id: usize,
        attempt: &mut dyn FnMut(&mut WorkerClient) -> Result<Json>,
        may_retry: &mut dyn FnMut() -> bool,
    ) -> Result<Json> {
        let had_cached = self.clients.contains_key(&id);
        if let Err(e) = self.ensure_client(id) {
            self.fail_worker(id, &e);
            return Err(e);
        }
        let r = match self.clients.get_mut(&id) {
            Some(c) => attempt(c),
            None => Err(anyhow!("worker {id} lost its client after ensure")),
        };
        match r {
            Err(_) if had_cached && may_retry() => {
                self.clients.remove(&id);
                if let Err(e) = self.ensure_client(id) {
                    self.fail_worker(id, &e);
                    return Err(e);
                }
                let r2 = match self.clients.get_mut(&id) {
                    Some(c) => attempt(c),
                    None => Err(anyhow!("worker {id} lost its client after ensure")),
                };
                if let Err(e) = &r2 {
                    self.fail_worker(id, e);
                }
                r2
            }
            Err(e) => {
                self.fail_worker(id, &e);
                Err(e)
            }
            ok => ok,
        }
    }

    /// Forward one buffered request to a worker (reconnect-once on a
    /// stale cached connection — safe to resend, every op routed through
    /// here is idempotent).
    fn request_worker(&mut self, id: usize, req: &Json) -> Result<Json> {
        // Per-worker telemetry brackets every forwarded request: an
        // in-flight gauge (queue-depth proxy) plus the round-trip into
        // that worker's latency window.
        let tel = self.fleet.telemetry();
        tel.inflight_enter(id);
        let t0 = std::time::Instant::now();
        let r = self.with_reconnect(id, &mut |c| c.request(req), &mut || true);
        tel.record_worker(id, (t0.elapsed().as_secs_f64() * 1e3) as f32);
        tel.inflight_exit(id);
        r
    }

    fn ensure_client(&mut self, id: usize) -> Result<()> {
        if !self.clients.contains_key(&id) {
            let addr = self.fleet.topology().addr_of(id)?;
            let mut c = WorkerClient::connect(&addr, self.fleet.opts.io_timeout)?;
            // Streamed chunks from this worker then pass through as
            // binary frames instead of being re-parsed per hop; a worker
            // without frame support just stays in JSON mode.
            c.negotiate_frames()?;
            self.clients.insert(id, c);
        }
        Ok(())
    }

    fn fail_worker(&mut self, id: usize, e: &anyhow::Error) {
        self.clients.remove(&id);
        self.fleet.topology().mark_down(id, &format!("{e:#}"));
    }

    /// Make `key` resident on worker `id` by replaying a `load` derived
    /// from the registry key (no-op when the roster already shows it
    /// resident, or when the key is a bare model key the worker resolves
    /// itself).
    fn ensure_resident(&mut self, id: usize, key: &str) -> Result<()> {
        if !key.contains('@') {
            return Ok(());
        }
        if self.fleet.topology().is_resident(id, key) {
            return Ok(());
        }
        let load = load_request_for_key(&self.fleet.manifest, key)?;
        let resp = self.request_worker(id, &load)?;
        if let Some(e) = resp.opt("error") {
            bail!(
                "worker cannot load {key:?} for failover: {}",
                e.as_str().unwrap_or("unknown error")
            );
        }
        self.fleet.topology().note_loaded(id, key);
        Ok(())
    }

    /// Candidate worker order for scoring `key`: replicas first
    /// (round-robin rotated so concurrent connections spread), then
    /// every other healthy worker (load-replay failover targets).
    ///
    /// "Usable" is up-per-roster **or** cached-connection-alive: when
    /// every backend worker thread is pinned by long-lived router
    /// connections, a probe can starve in the backend's accept queue and
    /// mark the worker down even though this connection's cached socket
    /// still serves fine — so a live cached client outvotes the roster,
    /// and an actually-dead socket just fails over on first use.
    fn route_order(&self, key: &str) -> Result<Vec<usize>> {
        let snap = self.fleet.topology().snapshot();
        let usable = |w: &WorkerView| w.up || self.clients.contains_key(&w.id);
        let mut order: Vec<usize> = snap
            .iter()
            .filter(|w| usable(w) && w.resident.contains(key))
            .map(|w| w.id)
            .collect();
        if order.is_empty() && !key.contains('@') {
            // Bare model key: any worker holding *some* variant of it
            // can resolve it (ambiguity errors surface worker-side).
            let prefix = format!("{key}@");
            order = snap
                .iter()
                .filter(|w| usable(w) && w.resident.iter().any(|k| k.starts_with(&prefix)))
                .map(|w| w.id)
                .collect();
        }
        if !order.is_empty() {
            let r = self.fleet.next_rr() % order.len();
            order.rotate_left(r);
        }
        for w in snap.iter().filter(|w| usable(w)) {
            if !order.contains(&w.id) {
                order.push(w.id);
            }
        }
        if order.is_empty() {
            bail!("no healthy workers in the fleet");
        }
        Ok(order)
    }

    /// The variant a scoring request addresses: explicit `"model"`, else
    /// the connection's current model, else `None` — a model-less
    /// request forwards verbatim and resolves against the addressed
    /// worker's registry default, exactly like a direct client's would.
    fn target_key(&self, req: &Json) -> Result<Option<String>> {
        if let Some(m) = req.opt("model") {
            return Ok(Some(m.as_str()?.to_string()));
        }
        Ok(self.current.as_ref().map(|(_, k)| k.clone()))
    }

    /// Governor/class-aware key resolution for scoring. Only a **bare**
    /// model key is ever rewritten — an explicit full variant key
    /// (contains `@`) routes verbatim, so explicitly keyed scoring
    /// stays bit-identical no matter what the governor is doing. A
    /// bare key resolves, in order: the governor's installed target
    /// (`model|class` first, then model-wide), then the policy's
    /// per-class frontier for `"class"`-tagged requests, then the key
    /// as given (worker-side default resolution).
    fn resolve_governed(&self, req: &Json, key: Option<String>) -> Result<Option<String>> {
        let Some(key) = key else { return Ok(None) };
        if key.contains('@') {
            return Ok(Some(key));
        }
        let class = match req.opt("class") {
            Some(v) => Some(v.as_str()?.to_string()),
            None => None,
        };
        if let Some(t) = self.fleet.governor().target_for(&key, class.as_deref()) {
            return Ok(Some(t));
        }
        if let Some(c) = class.as_deref() {
            if let Some(t) = self.class_frontier_key(&key, c)? {
                return Ok(Some(t));
            }
        }
        Ok(Some(key))
    }

    /// Resolve a class-tagged bare key against the policy's class
    /// frontier: best entry that fits the roomiest up worker. `None`
    /// when no policy is installed or it has no frontier for `class`
    /// (the request then falls back to worker-side resolution, same
    /// as an untagged one).
    fn class_frontier_key(&self, model: &str, class: &str) -> Result<Option<String>> {
        let Some(policy) = self.fleet.policy() else { return Ok(None) };
        if !policy.classes.contains_key(class) {
            return Ok(None);
        }
        let (_, tier_name) = split_model_key(&self.fleet.manifest, model)?;
        let tier = self.fleet.manifest.tier(&tier_name)?;
        let snap = self.fleet.topology().snapshot();
        let headroom = snap.iter().filter(|w| w.up).map(WorkerView::headroom).max();
        Ok(policy
            .pick_for_class(Some(class), tier, headroom)
            .and_then(|e| super::governor::entry_key(model, e)))
    }

    // -- scoring ---------------------------------------------------------

    fn op_score(&mut self, req: &Json, sink: Option<&mut EmitSink<'_>>) -> Result<Json> {
        if req.opt("rows").is_some() && req.opt("tokens").is_some() {
            bail!(r#"give "tokens" or "rows", not both"#);
        }
        let key = self.target_key(req)?;
        let key = self.resolve_governed(req, key)?;
        let stream = match req.opt("stream") {
            Some(v) => v.as_bool()?,
            None => false,
        };
        let n_rows = match req.opt("rows") {
            Some(v) => v.as_arr()?.len(),
            None => 1,
        };
        // Only multi-row keyed requests can scatter; the single-row hot
        // path skips straight to forwarding (one roster snapshot inside
        // route_order, not two).
        if let Some(key) = key.as_ref().filter(|_| n_rows >= 2) {
            let snap = self.fleet.topology().snapshot();
            let reps = placement::replicas(&snap, key);
            if reps.len() >= 2 {
                let rows: Vec<Json> = req.get("rows")?.as_arr()?.to_vec();
                if stream {
                    let Some(sink) = sink else {
                        bail!("streaming requires a line transport (stdin or TCP serving)")
                    };
                    return Ok(self.scatter_stream(req, key, &rows, &reps, &snap, sink));
                }
                return self.scatter_buffered(req, key, &rows, &reps, &snap);
            }
        }
        self.forward_scoring(req, key.as_deref(), stream, sink)
    }

    fn op_choose(&mut self, req: &Json) -> Result<Json> {
        let key = self.target_key(req)?;
        let key = self.resolve_governed(req, key)?;
        self.forward_scoring(req, key.as_deref(), false, None)
    }

    /// Single-target forwarding with transparent failover: walk the
    /// candidate order, replaying the variant load where needed. A
    /// model-less request (`key: None`) forwards verbatim to a stable
    /// healthy worker, whose registry default resolves it — the same
    /// behavior a direct client gets. A worker dying mid-stream (chunks
    /// already on the wire) terminates the stream like the
    /// single-process server would.
    fn forward_scoring(
        &mut self,
        req: &Json,
        key: Option<&str>,
        stream: bool,
        mut sink: Option<&mut EmitSink<'_>>,
    ) -> Result<Json> {
        let fwd = match key {
            Some(k) => with_field(req, "model", Json::str(k)),
            None => req.clone(),
        };
        let order = match key {
            Some(k) => self.route_order(k)?,
            None => {
                // Roster order, not round-robin: different workers may
                // have different default models, and one connection's
                // model-less requests should answer consistently.
                let snap = self.fleet.topology().snapshot();
                let order: Vec<usize> = snap
                    .iter()
                    .filter(|w| w.up || self.clients.contains_key(&w.id))
                    .map(|w| w.id)
                    .collect();
                if order.is_empty() {
                    bail!("no healthy workers in the fleet");
                }
                order
            }
        };
        let mut last: Option<anyhow::Error> = None;
        'candidates: for id in order {
            if let Some(k) = key {
                if let Err(e) = self.ensure_resident(id, k) {
                    last = Some(e);
                    continue;
                }
            }
            // Up to two tries per candidate: a worker answering "not
            // resident" despite the roster (evicted worker-side between
            // probes) gets the roster corrected, the load replayed, and
            // one clean resend — nothing was emitted for such a
            // request-level rejection, so resending is safe.
            for attempt in 0..2 {
                let stale = |resp: &Json| {
                    attempt == 0 && key.is_some() && is_not_resident_error(resp)
                };
                if stream {
                    let s = match sink {
                        Some(ref mut s) => &mut **s,
                        None => {
                            bail!("streaming requires a line transport (stdin or TCP serving)")
                        }
                    };
                    let mut emitted = 0usize;
                    match self.stream_worker(id, &fwd, s, &mut emitted) {
                        Ok(term) if emitted == 0 && stale(&term) => {
                            if let Err(e) = self.reload_stale(id, key) {
                                last = Some(e);
                                continue 'candidates;
                            }
                        }
                        Ok(term) => return Ok(term),
                        Err(e) if emitted > 0 => {
                            // Partial stream already delivered:
                            // terminate it honestly; the *next* request
                            // fails over.
                            return Ok(Json::obj(vec![
                                ("done", Json::Bool(true)),
                                (
                                    "error",
                                    Json::str(format!("worker failed mid-stream: {e:#}")),
                                ),
                                ("chunks", Json::num(emitted as f64)),
                            ]));
                        }
                        Err(e) => {
                            last = Some(e);
                            continue 'candidates;
                        }
                    }
                } else {
                    match self.request_worker(id, &fwd) {
                        Ok(resp) if stale(&resp) => {
                            if let Err(e) = self.reload_stale(id, key) {
                                last = Some(e);
                                continue 'candidates;
                            }
                        }
                        Ok(resp) => return Ok(resp),
                        Err(e) => {
                            last = Some(e);
                            continue 'candidates;
                        }
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("no healthy worker available for {key:?}")))
    }

    /// Roster said resident, the worker disagreed: fix the roster and
    /// replay the load so the next attempt can land.
    fn reload_stale(&mut self, id: usize, key: Option<&str>) -> Result<()> {
        let Some(key) = key else {
            bail!("worker {id} reported stale residency for an unkeyed request")
        };
        self.fleet.topology().note_unloaded(id, key);
        self.ensure_resident(id, key)
    }

    /// One streamed request against one worker; `emitted` counts chunk
    /// lines already written to the client when an error interrupts. The
    /// reconnect-once retry only applies while nothing has been emitted
    /// yet — a resend after chunks are on the wire would duplicate rows.
    fn stream_worker(
        &mut self,
        id: usize,
        req: &Json,
        sink: &mut EmitSink<'_>,
        emitted: &mut usize,
    ) -> Result<Json> {
        let count = std::cell::Cell::new(0usize);
        let r = self.with_reconnect(
            id,
            &mut |c| {
                let mut counting = |e: Emit<'_>| -> Result<()> {
                    sink(e)?;
                    count.set(count.get() + 1);
                    Ok(())
                };
                c.request_streaming(req, &mut counting)
            },
            &mut || count.get() == 0,
        );
        *emitted = count.get();
        r
    }

    /// Buffered multi-row scatter: contiguous row blocks across the
    /// replicas, scored concurrently over fresh connections, reassembled
    /// in request order with a router-computed summary matching the
    /// single-worker response shape. A failed block retries once on
    /// another replica before the request errors.
    fn scatter_buffered(
        &mut self,
        _req: &Json,
        key: &str,
        rows: &[Json],
        reps: &[usize],
        snap: &[WorkerView],
    ) -> Result<Json> {
        let fleet = self.fleet;
        let blocks = split_blocks(rows.len(), reps.len());
        let io_t = fleet.opts.io_timeout;
        let addr_of = |id: usize| -> String {
            snap.iter().find(|w| w.id == id).map(|w| w.addr.clone()).unwrap_or_default()
        };
        let results: Vec<Result<Json>> = std::thread::scope(|s| {
            let joins: Vec<_> = blocks
                .iter()
                .zip(reps)
                .map(|(&(a, b), &rep)| {
                    let addr = addr_of(rep);
                    // lint: allow(panic-path) — block bounds come from split_blocks(rows.len(), ..), always in range
                    let sub = sub_score_request(key, &rows[a..b], false, None);
                    s.spawn(move || -> Result<Json> {
                        let mut c = WorkerClient::connect(&addr, io_t)?;
                        c.request(&sub)
                    })
                })
                .collect();
            joins
                .into_iter()
                .map(|j| j.join().unwrap_or_else(|_| Err(anyhow!("scatter thread panicked"))))
                .collect()
        });
        let mut merged: Vec<Json> = Vec::with_capacity(rows.len());
        for (i, ((&(a, b), &rep), r)) in
            blocks.iter().zip(reps).zip(results).enumerate()
        {
            let resp = match r {
                Ok(resp) if is_not_resident_error(&resp) => {
                    // The roster was stale (evicted worker-side between
                    // probes): correct it and retry the block on another
                    // replica — unlike other semantic errors, this one
                    // is not reproducible fleet-wide.
                    self.fleet.topology().note_unloaded(rep, key);
                    self.retry_block(key, block_rows(rows, a, b)?, rep).with_context(|| {
                        format!("scatter block {i} hit stale residency; retry failed too")
                    })?
                }
                Ok(resp) => {
                    if let Some(e) = resp.opt("error") {
                        // Any other semantic error (bad row, worker-side
                        // fault) would fail identically elsewhere.
                        bail!(
                            "worker {}: {}",
                            addr_of(rep),
                            e.as_str().unwrap_or("scoring error")
                        );
                    }
                    resp
                }
                Err(e) => {
                    self.fail_worker(rep, &e);
                    self.retry_block(key, block_rows(rows, a, b)?, rep).with_context(|| {
                        format!("scatter block {i} failed ({e:#}); failover retry failed too")
                    })?
                }
            };
            merged.extend(resp.get("rows")?.as_arr()?.iter().cloned());
        }
        Ok(summarize_rows(merged))
    }

    /// Failover for one scatter block: the remaining candidates in route
    /// order, loading the variant where it is not yet resident.
    fn retry_block(&mut self, key: &str, rows: &[Json], failed: usize) -> Result<Json> {
        let mut last: Option<anyhow::Error> = None;
        let order: Vec<usize> =
            self.route_order(key)?.into_iter().filter(|&id| id != failed).collect();
        for id in order {
            if let Err(e) = self.ensure_resident(id, key) {
                last = Some(e);
                continue;
            }
            let sub = sub_score_request(key, rows, false, None);
            match self.request_worker(id, &sub) {
                Ok(resp) => {
                    if let Some(e) = resp.opt("error") {
                        bail!("retry worker: {}", e.as_str().unwrap_or("scoring error"));
                    }
                    return Ok(resp);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("no healthy replica left for {key:?}")))
    }

    /// Streamed multi-row scatter: every replica streams its contiguous
    /// block concurrently; the router interleaves chunk units back into
    /// global row order (renumbered chunks, re-offset `first_row`) and
    /// synthesizes the one terminal summary. On a `bin1` worker
    /// connection the chunks arrive as binary frames and are forwarded
    /// verbatim — [`frames::patch_header`] renumbers them in place and
    /// [`frames::rows_nll_tok`] reads the summary totals, so no float is
    /// re-serialized on this hop. Any block failure after chunks are on
    /// the wire terminates the stream with a `done`+`error` line;
    /// already-emitted chunks stand.
    fn scatter_stream(
        &mut self,
        req: &Json,
        key: &str,
        rows: &[Json],
        reps: &[usize],
        snap: &[WorkerView],
        sink: &mut EmitSink<'_>,
    ) -> Json {
        let fleet = self.fleet;
        let blocks = split_blocks(rows.len(), reps.len());
        let chunk = req.opt("chunk").cloned();
        let io_t = fleet.opts.io_timeout;
        let addr_of = |id: usize| -> String {
            snap.iter().find(|w| w.id == id).map(|w| w.addr.clone()).unwrap_or_default()
        };
        // One bounded queue per block: replica threads push chunk units
        // (JSON lines re-offset at push; binary frames renumbered at
        // drain, where the global chunk counter lives), the main loop
        // drains the queues in block order so chunks reach the client in
        // global row order while later blocks keep scoring concurrently
        // (bounded buffering = backpressure, never unbounded memory).
        let queues: Vec<pool::BoundedQueue<ScatterChunk>> =
            blocks.iter().map(|_| pool::BoundedQueue::new(64)).collect();
        let mut chunks_out = 0usize;
        let mut rows_out = 0usize;
        let mut nll = 0.0f64;
        let mut tok = 0.0f64;
        let mut failure: Option<String> = None;
        std::thread::scope(|s| {
            let mut joins: Vec<Option<std::thread::ScopedJoinHandle<'_, Result<()>>>> =
                Vec::with_capacity(blocks.len());
            for ((&(a, b), &rep), q) in blocks.iter().zip(reps).zip(&queues) {
                let addr = addr_of(rep);
                // lint: allow(panic-path) — block bounds come from split_blocks(rows.len(), ..), always in range
                let sub = sub_score_request(key, &rows[a..b], true, chunk.as_ref());
                joins.push(Some(s.spawn(move || -> Result<()> {
                    // The queue MUST close on every exit path — an early
                    // error (a failed connect included) would otherwise
                    // leave the drain loop blocked in pop() forever.
                    let mut run = || -> Result<()> {
                        let mut c = WorkerClient::connect(&addr, io_t)?;
                        c.negotiate_frames()?;
                        let mut push = |e: Emit<'_>| -> Result<()> {
                            let item = match e {
                                Emit::Line(j) => ScatterChunk::Line(offset_first_row(j, a)?),
                                Emit::Raw(f) => ScatterChunk::Frame(f.to_vec()),
                            };
                            if !q.push(item) {
                                bail!("stream cancelled");
                            }
                            Ok(())
                        };
                        let term = c.request_streaming(&sub, &mut push)?;
                        if let Some(e) = term.opt("error") {
                            bail!("worker {addr}: {}", e.as_str().unwrap_or("stream error"));
                        }
                        Ok(())
                    };
                    let r = run();
                    q.close();
                    r
                })));
            }
            'blocks: for (((q, &(base, _)), &rep), join_slot) in
                queues.iter().zip(&blocks).zip(reps).zip(joins.iter_mut())
            {
                while let Some(item) = q.pop() {
                    let write_failed = match item {
                        ScatterChunk::Line(line) => {
                            let line =
                                with_field(&line, "chunk", Json::num(chunks_out as f64));
                            if let Some(Json::Arr(rs)) = line.opt("rows") {
                                rows_out += rs.len();
                                for r in rs {
                                    nll += r
                                        .opt("nll")
                                        .and_then(|v| v.as_f64().ok())
                                        .unwrap_or(0.0);
                                    tok += r
                                        .opt("tokens_scored")
                                        .and_then(|v| v.as_f64().ok())
                                        .unwrap_or(0.0);
                                }
                            }
                            sink(Emit::Line(&line)).is_err()
                        }
                        ScatterChunk::Frame(mut buf) => {
                            // Renumber in place; floats stay untouched.
                            match patch_scatter_frame(&mut buf, chunks_out, base) {
                                Ok((n, t, nrows)) => {
                                    nll += n;
                                    tok += t;
                                    rows_out += nrows;
                                    sink(Emit::Raw(&buf)).is_err()
                                }
                                Err(e) => {
                                    failure = Some(format!("bad worker frame: {e:#}"));
                                    break 'blocks;
                                }
                            }
                        }
                    };
                    if write_failed {
                        failure = Some("stream write failed (client gone)".to_string());
                        break 'blocks;
                    }
                    chunks_out += 1;
                }
                let Some(handle) = join_slot.take() else { continue };
                let joined = handle
                    .join()
                    .unwrap_or_else(|_| Err(anyhow!("scatter thread panicked")));
                if let Err(e) = joined {
                    let msg = format!("{e:#}");
                    if is_io_error(&e) {
                        fleet.topology().mark_down(rep, &msg);
                    } else if msg.contains("not resident") {
                        // Stale roster residency: correct it so the
                        // *next* request routes (and reloads) right.
                        fleet.topology().note_unloaded(rep, key);
                    }
                    failure = Some(msg);
                    break 'blocks;
                }
            }
            // Cancel whatever is still streaming and reap the threads
            // (closed queues make their pushes fail fast).
            for q in &queues {
                q.close();
            }
            for j in joins.iter_mut().filter_map(|o| o.take()) {
                let _ = j.join();
            }
        });
        match failure {
            Some(e) => Json::obj(vec![
                ("done", Json::Bool(true)),
                ("error", Json::str(e)),
                ("rows_scored", Json::num(rows_out as f64)),
                ("chunks", Json::num(chunks_out as f64)),
            ]),
            None => Json::obj(vec![
                ("done", Json::Bool(true)),
                ("rows_scored", Json::num(rows_out as f64)),
                ("chunks", Json::num(chunks_out as f64)),
                ("nll", Json::num(nll)),
                ("ce", Json::num(nll / tok.max(1.0))),
            ]),
        }
    }

    // -- residency ops ---------------------------------------------------

    fn op_load(&mut self, req: &Json) -> Result<Json> {
        let auto = match req.opt("auto") {
            Some(v) => v.as_bool()?,
            None => false,
        };
        if auto {
            return self.op_load_auto(req);
        }
        let fleet = self.fleet;
        let family = req.get("family")?.as_str()?.to_string();
        let tier_name = req.get("tier")?.as_str()?.to_string();
        let bits = match req.opt("bits") {
            Some(v) => v.as_usize()?,
            None => 4,
        };
        let dtype = match req.opt("dtype") {
            Some(v) => DataType::parse(v.as_str()?)?,
            None => DataType::Fp,
        };
        let block = match req.opt("block") {
            Some(v) => match v.as_usize()? {
                0 => None,
                b => Some(b),
            },
            None => Some(64),
        };
        let plan = PlanRequest {
            pipeline: match req.opt("pipeline") {
                Some(v) => v.as_bool()?,
                None => false,
            },
            stage_bits: match req.opt("stage_bits") {
                Some(v) => Some(v.usizes()?),
                None => None,
            },
            fused: match req.opt("fused") {
                Some(v) => v.as_bool()?,
                None => false,
            },
            entropy: match req.opt("entropy") {
                Some(v) => v.as_bool()?,
                None => false,
            },
        };
        if plan.stage_bits.is_some() && !plan.pipeline {
            bail!("stage_bits requires the pipeline plan");
        }
        // Validate the spec at the router boundary (same rule as the
        // worker) so a bad request never consumes a failover walk.
        let spec = spec_from_parts(bits, dtype, block)?;
        let key = format!("{family}_{tier_name}@{}{}", spec.key(), plan.suffix());
        let tier = fleet.manifest.tier(&tier_name)?;
        // Footprint estimate for placement: the tuner's candidate
        // accounting, which prices staged mixed-precision loads per
        // stage — a [16,4] request must not be placed by its 4-bit base
        // spec alone. Entropy-coded loads are placed at the uncoded
        // estimate (the coded size is only known after building, and a
        // conservative over-estimate never overfills a worker).
        let cand =
            Candidate { spec, stage_bits: plan.stage_bits.clone(), entropy: plan.entropy };
        let est = (cand.total_bits(tier)? / 8.0).ceil() as usize;
        let snap = fleet.topology().snapshot();
        let target = placement::place_load(&snap, &key, est)?;
        let mut order = vec![target];
        for w in snap.iter().filter(|w| w.up) {
            if !order.contains(&w.id) {
                order.push(w.id);
            }
        }
        self.finish_load(&order, req, &snap)
    }

    fn op_load_auto(&mut self, req: &Json) -> Result<Json> {
        for k in ["bits", "dtype", "block", "pipeline", "stage_bits", "fused", "entropy"] {
            if req.opt(k).is_some() {
                bail!(r#""auto":true picks the config from the policy; drop {k:?}"#);
            }
        }
        let fleet = self.fleet;
        let (family, tier_name) = match (req.opt("family"), req.opt("tier")) {
            (Some(f), Some(t)) => (f.as_str()?.to_string(), t.as_str()?.to_string()),
            (None, None) => {
                let key = match &self.current {
                    Some((_, k)) => k.clone(),
                    None => bail!(r#"give "family" and "tier" (no model loaded yet)"#),
                };
                let model_key = key.split('@').next().unwrap_or(&key).to_string();
                split_model_key(&fleet.manifest, &model_key)?
            }
            _ => bail!(r#"give both "family" and "tier", or neither"#),
        };
        let fwd = Json::obj(vec![
            ("op", Json::str("load")),
            ("auto", Json::Bool(true)),
            ("family", Json::str(&family)),
            ("tier", Json::str(&tier_name)),
        ]);
        let snap = fleet.topology().snapshot();
        let mut order: Vec<usize> = Vec::new();
        if let Some(policy) = fleet.policy() {
            let tier = fleet.manifest.tier(&tier_name)?;
            let model_key = format!("{family}_{tier_name}");
            let (w, entry) = placement::place_auto(&snap, &policy, tier, &model_key)?;
            log::info!(
                "fleet: placing auto-load of {model_key} on worker {} (frontier entry {})",
                addr_in(&snap, w),
                entry.key()
            );
            order.push(w);
        }
        // Failover candidates (and the no-router-policy path): healthy
        // workers roomiest-first — each worker's own policy makes the
        // final pick under its local headroom.
        let mut rest: Vec<&WorkerView> =
            snap.iter().filter(|w| w.up && !order.contains(&w.id)).collect();
        rest.sort_by_key(|w| std::cmp::Reverse(w.headroom()));
        order.extend(rest.iter().map(|w| w.id));
        if order.is_empty() {
            bail!("no healthy workers in the fleet");
        }
        self.finish_load(&order, &fwd, &snap)
    }

    /// Forward a load along the candidate order (transport failures and
    /// semantic rejections both fall through to the next worker), then
    /// record the residency and the connection's current model.
    fn finish_load(
        &mut self,
        order: &[usize],
        req: &Json,
        snap: &[WorkerView],
    ) -> Result<Json> {
        let mut last_resp: Option<Json> = None;
        let mut last_err: Option<anyhow::Error> = None;
        for &id in order {
            match self.request_worker(id, req) {
                Ok(resp) if resp.opt("error").is_none() => {
                    let full = resp.get("model")?.as_str()?.to_string();
                    self.fleet.topology().note_loaded(id, &full);
                    self.current = Some((id, full));
                    return Ok(with_field(&resp, "worker", Json::str(addr_in(snap, id))));
                }
                Ok(resp) => last_resp = Some(resp),
                Err(e) => last_err = Some(e),
            }
        }
        // Every worker rejected (e.g. nothing fits any headroom): the
        // last worker's own error is the most useful response.
        if let Some(r) = last_resp {
            return Ok(r);
        }
        Err(last_err.unwrap_or_else(|| anyhow!("no healthy workers in the fleet")))
    }

    fn op_unload(&mut self, req: &Json) -> Result<Json> {
        let key = req.get("model")?.as_str()?.to_string();
        let snap = self.fleet.topology().snapshot();
        let mut done: Vec<Json> = Vec::new();
        let mut last_resp: Option<Json> = None;
        for w in snap.iter().filter(|w| w.up) {
            match self.request_worker(w.id, req) {
                Ok(resp) if resp.opt("error").is_none() => {
                    let full = resp
                        .opt("unloaded")
                        .and_then(|v| v.as_str().ok())
                        .unwrap_or(&key)
                        .to_string();
                    self.fleet.topology().note_unloaded(w.id, &full);
                    if self.current.as_ref().is_some_and(|(_, k)| *k == full) {
                        self.current = None;
                    }
                    done.push(Json::str(&w.addr));
                }
                Ok(resp) => last_resp = Some(resp),
                Err(_) => {}
            }
        }
        if done.is_empty() {
            return Ok(last_resp
                .unwrap_or_else(|| Json::obj(vec![("error", Json::str("no healthy workers"))])));
        }
        Ok(Json::obj(vec![
            ("unloaded", Json::str(key)),
            ("workers", Json::Arr(done)),
        ]))
    }

    // -- aggregation ops -------------------------------------------------

    fn op_models(&mut self) -> Result<Json> {
        let snap = self.fleet.topology().snapshot();
        let probe = Json::obj(vec![("op", Json::str("models"))]);
        let mut entries: Vec<Json> = Vec::new();
        let mut up = 0usize;
        for w in snap.iter().filter(|w| w.up) {
            match self.request_worker(w.id, &probe) {
                Ok(resp) => {
                    up += 1;
                    if let Some(models) = resp.opt("models") {
                        for m in models.as_arr()? {
                            entries.push(with_field(m, "worker", Json::str(&w.addr)));
                        }
                    }
                }
                Err(e) => log::warn!("fleet: models query of {} failed: {e:#}", w.addr),
            }
        }
        Ok(Json::obj(vec![
            ("models", Json::Arr(entries)),
            ("workers", Json::num(snap.len() as f64)),
            ("workers_up", Json::num(up as f64)),
        ]))
    }

    fn op_stats(&mut self) -> Result<Json> {
        let snap = self.fleet.topology().snapshot();
        let probe = Json::obj(vec![("op", Json::str("stats"))]);
        let mut workers_json: Vec<Json> = Vec::new();
        let mut total = 0.0f64;
        let mut up = 0usize;
        let mut idents: HashSet<String> = HashSet::new();
        for w in &snap {
            if !w.up {
                workers_json.push(Json::obj(vec![
                    ("addr", Json::str(&w.addr)),
                    ("up", Json::Bool(false)),
                    (
                        "error",
                        Json::str(w.last_error.clone().unwrap_or_else(|| "down".to_string())),
                    ),
                ]));
                continue;
            }
            match self.request_worker(w.id, &probe) {
                Ok(resp) => {
                    up += 1;
                    total += resp
                        .opt("resident_bytes_total")
                        .and_then(|v| v.as_f64().ok())
                        .unwrap_or(0.0);
                    // Policy identity for skew detection: a worker with
                    // no policy is its own (distinct) identity.
                    let ident = match resp.opt("policy") {
                        Some(Json::Null) | None => "none".to_string(),
                        Some(p) => p
                            .opt("hash")
                            .and_then(|h| h.as_str().ok())
                            .unwrap_or("unknown")
                            .to_string(),
                    };
                    idents.insert(ident);
                    workers_json.push(Json::obj(vec![
                        ("addr", Json::str(&w.addr)),
                        ("up", Json::Bool(true)),
                        ("stats", resp),
                    ]));
                }
                Err(e) => workers_json.push(Json::obj(vec![
                    ("addr", Json::str(&w.addr)),
                    ("up", Json::Bool(false)),
                    ("error", Json::str(format!("{e:#}"))),
                ])),
            }
        }
        Ok(Json::obj(vec![
            ("fleet", Json::Bool(true)),
            ("workers", Json::Arr(workers_json)),
            ("workers_up", Json::num(up as f64)),
            ("workers_total", Json::num(snap.len() as f64)),
            ("resident_bytes_total", Json::num(total)),
            ("policy_skew", Json::Bool(idents.len() > 1)),
            // Router-side latency/in-flight telemetry — present whether
            // or not the governor is enabled, so `stats` is enough to
            // inspect fleet latency.
            ("latency", self.fleet.telemetry().to_json()),
        ]))
    }

    /// `{"op":"governor"}`: status (config + targets + recent decisions
    /// + live telemetry), with optional config fields applied first —
    /// `"enable"`/`"disable"` (bool), `"target_p99_ms"`, `"cooldown_ms"`.
    fn op_governor(&mut self, req: &Json) -> Result<Json> {
        let enable = match (req.opt("enable"), req.opt("disable")) {
            (Some(_), Some(_)) => bail!(r#"give "enable" or "disable", not both"#),
            (Some(v), None) => Some(v.as_bool()?),
            (None, Some(v)) => Some(!v.as_bool()?),
            (None, None) => None,
        };
        let target_p99_ms = match req.opt("target_p99_ms") {
            Some(v) => {
                let t = v.as_f64()?;
                if !t.is_finite() || t <= 0.0 {
                    bail!("target_p99_ms must be a finite number > 0");
                }
                Some(t)
            }
            None => None,
        };
        let cooldown_ms = match req.opt("cooldown_ms") {
            Some(v) => Some(v.as_usize()? as u64),
            None => None,
        };
        if enable.is_some() || target_p99_ms.is_some() || cooldown_ms.is_some() {
            self.fleet.governor().configure(enable, target_p99_ms, cooldown_ms, None, None);
        }
        let status = self.fleet.governor().status_json();
        Ok(with_field(&status, "telemetry", self.fleet.telemetry().to_json()))
    }

    fn op_info(&mut self, req: &Json) -> Result<Json> {
        let key = match req.opt("model") {
            Some(m) => Some(m.as_str()?.to_string()),
            None => self.current.as_ref().map(|(_, k)| k.clone()),
        };
        let snap = self.fleet.topology().snapshot();
        let up = snap.iter().filter(|w| w.up).count();
        match key {
            Some(key) => {
                let fwd = with_field(req, "model", Json::str(&key));
                let mut last: Option<anyhow::Error> = None;
                for id in self.route_order(&key)? {
                    match self.request_worker(id, &fwd) {
                        Ok(resp) => {
                            let resp = with_field(&resp, "worker", Json::str(addr_in(&snap, id)));
                            return Ok(with_field(&resp, "workers_up", Json::num(up as f64)));
                        }
                        Err(e) => last = Some(e),
                    }
                }
                Err(last.unwrap_or_else(|| anyhow!("no healthy worker answered info")))
            }
            None => {
                // Fleet-level summary straight from the roster — no
                // model selected means there is no single variant to
                // describe.
                let resident: HashSet<&String> =
                    snap.iter().filter(|w| w.up).flat_map(|w| w.resident.iter()).collect();
                let bytes: usize = snap.iter().filter(|w| w.up).map(|w| w.resident_bytes).sum();
                Ok(Json::obj(vec![
                    ("fleet", Json::Bool(true)),
                    ("workers", Json::num(snap.len() as f64)),
                    ("workers_up", Json::num(up as f64)),
                    ("models", Json::num(resident.len() as f64)),
                    ("resident_bytes", Json::num(bytes as f64)),
                    ("requests", Json::num(self.requests as f64)),
                ]))
            }
        }
    }

    fn op_policy(&mut self, req: &Json) -> Result<Json> {
        let snap = self.fleet.topology().snapshot();
        let mut first: Option<Json> = None;
        let mut idents: HashSet<u64> = HashSet::new();
        let mut up = 0usize;
        for w in snap.iter().filter(|w| w.up) {
            match self.request_worker(w.id, req) {
                Ok(resp) => {
                    up += 1;
                    if let Some(p) = resp.opt("policy") {
                        idents.insert(crate::util::fnv1a(p.dump().as_bytes()));
                    }
                    if first.is_none() {
                        first = Some(resp);
                    }
                }
                Err(e) => log::warn!("fleet: policy op on {} failed: {e:#}", w.addr),
            }
        }
        let Some(first) = first else { bail!("no healthy workers in the fleet") };
        // Mirror a successful set/clear into the router's own policy so
        // placement and the prober's skew-heal pushes follow the live
        // install instead of reverting it on the next probe round.
        if let Some(v) = req.opt("set") {
            if let Ok(p) = TunedPolicy::from_json(v) {
                self.fleet.set_policy(Some(p));
            }
        } else if let Some(v) = req.opt("clear") {
            if v.as_bool().unwrap_or(false) {
                self.fleet.set_policy(None);
            }
        }
        let first = with_field(&first, "workers_up", Json::num(up as f64));
        Ok(with_field(&first, "policy_skew", Json::Bool(idents.len() > 1)))
    }

    fn op_tune(&mut self, req: &Json) -> Result<Json> {
        let snap = self.fleet.topology().snapshot();
        // Tune on the connection's current worker when set, else the
        // first healthy one. A tuning search runs far past any io
        // timeout, so it gets a dedicated unbounded connection.
        let id = match &self.current {
            Some((id, _)) if snap.iter().any(|w| w.id == *id && w.up) => *id,
            _ => snap
                .iter()
                .find(|w| w.up)
                .map(|w| w.id)
                .ok_or_else(|| anyhow!("no healthy workers in the fleet"))?,
        };
        let addr = addr_in(&snap, id).to_string();
        // Bounded connect (a dead-but-roster-up worker must not pin this
        // router thread for the OS connect timeout), unbounded read: the
        // search legitimately runs for minutes.
        let mut c = WorkerClient::connect(&addr, self.fleet.opts.io_timeout)?;
        c.set_io_timeout(None)?;
        let resp = match c.request(req) {
            Ok(r) => r,
            Err(e) => {
                self.fail_worker(id, &e);
                return Err(e);
            }
        };
        if resp.opt("error").is_some() {
            return Ok(resp);
        }
        // Broadcast the freshly tuned policy so the fleet stays
        // skew-free (same heal path as the prober's push).
        let broadcast = if self.fleet.opts.push_policy {
            resp.opt("policy").cloned().filter(|p| *p != Json::Null)
        } else {
            None
        };
        if let Some(policy_json) = broadcast {
            // The router's own copy must track the install, or the next
            // probe round would push the stale policy back over it.
            match TunedPolicy::from_json(&policy_json) {
                Ok(p) => self.fleet.set_policy(Some(p)),
                Err(e) => log::warn!("fleet: tuned policy does not parse: {e:#}"),
            }
            let set = Json::obj(vec![("op", Json::str("policy")), ("set", policy_json)]);
            for w in snap.iter().filter(|w| w.up && w.id != id) {
                if let Err(e) = self.request_worker(w.id, &set) {
                    log::warn!("fleet: policy broadcast to {} failed: {e:#}", w.addr);
                }
            }
        }
        Ok(with_field(&resp, "worker", Json::str(addr)))
    }
}

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

fn addr_in<'a>(snap: &'a [WorkerView], id: usize) -> &'a str {
    snap.iter().find(|w| w.id == id).map(|w| w.addr.as_str()).unwrap_or("?")
}

/// Clone an object with one field added/replaced (non-objects become an
/// object holding just the field).
fn with_field(j: &Json, key: &str, val: Json) -> Json {
    let mut m = match j {
        Json::Obj(m) => m.clone(),
        _ => Default::default(),
    };
    m.insert(key.to_string(), val);
    Json::Obj(m)
}

/// `true` when an error chain bottoms out in socket-level I/O — the
/// mark-the-worker-down class, as opposed to semantic scoring errors.
fn is_io_error(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<std::io::Error>().is_some())
}

/// Worker-side "model not resident" rejection (the registry's
/// `resolve_full_key` wording) — the one semantic error the router can
/// heal by correcting the roster and replaying the load, as opposed to
/// errors that would fail identically on any replica.
fn is_not_resident_error(resp: &Json) -> bool {
    resp.opt("error")
        .and_then(|e| e.as_str().ok())
        .is_some_and(|s| s.contains("not resident"))
}

/// Split `n` rows into at most `k` contiguous, near-even, non-empty
/// blocks (fewer when `n < k`).
fn split_blocks(n: usize, k: usize) -> Vec<(usize, usize)> {
    let k = k.max(1).min(n.max(1));
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Checked view of one scatter block's rows: a malformed block table is a
/// routing bug surfaced as a protocol error, never an out-of-bounds panic
/// on a connection thread.
fn block_rows(rows: &[Json], a: usize, b: usize) -> Result<&[Json]> {
    rows.get(a..b).with_context(|| {
        format!("scatter block {a}..{b} out of range ({} rows)", rows.len())
    })
}

/// The per-block scatter sub-request: the same score op a direct client
/// would send, routed to one replica.
fn sub_score_request(key: &str, rows: &[Json], stream: bool, chunk: Option<&Json>) -> Json {
    let mut pairs = vec![
        ("op", Json::str("score")),
        ("model", Json::str(key)),
        ("rows", Json::Arr(rows.to_vec())),
    ];
    if stream {
        pairs.push(("stream", Json::Bool(true)));
    }
    if let Some(c) = chunk {
        pairs.push(("chunk", c.clone()));
    }
    Json::obj(pairs)
}

/// Re-offset a replica-local chunk line into global row coordinates.
fn offset_first_row(line: &Json, base: usize) -> Result<Json> {
    let fr = line.get("first_row")?.as_usize()?;
    Ok(with_field(line, "first_row", Json::num((fr + base) as f64)))
}

/// One queued scatter-stream unit from a replica: a chunk line (JSON
/// worker connection) or its verbatim binary frame (`bin1` connection).
enum ScatterChunk {
    Line(Json),
    Frame(Vec<u8>),
}

/// Renumber one forwarded scatter frame into global coordinates (chunk
/// index and `first_row` base offset, in place — the float payload is
/// never touched) and return its `(nll, tokens, rows)` totals for the
/// router-synthesized terminal summary.
fn patch_scatter_frame(buf: &mut [u8], chunk: usize, base: usize) -> Result<(f64, f64, usize)> {
    let (_, first_row, _) = frames::chunk_header(buf)?;
    let sums = frames::rows_nll_tok(buf)?;
    let global_first = u32::try_from(base)
        .ok()
        .and_then(|b| first_row.checked_add(b))
        .ok_or_else(|| anyhow!("chunk renumber overflow: first_row {first_row} + base {base}"))?;
    frames::patch_header(buf, chunk as u32, global_first)?;
    Ok(sums)
}

/// `family_tier` → `(family, tier)`, resolved against the manifest's
/// declared tier names so a tier name containing `_` can never
/// mis-parse the family.
pub(crate) fn split_model_key(manifest: &Manifest, model_key: &str) -> Result<(String, String)> {
    for t in &manifest.tiers {
        if let Some(family) = model_key.strip_suffix(&format!("_{}", t.name)) {
            if !family.is_empty() {
                return Ok((family.to_string(), t.name.clone()));
            }
        }
    }
    bail!(
        "cannot split model key {model_key:?} into family/tier (tiers: {:?})",
        manifest.tiers.iter().map(|t| &t.name).collect::<Vec<_>>()
    )
}

/// The parsed identity of a full registry key
/// (`family_tier@dtype:bits:bBLOCK[#pipe[..]][#ec][#fused]`) — what
/// failover needs to replay the exact variant on another worker.
#[derive(Debug, PartialEq)]
pub(crate) struct VariantKey {
    pub model_key: String,
    pub dtype: String,
    pub bits: usize,
    /// `0` = tensor-wise (the load op's spelling of `bnone`).
    pub block: usize,
    pub pipeline: bool,
    pub stage_bits: Option<Vec<usize>>,
    pub entropy: bool,
    pub fused: bool,
}

pub(crate) fn parse_variant_key(key: &str) -> Result<VariantKey> {
    let (model_key, rest) = key
        .split_once('@')
        .ok_or_else(|| anyhow!("not a full registry key: {key:?}"))?;
    // Suffix components come in `PlanRequest::suffix` order — pipe, then
    // `#ec`, then `#fused` last — so strip from the right. A
    // non-canonical spelling (`#fused#ec`) falls through to the plan
    // parser below and is rejected.
    let (rest, fused) = match rest.strip_suffix("#fused") {
        Some(r) => (r, true),
        None => (rest, false),
    };
    let (rest, entropy) = match rest.strip_suffix("#ec") {
        Some(r) => (r, true),
        None => (rest, false),
    };
    let (spec_str, plan_str) = match rest.find('#') {
        Some(i) => {
            let (spec, plan) = rest.split_at(i);
            (spec, Some(plan))
        }
        None => (rest, None),
    };
    let parts: Vec<&str> = spec_str.split(':').collect();
    let &[dtype_s, bits_s, block_s] = parts.as_slice() else {
        // Exponent-bit/centering/proxy specs never come from policy or
        // load responses; refusing them here keeps replay honest.
        bail!("cannot replay load for spec {spec_str:?} (want dtype:bits:bBLOCK)");
    };
    let bits: usize = bits_s.parse().map_err(|_| anyhow!("bad bits in registry key {key:?}"))?;
    let block: usize = match block_s {
        "bnone" => 0,
        b => b
            .strip_prefix('b')
            .and_then(|n| n.parse().ok())
            .ok_or_else(|| anyhow!("bad block in registry key {key:?}"))?,
    };
    let (pipeline, stage_bits) = match plan_str {
        None => (false, None),
        Some("#pipe") => (true, None),
        Some(p) => {
            let inner = p
                .strip_prefix("#pipe[")
                .and_then(|s| s.strip_suffix(']'))
                .ok_or_else(|| anyhow!("bad plan suffix in registry key {key:?}"))?;
            let bits: Vec<usize> = inner
                .split(',')
                .map(|b| {
                    b.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("bad stage bits in registry key {key:?}"))
                })
                .collect::<Result<_>>()?;
            (true, Some(bits))
        }
    };
    Ok(VariantKey {
        model_key: model_key.to_string(),
        dtype: dtype_s.to_string(),
        bits,
        block,
        pipeline,
        stage_bits,
        entropy,
        fused,
    })
}

/// Build the explicit `load` request that re-creates `key` on any worker
/// — the failover replay path.
pub(crate) fn load_request_for_key(manifest: &Manifest, key: &str) -> Result<Json> {
    let v = parse_variant_key(key)?;
    let (family, tier) = split_model_key(manifest, &v.model_key)?;
    let mut pairs = vec![
        ("op", Json::str("load")),
        ("family", Json::str(family)),
        ("tier", Json::str(tier)),
        ("bits", Json::num(v.bits as f64)),
        ("dtype", Json::str(&v.dtype)),
        ("block", Json::num(v.block as f64)),
    ];
    if v.pipeline {
        pairs.push(("pipeline", Json::Bool(true)));
    }
    if let Some(bits) = &v.stage_bits {
        pairs.push((
            "stage_bits",
            Json::Arr(bits.iter().map(|&b| Json::num(b as f64)).collect()),
        ));
    }
    if v.entropy {
        pairs.push(("entropy", Json::Bool(true)));
    }
    if v.fused {
        pairs.push(("fused", Json::Bool(true)));
    }
    Ok(Json::obj(pairs))
}

/// The buffered multi-row response shape shared with the single-process
/// server (`rows_scored`/`rows`/`nll`/`ce`), recomputed from the merged
/// per-row objects.
fn summarize_rows(rows: Vec<Json>) -> Json {
    let mut nll = 0.0f64;
    let mut tok = 0.0f64;
    for r in &rows {
        nll += r.opt("nll").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        tok += r.opt("tokens_scored").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    }
    Json::obj(vec![
        ("rows_scored", Json::num(rows.len() as f64)),
        ("rows", Json::Arr(rows)),
        ("nll", Json::num(nll)),
        ("ce", Json::num(nll / tok.max(1.0))),
    ])
}

// ---------------------------------------------------------------------------
// Serving
// ---------------------------------------------------------------------------

/// Serve an already-bound router listener: a worker-thread pool consumes
/// accepted client sockets (the same accept/fault-isolation structure as
/// [`crate::server::serve_listener`]) while a background prober keeps the
/// topology's health and residency fresh.
pub fn serve_fleet(fleet: &Fleet, listener: TcpListener) -> Result<()> {
    const MAX_CONSECUTIVE_ACCEPT_ERRORS: u32 = 32;
    let opts = &fleet.opts;
    let workers = opts.workers.max(1);
    let conns: pool::BoundedQueue<TcpStream> = pool::BoundedQueue::new(workers * 2);
    let stop = pool::Latch::new();
    let accept_err = std::thread::scope(|s| {
        let prober = s.spawn(|| {
            fleet.probe();
            fleet.govern_tick();
            // Condvar sleep: a tripped latch ends the wait (and the
            // prober) immediately instead of after a polling slice.
            while !stop.wait_timeout(opts.probe_interval) {
                fleet.probe();
                // Governor rounds ride the probe cadence: decisions see
                // a roster at most one probe old, and a disabled
                // governor makes this a no-op.
                fleet.govern_tick();
            }
        });
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(s.spawn(|| {
                while let Some(stream) = conns.pop() {
                    let peer =
                        stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
                    if let Some(t) = opts.io_timeout {
                        let set = stream
                            .set_read_timeout(Some(t))
                            .and_then(|_| stream.set_write_timeout(Some(t)));
                        if let Err(e) = set {
                            log::warn!("fleet client {peer}: cannot set io timeout: {e:#}");
                            continue;
                        }
                    }
                    match serve_client(fleet, stream) {
                        Ok(n) => log::info!("fleet client {peer}: {n} requests"),
                        Err(e) => log::warn!("fleet client {peer}: connection error: {e:#}"),
                    }
                }
            }));
        }
        let mut accepted = 0u64;
        let mut consecutive_errors = 0u32;
        let mut accept_err: Option<anyhow::Error> = None;
        for stream in listener.incoming() {
            match stream {
                Ok(stm) => {
                    consecutive_errors = 0;
                    if !conns.push(stm) {
                        break;
                    }
                    accepted += 1;
                }
                Err(e) => {
                    consecutive_errors += 1;
                    log::warn!("fleet accept error ({consecutive_errors} consecutive): {e:#}");
                    if consecutive_errors >= MAX_CONSECUTIVE_ACCEPT_ERRORS {
                        accept_err = Some(anyhow::Error::new(e).context(format!(
                            "{consecutive_errors} consecutive accept failures; shutting down"
                        )));
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            }
            if opts.max_conns.is_some_and(|m| accepted >= m) {
                break;
            }
        }
        conns.close();
        for h in handles {
            let _ = h.join();
        }
        stop.set();
        let _ = prober.join();
        accept_err
    });
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Serve one accepted client socket through the shared `pump` seam —
/// streamed chunk lines go straight to the client as they arrive.
fn serve_client(fleet: &Fleet, stream: TcpStream) -> Result<u64> {
    let mut conn = FleetConn::new(fleet);
    let reader = BufReader::new(stream.try_clone()?);
    crate::server::pump(|req, sink| conn.handle_streaming(req, sink), reader, stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_blocks_covers_rows_contiguously() {
        assert_eq!(split_blocks(5, 2), vec![(0, 3), (3, 5)]);
        assert_eq!(split_blocks(6, 3), vec![(0, 2), (2, 4), (4, 6)]);
        // Fewer rows than replicas: one row per block, no empty blocks.
        assert_eq!(split_blocks(2, 5), vec![(0, 1), (1, 2)]);
        assert_eq!(split_blocks(1, 1), vec![(0, 1)]);
        for (n, k) in [(7, 3), (16, 5), (4, 4), (9, 2)] {
            let blocks = split_blocks(n, k);
            assert_eq!(blocks[0].0, 0);
            assert_eq!(blocks.last().unwrap().1, n);
            for w in blocks.windows(2) {
                assert_eq!(w[0].1, w[1].0, "blocks must tile contiguously");
                assert!(w[0].1 > w[0].0, "no empty blocks");
            }
        }
    }

    #[test]
    fn variant_keys_parse_spec_and_plan() {
        let v = parse_variant_key("gpt2like_t0@fp:4:b64").unwrap();
        assert_eq!(v.model_key, "gpt2like_t0");
        assert_eq!((v.dtype.as_str(), v.bits, v.block), ("fp", 4, 64));
        assert!(!v.pipeline && v.stage_bits.is_none());

        let v = parse_variant_key("gpt2like_t0@fp:16:bnone").unwrap();
        assert_eq!((v.bits, v.block), (16, 0), "baseline key round-trips to block 0");

        let v = parse_variant_key("gpt2like_t0@int:3:b32#pipe").unwrap();
        assert!(v.pipeline && v.stage_bits.is_none());

        let v = parse_variant_key("gpt2like_t0@fp:4:b64#pipe[16,4]").unwrap();
        assert!(v.pipeline);
        assert_eq!(v.stage_bits, Some(vec![16, 4]));
        assert!(!v.fused);

        let v = parse_variant_key("gpt2like_t0@fp:4:b64#fused").unwrap();
        assert!(v.fused && !v.pipeline && v.stage_bits.is_none());
        assert_eq!((v.dtype.as_str(), v.bits, v.block), ("fp", 4, 64));

        let v = parse_variant_key("gpt2like_t0@fp:4:b64#pipe[16,4]#fused").unwrap();
        assert!(v.fused && v.pipeline);
        assert_eq!(v.stage_bits, Some(vec![16, 4]));
        assert!(!v.entropy);

        let v = parse_variant_key("gpt2like_t0@fp:4:b64#ec").unwrap();
        assert!(v.entropy && !v.fused && !v.pipeline);

        let v = parse_variant_key("gpt2like_t0@fp:4:b64#ec#fused").unwrap();
        assert!(v.entropy && v.fused && !v.pipeline);

        let v = parse_variant_key("gpt2like_t0@fp:4:b64#pipe[16,4]#ec#fused").unwrap();
        assert!(v.entropy && v.fused && v.pipeline);
        assert_eq!(v.stage_bits, Some(vec![16, 4]));

        // Only the canonical suffix order (#pipe, #ec, #fused) replays.
        assert!(parse_variant_key("m@fp:4:b64#fused#ec").is_err());

        assert!(parse_variant_key("gpt2like_t0").is_err(), "bare model key is not a variant");
        assert!(parse_variant_key("m@fp:4:b64:e3").is_err(), "exponent specs are not replayable");
        assert!(parse_variant_key("m@fp:4:b64#pipe[x]").is_err());
        assert!(parse_variant_key("m@fp:4:64").is_err(), "block must be b-prefixed");
    }

    #[test]
    fn with_field_replaces_and_preserves() {
        let j = Json::parse(r#"{"op":"score","tokens":[1]}"#).unwrap();
        let out = with_field(&j, "model", Json::str("k"));
        assert_eq!(out.get("model").unwrap().as_str().unwrap(), "k");
        assert_eq!(out.get("op").unwrap().as_str().unwrap(), "score");
        // Replacement, not duplication.
        let out2 = with_field(&out, "model", Json::str("k2"));
        assert_eq!(out2.get("model").unwrap().as_str().unwrap(), "k2");
        assert_eq!(out2.as_obj().unwrap().len(), 3);
    }

    #[test]
    fn summarize_rows_matches_worker_shape() {
        let rows = vec![
            Json::parse(r#"{"nll":2.0,"tokens_scored":4,"ce":0.5}"#).unwrap(),
            Json::parse(r#"{"nll":6.0,"tokens_scored":2,"ce":3.0}"#).unwrap(),
        ];
        let s = summarize_rows(rows);
        assert_eq!(s.get("rows_scored").unwrap().as_usize().unwrap(), 2);
        assert_eq!(s.get("nll").unwrap().as_f64().unwrap(), 8.0);
        assert!((s.get("ce").unwrap().as_f64().unwrap() - 8.0 / 6.0).abs() < 1e-12);
        assert_eq!(s.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn offset_first_row_shifts_into_global_coordinates() {
        let line = Json::parse(r#"{"chunk":0,"first_row":2,"rows":[]}"#).unwrap();
        let out = offset_first_row(&line, 8).unwrap();
        assert_eq!(out.get("first_row").unwrap().as_usize().unwrap(), 10);
        assert!(offset_first_row(&Json::parse(r#"{"x":1}"#).unwrap(), 0).is_err());
    }

    #[test]
    fn patch_scatter_frame_renumbers_and_sums_in_place() {
        let line = Json::parse(
            r#"{"chunk":0,"first_row":2,"rows":[{"nll":2.5,"tokens_scored":4,"greedy_hits":1}]}"#,
        )
        .unwrap();
        let mut buf = Vec::new();
        frames::encode_chunk_into(&line, &mut buf).unwrap();
        let (nll, tok, nrows) = patch_scatter_frame(&mut buf, 7, 16).unwrap();
        assert_eq!((nll, tok, nrows), (2.5, 4.0, 1));
        let (chunk, first_row, _) = frames::chunk_header(&buf).unwrap();
        assert_eq!((chunk, first_row), (7, 18), "chunk renumbered, first_row offset by base");
        assert!(patch_scatter_frame(&mut vec![0u8; 4], 0, 0).is_err(), "garbage rejected");
    }
}
