//! Fleet serving: a policy-aware multi-node router over the line
//! protocol.
//!
//! The paper's headline — 4-bit precision maximizes accuracy per total
//! model bit — becomes an *allocation* problem at serving scale: a fixed
//! fleet-wide byte budget should hold the Pareto-optimal mix of resident
//! variants, not whatever one process happens to fit. This module is the
//! horizontal layer over [`crate::server`]: a front-door **router** that
//! treats N backend `serve_tcp` workers (each its own process with its
//! own `--max-resident-bytes` budget) as one logical server, speaking the
//! existing JSON-line protocol as the inter-node wire format — a worker
//! cannot tell the router from a direct client, so any mix of routed and
//! direct traffic stays valid.
//!
//! Five pieces, smallest state first:
//!
//! * [`topology`] — the worker roster: per-worker address + byte budget,
//!   periodic `{"op":"ping"}`/`{"op":"stats"}` health and residency
//!   probes, mark-down on failure and mark-up on the next successful
//!   probe, and the per-worker resident-variant sets placement and
//!   scatter routing read.
//! * [`placement`] — policy-aware placement: route
//!   `{"op":"load","auto":true}` to the worker whose headroom fits the
//!   tuned frontier pick, prefer workers where a frontier variant is
//!   **already resident** (zero marginal bytes), and spill to the
//!   next-best frontier entry when nothing fits anywhere.
//! * [`telemetry`] — sliding-window p50/p99 latency histograms (router-
//!   wide and per-worker) plus in-flight gauges, fed from the router's
//!   request path and reported under `"latency"` in `{"op":"stats"}`.
//! * [`governor`] — the live precision governor: watches telemetry and
//!   headroom, and migrates bare-keyed traffic along the tuned Pareto
//!   frontier (demote under p99 pressure, promote under headroom) with
//!   pre-warm-before-cutover and a structural anti-flap cooldown.
//! * [`router`] — the per-connection proxy loop: forwards ops to the
//!   owning worker with retry-on-next-worker failover, scatters
//!   multi-row `{"op":"score"}` requests across replicas and reassembles
//!   rows in order (including `{"stream":true}` chunk interleaving with
//!   one terminal summary), and aggregates `{"op":"info"}`/
//!   `{"op":"stats"}`/`{"op":"models"}` fleet-wide — with policy-skew
//!   detection via the workers' reported policy fingerprints.
//!
//! The CLI front end is `kbitscale fleet` (`--worker host:port[:budget]`
//! repeatable, `--policy`, and `--spawn n` for self-hosted in-process
//! workers in tests and benches).
//!
//! Sizing note: each backend serves one connection per worker thread
//! (`serve --workers`), and the router holds one connection per (client
//! × worker) — size backend worker pools at least one above the
//! expected concurrent client count so health probes never starve in
//! the accept queue. Routing is resilient to a starved probe (a live
//! cached connection outvotes a probe-declared down mark), but
//! fleet-wide `stats` reflects the prober's view.

pub mod governor;
pub mod placement;
pub mod router;
pub mod telemetry;
pub mod topology;

pub use governor::{Governor, GovernorConfig};
pub use placement::{place_auto, place_load, replicas};
pub use router::{serve_fleet, FleetConn};
pub use telemetry::{Clock, FleetTelemetry, LatencySnapshot, ManualClock, WallClock};
pub use topology::{Topology, WorkerClient, WorkerSpec, WorkerView};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::models::manifest::Manifest;
use crate::tune::TunedPolicy;
use crate::util::pool;

/// Router-side knobs (the worker-side equivalents live in
/// [`crate::server::ServeOpts`]).
pub struct FleetOpts {
    /// Client-connection worker threads on the router.
    pub workers: usize,
    /// Read/write timeout on both sides of the router: client sockets
    /// (a stalled client must not pin a router worker) and backend
    /// worker connections (a stalled backend must not wedge the router).
    /// This bounds a *single backend response*, so set it above the
    /// worst-case scoring latency of your largest tier — a healthy
    /// worker that computes past the timeout is indistinguishable from a
    /// stalled one and gets marked down. (`{"op":"tune"}` is exempt: it
    /// runs on a dedicated unbounded connection.)
    pub io_timeout: Option<Duration>,
    /// How often the background prober re-checks every worker's health
    /// and residency (down workers are re-probed too — that is the
    /// mark-up path).
    pub probe_interval: Duration,
    /// Push the router's `--policy` to any worker whose policy
    /// fingerprint differs (heals policy skew instead of just reporting
    /// it). No-op when the router has no policy.
    pub push_policy: bool,
    /// Stop accepting after this many client connections (tests and
    /// benches; `None` = serve forever).
    pub max_conns: Option<u64>,
    /// Start with the precision governor enabled (`kbitscale fleet
    /// --govern`); it can also be toggled live via `{"op":"governor"}`.
    pub govern: bool,
    /// Governor demote threshold: windowed p99 above this triggers a
    /// down-frontier migration (`--target-p99-ms`).
    pub target_p99_ms: f64,
    /// Governor anti-flap cooldown between migrations of one model.
    pub cooldown_ms: u64,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            workers: pool::default_threads().min(8),
            io_timeout: Some(Duration::from_secs(30)),
            probe_interval: Duration::from_secs(2),
            push_policy: true,
            max_conns: None,
            govern: false,
            target_p99_ms: 250.0,
            cooldown_ms: 10_000,
        }
    }
}

/// One logical server over N backend workers: the shared state every
/// router connection reads (roster, policy, manifest geometry).
pub struct Fleet {
    topology: Topology,
    /// Tier geometry for placement estimates and registry-key parsing
    /// (the router and its workers serve the same artifact set).
    pub manifest: Manifest,
    /// The router's own copy of the tuned policy: drives worker
    /// *selection* for auto loads (each worker's own policy still makes
    /// the final config pick under its local headroom) and, with
    /// [`FleetOpts::push_policy`], is installed on skewed workers.
    /// Mutable: a routed `{"op":"tune"}` or `{"op":"policy","set":...}`
    /// updates it, so the prober's skew-heal pushes follow live installs
    /// instead of reverting them to the `--policy` startup artifact.
    policy: Mutex<Option<TunedPolicy>>,
    pub opts: FleetOpts,
    /// Round-robin cursor spreading single-row scoring across replicas.
    rr: AtomicUsize,
    /// Sliding-window latency + in-flight telemetry, fed by every
    /// router connection, read by stats and the governor.
    telemetry: FleetTelemetry,
    /// The live precision governor (disabled unless
    /// [`FleetOpts::govern`] or a runtime `{"op":"governor"}` enable).
    governor: Governor,
}

impl Fleet {
    pub fn new(
        manifest: &Manifest,
        workers: Vec<WorkerSpec>,
        policy: Option<TunedPolicy>,
        opts: FleetOpts,
    ) -> Fleet {
        let n_workers = workers.len();
        let topology = Topology::new(workers, opts.io_timeout);
        let governor = Governor::new(GovernorConfig {
            enabled: opts.govern,
            target_p99_ms: opts.target_p99_ms,
            cooldown_ms: opts.cooldown_ms,
            ..GovernorConfig::default()
        });
        Fleet {
            topology,
            manifest: manifest.clone(),
            policy: Mutex::new(policy),
            opts,
            rr: AtomicUsize::new(0),
            telemetry: FleetTelemetry::new(n_workers, Arc::new(WallClock::new())),
            governor,
        }
    }

    /// Rebuild telemetry on an injected clock (tests drive a
    /// [`ManualClock`] so window eviction and governor cooldowns are
    /// deterministic). Call before any samples are recorded.
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Fleet {
        self.telemetry = FleetTelemetry::new(self.topology.len(), clock);
        self
    }

    /// The worker roster (health, budgets, residency).
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Latency windows and in-flight gauges for this fleet.
    pub fn telemetry(&self) -> &FleetTelemetry {
        &self.telemetry
    }

    /// The precision governor (status, config, routing targets).
    pub fn governor(&self) -> &Governor {
        &self.governor
    }

    /// One governor round: observe telemetry, decide, pre-warm, and
    /// retarget. Called by the background prober after each probe
    /// round; tests call it directly for deterministic decisions.
    pub fn govern_tick(&self) -> Vec<governor::Decision> {
        self.governor.tick(self)
    }

    /// The router's current policy (startup `--policy`, or the last
    /// routed live install).
    pub fn policy(&self) -> Option<TunedPolicy> {
        self.policy.lock().unwrap().clone()
    }

    pub fn has_policy(&self) -> bool {
        self.policy.lock().unwrap().is_some()
    }

    /// Swap the router's policy — called when a routed `tune`/`policy`
    /// op installs (or clears) one fleet-wide.
    pub fn set_policy(&self, policy: Option<TunedPolicy>) {
        *self.policy.lock().unwrap() = policy;
    }

    /// One health + residency probe round across every worker, pushing
    /// the router policy to skewed workers when configured. Called by the
    /// background prober in [`router::serve_fleet`]; tests call it
    /// directly for a deterministic roster.
    pub fn probe(&self) {
        let push = if self.opts.push_policy { self.policy() } else { None };
        self.topology.probe_all(push.as_ref());
    }

    /// Next round-robin ticket (replica spreading for scoring traffic).
    pub(crate) fn next_rr(&self) -> usize {
        self.rr.fetch_add(1, Ordering::Relaxed)
    }
}
