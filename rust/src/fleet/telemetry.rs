//! Fleet telemetry: sliding-window latency tracking and in-flight
//! counters for the precision governor and `{"op":"stats"}`.
//!
//! Every router-handled `score`/`choose` request records one latency
//! sample into a [`LatencyWindow`] (router-wide) and one into the
//! window of the worker that served it, alongside a per-worker
//! in-flight gauge (queue depth proxy). Windows are time-bounded
//! (default 10 s) *and* sample-capped, so a traffic spike cannot grow
//! them without bound; percentiles are nearest-rank over the samples
//! still inside the window.
//!
//! Time never comes from the ambient wall clock directly: everything
//! reads through the [`Clock`] trait so tests drive a [`ManualClock`]
//! and governor decisions (cooldowns, window eviction) are exactly
//! reproducible. Production uses [`WallClock`], a monotonic
//! `Instant`-anchored millisecond counter.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Millisecond time source. Monotonic; the zero point is arbitrary
/// (process start for [`WallClock`], whatever the test sets for
/// [`ManualClock`]).
pub trait Clock: Send + Sync {
    /// Milliseconds since this clock's epoch.
    fn now_ms(&self) -> u64;
}

/// Production clock: milliseconds since the clock was created,
/// measured on the monotonic [`Instant`] timeline (immune to wall
/// clock steps).
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }
}

/// Test clock: time advances only when the test says so, making
/// window eviction and governor cooldowns deterministic.
pub struct ManualClock {
    ms: AtomicU64,
}

impl ManualClock {
    pub fn new(start_ms: u64) -> ManualClock {
        ManualClock { ms: AtomicU64::new(start_ms) }
    }

    /// Advance the clock by `delta_ms`.
    pub fn advance(&self, delta_ms: u64) {
        self.ms.fetch_add(delta_ms, Ordering::SeqCst);
    }

    /// Jump the clock to an absolute time.
    pub fn set(&self, now_ms: u64) {
        self.ms.store(now_ms, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.ms.load(Ordering::SeqCst)
    }
}

/// Mutable interior of a [`LatencyWindow`]: timestamped samples in
/// arrival order plus a lifetime counter.
struct WindowState {
    /// `(at_ms, latency_ms)` pairs, oldest first.
    samples: VecDeque<(u64, f32)>,
    /// Lifetime sample count (never evicted).
    total: u64,
}

/// A sliding-window latency recorder: keeps the last `cap` samples no
/// older than `window_ms`, and answers nearest-rank p50/p99 over
/// whatever is still inside the window.
pub struct LatencyWindow {
    window: Mutex<WindowState>,
    window_ms: u64,
    cap: usize,
}

/// Point-in-time percentile summary of one [`LatencyWindow`].
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySnapshot {
    /// Lifetime samples recorded (monotone; survives eviction).
    pub count: u64,
    /// Samples inside the window right now (the percentile basis).
    pub in_window: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Window width the percentiles were computed over.
    pub window_ms: u64,
}

impl LatencySnapshot {
    /// The `latency` block shape used by `{"op":"stats"}` and
    /// `{"op":"governor"}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("in_window", Json::num(self.in_window as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("window_ms", Json::num(self.window_ms as f64)),
        ])
    }
}

/// Nearest-rank percentile over an ascending-sorted slice; 0.0 on an
/// empty window (callers gate on `in_window` before acting).
fn percentile(sorted: &[f32], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    let idx = rank.clamp(1, sorted.len()) - 1;
    sorted.get(idx).copied().unwrap_or(0.0) as f64
}

impl LatencyWindow {
    pub fn new(window_ms: u64, cap: usize) -> LatencyWindow {
        LatencyWindow {
            window: Mutex::new(WindowState { samples: VecDeque::new(), total: 0 }),
            window_ms,
            cap,
        }
    }

    /// Record one latency sample observed at `now_ms`.
    pub fn record(&self, now_ms: u64, latency_ms: f32) {
        let mut w = self.window.lock().unwrap();
        w.total += 1;
        w.samples.push_back((now_ms, latency_ms));
        while w.samples.len() > self.cap {
            w.samples.pop_front();
        }
        let cutoff = now_ms.saturating_sub(self.window_ms);
        while w.samples.front().map(|(at, _)| *at < cutoff).unwrap_or(false) {
            w.samples.pop_front();
        }
    }

    /// Percentiles over the samples still inside the window at
    /// `now_ms`. Does not mutate the window (eviction happens on
    /// record), so stale samples are filtered, not dropped.
    pub fn snapshot(&self, now_ms: u64) -> LatencySnapshot {
        let w = self.window.lock().unwrap();
        let cutoff = now_ms.saturating_sub(self.window_ms);
        let mut vals: Vec<f32> =
            w.samples.iter().filter(|(at, _)| *at >= cutoff).map(|(_, v)| *v).collect();
        vals.sort_by(f32::total_cmp);
        LatencySnapshot {
            count: w.total,
            in_window: vals.len(),
            p50_ms: percentile(&vals, 50.0),
            p99_ms: percentile(&vals, 99.0),
            window_ms: self.window_ms,
        }
    }
}

/// Default sliding-window width for fleet latency tracking.
pub const DEFAULT_WINDOW_MS: u64 = 10_000;
/// Default per-window sample cap (bounds memory under traffic spikes).
pub const DEFAULT_WINDOW_CAP: usize = 4096;

/// All latency/queue-depth state for one fleet: a router-wide window,
/// one window per worker, and per-worker in-flight gauges. Shared by
/// every router connection and the governor (all methods take
/// `&self`).
pub struct FleetTelemetry {
    clock: Arc<dyn Clock>,
    router: LatencyWindow,
    workers: Vec<LatencyWindow>,
    inflight: Vec<AtomicUsize>,
}

impl FleetTelemetry {
    pub fn new(n_workers: usize, clock: Arc<dyn Clock>) -> FleetTelemetry {
        FleetTelemetry {
            clock,
            router: LatencyWindow::new(DEFAULT_WINDOW_MS, DEFAULT_WINDOW_CAP),
            workers: (0..n_workers)
                .map(|_| LatencyWindow::new(DEFAULT_WINDOW_MS, DEFAULT_WINDOW_CAP))
                .collect(),
            inflight: (0..n_workers).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// Current time on this fleet's clock (ms).
    pub fn now_ms(&self) -> u64 {
        self.clock.now_ms()
    }

    /// Record one router-level request latency.
    pub fn record_router(&self, latency_ms: f32) {
        self.router.record(self.clock.now_ms(), latency_ms);
    }

    /// Record one request latency attributed to worker `id` (out-of-
    /// range ids are ignored — the roster is fixed at fleet build).
    pub fn record_worker(&self, id: usize, latency_ms: f32) {
        if let Some(w) = self.workers.get(id) {
            w.record(self.clock.now_ms(), latency_ms);
        }
    }

    /// Bump worker `id`'s in-flight gauge (a request was dispatched).
    pub fn inflight_enter(&self, id: usize) {
        if let Some(g) = self.inflight.get(id) {
            g.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Drop worker `id`'s in-flight gauge (the request finished,
    /// successfully or not).
    pub fn inflight_exit(&self, id: usize) {
        if let Some(g) = self.inflight.get(id) {
            // Saturating decrement: a mismatched exit must not wrap.
            let _ = g.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                Some(v.saturating_sub(1))
            });
        }
    }

    /// Requests currently in flight against worker `id`.
    pub fn inflight(&self, id: usize) -> usize {
        self.inflight.get(id).map(|g| g.load(Ordering::SeqCst)).unwrap_or(0)
    }

    /// Router-wide latency summary.
    pub fn router_snapshot(&self) -> LatencySnapshot {
        self.router.snapshot(self.clock.now_ms())
    }

    /// Latency summary for worker `id` (None when out of range).
    pub fn worker_snapshot(&self, id: usize) -> Option<LatencySnapshot> {
        self.workers.get(id).map(|w| w.snapshot(self.clock.now_ms()))
    }

    /// The fleet-level `latency` block for `{"op":"stats"}`:
    /// router-wide percentiles plus one entry per worker with its
    /// in-flight depth.
    pub fn to_json(&self) -> Json {
        let workers: Vec<Json> = self
            .workers
            .iter()
            .enumerate()
            .map(|(id, w)| {
                let snap = w.snapshot(self.clock.now_ms());
                let mut obj = match snap.to_json() {
                    Json::Obj(m) => m,
                    _ => Default::default(),
                };
                obj.insert("worker".into(), Json::num(id as f64));
                obj.insert("inflight".into(), Json::num(self.inflight(id) as f64));
                Json::Obj(obj)
            })
            .collect();
        Json::obj(vec![
            ("router", self.router_snapshot().to_json()),
            ("workers", Json::Arr(workers)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_nearest_rank() {
        let w = LatencyWindow::new(1_000, 64);
        for v in 1..=100 {
            w.record(10, v as f32);
        }
        let s = w.snapshot(10);
        assert_eq!(s.in_window, 100);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ms, 50.0, "nearest-rank p50 of 1..=100 is the 50th value");
        assert_eq!(s.p99_ms, 99.0, "nearest-rank p99 of 1..=100 is the 99th value");
        // A single sample is every percentile.
        let w = LatencyWindow::new(1_000, 64);
        w.record(0, 7.5);
        let s = w.snapshot(0);
        assert_eq!((s.p50_ms, s.p99_ms), (7.5, 7.5));
    }

    #[test]
    fn empty_window_reports_zeros() {
        let w = LatencyWindow::new(1_000, 64);
        let s = w.snapshot(123);
        assert_eq!((s.count, s.in_window), (0, 0));
        assert_eq!((s.p50_ms, s.p99_ms), (0.0, 0.0));
    }

    #[test]
    fn old_samples_age_out_of_the_window() {
        let w = LatencyWindow::new(1_000, 64);
        w.record(0, 100.0);
        w.record(500, 200.0);
        w.record(1_600, 10.0);
        // At t=1600 the cutoff is 600: only the last sample remains.
        let s = w.snapshot(1_600);
        assert_eq!(s.in_window, 1, "samples older than window_ms must not count");
        assert_eq!(s.p99_ms, 10.0);
        assert_eq!(s.count, 3, "lifetime count survives eviction");
        // Snapshot filtering is time-based even without a record call.
        let s = w.snapshot(3_000);
        assert_eq!(s.in_window, 0);
    }

    #[test]
    fn sample_cap_bounds_memory() {
        let w = LatencyWindow::new(u64::MAX / 2, 8);
        for v in 0..100 {
            w.record(v, v as f32);
        }
        let s = w.snapshot(100);
        assert_eq!(s.in_window, 8, "cap evicts oldest samples");
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ms, 95.0, "survivors are the newest samples");
    }

    #[test]
    fn manual_clock_drives_fleet_telemetry() {
        let clock = Arc::new(ManualClock::new(0));
        let t = FleetTelemetry::new(2, clock.clone());
        t.record_worker(0, 5.0);
        t.record_worker(1, 50.0);
        t.record_worker(9, 1.0); // out of range: ignored
        t.record_router(30.0);
        assert_eq!(t.worker_snapshot(0).map(|s| s.in_window), Some(1));
        assert_eq!(t.worker_snapshot(9).map(|s| s.in_window), None);
        assert_eq!(t.router_snapshot().in_window, 1);
        // Advance past the window: everything ages out.
        clock.advance(DEFAULT_WINDOW_MS + 1);
        assert_eq!(t.router_snapshot().in_window, 0);
        assert_eq!(t.worker_snapshot(1).map(|s| s.in_window), Some(0));
    }

    #[test]
    fn inflight_gauges_saturate_at_zero() {
        let t = FleetTelemetry::new(1, Arc::new(ManualClock::new(0)));
        t.inflight_enter(0);
        t.inflight_enter(0);
        assert_eq!(t.inflight(0), 2);
        t.inflight_exit(0);
        t.inflight_exit(0);
        t.inflight_exit(0); // extra exit must not wrap
        assert_eq!(t.inflight(0), 0);
        assert_eq!(t.inflight(42), 0, "out-of-range gauge reads as idle");
    }

    #[test]
    fn telemetry_json_shape() {
        let t = FleetTelemetry::new(2, Arc::new(ManualClock::new(0)));
        t.record_worker(0, 5.0);
        t.inflight_enter(1);
        let j = t.to_json();
        assert!(j.get("router").and_then(|r| r.get("p99_ms")).is_ok());
        let workers = j.get("workers").and_then(|w| w.as_arr()).unwrap();
        assert_eq!(workers.len(), 2);
        let w1 = &workers[1];
        assert_eq!(w1.get("inflight").and_then(|v| v.as_f64()).unwrap(), 1.0);
        assert_eq!(w1.get("worker").and_then(|v| v.as_f64()).unwrap(), 1.0);
    }
}
