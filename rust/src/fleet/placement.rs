//! Policy-aware placement: which worker should host (or already hosts)
//! a variant.
//!
//! Placement mirrors the single-process registry's `load_auto` logic at
//! fleet scale. The rules, in order of preference:
//!
//! 1. **Already resident wins** — serving a frontier variant that some
//!    worker already holds costs zero marginal bytes, so a fleet of
//!    clients auto-loading on connect converges on shared residents
//!    instead of duplicating models across workers.
//! 2. **Best frontier entry that fits** — otherwise walk the tuned
//!    policy's entries best-metric-first and place the first one whose
//!    estimated footprint fits some worker's headroom, on the worker
//!    with the *most* headroom (spreads load, leaves small holes free
//!    for small variants).
//! 3. **Spill down the frontier** — when the best entry fits nowhere,
//!    the next-best entry is tried, exactly like a single worker's
//!    budget-constrained `pick`.
//!
//! A resident pick only loses to a fresh pick with a strictly better
//! metric (the upgrade path when an operator grows the fleet).

use anyhow::{bail, Result};

use super::topology::WorkerView;
use crate::models::manifest::TierManifest;
use crate::tune::{PolicyEntry, TunedPolicy};
use crate::util::order::nan_last_cmp;

/// Up workers holding `key` resident — the scatter set for multi-row
/// scoring.
pub fn replicas(workers: &[WorkerView], key: &str) -> Vec<usize> {
    workers
        .iter()
        .filter(|w| w.up && w.resident.contains(key))
        .map(|w| w.id)
        .collect()
}

/// Place an **explicit** load of `key` with an estimated packed
/// footprint of `est_bytes`: resident replica first, then the roomiest
/// worker that fits, then the roomiest worker at all (its own LRU
/// eviction absorbs the overflow — a single variant larger than any
/// budget must still serve somewhere).
pub fn place_load(workers: &[WorkerView], key: &str, est_bytes: usize) -> Result<usize> {
    if let Some(w) = workers.iter().filter(|w| w.up).find(|w| w.resident.contains(key)) {
        return Ok(w.id);
    }
    if let Some(w) = workers
        .iter()
        .filter(|w| w.up && w.headroom() >= est_bytes)
        .max_by_key(|w| w.headroom())
    {
        return Ok(w.id);
    }
    workers
        .iter()
        .filter(|w| w.up)
        .max_by_key(|w| w.headroom())
        .map(|w| w.id)
        .ok_or_else(|| anyhow::anyhow!("no healthy workers in the fleet"))
}

/// Place a policy-driven (`{"op":"load","auto":true}`) request for
/// `model_key` (= `family_tier`) on `tier`: returns the chosen worker
/// and the frontier entry that motivated the choice. The addressed
/// worker's own policy still makes the final pick under its local
/// headroom; this function only decides *where* the request lands.
pub fn place_auto(
    workers: &[WorkerView],
    policy: &TunedPolicy,
    tier: &TierManifest,
    model_key: &str,
) -> Result<(usize, PolicyEntry)> {
    let n_stages = tier.stages.len();
    // Entries sort by bits-per-param ascending with strictly increasing
    // metric, so reverse order is best-metric-first.
    let applicable: Vec<&PolicyEntry> = policy
        .entries
        .iter()
        .rev()
        .filter(|e| match &e.stage_bits {
            None => true,
            Some(v) => v.len() == n_stages,
        })
        .collect();
    if applicable.is_empty() {
        bail!("policy has no entry applicable to tier {}", tier.name);
    }
    // Best already-resident frontier entry anywhere in the fleet.
    let mut resident_pick: Option<(usize, &PolicyEntry)> = None;
    'resident: for e in applicable.iter().copied() {
        let Ok(spec) = e.spec() else { continue };
        let key = format!("{model_key}@{}{}", spec.key(), e.plan_request().suffix());
        for w in workers.iter().filter(|w| w.up) {
            if w.resident.contains(&key) {
                resident_pick = Some((w.id, e));
                break 'resident;
            }
        }
    }
    // Best entry some worker could load fresh (spilling down the
    // frontier until one fits).
    let mut fresh_pick: Option<(usize, &PolicyEntry)> = None;
    for e in applicable.iter().copied() {
        let bytes = e.estimated_model_bytes(tier);
        if let Some(w) = workers
            .iter()
            .filter(|w| w.up && w.headroom() >= bytes)
            .max_by_key(|w| w.headroom())
        {
            fresh_pick = Some((w.id, e));
            break;
        }
    }
    let chosen = match (resident_pick, fresh_pick) {
        (Some((wr, er)), Some((wf, ef))) => {
            // A strictly better entry that fits fresh beats residency
            // (the operator-raised-the-budget upgrade path); ties keep
            // the zero-marginal-bytes resident.
            if nan_last_cmp(ef.metric, er.metric).is_gt() {
                (wf, ef)
            } else {
                (wr, er)
            }
        }
        (Some(r), None) => r,
        (None, Some(f)) => f,
        (None, None) => bail!(
            "no worker has headroom for any policy entry on tier {} \
             (smallest applicable entry wants ~{} bytes)",
            tier.name,
            applicable
                .iter()
                .map(|e| e.estimated_model_bytes(tier))
                .min()
                .unwrap_or(0)
        ),
    };
    Ok((chosen.0, chosen.1.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::{ParamInfo, StageManifest, StageParamRef};
    use crate::quant::DataType;
    use std::collections::HashSet;

    fn worker(
        id: usize,
        up: bool,
        resident: &[&str],
        used: usize,
        budget: Option<usize>,
    ) -> WorkerView {
        WorkerView {
            id,
            addr: format!("127.0.0.1:{}", 7000 + id),
            up,
            resident: resident.iter().map(|s| s.to_string()).collect::<HashSet<_>>(),
            resident_bytes: used,
            budget_bytes: budget,
            policy_hash: None,
            policy_entries: 0,
            policy_source: None,
            last_error: None,
        }
    }

    fn entry(bits: usize, stage_bits: Option<Vec<usize>>, metric: f64, bpp: f64) -> PolicyEntry {
        PolicyEntry {
            bits,
            dtype: DataType::Fp,
            block: Some(64),
            stage_bits,
            entropy: false,
            metric,
            total_bits: bpp * 1e5,
            bits_per_param: bpp,
        }
    }

    fn tier(n_stages: usize) -> TierManifest {
        let stages = (0..n_stages)
            .map(|i| StageManifest {
                name: format!("s{i}"),
                hlo: format!("fwd_{i}.hlo.txt"),
                outputs: if i + 1 == n_stages { 2 } else { 1 },
                params: vec![StageParamRef { source: "embed".into(), layers: None }],
            })
            .collect();
        TierManifest {
            name: "t0".into(),
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 128,
            vocab: 512,
            seq: 64,
            batch_train: 8,
            batch_eval: 16,
            param_count: 100_000,
            params: vec![ParamInfo { name: "embed".into(), shape: vec![512, 32] }],
            quantized_params: vec![],
            fwd_hlo: "fwd.hlo.txt".into(),
            train_hlo: "train.hlo.txt".into(),
            acts_hlo: None,
            stages,
        }
    }

    fn policy() -> TunedPolicy {
        TunedPolicy {
            suite: "ppl".into(),
            tuned_on: vec!["gpt2like_t0".into()],
            entries: vec![
                entry(3, None, 0.40, 3.25),
                entry(4, None, 0.55, 4.25),
                entry(16, None, 0.60, 16.0),
            ],
            classes: Default::default(),
        }
    }

    /// Estimated model bytes of the bpp-entry on the test tier.
    fn bytes(bpp: f64) -> usize {
        (bpp * 100_000.0 / 8.0).ceil() as usize
    }

    #[test]
    fn replicas_filters_down_and_nonresident() {
        let ws = [
            worker(0, true, &["m@fp:4:b64"], 0, None),
            worker(1, false, &["m@fp:4:b64"], 0, None),
            worker(2, true, &["m@int:3:b32"], 0, None),
        ];
        assert_eq!(replicas(&ws, "m@fp:4:b64"), vec![0], "down/non-resident workers excluded");
    }

    #[test]
    fn place_load_prefers_resident_then_fit_then_spill() {
        let key = "m@fp:4:b64";
        // Resident beats bigger headroom.
        let ws = [
            worker(0, true, &[key], 90, Some(100)),
            worker(1, true, &[], 0, Some(1_000_000)),
        ];
        assert_eq!(place_load(&ws, key, 50).unwrap(), 0);
        // No resident: roomiest worker that fits.
        let ws = [
            worker(0, true, &[], 80, Some(100)),
            worker(1, true, &[], 10, Some(100)),
            worker(2, false, &[], 0, Some(1_000_000)),
        ];
        assert_eq!(place_load(&ws, key, 50).unwrap(), 1, "down workers never place");
        // Nothing fits: spill to the roomiest anyway (worker-side LRU
        // eviction absorbs it).
        assert_eq!(place_load(&ws, key, 5_000).unwrap(), 1);
        // No healthy workers at all is an error.
        let ws = [worker(0, false, &[], 0, None)];
        assert!(place_load(&ws, key, 1).is_err());
    }

    #[test]
    fn place_auto_picks_best_entry_fitting_headroom() {
        let p = policy();
        let t = tier(0);
        // Both workers empty: best entry (16-bit) on the roomiest worker.
        let ws = [
            worker(0, true, &[], 0, Some(bytes(16.0) + 10)),
            worker(1, true, &[], 0, Some(bytes(4.25) + 10)),
        ];
        let (w, e) = place_auto(&ws, &p, &t, "gpt2like_t0").unwrap();
        assert_eq!((w, e.bits), (0, 16));
        // Only the small worker up: the frontier spills to 4-bit.
        let ws = [
            worker(0, false, &[], 0, Some(bytes(16.0) + 10)),
            worker(1, true, &[], 0, Some(bytes(4.25) + 10)),
        ];
        let (w, e) = place_auto(&ws, &p, &t, "gpt2like_t0").unwrap();
        assert_eq!((w, e.bits), (1, 4));
        // Nothing fits anywhere: an error naming the smallest entry.
        let ws = [worker(0, true, &[], 0, Some(10))];
        let err = place_auto(&ws, &p, &t, "gpt2like_t0").unwrap_err().to_string();
        assert!(err.contains("headroom"), "{err}");
    }

    #[test]
    fn place_auto_prefers_resident_unless_strictly_better_fits() {
        let p = policy();
        let t = tier(0);
        // The 4-bit entry is resident on worker 1; worker 0 could fit it
        // fresh but not the 16-bit entry → residency wins (equal metric).
        let key4 = "gpt2like_t0@fp:4:b64";
        let ws = [
            worker(0, true, &[], 0, Some(bytes(4.25) + 10)),
            worker(1, true, &[key4], bytes(4.25), Some(bytes(4.25) + 10)),
        ];
        let (w, e) = place_auto(&ws, &p, &t, "gpt2like_t0").unwrap();
        assert_eq!((w, e.bits), (1, 4), "resident replica must win at equal metric");
        // A roomy worker joins: the strictly better 16-bit entry fits
        // fresh and beats the resident 4-bit one.
        let ws = [
            worker(0, true, &[], 0, Some(bytes(16.0) + 10)),
            worker(1, true, &[key4], bytes(4.25), Some(bytes(4.25) + 10)),
        ];
        let (w, e) = place_auto(&ws, &p, &t, "gpt2like_t0").unwrap();
        assert_eq!((w, e.bits), (0, 16), "strictly better fresh entry must win");
    }

    #[test]
    fn place_auto_skips_stage_mismatched_entries() {
        let mut p = policy();
        p.entries.push(entry(4, Some(vec![16, 4]), 0.65, 17.0));
        // A monolithic-only tier must never be placed via a staged entry.
        let t = tier(0);
        let ws = [worker(0, true, &[], 0, None)];
        let (_, e) = place_auto(&ws, &p, &t, "gpt2like_t0").unwrap();
        assert!(e.stage_bits.is_none());
        assert_eq!(e.bits, 16);
        // On a 2-stage tier the staged entry (best metric) wins.
        let t = tier(2);
        let (_, e) = place_auto(&ws, &p, &t, "gpt2like_t0").unwrap();
        assert_eq!(e.stage_bits.as_deref(), Some(&[16usize, 4][..]));
    }
}
