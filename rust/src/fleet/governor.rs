//! Live precision governor: a bitrate manager for the fleet.
//!
//! `TunedPolicy` is measured at tune time and — without this module —
//! frozen at load time. The governor makes precision a *runtime*
//! decision: it watches the fleet's sliding-window p99 latency
//! ([`super::telemetry`]), per-worker headroom, and queue depth, and
//! migrates traffic along the measured Pareto frontier — **demoting**
//! a hot model to a lower-bit variant (3-bit, `#ec`) when p99 runs
//! over target, **promoting** back up the frontier when latency is
//! comfortably under target and some worker has headroom for the
//! larger variant.
//!
//! Safety properties, by construction:
//!
//! * **No flapping.** Every applied migration stamps the model's
//!   `last_change`; [`decide`] returns `None` for that model until
//!   `cooldown_ms` has elapsed, so a promote can never be followed by
//!   a demote of the same model inside one cooldown window. A
//!   hysteresis dead band (`promote_ratio`) separates the demote
//!   threshold (p99 > target) from the promote threshold
//!   (p99 < target × ratio), so a p99 sitting *near* target moves
//!   nothing.
//! * **Load-then-route.** A migration first replays an existing-keyed
//!   `{"op":"load"}` on the chosen worker (the same replay the
//!   router's failover path uses) and only switches the routing
//!   target after that load succeeds — traffic never scores through a
//!   cold load, and a failed pre-warm leaves the old target serving.
//! * **Bit identity.** The governor only changes *which* registry key
//!   bare-model traffic resolves to; each key still loads through the
//!   deterministic quantize path, so scores for a given key are
//!   bit-identical to a statically loaded instance of that key.
//!
//! Decisions are kept in a bounded log and exposed (with targets and
//! a telemetry snapshot) through `{"op":"governor"}` on the router.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::placement::place_load;
use super::router::{load_request_for_key, split_model_key};
use super::topology::{WorkerClient, WorkerView};
use super::Fleet;
use crate::models::manifest::TierManifest;
use crate::tune::PolicyEntry;
use crate::util::json::Json;

/// Most recent decisions retained for `{"op":"governor"}` status.
const LOG_CAP: usize = 64;

/// Governor tuning knobs (set at fleet build from CLI flags, mutable
/// at runtime via `{"op":"governor","config":...}`).
#[derive(Debug, Clone, PartialEq)]
pub struct GovernorConfig {
    /// Master switch; a disabled governor observes but never migrates.
    pub enabled: bool,
    /// Demote when windowed p99 exceeds this (ms).
    pub target_p99_ms: f64,
    /// Promote only when p99 < `target_p99_ms * promote_ratio` — the
    /// hysteresis dead band between the two thresholds.
    pub promote_ratio: f64,
    /// Minimum ms between migrations of the same model (anti-flap).
    pub cooldown_ms: u64,
    /// Minimum in-window samples before any decision (cold windows
    /// carry no signal).
    pub min_samples: usize,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            enabled: false,
            target_p99_ms: 250.0,
            promote_ratio: 0.5,
            cooldown_ms: 10_000,
            min_samples: 8,
        }
    }
}

/// What [`decide`] saw for one model at one tick.
#[derive(Debug, Clone, PartialEq)]
pub struct Observation {
    pub now_ms: u64,
    /// Windowed p99 of routed scoring traffic (ms).
    pub p99_ms: f64,
    /// Samples inside the window (decision basis size).
    pub in_window: usize,
    /// When this model last migrated, if ever.
    pub last_change_ms: Option<u64>,
    /// Index of the model's current target in the policy's frontier
    /// entries (ascending bits-per-param).
    pub current_idx: usize,
    /// Largest single-worker packed-byte headroom in the fleet.
    pub headroom: usize,
}

/// A migration verdict: the frontier-entry index to move to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Move up-frontier to `entries[idx]` (more bits, better metric).
    Promote(usize),
    /// Move down-frontier to `entries[idx]` (fewer bits, cheaper).
    Demote(usize),
}

/// One applied (or attempted) migration, for the status log.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// `"promote"`, `"demote"`, or `"prewarm-failed"`.
    pub action: String,
    /// Bare model key (`family_tier`) being governed.
    pub model: String,
    /// Full registry key traffic resolved to before.
    pub from: String,
    /// Full registry key traffic resolves to after.
    pub to: String,
    /// Worker the target variant was pre-warmed on.
    pub worker: usize,
    /// Human-readable trigger (thresholds and measured p99).
    pub reason: String,
    /// Governor-clock timestamp of the decision.
    pub at_ms: u64,
}

impl Decision {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("action", Json::str(&self.action)),
            ("model", Json::str(&self.model)),
            ("from", Json::str(&self.from)),
            ("to", Json::str(&self.to)),
            ("worker", Json::num(self.worker as f64)),
            ("reason", Json::str(&self.reason)),
            ("at_ms", Json::num(self.at_ms as f64)),
        ])
    }
}

/// The pure decision function: everything time- and policy-dependent
/// comes in through `cfg`/`obs`, so tests drive it with a simulated
/// clock and synthetic pressure. `entries` is the frontier in
/// ascending bits-per-param order (the [`crate::tune::TunedPolicy`]
/// invariant).
///
/// Anti-flap is structural: any `last_change_ms` within
/// `cooldown_ms` of `now_ms` returns `None` before either threshold
/// is even consulted, so two migrations of one model can never land
/// inside one cooldown window.
pub fn decide(
    cfg: &GovernorConfig,
    obs: &Observation,
    entries: &[PolicyEntry],
    tier: &TierManifest,
) -> Option<Verdict> {
    if !cfg.enabled || obs.in_window < cfg.min_samples {
        return None;
    }
    if let Some(last) = obs.last_change_ms {
        if obs.now_ms < last.saturating_add(cfg.cooldown_ms) {
            return None;
        }
    }
    let applicable = |e: &PolicyEntry| match &e.stage_bits {
        None => true,
        Some(v) => v.len() == tier.stages.len(),
    };
    if obs.p99_ms > cfg.target_p99_ms {
        // Under pressure: nearest applicable entry below the current
        // one (smallest step down the frontier that sheds bytes).
        return (0..obs.current_idx)
            .rev()
            .find(|&i| entries.get(i).is_some_and(&applicable))
            .map(Verdict::Demote);
    }
    if obs.p99_ms < cfg.target_p99_ms * cfg.promote_ratio {
        // Comfortable: next applicable entry up the frontier whose
        // footprint fits the roomiest worker (load-then-route needs
        // the bytes *before* traffic moves).
        return (obs.current_idx + 1..entries.len())
            .find(|&i| {
                entries
                    .get(i)
                    .is_some_and(|e| applicable(e) && e.estimated_model_bytes(tier) <= obs.headroom)
            })
            .map(Verdict::Promote);
    }
    None
}

/// Full registry key the frontier entry resolves to for `model`
/// (exactly the spelling `load_auto`/placement use).
pub(crate) fn entry_key(model: &str, e: &PolicyEntry) -> Option<String> {
    let spec = e.spec().ok()?;
    Some(format!("{model}@{}{}", spec.key(), e.plan_request().suffix()))
}

/// Mutable governor state behind one mutex (lock class
/// `fleet.governor`; never held across worker I/O).
struct GovState {
    config: GovernorConfig,
    /// Per-model timestamp of the last applied migration (cooldown).
    last_change: BTreeMap<String, u64>,
    /// Routing targets: bare model key (or `model|class`) → full
    /// registry key bare-keyed traffic resolves to.
    targets: BTreeMap<String, String>,
    /// Bounded recent-decision log, oldest first.
    log: VecDeque<Decision>,
}

/// The fleet's precision governor: shared by the background prober
/// (which calls [`Governor::tick`] every probe round) and every
/// router connection (which consults [`Governor::target_for`] on
/// bare-keyed scoring and serves `{"op":"governor"}`).
pub struct Governor {
    govstate: Mutex<GovState>,
}

impl Governor {
    pub fn new(config: GovernorConfig) -> Governor {
        Governor {
            govstate: Mutex::new(GovState {
                config,
                last_change: BTreeMap::new(),
                targets: BTreeMap::new(),
                log: VecDeque::new(),
            }),
        }
    }

    /// Current config (a copy; mutation goes through [`Governor::configure`]).
    pub fn config(&self) -> GovernorConfig {
        self.govstate.lock().unwrap().config.clone()
    }

    /// Apply a partial config update (`None` fields keep their value).
    /// Returns the resulting config.
    pub fn configure(
        &self,
        enabled: Option<bool>,
        target_p99_ms: Option<f64>,
        cooldown_ms: Option<u64>,
        promote_ratio: Option<f64>,
        min_samples: Option<usize>,
    ) -> GovernorConfig {
        let mut g = self.govstate.lock().unwrap();
        if let Some(v) = enabled {
            g.config.enabled = v;
        }
        if let Some(v) = target_p99_ms {
            g.config.target_p99_ms = v;
        }
        if let Some(v) = cooldown_ms {
            g.config.cooldown_ms = v;
        }
        if let Some(v) = promote_ratio {
            g.config.promote_ratio = v;
        }
        if let Some(v) = min_samples {
            g.config.min_samples = v;
        }
        g.config.clone()
    }

    /// The full registry key bare-model traffic should resolve to, if
    /// the governor has installed one. Class-tagged requests check
    /// the `model|class` target first, then the model-wide one.
    pub fn target_for(&self, model: &str, class: Option<&str>) -> Option<String> {
        let g = self.govstate.lock().unwrap();
        if let Some(c) = class {
            if let Some(t) = g.targets.get(&format!("{model}|{c}")) {
                return Some(t.clone());
            }
        }
        g.targets.get(model).cloned()
    }

    /// Status for `{"op":"governor"}`: config, current targets, and
    /// the recent-decision log (telemetry is appended by the router,
    /// which owns the [`super::telemetry::FleetTelemetry`] handle).
    pub fn status_json(&self) -> Json {
        let g = self.govstate.lock().unwrap();
        let targets: BTreeMap<String, Json> =
            g.targets.iter().map(|(k, v)| (k.clone(), Json::str(v))).collect();
        Json::obj(vec![
            ("enabled", Json::Bool(g.config.enabled)),
            ("target_p99_ms", Json::num(g.config.target_p99_ms)),
            ("promote_ratio", Json::num(g.config.promote_ratio)),
            ("cooldown_ms", Json::num(g.config.cooldown_ms as f64)),
            ("min_samples", Json::num(g.config.min_samples as f64)),
            ("targets", Json::Obj(targets)),
            ("decisions", Json::Arr(g.log.iter().map(Decision::to_json).collect())),
        ])
    }

    /// One governor round over every model with resident variants in
    /// the fleet: observe, decide, pre-warm, and only then retarget.
    /// Returns the migrations applied this round. Called by the
    /// background prober after each probe; tests call it directly for
    /// deterministic rounds.
    pub fn tick(&self, fleet: &Fleet) -> Vec<Decision> {
        let now = fleet.telemetry().now_ms();
        let (cfg, last_change, targets) = {
            let g = self.govstate.lock().unwrap();
            (g.config.clone(), g.last_change.clone(), g.targets.clone())
        };
        if !cfg.enabled {
            return Vec::new();
        }
        let Some(policy) = fleet.policy() else {
            return Vec::new();
        };
        let router_snap = fleet.telemetry().router_snapshot();
        let workers = fleet.topology().snapshot();
        // Every bare model key with at least one resident variant is
        // governed; explicit-keyed traffic is untouched either way.
        let mut models: Vec<String> = workers
            .iter()
            .flat_map(|w| w.resident.iter())
            .filter_map(|k| k.split_once('@').map(|(m, _)| m.to_string()))
            .collect();
        models.sort();
        models.dedup();
        let mut applied = Vec::new();
        for model in models {
            let Ok((_, tier_name)) = split_model_key(&fleet.manifest, &model) else {
                continue;
            };
            let Ok(tier) = fleet.manifest.tier(&tier_name) else {
                continue;
            };
            let keys: Vec<Option<String>> =
                policy.entries.iter().map(|e| entry_key(&model, e)).collect();
            // Current target: the installed one, else the best (highest
            // frontier index) variant resident anywhere in the fleet.
            let current_key = targets.get(&model).cloned().or_else(|| {
                keys.iter()
                    .rev()
                    .flatten()
                    .find(|k| workers.iter().any(|w| w.up && w.resident.contains(k.as_str())))
                    .cloned()
            });
            let Some(current_key) = current_key else {
                continue;
            };
            let Some(current_idx) =
                keys.iter().position(|k| k.as_deref() == Some(current_key.as_str()))
            else {
                continue;
            };
            let headroom =
                workers.iter().filter(|w| w.up).map(|w| w.headroom()).max().unwrap_or(0);
            let obs = Observation {
                now_ms: now,
                p99_ms: router_snap.p99_ms,
                in_window: router_snap.in_window,
                last_change_ms: last_change.get(&model).copied(),
                current_idx,
                headroom,
            };
            let Some(verdict) = decide(&cfg, &obs, &policy.entries, tier) else {
                continue;
            };
            let (to_idx, action, reason) = match verdict {
                Verdict::Demote(i) => (
                    i,
                    "demote",
                    format!(
                        "p99 {:.1}ms > target {:.1}ms over {} samples",
                        router_snap.p99_ms, cfg.target_p99_ms, router_snap.in_window
                    ),
                ),
                Verdict::Promote(i) => (
                    i,
                    "promote",
                    format!(
                        "p99 {:.1}ms < {:.1}ms and headroom {} fits",
                        router_snap.p99_ms,
                        cfg.target_p99_ms * cfg.promote_ratio,
                        headroom
                    ),
                ),
            };
            let Some(Some(to_key)) = keys.get(to_idx).cloned() else {
                continue;
            };
            let est =
                policy.entries.get(to_idx).map(|e| e.estimated_model_bytes(tier)).unwrap_or(0);
            let Ok(worker_id) = place_load(&workers, &to_key, est) else {
                continue;
            };
            match prewarm(fleet, &workers, worker_id, &to_key) {
                Ok(()) => {
                    let d = Decision {
                        action: action.to_string(),
                        model: model.clone(),
                        from: current_key,
                        to: to_key.clone(),
                        worker: worker_id,
                        reason,
                        at_ms: now,
                    };
                    {
                        let mut g = self.govstate.lock().unwrap();
                        g.targets.insert(model.clone(), to_key.clone());
                        g.last_change.insert(model.clone(), now);
                        g.log.push_back(d.clone());
                        while g.log.len() > LOG_CAP {
                            g.log.pop_front();
                        }
                    }
                    applied.push(d);
                }
                Err(err) => {
                    // Load-then-route: a failed pre-warm changes
                    // nothing — old target keeps serving, no cooldown
                    // stamp, only a log entry for the operator.
                    let d = Decision {
                        action: "prewarm-failed".to_string(),
                        model: model.clone(),
                        from: current_key,
                        to: to_key,
                        worker: worker_id,
                        reason: err.to_string(),
                        at_ms: now,
                    };
                    let mut g = self.govstate.lock().unwrap();
                    g.log.push_back(d);
                    while g.log.len() > LOG_CAP {
                        g.log.pop_front();
                    }
                }
            }
        }
        applied
    }
}

/// Replay an existing-keyed load of `key` on `worker_id` and record
/// the new residency — the same key-replay seam the router's failover
/// uses, so the variant that comes up is bit-identical to any other
/// load of that key.
fn prewarm(fleet: &Fleet, workers: &[WorkerView], worker_id: usize, key: &str) -> Result<()> {
    let view = workers
        .iter()
        .find(|w| w.id == worker_id)
        .ok_or_else(|| anyhow!("worker {worker_id} not in roster"))?;
    if view.resident.contains(key) {
        return Ok(()); // already warm: nothing to load
    }
    let req = load_request_for_key(&fleet.manifest, key)?;
    let mut client = WorkerClient::connect(&view.addr, fleet.opts.io_timeout)?;
    let resp = client.request(&req)?;
    if let Some(err) = resp.opt("error") {
        bail!("worker {} rejected pre-warm of {key}: {}", view.addr, err.dump());
    }
    fleet.topology().note_loaded(worker_id, key);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::{ParamInfo, StageManifest, StageParamRef, TierManifest};
    use crate::quant::DataType;

    fn entry(bits: usize, stage_bits: Option<Vec<usize>>, metric: f64, bpp: f64) -> PolicyEntry {
        PolicyEntry {
            bits,
            dtype: DataType::Fp,
            block: Some(64),
            stage_bits,
            entropy: false,
            metric,
            total_bits: bpp * 1e5,
            bits_per_param: bpp,
        }
    }

    fn tier(n_stages: usize) -> TierManifest {
        let stages = (0..n_stages)
            .map(|i| StageManifest {
                name: format!("s{i}"),
                hlo: format!("fwd_{i}.hlo.txt"),
                outputs: if i + 1 == n_stages { 2 } else { 1 },
                params: vec![StageParamRef { source: "embed".into(), layers: None }],
            })
            .collect();
        TierManifest {
            name: "t0".into(),
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 128,
            vocab: 512,
            seq: 64,
            batch_train: 8,
            batch_eval: 16,
            param_count: 100_000,
            params: vec![ParamInfo { name: "embed".into(), shape: vec![512, 32] }],
            quantized_params: vec![],
            fwd_hlo: "fwd.hlo.txt".into(),
            train_hlo: "train.hlo.txt".into(),
            acts_hlo: None,
            stages,
        }
    }

    fn frontier() -> Vec<PolicyEntry> {
        vec![
            entry(3, None, 0.40, 3.25),
            entry(4, None, 0.55, 4.25),
            entry(16, None, 0.60, 16.0),
        ]
    }

    fn cfg() -> GovernorConfig {
        GovernorConfig {
            enabled: true,
            target_p99_ms: 100.0,
            promote_ratio: 0.5,
            cooldown_ms: 5_000,
            min_samples: 4,
        }
    }

    fn obs(now_ms: u64, p99_ms: f64, current_idx: usize, headroom: usize) -> Observation {
        Observation {
            now_ms,
            p99_ms,
            in_window: 16,
            last_change_ms: None,
            current_idx,
            headroom,
        }
    }

    /// Estimated bytes of the 16-bit entry on the test tier.
    fn bytes16() -> usize {
        entry(16, None, 0.60, 16.0).estimated_model_bytes(&tier(0))
    }

    #[test]
    fn promotes_under_headroom_when_p99_comfortable() {
        let v = decide(&cfg(), &obs(0, 10.0, 1, bytes16()), &frontier(), &tier(0));
        assert_eq!(v, Some(Verdict::Promote(2)), "fast p99 + room → next entry up");
        // Without headroom for the 16-bit entry, no promotion happens.
        let v = decide(&cfg(), &obs(0, 10.0, 1, bytes16() - 1), &frontier(), &tier(0));
        assert_eq!(v, None, "promotion must fit the roomiest worker");
        // Already at the top of the frontier: nowhere to go.
        let v = decide(&cfg(), &obs(0, 10.0, 2, usize::MAX / 2), &frontier(), &tier(0));
        assert_eq!(v, None);
    }

    #[test]
    fn demotes_under_p99_pressure() {
        let v = decide(&cfg(), &obs(0, 500.0, 2, 0), &frontier(), &tier(0));
        assert_eq!(v, Some(Verdict::Demote(1)), "pressure → nearest entry down");
        // Already at the bottom: nothing below to demote to.
        let v = decide(&cfg(), &obs(0, 500.0, 0, 0), &frontier(), &tier(0));
        assert_eq!(v, None);
    }

    #[test]
    fn hysteresis_dead_band_moves_nothing() {
        // p99 between target*ratio (50) and target (100): hold.
        for p99 in [50.0, 75.0, 100.0] {
            let v = decide(&cfg(), &obs(0, p99, 1, usize::MAX / 2), &frontier(), &tier(0));
            assert_eq!(v, None, "p99 {p99} is inside the dead band");
        }
    }

    #[test]
    fn cooldown_blocks_flapping_by_construction() {
        let c = cfg();
        // A migration at t=1000 silences both directions until t=6000.
        for (p99, current) in [(500.0, 2), (10.0, 0)] {
            let mut o = obs(1_500, p99, current, usize::MAX / 2);
            o.last_change_ms = Some(1_000);
            assert_eq!(decide(&c, &o, &frontier(), &tier(0)), None, "inside cooldown");
            o.now_ms = 1_000 + c.cooldown_ms;
            assert!(decide(&c, &o, &frontier(), &tier(0)).is_some(), "cooldown elapsed");
        }
    }

    #[test]
    fn gates_on_enabled_and_sample_count() {
        let mut c = cfg();
        c.enabled = false;
        assert_eq!(decide(&c, &obs(0, 500.0, 2, 0), &frontier(), &tier(0)), None);
        let c = cfg();
        let mut o = obs(0, 500.0, 2, 0);
        o.in_window = c.min_samples - 1;
        assert_eq!(decide(&c, &o, &frontier(), &tier(0)), None, "cold window carries no signal");
    }

    #[test]
    fn stage_mismatched_entries_are_skipped() {
        let mut entries = frontier();
        entries.insert(2, entry(4, Some(vec![16, 4]), 0.58, 9.0));
        // Monolithic tier under pressure at the 16-bit entry (idx 3):
        // the staged idx-2 entry must be skipped, landing on idx 1.
        let v = decide(&cfg(), &obs(0, 500.0, 3, 0), &entries, &tier(0));
        assert_eq!(v, Some(Verdict::Demote(1)));
        // On a 2-stage tier the staged entry is a valid demote step.
        let v = decide(&cfg(), &obs(0, 500.0, 3, 0), &entries, &tier(2));
        assert_eq!(v, Some(Verdict::Demote(2)));
    }

    #[test]
    fn class_targets_shadow_model_targets() {
        let g = Governor::new(cfg());
        {
            let mut s = g.govstate.lock().unwrap();
            s.targets.insert("m_t0".into(), "m_t0@fp:4:b64".into());
            s.targets.insert("m_t0|chat".into(), "m_t0@fp:3:b64".into());
        }
        assert_eq!(g.target_for("m_t0", None).as_deref(), Some("m_t0@fp:4:b64"));
        assert_eq!(g.target_for("m_t0", Some("chat")).as_deref(), Some("m_t0@fp:3:b64"));
        assert_eq!(
            g.target_for("m_t0", Some("batch")).as_deref(),
            Some("m_t0@fp:4:b64"),
            "unknown class falls back to the model-wide target"
        );
        assert_eq!(g.target_for("other", None), None);
    }

    #[test]
    fn configure_is_partial_and_status_reflects_it() {
        let g = Governor::new(GovernorConfig::default());
        let c = g.configure(Some(true), Some(42.0), Some(1_234), None, None);
        assert!(c.enabled);
        assert_eq!(c.target_p99_ms, 42.0);
        assert_eq!(c.cooldown_ms, 1_234);
        assert_eq!(c.promote_ratio, GovernorConfig::default().promote_ratio, "untouched");
        let j = g.status_json();
        assert!(j.get("enabled").and_then(|v| v.as_bool()).unwrap());
        assert_eq!(j.get("target_p99_ms").and_then(|v| v.as_f64()).unwrap(), 42.0);
        assert!(j.get("decisions").and_then(|v| v.as_arr()).unwrap().is_empty());
    }
}
